//! Collective communication substrate.
//!
//! The paper's communication claims (Table 1, Figure 9) are about bytes
//! moved per synchronization: MKOR all-reduces two rank-1 vectors (O(d),
//! halved again by fp16) where KFAC moves O(4d²) and SNGD O(2bd + b²).
//! This module provides:
//!
//! * [`ring`] — a real ring all-reduce over in-process worker buffers
//!   (reduce-scatter + all-gather, chunked exactly like NCCL's ring), in
//!   fp32 and bf16-quantized forms, with byte/step accounting;
//! * [`cost`] — an α–β cluster cost model (NVLink intra-node, InfiniBand
//!   inter-node, matching the paper's Polaris/Mist testbeds) that prices a
//!   collective at any worker count — this is what stands in for the
//!   64-GPU measurements (DESIGN.md §3).
//!
//! The ring operates on plain per-worker buffers:
//!
//! ```
//! use mkor::collective::allreduce_mean;
//!
//! // Two workers, two elements: every buffer ends as the element-wise mean.
//! let mut bufs = vec![vec![1.0_f32, 2.0], vec![3.0, 4.0]];
//! let stats = allreduce_mean(&mut bufs);
//! assert_eq!(bufs[0], vec![2.0, 3.0]);
//! assert_eq!(bufs[0], bufs[1]);
//! assert!(stats.bytes_per_worker > 0);
//! ```

pub mod cost;
pub mod ring;

pub use cost::{ClusterModel, LinkParams};
pub use ring::{allreduce_mean, allreduce_mean_bf16, AllreduceStats};
