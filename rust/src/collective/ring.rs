//! Ring all-reduce over in-process worker buffers.
//!
//! Implements the standard two-phase ring algorithm: W−1 reduce-scatter
//! steps followed by W−1 all-gather steps over W equal chunks, so each
//! worker sends/receives `2·(W−1)/W · n` elements — the bandwidth-optimal
//! schedule whose cost the α–β model in [`super::cost`] prices. Buffers
//! live in one process (our "workers" are threads), but the data movement
//! and the arithmetic are the real thing, including optional bf16
//! quantization of the wire format (MKOR's half-precision sync).

use crate::linalg::half::{accumulate_bf16_wire, quantize_bf16_into, write_bf16_wire};
use crate::obs::{self, EventKind, TraceEvent};

/// Accounting from one collective call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllreduceStats {
    /// Bytes a single worker sent (= received) during the collective.
    pub bytes_per_worker: usize,
    /// Number of communication steps (latency terms).
    pub steps: usize,
}

/// Trace one completed collective (callers already checked
/// [`obs::enabled`], so the disabled path never reaches here).
fn trace_allreduce(wire: &str, workers: usize, stats: &AllreduceStats, secs: f64) {
    obs::emit(
        TraceEvent::new(EventKind::Allreduce)
            .label("wire", wire)
            .num("workers", workers as f64)
            .num("bytes_per_worker", stats.bytes_per_worker as f64)
            .num("comm_steps", stats.steps as f64)
            .num("secs", secs)
            .maybe_under(obs::span::current()),
    );
    obs::registry::with_global(|r| {
        r.inc("collective.allreduces", 1);
        r.inc("collective.bytes_per_worker", stats.bytes_per_worker as u64);
        r.observe("collective.allreduce_secs", secs);
    });
}

/// Chunk boundaries for `n` elements over `w` ranks.
fn chunk_bounds(n: usize, w: usize) -> Vec<(usize, usize)> {
    let base = n / w;
    let rem = n % w;
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for r in 0..w {
        let len = base + usize::from(r < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// In-place ring all-reduce (mean) over `bufs` (one buffer per worker, all
/// the same length). After the call every buffer holds the element-wise
/// mean. Returns per-worker byte accounting (fp32 wire format).
pub fn allreduce_mean(bufs: &mut [Vec<f32>]) -> AllreduceStats {
    let w = bufs.len();
    assert!(w > 0);
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "ragged all-reduce buffers");
    if w == 1 {
        return AllreduceStats { bytes_per_worker: 0, steps: 0 };
    }
    let t0 = obs::enabled().then(std::time::Instant::now);
    let chunks = chunk_bounds(n, w);
    let max_chunk = chunks.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0);
    // One payload scratch reused for every send (the "wire"): the collective
    // stays allocation-free per step no matter how many ranks circulate.
    let mut payload = vec![0.0f32; max_chunk];
    let mut bytes = 0usize;

    // Reduce-scatter: at step s, rank r sends chunk (r−s) to rank r+1,
    // which accumulates it. After W−1 steps, rank r owns the full sum of
    // chunk (r+1) mod w.
    for s in 0..w - 1 {
        for r in 0..w {
            let send_chunk = (r + w - s) % w;
            let dst = (r + 1) % w;
            let (lo, hi) = chunks[send_chunk];
            let wire = &mut payload[..hi - lo];
            wire.copy_from_slice(&bufs[r][lo..hi]);
            for (d, &p) in bufs[dst][lo..hi].iter_mut().zip(wire.iter()) {
                *d += p;
            }
            bytes += (hi - lo) * 4;
        }
    }
    // All-gather: rank r owns reduced chunk (r+1); circulate W−1 times.
    for s in 0..w - 1 {
        for r in 0..w {
            let send_chunk = (r + 1 + w - s) % w;
            let dst = (r + 1) % w;
            let (lo, hi) = chunks[send_chunk];
            let wire = &mut payload[..hi - lo];
            wire.copy_from_slice(&bufs[r][lo..hi]);
            bufs[dst][lo..hi].copy_from_slice(wire);
            bytes += (hi - lo) * 4;
        }
    }
    // Mean.
    let inv_w = 1.0 / w as f32;
    for b in bufs.iter_mut() {
        for v in b.iter_mut() {
            *v *= inv_w;
        }
    }
    let stats = AllreduceStats { bytes_per_worker: bytes / w, steps: 2 * (w - 1) };
    if let Some(t0) = t0 {
        trace_allreduce("fp32", w, &stats, t0.elapsed().as_secs_f64());
    }
    stats
}

/// Ring all-reduce (mean) with bf16 wire format: every payload is
/// quantized before the "send" and dequantized at the receiver, halving
/// bytes at the cost of bounded rounding error (Lemma 3.2 regime). The
/// local accumulations still happen in fp32.
///
/// The wire is one reused `u16` scratch buffer and the receive side goes
/// through the fused `half.rs` paths ([`accumulate_bf16_wire`] /
/// [`write_bf16_wire`]) — no intermediate f32 round-trip buffer is ever
/// materialized. Numerics are identical to the unfused formulation
/// (decode-then-accumulate element-wise, in the same order).
pub fn allreduce_mean_bf16(bufs: &mut [Vec<f32>]) -> AllreduceStats {
    let w = bufs.len();
    assert!(w > 0);
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "ragged all-reduce buffers");
    if w == 1 {
        return AllreduceStats { bytes_per_worker: 0, steps: 0 };
    }
    let t0 = obs::enabled().then(std::time::Instant::now);
    let chunks = chunk_bounds(n, w);
    let max_chunk = chunks.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0);
    let mut wire_scratch = vec![0u16; max_chunk];
    let mut bytes = 0usize;

    for s in 0..w - 1 {
        for r in 0..w {
            let send_chunk = (r + w - s) % w;
            let dst = (r + 1) % w;
            let (lo, hi) = chunks[send_chunk];
            let wire = &mut wire_scratch[..hi - lo];
            quantize_bf16_into(&bufs[r][lo..hi], wire);
            accumulate_bf16_wire(wire, &mut bufs[dst][lo..hi]);
            bytes += (hi - lo) * 2;
        }
    }
    for s in 0..w - 1 {
        for r in 0..w {
            let send_chunk = (r + 1 + w - s) % w;
            let dst = (r + 1) % w;
            let (lo, hi) = chunks[send_chunk];
            let wire = &mut wire_scratch[..hi - lo];
            quantize_bf16_into(&bufs[r][lo..hi], wire);
            write_bf16_wire(wire, &mut bufs[dst][lo..hi]);
            bytes += (hi - lo) * 2;
        }
    }
    let inv_w = 1.0 / w as f32;
    for b in bufs.iter_mut() {
        for v in b.iter_mut() {
            *v *= inv_w;
        }
    }
    let stats = AllreduceStats { bytes_per_worker: bytes / w, steps: 2 * (w - 1) };
    if let Some(t0) = t0 {
        trace_allreduce("bf16", w, &stats, t0.elapsed().as_secs_f64());
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn worker_bufs(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| (0..n).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn fp32_allreduce_computes_exact_mean() {
        for &(w, n) in &[(2usize, 10usize), (4, 17), (8, 64), (3, 1), (5, 4)] {
            let mut bufs = worker_bufs(w, n, 42 + w as u64);
            // Reference mean.
            let mut want = vec![0.0f64; n];
            for b in &bufs {
                for (wv, &x) in want.iter_mut().zip(b) {
                    *wv += x as f64;
                }
            }
            for wv in want.iter_mut() {
                *wv /= w as f64;
            }
            let stats = allreduce_mean(&mut bufs);
            for b in &bufs {
                for (i, (&got, &wv)) in b.iter().zip(&want).enumerate() {
                    assert!(
                        (got as f64 - wv).abs() < 1e-5,
                        "w={w} n={n} i={i}: {got} vs {wv}"
                    );
                }
            }
            assert_eq!(stats.steps, 2 * (w - 1));
        }
    }

    #[test]
    fn byte_accounting_matches_ring_formula() {
        let w = 4;
        let n = 1000;
        let mut bufs = worker_bufs(w, n, 7);
        let stats = allreduce_mean(&mut bufs);
        // 2(W−1)/W · n elements × 4 bytes per worker.
        let want = 2 * (w - 1) * n / w * 4;
        assert_eq!(stats.bytes_per_worker, want);
    }

    #[test]
    fn bf16_halves_bytes_and_bounds_error() {
        let w = 4;
        let n = 512;
        let mut a = worker_bufs(w, n, 9);
        let mut b = a.clone();
        let s32 = allreduce_mean(&mut a);
        let s16 = allreduce_mean_bf16(&mut b);
        assert_eq!(s16.bytes_per_worker * 2, s32.bytes_per_worker);
        // bf16 has ~2⁻⁸ relative step; the ring accumulates a few of them.
        for (x, y) in a[0].iter().zip(&b[0]) {
            let denom = x.abs().max(0.1);
            assert!(
                ((x - y) / denom).abs() < 0.05,
                "fp32 {x} vs bf16 {y}"
            );
        }
    }

    #[test]
    fn single_worker_is_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0, 3.0]];
        let stats = allreduce_mean(&mut bufs);
        assert_eq!(stats.bytes_per_worker, 0);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn uneven_chunks_are_covered() {
        // n not divisible by w exercises the remainder path.
        let mut bufs = worker_bufs(3, 7, 11);
        let mut want = vec![0.0f32; 7];
        for b in &bufs {
            for (wv, &x) in want.iter_mut().zip(b) {
                *wv += x / 3.0;
            }
        }
        allreduce_mean(&mut bufs);
        for b in &bufs {
            for (got, wv) in b.iter().zip(&want) {
                assert!((got - wv).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_buffers_rejected() {
        let mut bufs = vec![vec![0.0f32; 3], vec![0.0f32; 4]];
        allreduce_mean(&mut bufs);
    }
}
