//! α–β cluster communication cost model.
//!
//! Stands in for the paper's 64-GPU Polaris (4×A100/node, NVLink
//! intra-node, Slingshot/IB inter-node) and Mist (4×V100/node) testbeds.
//! A collective over W workers arranged `gpus_per_node` to a node is priced
//! with the classic latency–bandwidth model: each of the 2(W−1) ring steps
//! costs `α + chunk_bytes·β` on the slowest link it crosses; with W > one
//! node, W−ish of the steps cross the inter-node fabric, so the effective
//! β is the inter-node one (ring bandwidth is bottlenecked by its slowest
//! link — the standard NCCL result).

/// One link class.
#[derive(Clone, Copy, Debug)]
pub struct LinkParams {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Seconds per byte (1/bandwidth).
    pub beta: f64,
}

impl LinkParams {
    pub fn from_bandwidth_gbps(alpha_us: f64, gb_per_s: f64) -> Self {
        LinkParams { alpha: alpha_us * 1e-6, beta: 1.0 / (gb_per_s * 1e9) }
    }
}

/// A homogeneous cluster of `gpus_per_node`-wide nodes.
#[derive(Clone, Copy, Debug)]
pub struct ClusterModel {
    pub intra: LinkParams,
    pub inter: LinkParams,
    pub gpus_per_node: usize,
}

impl ClusterModel {
    /// Polaris-like: A100 nodes, NVLink ~ 250 GB/s effective pairwise,
    /// inter-node fabric ~ 20 GB/s effective per GPU.
    pub fn polaris_a100() -> Self {
        ClusterModel {
            intra: LinkParams::from_bandwidth_gbps(3.0, 250.0),
            inter: LinkParams::from_bandwidth_gbps(8.0, 20.0),
            gpus_per_node: 4,
        }
    }

    /// Mist-like: V100 nodes, NVLink ~ 130 GB/s, EDR IB ~ 10 GB/s.
    pub fn mist_v100() -> Self {
        ClusterModel {
            intra: LinkParams::from_bandwidth_gbps(4.0, 130.0),
            inter: LinkParams::from_bandwidth_gbps(10.0, 10.0),
            gpus_per_node: 4,
        }
    }

    /// The slowest link a W-worker ring crosses.
    fn bottleneck(&self, workers: usize) -> LinkParams {
        if workers <= self.gpus_per_node {
            self.intra
        } else {
            self.inter
        }
    }

    /// Time for a ring all-reduce of `bytes` payload over `workers`.
    pub fn allreduce_time(&self, bytes: usize, workers: usize) -> f64 {
        if workers <= 1 || bytes == 0 {
            return 0.0;
        }
        let w = workers as f64;
        let link = self.bottleneck(workers);
        let steps = 2.0 * (w - 1.0);
        let chunk = bytes as f64 / w;
        steps * (link.alpha + chunk * link.beta)
    }

    /// Time for a broadcast of `bytes` from one root (tree, ⌈log2 W⌉
    /// stages of the full payload).
    pub fn broadcast_time(&self, bytes: usize, workers: usize) -> f64 {
        if workers <= 1 || bytes == 0 {
            return 0.0;
        }
        let stages = (workers as f64).log2().ceil();
        let link = self.bottleneck(workers);
        stages * (link.alpha + bytes as f64 * link.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cases() {
        let c = ClusterModel::polaris_a100();
        assert_eq!(c.allreduce_time(0, 8), 0.0);
        assert_eq!(c.allreduce_time(1024, 1), 0.0);
        assert_eq!(c.broadcast_time(1024, 1), 0.0);
    }

    #[test]
    fn allreduce_time_scales_with_bytes() {
        let c = ClusterModel::polaris_a100();
        let t1 = c.allreduce_time(1 << 20, 8);
        let t2 = c.allreduce_time(1 << 26, 8);
        assert!(t2 > 10.0 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn crossing_a_node_boundary_costs_more() {
        let c = ClusterModel::polaris_a100();
        // 4 workers fit one node; 8 span two.
        let t4 = c.allreduce_time(1 << 24, 4);
        let t8 = c.allreduce_time(1 << 24, 8);
        assert!(t8 > 2.0 * t4, "t4={t4} t8={t8}");
    }

    #[test]
    fn bandwidth_term_saturates_with_workers() {
        // For large payloads the ring time approaches 2·bytes·β regardless
        // of W — strong scaling of the bandwidth term.
        let c = ClusterModel::polaris_a100();
        let t16 = c.allreduce_time(1 << 28, 16);
        let t64 = c.allreduce_time(1 << 28, 64);
        assert!((t64 / t16 - 1.0).abs() < 0.1, "t16={t16} t64={t64}");
    }

    #[test]
    fn latency_term_dominates_small_payloads() {
        // MKOR's O(d) sync is latency-bound at scale: time grows ~linearly
        // with W for tiny payloads.
        let c = ClusterModel::polaris_a100();
        let t8 = c.allreduce_time(4096, 8);
        let t64 = c.allreduce_time(4096, 64);
        assert!(t64 > 4.0 * t8, "t8={t8} t64={t64}");
    }
}
