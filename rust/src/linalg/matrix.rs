//! Row-major dense `f32` matrix.

use crate::util::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f32`.
///
/// `f32` matches the training dtype in the paper's GPU implementation; the
/// few numerically delicate routines (Cholesky, Jacobi) accumulate in `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f32]) -> Self {
        let mut m = Matrix::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// From an existing buffer (len must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// From a row-major nested slice (tests/fixtures).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Gaussian random matrix N(0, sigma^2).
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data, sigma);
        m
    }

    /// A random symmetric positive-definite matrix `A Aᵀ/cols + eps·I`
    /// (test fixture for factor math).
    pub fn rand_spd(n: usize, eps: f32, rng: &mut Rng) -> Self {
        let a = Matrix::randn(n, n, 1.0, rng);
        let mut m = crate::linalg::ops::matmul_nt(&a, &a);
        let inv_n = 1.0 / n as f32;
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] *= inv_n;
            }
            m[(i, i)] += eps;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` as an owned vector.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Frobenius norm (f64 accumulation).
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Infinity norm: max absolute row sum. This is the norm the paper's
    /// norm-based stabilizer monitors (§3.3).
    pub fn inf_norm(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&x| x.abs() as f64).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Trace (square).
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)] as f64).sum()
    }

    /// True if all entries are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Symmetry check within tolerance.
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if !self.is_square() {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Elementwise maximum absolute difference (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// In-place `self = alpha*self + beta*other`.
    pub fn blend(&mut self, alpha: f32, beta: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = alpha * *a + beta * b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// In-place `self = zeta*self + (1-zeta)*I` — the paper's stabilizer
    /// blend toward identity (Equations 7/8).
    pub fn blend_identity(&mut self, zeta: f32) {
        assert!(self.is_square());
        self.scale(zeta);
        let one_minus = 1.0 - zeta;
        for i in 0..self.rows {
            self[(i, i)] += one_minus;
        }
    }

    /// Number of parameters (rows*cols).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Borrow the matrix as a strided [`MatrixView`] (row stride = `cols`,
    /// col stride = 1).
    #[inline]
    pub fn view(&self) -> MatrixView<'_> {
        MatrixView {
            data: &self.data,
            rows: self.rows,
            cols: self.cols,
            row_stride: self.cols,
            col_stride: 1,
        }
    }

    /// Borrow the matrix as its transpose, without copying: the view swaps
    /// the strides, so `self.t_view().get(i, j) == self[(j, i)]`.
    #[inline]
    pub fn t_view(&self) -> MatrixView<'_> {
        self.view().t()
    }
}

/// Read-only strided view into a matrix's storage: a logical `rows × cols`
/// matrix whose element `(i, j)` lives at `data[i·row_stride + j·col_stride]`.
///
/// A transpose is a stride swap instead of a copy, which is what lets the
/// tiled engine ([`crate::linalg::engine`]) serve the NN/NT/TN GEMM call
/// forms with one packed-panel code path: the packing routines read through
/// a view and never materialize `Aᵀ` or `Bᵀ`.
#[derive(Clone, Copy, Debug)]
pub struct MatrixView<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
    row_stride: usize,
    col_stride: usize,
}

impl<'a> MatrixView<'a> {
    /// Build a view over a raw buffer. Panics if the largest reachable
    /// index falls outside `data`.
    pub fn new(
        data: &'a [f32],
        rows: usize,
        cols: usize,
        row_stride: usize,
        col_stride: usize,
    ) -> Self {
        if rows > 0 && cols > 0 {
            let last = (rows - 1) * row_stride + (cols - 1) * col_stride;
            assert!(
                last < data.len(),
                "view exceeds buffer: last index {last} >= len {}",
                data.len()
            );
        }
        MatrixView { data, rows, cols, row_stride, col_stride }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    #[inline]
    pub fn col_stride(&self) -> usize {
        self.col_stride
    }

    /// Element `(i, j)` through the strides.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.row_stride + j * self.col_stride]
    }

    /// The transposed view (stride swap, no copy).
    #[inline]
    pub fn t(&self) -> MatrixView<'a> {
        MatrixView {
            data: self.data,
            rows: self.cols,
            cols: self.rows,
            row_stride: self.col_stride,
            col_stride: self.row_stride,
        }
    }

    /// True when logical row `i` is contiguous in memory (col stride 1) —
    /// the packing fast path.
    #[inline]
    pub fn row_contiguous(&self) -> bool {
        self.col_stride == 1
    }

    /// Logical row `i` as a slice — only valid for row-contiguous views.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        debug_assert!(self.row_contiguous() && i < self.rows);
        &self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let row: Vec<String> = (0..cols).map(|j| format!("{:+.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", row.join(", "), if self.cols > 8 { ", …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_and_index() {
        let m = Matrix::identity(3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
        assert_eq!(m.trace(), 3.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let m = Matrix::randn(4, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(&[&[3.0, 4.0], &[0.0, 0.0]]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
        assert!((m.inf_norm() - 7.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn blend_identity_matches_formula() {
        let mut m = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        m.blend_identity(0.5);
        assert_eq!(m[(0, 0)], 1.5);
        assert_eq!(m[(0, 1)], 0.5);
    }

    #[test]
    fn rand_spd_is_symmetric() {
        let mut rng = Rng::new(5);
        let m = Matrix::rand_spd(16, 0.1, &mut rng);
        assert!(m.is_symmetric(1e-5));
        assert!(m.all_finite());
    }

    #[test]
    #[should_panic]
    fn from_vec_size_checked() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn views_read_through_strides() {
        let mut rng = Rng::new(7);
        let m = Matrix::randn(5, 3, 1.0, &mut rng);
        let v = m.view();
        let t = m.t_view();
        assert_eq!((v.rows(), v.cols()), (5, 3));
        assert_eq!((t.rows(), t.cols()), (3, 5));
        for i in 0..5 {
            for j in 0..3 {
                assert_eq!(v.get(i, j), m[(i, j)]);
                assert_eq!(t.get(j, i), m[(i, j)]);
            }
        }
        // Double transpose is the identity view.
        let tt = t.t();
        assert_eq!(tt.get(4, 2), m[(4, 2)]);
        assert!(v.row_contiguous());
        assert!(!t.row_contiguous() || m.rows() == 1);
        assert_eq!(v.row(2), m.row(2));
    }

    #[test]
    #[should_panic(expected = "view exceeds buffer")]
    fn view_bounds_checked() {
        let data = vec![0.0f32; 5];
        let _ = MatrixView::new(&data, 2, 3, 3, 1);
    }
}
