//! General matrix inversion (Gauss–Jordan with partial pivoting).
//!
//! The SNGD/HyLo baseline inverts `AᵀA ⊙ GᵀG + μI` kernels which are
//! symmetric but, with KID-style sampling, occasionally only semi-definite
//! after masking — the general path mirrors the reference implementation's
//! use of a dense LU/GJ solve rather than assuming SPD.

use super::Matrix;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum InverseError {
    #[error("matrix is singular (pivot magnitude {pivot:.3e} at column {col})")]
    Singular { col: usize, pivot: f64 },
    #[error("matrix is not square")]
    NotSquare,
}

/// Invert a general square matrix with Gauss–Jordan + partial pivoting,
/// f64 internal precision. O(d³).
pub fn invert(a: &Matrix) -> Result<Matrix, InverseError> {
    if !a.is_square() {
        return Err(InverseError::NotSquare);
    }
    let n = a.rows();
    // Augmented [A | I] in f64.
    let mut m = vec![0.0f64; n * 2 * n];
    let w = 2 * n;
    for i in 0..n {
        for j in 0..n {
            m[i * w + j] = a[(i, j)] as f64;
        }
        m[i * w + n + i] = 1.0;
    }
    for col in 0..n {
        // Partial pivot.
        let mut piv_row = col;
        let mut piv_val = m[col * w + col].abs();
        for r in (col + 1)..n {
            let v = m[r * w + col].abs();
            if v > piv_val {
                piv_val = v;
                piv_row = r;
            }
        }
        if piv_val < 1e-12 {
            return Err(InverseError::Singular { col, pivot: piv_val });
        }
        if piv_row != col {
            for j in 0..w {
                m.swap(col * w + j, piv_row * w + j);
            }
        }
        let inv_piv = 1.0 / m[col * w + col];
        for j in 0..w {
            m[col * w + j] *= inv_piv;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m[r * w + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..w {
                m[r * w + j] -= f * m[col * w + j];
            }
        }
    }
    let mut inv = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            inv[(i, j)] = m[i * w + n + j] as f32;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::matmul;
    use crate::util::Rng;

    #[test]
    fn inverts_known() {
        let a = Matrix::from_rows(&[&[4.0, 7.0], &[2.0, 6.0]]);
        let inv = invert(&a).unwrap();
        // det = 10; inverse = [[0.6,-0.7],[-0.2,0.4]]
        assert!((inv[(0, 0)] - 0.6).abs() < 1e-6);
        assert!((inv[(0, 1)] + 0.7).abs() < 1e-6);
        assert!((inv[(1, 0)] + 0.2).abs() < 1e-6);
        assert!((inv[(1, 1)] - 0.4).abs() < 1e-6);
    }

    #[test]
    fn random_inverse_roundtrip() {
        let mut rng = Rng::new(17);
        let mut a = Matrix::randn(25, 25, 1.0, &mut rng);
        for i in 0..25 {
            a[(i, i)] += 5.0; // diagonally dominant => well-conditioned
        }
        let inv = invert(&a).unwrap();
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Matrix::identity(25)) < 1e-3);
    }

    #[test]
    fn detects_singular() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(matches!(invert(&a), Err(InverseError::Singular { .. })));
    }

    #[test]
    fn rejects_nonsquare() {
        assert_eq!(invert(&Matrix::zeros(2, 3)).unwrap_err(), InverseError::NotSquare);
    }

    #[test]
    fn needs_pivoting() {
        // Zero on the first diagonal entry requires a row swap.
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let inv = invert(&a).unwrap();
        assert!(inv.max_abs_diff(&a) < 1e-6); // permutation is its own inverse
    }
}
