//! Persistent worker pool for the tiled engine.
//!
//! One process-wide pool ([`global`]) spawns detached worker threads lazily
//! (up to [`hw_threads`]) and keeps them parked on a condvar between
//! dispatches, so a hot optimizer step pays a queue push + wakeup instead
//! of a thread spawn per GEMM. [`Pool::run`] fans a borrowed closure out
//! over `parts` logical partitions: parts `1..parts` are queued for the
//! workers, part `0` runs on the calling thread, and the call blocks until
//! every part has finished — which is what makes handing workers a
//! *borrowed* (non-`'static`) closure sound (see the safety comment in
//! `run`).
//!
//! The pool never decides *what* any part computes — partitioning is the
//! scheduler's job ([`super::schedule`]) and is a pure function of the
//! problem shape, so results cannot depend on which worker ran which part
//! or on how many workers exist.
//!
//! Thread-count resolution: [`set_threads`] (the `mkor perf --threads`
//! knob) wins, then the `MKOR_THREADS` environment variable, then
//! [`hw_threads`]. All of it only affects speed, never results: every
//! engine kernel is bitwise identical at any thread count.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Upper bound on pool workers (queue pressure beyond this many cores is
/// not a regime the in-process engine targets).
pub const MAX_THREADS: usize = 64;

thread_local! {
    /// Set inside pool workers: a kernel that re-enters the engine from a
    /// worker runs serially instead of queueing (no pool-in-pool
    /// deadlocks; results are identical either way).
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Completion latch: counts outstanding worker parts, records panics.
struct Latch {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Latch {
    fn new(parts: usize) -> Latch {
        Latch { state: Mutex::new((parts, false)), cv: Condvar::new() }
    }

    fn done(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= panicked;
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every part is done; returns whether any part panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1
    }
}

/// One queued partition of a dispatch. The closure reference has had its
/// lifetime erased; `Pool::run` guarantees the referent outlives the job
/// (it blocks on the latch before returning).
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    part: usize,
    latch: Arc<Latch>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

/// The persistent pool. Construct via [`global`].
pub struct Pool {
    shared: Arc<Shared>,
    spawned: Mutex<usize>,
}

impl Pool {
    fn new() -> Pool {
        Pool {
            shared: Arc::new(Shared { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() }),
            spawned: Mutex::new(0),
        }
    }

    /// Make sure at least `want` workers exist (capped at [`MAX_THREADS`]).
    fn ensure_workers(&self, want: usize) {
        let want = want.min(MAX_THREADS);
        let mut spawned = self.spawned.lock().unwrap();
        while *spawned < want {
            let shared = Arc::clone(&self.shared);
            std::thread::Builder::new()
                .name(format!("mkor-engine-{spawned}"))
                .spawn(move || worker_loop(shared))
                .expect("engine pool: failed to spawn worker");
            *spawned += 1;
        }
    }

    /// Run `f(part)` for every `part in 0..parts`, fanning parts `1..`
    /// out to the pool while the caller computes part 0. Blocks until all
    /// parts complete; propagates a panic if any part panicked.
    ///
    /// Called from a pool worker (nested dispatch) or with `parts <= 1`,
    /// it degenerates to a serial loop on the calling thread.
    pub fn run(&self, parts: usize, f: &(dyn Fn(usize) + Sync)) {
        if parts <= 1 || IN_POOL.with(|p| p.get()) {
            for part in 0..parts {
                f(part);
            }
            return;
        }
        self.ensure_workers(parts - 1);
        let latch = Arc::new(Latch::new(parts - 1));
        // SAFETY: the only thing unsafe here is erasing the closure's
        // lifetime so it can sit in the 'static job queue. The borrow
        // stays valid for as long as any worker can touch it because this
        // function does not return — not even by unwinding — until
        // `latch.wait()` has observed every queued part finished: the
        // caller's own part is run under `catch_unwind`, the wait happens
        // unconditionally, and only then is a caught panic resumed.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
        {
            let mut q = self.shared.queue.lock().unwrap();
            for part in 1..parts {
                q.push_back(Job { f: f_static, part, latch: Arc::clone(&latch) });
            }
        }
        self.shared.cv.notify_all();
        let mine = catch_unwind(AssertUnwindSafe(|| f(0)));
        let worker_panicked = latch.wait();
        if let Err(payload) = mine {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("engine pool: a worker part panicked");
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL.with(|p| p.set(true));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let ok = catch_unwind(AssertUnwindSafe(|| (job.f)(job.part))).is_ok();
        job.latch.done(!ok);
    }
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// The process-wide engine pool.
pub fn global() -> &'static Pool {
    POOL.get_or_init(Pool::new)
}

/// `0` = unset (fall through to `MKOR_THREADS` / hardware).
static THREADS: AtomicUsize = AtomicUsize::new(0);

static DEFAULT_THREADS: OnceLock<usize> = OnceLock::new();

/// Hardware thread count (cached `available_parallelism`).
pub fn hw_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

/// Pin the engine's thread count (e.g. `mkor perf --threads N`). Clamped
/// to `1..=MAX_THREADS`. Affects wall-clock only — never results.
pub fn set_threads(n: usize) {
    THREADS.store(n.clamp(1, MAX_THREADS), Ordering::Relaxed);
}

/// The thread count engine dispatches resolve to: [`set_threads`] if set,
/// else `MKOR_THREADS`, else [`hw_threads`].
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t > 0 {
        return t;
    }
    *DEFAULT_THREADS.get_or_init(|| {
        std::env::var("MKOR_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(hw_threads)
            .clamp(1, MAX_THREADS)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_executes_every_part_exactly_once() {
        for parts in [1usize, 2, 3, 8, 17] {
            let hits: Vec<AtomicUsize> = (0..parts).map(|_| AtomicUsize::new(0)).collect();
            global().run(parts, &|p| {
                hits[p].fetch_add(1, Ordering::SeqCst);
            });
            for (p, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "part {p} of {parts}");
            }
        }
    }

    #[test]
    fn run_sees_borrowed_state_and_sums_correctly() {
        let inputs: Vec<u64> = (0..1000).collect();
        let partial: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        global().run(4, &|p| {
            let chunk = inputs.len() / 4;
            let lo = p * chunk;
            let hi = if p == 3 { inputs.len() } else { lo + chunk };
            partial[p].store(inputs[lo..hi].iter().sum(), Ordering::SeqCst);
        });
        let total: u64 = partial.iter().map(|a| a.load(Ordering::SeqCst)).sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            global().run(4, &|p| {
                if p == 2 {
                    panic!("boom in part 2");
                }
            });
        }));
        assert!(caught.is_err());
    }

    #[test]
    fn repeated_dispatches_reuse_the_pool() {
        // Exercise the park/wake cycle: many small dispatches must all
        // complete (a deadlock here would hang the test).
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            global().run(3, &|_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 150);
    }

    #[test]
    fn thread_count_resolution_clamps() {
        // Only checks invariants on the resolved value: tests share the
        // global, so this avoids pinning an exact number.
        assert!(threads() >= 1 && threads() <= MAX_THREADS);
        assert!(hw_threads() >= 1);
    }
}
