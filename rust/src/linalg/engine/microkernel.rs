//! The 8×8 register-tile microkernel.
//!
//! Computes `acc += A_panel · B_strip` for one `MR×NR` output tile over a
//! packed depth chunk. The loop body is a rank-1 update of the accumulator
//! per depth step — 8 broadcast-multiplies against an 8-wide contiguous
//! B row — written so the accumulator array stays in registers and the
//! inner `NR` loop autovectorizes to full-width FMA lanes: fixed-size
//! arrays, unit-stride panel reads, and **no data-dependent branches**
//! (the zero-skip mistake documented in `ops.rs` §Perf cost 1.3–3×; padded
//! lanes multiply through as zeros instead).
//!
//! Determinism: for each `(r, c)`, products accumulate in ascending depth
//! order `p = 0..klen`, a pure function of the panel contents — the
//! scheduling layer above can hand tiles to any worker without changing a
//! single bit of the result.

use super::tile::{MR, NR};

/// `acc[r][c] += Σ_p pa[p*MR + r] · pb[p*NR + c]` for `p in 0..klen`.
#[inline]
pub fn kernel_8x8(klen: usize, pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(pa.len() >= klen * MR && pb.len() >= klen * NR);
    for p in 0..klen {
        let arow = &pa[p * MR..p * MR + MR];
        let brow = &pb[p * NR..p * NR + NR];
        for r in 0..MR {
            let ar = arow[r];
            for (c, a) in acc[r].iter_mut().enumerate() {
                *a += ar * brow[c];
            }
        }
    }
}

/// Accumulate the valid `mr×nv` corner of `acc` into `c` rows: row `r` of
/// the tile lands in `c[(row0 + r) * row_len + j0 ..][.. nv]`.
#[inline]
pub fn store_tile(
    acc: &[[f32; NR]; MR],
    c: &mut [f32],
    row0: usize,
    row_len: usize,
    j0: usize,
    mr: usize,
    nv: usize,
) {
    for (r, acc_row) in acc.iter().enumerate().take(mr) {
        let dst = &mut c[(row0 + r) * row_len + j0..(row0 + r) * row_len + j0 + nv];
        for (d, a) in dst.iter_mut().zip(acc_row) {
            *d += a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kernel_matches_naive_outer_products() {
        let mut rng = Rng::new(3);
        let klen = 37;
        let pa: Vec<f32> = (0..klen * MR).map(|_| rng.gaussian_f32()).collect();
        let pb: Vec<f32> = (0..klen * NR).map(|_| rng.gaussian_f32()).collect();
        let mut acc = [[0.0f32; NR]; MR];
        kernel_8x8(klen, &pa, &pb, &mut acc);
        for r in 0..MR {
            for c in 0..NR {
                // Same order, scalar reference: bitwise equal.
                let mut want = 0.0f32;
                for p in 0..klen {
                    want += pa[p * MR + r] * pb[p * NR + c];
                }
                assert_eq!(acc[r][c].to_bits(), want.to_bits(), "r={r} c={c}");
            }
        }
    }

    #[test]
    fn store_clips_to_valid_corner() {
        let mut acc = [[0.0f32; NR]; MR];
        for (r, row) in acc.iter_mut().enumerate() {
            for (c, v) in row.iter_mut().enumerate() {
                *v = (r * 10 + c) as f32;
            }
        }
        let row_len = 10;
        let mut c = vec![1.0f32; 4 * row_len];
        store_tile(&acc, &mut c, 1, row_len, 3, 2, 5);
        for (idx, &v) in c.iter().enumerate() {
            let (i, j) = (idx / row_len, idx % row_len);
            let want = if (1..3).contains(&i) && (3..8).contains(&j) {
                1.0 + ((i - 1) * 10 + (j - 3)) as f32
            } else {
                1.0
            };
            assert_eq!(v, want, "({i},{j})");
        }
    }
}
