//! Packed panels: the tile wire format the microkernel consumes.
//!
//! The engine copies operands into small contiguous scratch buffers before
//! multiplying, exactly like the Pallas kernel's HBM→VMEM block copies in
//! `python/compile/kernels/precond.py` (BlockSpec tiles there, packed
//! panels here): the microkernel then streams unit-stride panels regardless
//! of the original operand layout, which is what lets one code path serve
//! `A·B`, `A·Bᵀ` and `Aᵀ·B` — the transposed forms arrive as stride-swapped
//! [`MatrixView`]s and the packing loop absorbs the stride.
//!
//! Layouts (`MR`/`NR` are the microkernel tile edges, `KC` the k-chunk):
//!
//! * **A panel** — `MR` rows × `klen` depth, stored depth-major:
//!   `pa[p*MR + r] = A[i0+r, k0+p]`. Rows past the matrix edge pack as
//!   zero, so the microkernel never branches on ragged shapes.
//! * **B chunk** — `klen` depth × all columns, stored strip-major: strip
//!   `s` covers columns `[s*NR, s*NR+NR)` and occupies the contiguous
//!   range `pb[s*klen*NR ..][.. klen*NR]` with `pb_strip[p*NR + c] =
//!   B[k0+p, s*NR+c]` (edge columns zero-padded).
//!
//! Zero padding is sound for the *packed* operand because padded lanes are
//! never written back (the store loop clips to the valid tile), and it
//! must never be "optimized" into a skip-if-zero branch: the §Perf note in
//! `ops.rs` measured data-dependent branches in these loops at a 1.3–3×
//! slowdown, and the engine's panels inherit the no-branch rule.

use crate::linalg::MatrixView;

/// Microkernel tile rows (output rows per A panel).
pub const MR: usize = 8;
/// Microkernel tile columns (output columns per B strip).
pub const NR: usize = 8;
/// Depth (k) chunk: panels cover at most this much of the contraction per
/// pass, keeping pa + one B strip resident in L1/L2.
pub const KC: usize = 256;

/// Pack `A[i0..i0+mr, k0..k0+klen]` into `pa` (depth-major, zero-padded to
/// `MR` rows). `pa` must hold at least `klen * MR` elements.
pub fn pack_a_panel(
    a: MatrixView<'_>,
    i0: usize,
    mr: usize,
    k0: usize,
    klen: usize,
    pa: &mut [f32],
) {
    debug_assert!(mr >= 1 && mr <= MR && i0 + mr <= a.rows() && k0 + klen <= a.cols());
    debug_assert!(pa.len() >= klen * MR);
    for p in 0..klen {
        let dst = &mut pa[p * MR..p * MR + MR];
        for (r, d) in dst.iter_mut().enumerate() {
            *d = if r < mr { a.get(i0 + r, k0 + p) } else { 0.0 };
        }
    }
}

/// Number of `NR`-wide strips covering `n` columns.
pub fn strips(n: usize) -> usize {
    n.div_ceil(NR)
}

/// Pack `B[k0..k0+klen, ..]` into `pb` strip-major (see module docs).
/// `pb` must hold at least `strips(b.cols()) * klen * NR` elements.
pub fn pack_b_chunk(b: MatrixView<'_>, k0: usize, klen: usize, pb: &mut [f32]) {
    let n = b.cols();
    debug_assert!(k0 + klen <= b.rows());
    debug_assert!(pb.len() >= strips(n) * klen * NR);
    for s in 0..strips(n) {
        let j0 = s * NR;
        let nv = NR.min(n - j0);
        let strip = &mut pb[s * klen * NR..(s + 1) * klen * NR];
        if b.row_contiguous() {
            // Fast path: each source row segment is contiguous.
            for p in 0..klen {
                let src = &b.row(k0 + p)[j0..j0 + nv];
                let dst = &mut strip[p * NR..p * NR + NR];
                dst[..nv].copy_from_slice(src);
                dst[nv..].fill(0.0);
            }
        } else {
            for p in 0..klen {
                let dst = &mut strip[p * NR..p * NR + NR];
                for (c, d) in dst.iter_mut().enumerate() {
                    *d = if c < nv { b.get(k0 + p, j0 + c) } else { 0.0 };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::Rng;

    #[test]
    fn a_panel_layout_and_padding() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(5, 9, 1.0, &mut rng);
        let (i0, mr, k0, klen) = (2, 3, 4, 5);
        let mut pa = vec![f32::NAN; klen * MR];
        pack_a_panel(a.view(), i0, mr, k0, klen, &mut pa);
        for p in 0..klen {
            for r in 0..MR {
                let want = if r < mr { a[(i0 + r, k0 + p)] } else { 0.0 };
                assert_eq!(pa[p * MR + r], want, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn b_chunk_layout_matches_view_for_both_stride_forms() {
        let mut rng = Rng::new(2);
        let b = Matrix::randn(11, 13, 1.0, &mut rng);
        for view in [b.view(), b.t_view()] {
            let (k0, klen) = (3, 7);
            let mut pb = vec![f32::NAN; strips(view.cols()) * klen * NR];
            pack_b_chunk(view, k0, klen, &mut pb);
            for s in 0..strips(view.cols()) {
                for p in 0..klen {
                    for c in 0..NR {
                        let j = s * NR + c;
                        let want = if j < view.cols() { view.get(k0 + p, j) } else { 0.0 };
                        assert_eq!(pb[s * klen * NR + p * NR + c], want, "s={s} p={p} c={c}");
                    }
                }
            }
        }
    }

    #[test]
    fn strip_count() {
        assert_eq!(strips(0), 0);
        assert_eq!(strips(1), 1);
        assert_eq!(strips(8), 1);
        assert_eq!(strips(9), 2);
        assert_eq!(strips(64), 8);
    }
}
