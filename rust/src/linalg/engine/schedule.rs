//! Deterministic row-partitioned tile scheduling.
//!
//! Every parallel kernel in the engine partitions its *output rows* into
//! contiguous ranges — one per logical part — computed by [`partition`]
//! from the problem shape alone. Combined with two invariants this makes
//! results bitwise identical at any thread count:
//!
//! 1. **Exclusive ownership** — each output row belongs to exactly one
//!    part, so there are no cross-thread accumulations, no atomics, and no
//!    reduction trees whose shape depends on worker count.
//! 2. **Fixed per-row order** — within a part, the floating-point
//!    accumulation order for each output element is a pure function of the
//!    shape and the engine's (constant) tile sizes, never of the partition
//!    bounds.
//!
//! [`RowSlices`] hands each part an exclusive `&mut` window of the output
//! buffer (contiguous, because outputs are row-major and parts own
//! contiguous row ranges); disjointness is asserted at construction.
//!
//! Dispatch thresholds live here too: `ops.rs` consults them to decide
//! engine vs. serial-fallback, and they are functions of the problem size
//! ONLY — never of the thread count — so the code path (and therefore the
//! numerics) cannot change between `--threads 1` and `--threads 64`.

use std::marker::PhantomData;

/// Engine GEMM cut-over: dispatch to the tiled engine when `m·k·n` is at
/// least this much work (≈ a 128³ product). Below it the serial blocked
/// path wins on packing overhead.
pub const GEMM_PAR_MIN_WORK: usize = 1 << 21;

/// Cut-over for row-partitioned O(n²) kernels (matvec, rank-1 update,
/// col-mean): dispatch when the touched element count reaches this.
pub const SLICE_PAR_MIN_ELEMS: usize = 1 << 18;

/// Split `units` work units into at most `parts` contiguous ranges,
/// balanced to within one unit, in ascending order. Pure function of its
/// arguments; never returns empty ranges (fewer parts come back when
/// `units < parts`).
pub fn partition(units: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1).min(units.max(1));
    if units == 0 {
        return vec![(0, 0)];
    }
    let base = units / parts;
    let rem = units % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, units);
    out
}

/// Disjoint per-part `&mut` windows over one row-major output buffer.
///
/// Construction checks that the row ranges are ascending, disjoint, and
/// in bounds; [`RowSlices::part`] then hands out raw exclusive windows.
/// The scheduler's contract — each part index is executed by exactly one
/// worker, exactly once per dispatch — is what makes that sound.
pub struct RowSlices<'a> {
    ptr: *mut f32,
    cols: usize,
    bounds: Vec<(usize, usize)>,
    _marker: PhantomData<&'a mut [f32]>,
}

// SAFETY: RowSlices only allows access to disjoint windows of the
// underlying buffer (asserted in `new`), and the pool runs each part on
// one thread. The raw pointer is what makes these impls non-automatic.
unsafe impl Send for RowSlices<'_> {}
unsafe impl Sync for RowSlices<'_> {}

impl<'a> RowSlices<'a> {
    /// Wrap `data` (a row-major buffer of `cols`-wide rows) with one
    /// window per entry of `bounds` (half-open row ranges).
    pub fn new(data: &'a mut [f32], cols: usize, bounds: Vec<(usize, usize)>) -> Self {
        let rows = if cols == 0 { 0 } else { data.len() / cols };
        debug_assert_eq!(rows * cols, data.len(), "buffer is not rows×cols");
        let mut prev_end = 0usize;
        for &(r0, r1) in &bounds {
            assert!(
                r0 >= prev_end && r0 <= r1 && r1 <= rows,
                "row ranges must be ascending, disjoint, in-bounds"
            );
            prev_end = r1;
        }
        RowSlices { ptr: data.as_mut_ptr(), cols, bounds, _marker: PhantomData }
    }

    /// Number of parts.
    pub fn parts(&self) -> usize {
        self.bounds.len()
    }

    /// The row range of part `p`.
    pub fn rows(&self, p: usize) -> (usize, usize) {
        self.bounds[p]
    }

    /// Exclusive window of part `p`.
    ///
    /// # Safety
    /// Each part index must be materialized by at most one thread at a
    /// time (the scheduler assigns each part to exactly one worker per
    /// dispatch). Windows of distinct parts never alias by construction.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn part(&self, p: usize) -> &mut [f32] {
        let (r0, r1) = self.bounds[p];
        std::slice::from_raw_parts_mut(self.ptr.add(r0 * self.cols), (r1 - r0) * self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_and_balances() {
        for &(units, parts) in &[(10usize, 3usize), (7, 7), (3, 8), (100, 1), (1, 1), (64, 7)] {
            let ranges = partition(units, parts);
            assert!(ranges.len() <= parts.max(1));
            let mut next = 0;
            let mut sizes = Vec::new();
            for (lo, hi) in &ranges {
                assert_eq!(*lo, next, "contiguous");
                assert!(hi > lo, "no empty ranges for units={units}");
                sizes.push(hi - lo);
                next = *hi;
            }
            assert_eq!(next, units, "full coverage");
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced to within one unit");
        }
    }

    #[test]
    fn partition_is_deterministic_in_shape_only() {
        assert_eq!(partition(64, 4), partition(64, 4));
        assert_eq!(partition(0, 4), vec![(0, 0)]);
    }

    #[test]
    fn row_slices_hand_out_disjoint_windows() {
        let mut buf = vec![0.0f32; 6 * 4];
        let bounds = partition(6, 3);
        let slices = RowSlices::new(&mut buf, 4, bounds);
        for p in 0..slices.parts() {
            let w = unsafe { slices.part(p) };
            for v in w.iter_mut() {
                *v += (p + 1) as f32;
            }
        }
        // Each row was written by exactly its owner.
        for (i, chunk) in buf.chunks(4).enumerate() {
            let owner = (i / 2 + 1) as f32;
            assert!(chunk.iter().all(|&v| v == owner), "row {i}");
        }
    }

    #[test]
    #[should_panic(expected = "ascending, disjoint")]
    fn overlapping_bounds_rejected() {
        let mut buf = vec![0.0f32; 12];
        let _ = RowSlices::new(&mut buf, 4, vec![(0, 2), (1, 3)]);
    }
}
