//! Parallel tiled execution engine for the dense linalg hot paths.
//!
//! This is the CPU analogue of the Pallas kernel in
//! `python/compile/kernels/precond.py`: explicit tiles as the unit of
//! work. Operands are packed into contiguous panels ([`tile`]), an 8×8
//! register-tile microkernel does the arithmetic ([`microkernel`]), a
//! persistent worker pool executes partitions ([`pool`]), and a
//! deterministic row-partitioned schedule decides who computes what
//! ([`schedule`]).
//!
//! **Determinism invariant** — every entry point here is *bitwise
//! identical at any thread count*: output rows are owned exclusively by
//! one part, per-element accumulation order is a pure function of the
//! problem shape and the constant tile sizes, and partitioning never
//! feeds back into the numerics. `ops.rs` additionally guarantees that
//! its engine-vs-serial dispatch depends on problem size only, so a
//! training run's results cannot change with `--threads` — the property
//! the checkpoint-resume and sweep byte-equality suites rely on.
//!
//! Call forms: the GEMM takes [`MatrixView`]s, so `A·B`, `A·Bᵀ` and
//! `Aᵀ·B` are all the same routine with stride-swapped views — no
//! transpose is ever materialized.

pub mod microkernel;
pub mod pool;
pub mod schedule;
pub mod tile;

pub use pool::{hw_threads, set_threads, threads};
pub use schedule::{GEMM_PAR_MIN_WORK, SLICE_PAR_MIN_ELEMS};

use crate::linalg::{Matrix, MatrixView};
use crate::obs::{self, EventKind, TraceEvent};
use microkernel::{kernel_8x8, store_tile};
use schedule::{partition, RowSlices};
use tile::{pack_a_panel, pack_b_chunk, strips, KC, MR, NR};

/// Trace one engine dispatch (`op` distinguishes the GEMM from the
/// rowwise kernels). Callers already checked [`obs::enabled`]. The event
/// nests under whatever span encloses the *dispatching* thread (the
/// trainer's forward/backward, the optimizer's precond, …).
fn trace_dispatch(op: &str, m: usize, n: usize, k: usize, threads: usize, secs: f64) {
    obs::emit(
        TraceEvent::new(EventKind::Gemm)
            .label("op", op)
            .num("m", m as f64)
            .num("n", n as f64)
            .num("k", k as f64)
            .num("threads", threads as f64)
            .num("secs", secs)
            .maybe_under(obs::span::current()),
    );
    obs::registry::with_global(|r| {
        r.inc("engine.dispatches", 1);
        r.observe(&format!("engine.{op}_secs"), secs);
    });
}

/// `C = A · B` over views, tiled and fanned out over `threads` parts.
/// `c` is overwritten. Shapes: `a` is m×k, `b` is k×n, `c` is m×n.
pub fn gemm_into(a: MatrixView<'_>, b: MatrixView<'_>, c: &mut Matrix, threads: usize) {
    assert_eq!(a.cols(), b.rows(), "gemm shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, n) = (a.rows(), b.cols());
    c.data_mut().fill(0.0);
    if m == 0 || n == 0 || a.cols() == 0 {
        return;
    }
    let t0 = obs::enabled().then(std::time::Instant::now);
    let row_blocks = m.div_ceil(MR);
    let block_bounds = partition(row_blocks, threads);
    let row_bounds: Vec<(usize, usize)> = block_bounds
        .iter()
        .map(|&(b0, b1)| ((b0 * MR).min(m), (b1 * MR).min(m)))
        .collect();
    let parts = row_bounds.len();
    let slices = RowSlices::new(c.data_mut(), n, row_bounds.clone());
    let work = |p: usize| {
        // SAFETY: the pool runs each part exactly once, on one thread;
        // windows of distinct parts are disjoint by construction.
        let cpart = unsafe { slices.part(p) };
        let (r0, r1) = row_bounds[p];
        gemm_part(a, b, cpart, r0, r1);
    };
    pool::global().run(parts, &work);
    if let Some(t0) = t0 {
        trace_dispatch("gemm", m, n, a.cols(), parts, t0.elapsed().as_secs_f64());
    }
}

/// One part's share of the GEMM: rows `[r0, r1)` of `C`, all columns.
/// Loop order is k-chunk outer (one B pack per chunk, amortized over the
/// part's row blocks), row block middle, column strip inner.
fn gemm_part(a: MatrixView<'_>, b: MatrixView<'_>, cpart: &mut [f32], r0: usize, r1: usize) {
    let (k, n) = (a.cols(), b.cols());
    let nstrips = strips(n);
    let mut pa = vec![0.0f32; MR * KC];
    let mut pb = vec![0.0f32; nstrips * NR * KC];
    let mut k0 = 0;
    while k0 < k {
        let klen = KC.min(k - k0);
        pack_b_chunk(b, k0, klen, &mut pb);
        let mut i0 = r0;
        while i0 < r1 {
            let mr = MR.min(r1 - i0);
            pack_a_panel(a, i0, mr, k0, klen, &mut pa);
            for s in 0..nstrips {
                let j0 = s * NR;
                let nv = NR.min(n - j0);
                let mut acc = [[0.0f32; NR]; MR];
                kernel_8x8(klen, &pa, &pb[s * klen * NR..(s + 1) * klen * NR], &mut acc);
                store_tile(&acc, cpart, i0 - r0, n, j0, mr, nv);
            }
            i0 += MR;
        }
        k0 += KC;
    }
}

/// `y = A · x`, rows partitioned. Per-row accumulation is the same
/// ascending zip as the serial path, so this is bitwise equal to
/// `ops::matvec_into` at any thread count (including 1).
pub fn matvec_into(a: &Matrix, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    let t0 = obs::enabled().then(std::time::Instant::now);
    let bounds = partition(a.rows(), threads);
    let slices = RowSlices::new(y, 1, bounds);
    let work = |p: usize| {
        // SAFETY: see gemm_into.
        let ypart = unsafe { slices.part(p) };
        let (r0, _) = slices.rows(p);
        for (off, yi) in ypart.iter_mut().enumerate() {
            let row = a.row(r0 + off);
            let mut acc = 0.0f32;
            for (&r, &v) in row.iter().zip(x) {
                acc += r * v;
            }
            *yi = acc;
        }
    };
    pool::global().run(slices.parts(), &work);
    if let Some(t0) = t0 {
        trace_dispatch("matvec", a.rows(), 1, a.cols(), slices.parts(), t0.elapsed().as_secs_f64());
    }
}

/// `y = Aᵀ · x`, output columns partitioned. Each part sweeps the rows of
/// `A` in ascending order over its own column window — the same per-element
/// order as the serial path, so bitwise equal at any thread count.
pub fn matvec_t_into(a: &Matrix, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    let t0 = obs::enabled().then(std::time::Instant::now);
    y.fill(0.0);
    let bounds = partition(a.cols(), threads);
    let slices = RowSlices::new(y, 1, bounds);
    let work = |p: usize| {
        // SAFETY: see gemm_into.
        let ypart = unsafe { slices.part(p) };
        let (j0, j1) = slices.rows(p);
        for i in 0..a.rows() {
            let xi = x[i];
            let row = &a.row(i)[j0..j1];
            for (yj, &r) in ypart.iter_mut().zip(row) {
                *yj += xi * r;
            }
        }
    };
    pool::global().run(slices.parts(), &work);
    if let Some(t0) = t0 {
        trace_dispatch(
            "matvec_t",
            a.cols(),
            1,
            a.rows(),
            slices.parts(),
            t0.elapsed().as_secs_f64(),
        );
    }
}

/// Fused symmetric rank-1 update `A = alpha*A + beta·u uᵀ`, rows
/// partitioned; each row's sweep is identical to the serial path.
pub fn scaled_rank1_update(a: &mut Matrix, alpha: f32, beta: f32, u: &[f32], threads: usize) {
    assert!(a.is_square());
    assert_eq!(a.rows(), u.len());
    let t0 = obs::enabled().then(std::time::Instant::now);
    let n = u.len();
    let bounds = partition(n, threads);
    let slices = RowSlices::new(a.data_mut(), n, bounds);
    let work = |p: usize| {
        // SAFETY: see gemm_into.
        let apart = unsafe { slices.part(p) };
        let (r0, r1) = slices.rows(p);
        for (off, i) in (r0..r1).enumerate() {
            let bu = beta * u[i];
            let row = &mut apart[off * n..(off + 1) * n];
            for (rv, &uj) in row.iter_mut().zip(u) {
                *rv = alpha * *rv + bu * uj;
            }
        }
    };
    pool::global().run(slices.parts(), &work);
    if let Some(t0) = t0 {
        trace_dispatch("rank1", n, n, 1, slices.parts(), t0.elapsed().as_secs_f64());
    }
}

/// Column mean of a `d×b` matrix (the paper's rank-1 batch approximation,
/// Algorithm 1 lines 2–3), rows partitioned; f64 accumulation per row as
/// in the serial path.
pub fn col_mean_into(a: &Matrix, out: &mut [f32], threads: usize) {
    let (d, b) = (a.rows(), a.cols());
    assert!(b > 0);
    assert_eq!(out.len(), d);
    let t0 = obs::enabled().then(std::time::Instant::now);
    let bounds = partition(d, threads);
    let slices = RowSlices::new(out, 1, bounds);
    let work = |p: usize| {
        // SAFETY: see gemm_into.
        let opart = unsafe { slices.part(p) };
        let (r0, _) = slices.rows(p);
        for (off, o) in opart.iter_mut().enumerate() {
            let row = a.row(r0 + off);
            *o = (row.iter().map(|&x| x as f64).sum::<f64>() / b as f64) as f32;
        }
    };
    pool::global().run(slices.parts(), &work);
    if let Some(t0) = t0 {
        trace_dispatch("col_mean", d, 1, b, slices.parts(), t0.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for p in 0..a.cols() {
                    s += a[(i, p)] as f64 * b[(p, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    fn assert_bitwise(a: &Matrix, b: &Matrix, what: &str) {
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}");
        }
    }

    #[test]
    fn gemm_matches_naive_on_ragged_shapes() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (70, 129, 33)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut c = Matrix::zeros(m, n);
            gemm_into(a.view(), b.view(), &mut c, 3);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_bitwise_identical_across_thread_counts() {
        let mut rng = Rng::new(12);
        for &(m, k, n) in &[(13, 7, 11), (70, 129, 33), (64, 300, 8), (257, 40, 19)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut c1 = Matrix::zeros(m, n);
            gemm_into(a.view(), b.view(), &mut c1, 1);
            for t in [2usize, 7, 16] {
                let mut ct = Matrix::zeros(m, n);
                gemm_into(a.view(), b.view(), &mut ct, t);
                assert_bitwise(&c1, &ct, "gemm threads=1 vs {t} at {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn gemm_handles_transposed_views() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(13, 7, 1.0, &mut rng);
        let bt = Matrix::randn(11, 7, 1.0, &mut rng); // B = btᵀ is 7×11
        let mut c = Matrix::zeros(13, 11);
        gemm_into(a.view(), bt.t_view(), &mut c, 2);
        let want = naive(&a, &bt.transpose());
        assert!(c.max_abs_diff(&want) < 1e-3);

        let at = Matrix::randn(7, 13, 1.0, &mut rng); // A = atᵀ is 13×7
        let b = Matrix::randn(7, 5, 1.0, &mut rng);
        let mut c2 = Matrix::zeros(13, 5);
        gemm_into(at.t_view(), b.view(), &mut c2, 2);
        assert!(c2.max_abs_diff(&naive(&at.transpose(), &b)) < 1e-3);
    }

    #[test]
    fn rowwise_kernels_bitwise_match_serial_ops() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(70, 33, 1.0, &mut rng);
        let x: Vec<f32> = (0..33).map(|_| rng.gaussian_f32()).collect();
        let xr: Vec<f32> = (0..70).map(|_| rng.gaussian_f32()).collect();
        for t in [1usize, 2, 7] {
            let mut y = vec![0.0f32; 70];
            matvec_into(&a, &x, &mut y, t);
            let want = ops::matvec(&a, &x);
            assert!(y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()), "matvec t={t}");

            let mut yt = vec![0.0f32; 33];
            matvec_t_into(&a, &xr, &mut yt, t);
            let want_t = ops::matvec_t(&a, &xr);
            assert!(
                yt.iter().zip(&want_t).all(|(a, b)| a.to_bits() == b.to_bits()),
                "matvec_t t={t}"
            );

            let mut m = Matrix::rand_spd(33, 0.1, &mut Rng::new(5));
            let mut want_m = m.clone();
            scaled_rank1_update(&mut m, 0.9, 0.2, &x, t);
            ops::scaled_rank1_update(&mut want_m, 0.9, 0.2, &x);
            assert_bitwise(&m, &want_m, "rank1 t={t}");

            let mut cm = vec![0.0f32; 70];
            col_mean_into(&a, &mut cm, t);
            let want_cm = ops::col_mean(&a);
            assert!(
                cm.iter().zip(&want_cm).all(|(a, b)| a.to_bits() == b.to_bits()),
                "col_mean t={t}"
            );
        }
    }
}
