//! Cholesky factorization, solve and SPD inversion.
//!
//! Used by (a) the KFAC/KAISA baseline to invert damped factors, (b) the
//! SNGD/HyLo baseline to invert the b×b kernel, and (c) the Lemma 3.1
//! property tests ("Cholesky succeeds" is the constructive proof that a
//! matrix is positive-definite).

use super::Matrix;
use thiserror::Error;

/// Failure modes of the SPD routines.
#[derive(Debug, Error, PartialEq)]
pub enum CholeskyError {
    #[error("matrix is not positive definite (pivot {pivot} at index {index})")]
    NotPositiveDefinite { index: usize, pivot: f64 },
    #[error("matrix is not square")]
    NotSquare,
}

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
///
/// Accumulates in `f64` — the paper (§8.4) notes KFAC factors have huge
/// condition numbers, and f32 accumulation loses PD-ness well before the
/// matrix actually becomes indefinite.
pub fn cholesky(a: &Matrix) -> Result<Matrix, CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare);
    }
    let n = a.rows();
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)] as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return Err(CholeskyError::NotPositiveDefinite { index: i, pivot: sum });
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    let data: Vec<f32> = l.iter().map(|&x| x as f32).collect();
    Ok(Matrix::from_vec(n, n, data))
}

/// True iff `a` is positive definite (Cholesky succeeds).
pub fn is_positive_definite(a: &Matrix) -> bool {
    cholesky(a).is_ok()
}

/// Solve `A x = b` for SPD `A` via Cholesky (two triangular solves).
pub fn solve_spd(a: &Matrix, b: &[f32]) -> Result<Vec<f32>, CholeskyError> {
    let l = cholesky(a)?;
    let n = a.rows();
    assert_eq!(b.len(), n);
    // forward: L y = b
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l[(i, k)] as f64 * y[k];
        }
        y[i] = s / l[(i, i)] as f64;
    }
    // backward: Lᵀ x = y
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] as f64 * x[k];
        }
        x[i] = s / l[(i, i)] as f64;
    }
    Ok(x.iter().map(|&v| v as f32).collect())
}

/// Inverse of an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ L⁻¹`.
///
/// O(d³) — this cost is exactly what Table 1 charges KFAC for, and what
/// MKOR's O(d²) SM update avoids.
pub fn invert_spd(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let l = cholesky(a)?;
    let n = a.rows();
    // Invert L in-place (lower triangular), f64 accumulation.
    let mut linv = vec![0.0f64; n * n];
    for i in 0..n {
        linv[i * n + i] = 1.0 / l[(i, i)] as f64;
        for j in 0..i {
            let mut s = 0.0f64;
            for k in j..i {
                s -= l[(i, k)] as f64 * linv[k * n + j];
            }
            linv[i * n + j] = s / l[(i, i)] as f64;
        }
    }
    // A⁻¹ = Lᵀ⁻¹ L⁻¹; compute lower triangle then mirror.
    let mut inv = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0f64;
            for k in i..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            inv[(i, j)] = s as f32;
            inv[(j, i)] = s as f32;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::{matmul, matvec};
    use crate::util::Rng;

    #[test]
    fn factorizes_known_matrix() {
        // A = [[4,2],[2,3]] => L = [[2,0],[1,sqrt(2)]]
        let a = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert!((l[(0, 0)] - 2.0).abs() < 1e-6);
        assert!((l[(1, 0)] - 1.0).abs() < 1e-6);
        assert!((l[(1, 1)] - 2.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn reconstruction_llt() {
        let mut rng = Rng::new(8);
        let a = Matrix::rand_spd(24, 0.5, &mut rng);
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigs 3, -1
        assert!(matches!(cholesky(&a), Err(CholeskyError::NotPositiveDefinite { .. })));
        assert!(!is_positive_definite(&a));
    }

    #[test]
    fn rejects_nonsquare() {
        let a = Matrix::zeros(2, 3);
        assert_eq!(cholesky(&a).unwrap_err(), CholeskyError::NotSquare);
    }

    #[test]
    fn solve_recovers_x() {
        let mut rng = Rng::new(9);
        let a = Matrix::rand_spd(16, 0.5, &mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let b = matvec(&a, &x);
        let got = solve_spd(&a, &b).unwrap();
        for i in 0..16 {
            assert!((got[i] - x[i]).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Rng::new(10);
        let a = Matrix::rand_spd(20, 0.5, &mut rng);
        let inv = invert_spd(&a).unwrap();
        let prod = matmul(&inv, &a);
        assert!(prod.max_abs_diff(&Matrix::identity(20)) < 1e-2);
        assert!(inv.is_symmetric(1e-4));
    }
}
