//! Rank-1 (and rank-r) approximation quality of covariance matrices.
//!
//! Reproduces the measurement behind Figures 5 and 10: how well is
//! `C = X Xᵀ / b` approximated by (a) the *optimal* rank-1 matrix
//! `λ₁ v₁ v₁ᵀ` (Eckart–Young via power iteration) and (b) the *mean-based*
//! rank-1 matrix `x̄ x̄ᵀ` that MKOR actually uses (Algorithm 1 lines 2–3)?

use super::eigen::power_iteration;
use super::ops::{matmul_nt, outer, row_mean};
use super::Matrix;

/// Covariance `C = X Xᵀ / b` for column-sample layout `X ∈ R^{d×b}`.
pub fn covariance(x: &Matrix) -> Matrix {
    let b = x.cols().max(1);
    let mut c = matmul_nt(x, x);
    c.scale(1.0 / b as f32);
    c
}

/// Relative Frobenius error of the best rank-1 approximation of a symmetric
/// PSD matrix: `‖C − λ₁v₁v₁ᵀ‖_F / ‖C‖_F`.
pub fn optimal_rank1_error(c: &Matrix, power_iters: usize, seed: u64) -> f64 {
    let denom = c.fro_norm();
    if denom == 0.0 {
        return 0.0;
    }
    let (lambda, v) = power_iteration(c, power_iters, seed);
    let mut approx = outer(&v, &v);
    approx.scale(lambda as f32);
    let mut diff = c.clone();
    diff.blend(1.0, -1.0, &approx);
    diff.fro_norm() / denom
}

/// Relative Frobenius error of the *mean-vector* rank-1 approximation MKOR
/// uses: `‖C − x̄ x̄ᵀ‖_F / ‖C‖_F` with `x̄` the batch mean.
///
/// `x` is d×b (samples in columns). The paper argues (§4, Approximation
/// Error Analysis) that over-parameterization makes the gap between this and
/// the optimal rank-1 small; the Figure 5 bench measures both.
pub fn mean_rank1_error(x: &Matrix) -> f64 {
    let c = covariance(x);
    let denom = c.fro_norm();
    if denom == 0.0 {
        return 0.0;
    }
    let xbar = row_mean(&x.transpose()); // mean over columns of x = rows of xᵀ
    let approx = outer(&xbar, &xbar);
    let mut diff = c.clone();
    diff.blend(1.0, -1.0, &approx);
    diff.fro_norm() / denom
}

/// Spectral "effective rank" diagnostics: fraction of Frobenius mass in the
/// top eigenvalue, computed from a full Jacobi decomposition (small dims).
pub fn top_eig_mass(c: &Matrix) -> f64 {
    let e = super::eigen::jacobi_eigen(c, 1e-10, 60);
    let total: f64 = e.values.iter().map(|v| v * v).sum();
    if total == 0.0 {
        return 1.0;
    }
    (e.values[0] * e.values[0]) / total
}

/// Rank-r greedy approximation error via repeated deflation (the paper's
/// §4 "Extending MKOR to Higher Ranks" discussion): returns relative errors
/// for ranks `1..=r`.
pub fn rank_r_errors(c: &Matrix, r: usize, power_iters: usize, seed: u64) -> Vec<f64> {
    let denom = c.fro_norm();
    let mut residual = c.clone();
    let mut out = Vec::with_capacity(r);
    for k in 0..r {
        let (lambda, v) = power_iteration(&residual, power_iters, seed + k as u64);
        let mut approx = outer(&v, &v);
        approx.scale(lambda as f32);
        residual.blend(1.0, -1.0, &approx);
        out.push(if denom == 0.0 { 0.0 } else { residual.fro_norm() / denom });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_rank1_has_zero_error() {
        let v = vec![1.0f32, 2.0, -1.0, 0.5];
        let c = outer(&v, &v);
        let err = optimal_rank1_error(&c, 100, 3);
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn identity_has_high_rank1_error() {
        let c = Matrix::identity(16);
        let err = optimal_rank1_error(&c, 100, 3);
        // Best rank-1 of I_n removes 1/n of the mass: err = sqrt(1-1/n).
        let expect = (1.0 - 1.0 / 16.0f64).sqrt();
        assert!((err - expect).abs() < 1e-3, "err={err}, expect={expect}");
    }

    #[test]
    fn mean_rank1_error_zero_for_constant_samples() {
        // All columns equal x̄ ⇒ C = x̄x̄ᵀ exactly.
        let d = 6;
        let b = 10;
        let mut x = Matrix::zeros(d, b);
        for i in 0..d {
            for j in 0..b {
                x[(i, j)] = (i as f32) - 2.0;
            }
        }
        assert!(mean_rank1_error(&x) < 1e-5);
    }

    #[test]
    fn rank_r_errors_decrease() {
        let mut rng = Rng::new(55);
        let x = Matrix::randn(12, 8, 1.0, &mut rng);
        let c = covariance(&x);
        let errs = rank_r_errors(&c, 5, 100, 1);
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{errs:?}");
        }
        // C has rank ≤ 8, so by r=5 error should be well below rank-1 error.
        assert!(errs[4] < errs[0]);
    }

    #[test]
    fn top_eig_mass_of_rank1_is_one() {
        let v = vec![1.0f32, -1.0, 2.0];
        let c = outer(&v, &v);
        assert!((top_eig_mass(&c) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn covariance_shape_and_symmetry() {
        let mut rng = Rng::new(56);
        let x = Matrix::randn(9, 4, 1.0, &mut rng);
        let c = covariance(&x);
        assert_eq!(c.rows(), 9);
        assert!(c.is_symmetric(1e-5));
    }
}
