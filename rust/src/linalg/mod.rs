//! Dense linear algebra substrate.
//!
//! Everything the optimizer family needs, implemented from scratch (the
//! offline crate set has no BLAS/ndarray): a row-major `f32` [`Matrix`],
//! cache-blocked matmul, Cholesky factorization/solve/inverse, a Jacobi
//! eigensolver for symmetric matrices, power-iteration rank-1 approximation
//! (Figures 5/10), Gauss–Jordan inversion (SNGD kernels), and bf16/f16
//! software floats (MKOR's half-precision communication, Lemma 3.2).

pub mod cholesky;
pub mod eigen;
pub mod engine;
pub mod half;
pub mod inverse;
pub mod lowrank;
pub mod matrix;
pub mod ops;

pub use matrix::{Matrix, MatrixView};
