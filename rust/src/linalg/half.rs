//! Software half-precision floats: IEEE binary16 (`f16`) and bfloat16.
//!
//! MKOR's communication contribution includes synchronizing the rank-1
//! vectors in half precision (Table 1's "divide by 2"); the collective layer
//! quantizes through this module, and the Lemma 3.2 property test bounds the
//! end-to-end quantization error of the SM update.

/// Encode an `f32` as IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        let m = if mant != 0 { 0x200 } else { 0 };
        return sign | 0x7C00 | m;
    }
    // Re-bias: f32 bias 127 -> f16 bias 15.
    let new_exp = exp - 127 + 15;
    if new_exp >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if new_exp <= 0 {
        // Subnormal or zero.
        if new_exp < -10 {
            return sign; // underflow to zero
        }
        let full_mant = mant | 0x80_0000;
        let shift = (14 - new_exp) as u32;
        let halfway = 1u32 << (shift - 1);
        let mut half_mant = full_mant >> shift;
        let rem = full_mant & ((1 << shift) - 1);
        if rem > halfway || (rem == halfway && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }
    // Normal: round mantissa 23 -> 10 bits, RNE.
    let mut half_mant = (mant >> 13) as u16;
    let rem = mant & 0x1FFF;
    let mut half_exp = new_exp as u16;
    if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
        half_mant += 1;
        if half_mant == 0x400 {
            half_mant = 0;
            half_exp += 1;
            if half_exp >= 0x1F {
                return sign | 0x7C00;
            }
        }
    }
    sign | (half_exp << 10) | half_mant
}

/// Decode IEEE binary16 bits into `f32`.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: value = mant · 2⁻²⁴. Normalize via the position p
            // of the highest set bit: value = 1.frac · 2^(p−24).
            let p = 31 - mant.leading_zeros(); // 0..=9
            let frac = (mant ^ (1 << p)) << (23 - p);
            let new_exp = p + 103; // (p − 24) + 127
            sign | (new_exp << 23) | frac
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Encode an `f32` as bfloat16 bits (truncate-with-RNE of the top 16 bits).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet the NaN
    }
    let round_bit = 0x8000u32;
    let lsb = (bits >> 16) & 1;
    let rem = bits & 0xFFFF;
    let mut hi = bits >> 16;
    if rem > round_bit || (rem == round_bit && lsb == 1) {
        hi += 1;
    }
    hi as u16
}

/// Decode bfloat16 bits into `f32`.
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Quantize a slice into a preallocated bf16 wire buffer (no allocation:
/// the collective layer reuses one scratch buffer across ring steps).
pub fn quantize_bf16_into(xs: &[f32], wire: &mut [u16]) {
    assert_eq!(xs.len(), wire.len());
    for (h, &x) in wire.iter_mut().zip(xs) {
        *h = f32_to_bf16_bits(x);
    }
}

/// Fused receive-and-accumulate: `dst[i] += decode(wire[i])` in fp32.
/// This is the reduce-scatter receiver's whole job — no intermediate f32
/// buffer is materialized between the wire and the accumulator.
pub fn accumulate_bf16_wire(wire: &[u16], dst: &mut [f32]) {
    assert_eq!(wire.len(), dst.len());
    for (d, &h) in dst.iter_mut().zip(wire) {
        *d += bf16_bits_to_f32(h);
    }
}

/// Fused receive-and-store: `dst[i] = decode(wire[i])` (the all-gather
/// receiver's job), again with no intermediate f32 buffer.
pub fn write_bf16_wire(wire: &[u16], dst: &mut [f32]) {
    assert_eq!(wire.len(), dst.len());
    for (d, &h) in dst.iter_mut().zip(wire) {
        *d = bf16_bits_to_f32(h);
    }
}

/// Quantization formats the collectives can use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HalfKind {
    /// IEEE binary16: 10-bit mantissa, narrow range (±65504).
    F16,
    /// bfloat16: 7-bit mantissa, f32 range. MKOR's default — factors and
    /// gradients can exceed f16 range early in training.
    Bf16,
}

/// Quantize a slice to 16-bit words.
pub fn quantize(xs: &[f32], kind: HalfKind) -> Vec<u16> {
    match kind {
        HalfKind::F16 => xs.iter().map(|&x| f32_to_f16_bits(x)).collect(),
        HalfKind::Bf16 => xs.iter().map(|&x| f32_to_bf16_bits(x)).collect(),
    }
}

/// Dequantize 16-bit words back to `f32`.
pub fn dequantize(hs: &[u16], kind: HalfKind) -> Vec<f32> {
    match kind {
        HalfKind::F16 => hs.iter().map(|&h| f16_bits_to_f32(h)).collect(),
        HalfKind::Bf16 => hs.iter().map(|&h| bf16_bits_to_f32(h)).collect(),
    }
}

/// Round-trip a slice through 16-bit (what a quantized all-reduce does to
/// the payload). Returns the dequantized values.
pub fn roundtrip(xs: &[f32], kind: HalfKind) -> Vec<f32> {
    dequantize(&quantize(xs, kind), kind)
}

/// Max relative quantization step for a format: 2^-(mantissa_bits+1).
pub fn unit_roundoff(kind: HalfKind) -> f64 {
    match kind {
        HalfKind::F16 => (2.0f64).powi(-11),
        HalfKind::Bf16 => (2.0f64).powi(-8),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -0.25] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "{v}");
        }
    }

    #[test]
    fn f16_known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00); // overflow -> +inf
        assert!(f16_bits_to_f32(0x7C00).is_infinite());
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 5.9604645e-8f32; // smallest f16 subnormal
        let rt = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((rt - tiny).abs() / tiny < 1e-3);
        // Deep underflow goes to zero.
        assert_eq!(f32_to_f16_bits(1e-10), 0);
    }

    #[test]
    fn bf16_roundtrip_error_bounded() {
        let u = unit_roundoff(HalfKind::Bf16);
        let mut x = -3.0f32;
        while x < 3.0 {
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(x));
            if x != 0.0 {
                assert!(
                    ((rt - x) as f64 / x as f64).abs() <= u,
                    "x={x} rt={rt}"
                );
            }
            x += 0.00137;
        }
    }

    #[test]
    fn bf16_preserves_f32_range() {
        let big = 1e30f32;
        let rt = bf16_bits_to_f32(f32_to_bf16_bits(big));
        assert!((rt - big).abs() / big < 0.01);
    }

    #[test]
    fn bf16_nan_stays_nan() {
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn quantize_dequantize_slices() {
        let xs = [1.0f32, -2.5, 0.125, 100.0];
        for kind in [HalfKind::F16, HalfKind::Bf16] {
            let rt = roundtrip(&xs, kind);
            for (a, b) in xs.iter().zip(&rt) {
                assert!((a - b).abs() / a.abs() < 0.01, "{kind:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fused_wire_paths_match_roundtrip() {
        let xs = [1.0f32, -2.5, 0.125, 100.0, 0.0, -0.0078];
        let mut wire = vec![0u16; xs.len()];
        quantize_bf16_into(&xs, &mut wire);
        assert_eq!(wire, quantize(&xs, HalfKind::Bf16), "same wire bits");

        let mut acc = [10.0f32; 6];
        accumulate_bf16_wire(&wire, &mut acc);
        let mut store = [f32::NAN; 6];
        write_bf16_wire(&wire, &mut store);
        let rt = roundtrip(&xs, HalfKind::Bf16);
        for i in 0..xs.len() {
            assert_eq!(acc[i].to_bits(), (10.0 + rt[i]).to_bits(), "acc[{i}]");
            assert_eq!(store[i].to_bits(), rt[i].to_bits(), "store[{i}]");
        }
    }

    #[test]
    fn f16_rne_ties_to_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 in f16:
        // RNE keeps the even mantissa (1.0).
        let x = 1.0 + (2.0f32).powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C00);
    }
}
