//! Matrix/vector kernels: matmul (plain and transposed variants), matvec,
//! outer products, and the fused rank-1 symmetric update at the heart of
//! MKOR's Sherman–Morrison step.
//!
//! These are the L3 hot paths: the preconditioning step (Equation 2) is two
//! matmuls, and the SM factor update (Equations 5/6) is one matvec + one
//! scaled outer product.
//!
//! Since the engine landed, the entry points here are **thin dispatchers**:
//! above a size threshold they hand the work to the parallel tiled engine
//! ([`crate::linalg::engine`]); below it they run the serial fallbacks
//! (exposed as `*_serial` for baselines and parity tests). The dispatch
//! decision is a pure function of the problem size — never the thread
//! count — and every engine kernel is bitwise deterministic at any thread
//! count, so results cannot change with `--threads`. Every optimizer gets
//! the speedup with zero call-site churn.
//!
//! §Perf note, still binding: **no data-dependent zero-skip branches** in
//! any inner loop (serial or packed). Skipping `x == 0.0` blocks
//! vectorization and was measured at a 1.3–3× slowdown; padded/zero lanes
//! multiply through instead.

use super::{engine, Matrix};

/// Tile edge for the serial blocked matmul. Swept in the §Perf pass
/// (32/64/128): 128 wins slightly at d≤256 and ties above, and keeps three
/// f32 tiles ≈192KB — within this host's L2. See EXPERIMENTS.md §Perf.
const BLOCK: usize = 128;

/// Column unroll for the serial `matmul_nt` path: four dot-product
/// accumulators share one streaming pass over A's row.
const NT_JB: usize = 4;

/// `m·k·n` for the engine-vs-serial GEMM decision (size only, see module
/// docs; saturating so absurd shapes still dispatch rather than overflow).
fn gemm_work(m: usize, k: usize, n: usize) -> usize {
    m.saturating_mul(k).saturating_mul(n)
}

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` writing into a preallocated output (hot-loop variant; the
/// coordinator reuses buffers to keep allocation out of the step path).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    if gemm_work(a.rows(), a.cols(), b.cols()) >= engine::GEMM_PAR_MIN_WORK {
        engine::gemm_into(a.view(), b.view(), c, engine::threads());
    } else {
        matmul_into_serial(a, b, c);
    }
}

/// Serial blocked `C = A · B` (the sub-threshold fallback, and the perf
/// suite's single-thread baseline).
pub fn matmul_into_serial(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    c.data_mut().fill(0.0);
    // i-k-j loop with blocking over all three dims: the inner j loop is a
    // contiguous FMA over C's row and B's row, which LLVM vectorizes.
    for ii in (0..m).step_by(BLOCK) {
        let i_end = (ii + BLOCK).min(m);
        for kk in (0..k).step_by(BLOCK) {
            let k_end = (kk + BLOCK).min(k);
            for jj in (0..n).step_by(BLOCK) {
                let j_end = (jj + BLOCK).min(n);
                for i in ii..i_end {
                    // 2-way k-unroll: two broadcast FMAs per pass over C's
                    // row keeps more of the loop in registers.
                    let mut p = kk;
                    while p + 1 < k_end {
                        let aip0 = a[(i, p)];
                        let aip1 = a[(i, p + 1)];
                        let (b0, b1) = {
                            let (lo, hi) = b.data().split_at((p + 1) * n);
                            (&lo[p * n + jj..p * n + j_end], &hi[jj..j_end])
                        };
                        let crow = &mut c.row_mut(i)[jj..j_end];
                        for ((cv, &bv0), &bv1) in crow.iter_mut().zip(b0).zip(b1) {
                            *cv += aip0 * bv0 + aip1 * bv1;
                        }
                        p += 2;
                    }
                    if p < k_end {
                        let aip = a[(i, p)];
                        let brow = &b.row(p)[jj..j_end];
                        let crow = &mut c.row_mut(i)[jj..j_end];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aip * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = A · Bᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let mut c = Matrix::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into a preallocated output.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.rows());
    if gemm_work(a.rows(), a.cols(), b.rows()) >= engine::GEMM_PAR_MIN_WORK {
        // Bᵀ is just B with swapped strides; the engine packs through it.
        engine::gemm_into(a.view(), b.t_view(), c, engine::threads());
    } else {
        matmul_nt_into_serial(a, b, c);
    }
}

/// Serial `C = A · Bᵀ`: both operands stream row-contiguous, so this is a
/// bank of dot products — unrolled `NT_JB` wide so four accumulators share
/// each pass over A's row (the fully-naive one-dot-at-a-time loop re-read
/// A's row per output column).
pub fn matmul_nt_into_serial(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    for i in 0..m {
        let arow = a.row(i);
        let mut j = 0;
        while j + NT_JB <= n {
            let (b0, b1) = (b.row(j), b.row(j + 1));
            let (b2, b3) = (b.row(j + 2), b.row(j + 3));
            let (mut s0, mut s1) = (0.0f32, 0.0f32);
            let (mut s2, mut s3) = (0.0f32, 0.0f32);
            for p in 0..k {
                let av = arow[p];
                s0 += av * b0[p];
                s1 += av * b1[p];
                s2 += av * b2[p];
                s3 += av * b3[p];
            }
            let crow = &mut c.row_mut(i)[j..j + NT_JB];
            crow.copy_from_slice(&[s0, s1, s2, s3]);
            j += NT_JB;
        }
        while j < n {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            c[(i, j)] = acc;
            j += 1;
        }
    }
}

/// `C = Aᵀ · B` without materializing the transpose.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    let mut c = Matrix::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` into a preallocated output.
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    assert_eq!(c.rows(), a.cols());
    assert_eq!(c.cols(), b.cols());
    if gemm_work(a.cols(), a.rows(), b.cols()) >= engine::GEMM_PAR_MIN_WORK {
        engine::gemm_into(a.t_view(), b.view(), c, engine::threads());
    } else {
        matmul_tn_into_serial(a, b, c);
    }
}

/// Serial `C = Aᵀ · B` (p-outer so both row reads are contiguous). No
/// zero-skip on `aip` — see the §Perf note in the module docs.
pub fn matmul_tn_into_serial(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    assert_eq!(c.rows(), a.cols());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    c.data_mut().fill(0.0);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let aip = arow[i];
            let crow = &mut c.row_mut(i)[..n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
}

/// `y = A · x`.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), x.len(), "matvec shape mismatch");
    let mut y = vec![0.0; a.rows()];
    matvec_into(a, x, &mut y);
    y
}

/// `y = A · x` into a preallocated output. The engine's row-partitioned
/// variant uses the identical per-row loop, so this is bitwise equal to
/// [`matvec_into_serial`] on every path.
pub fn matvec_into(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    if a.rows().saturating_mul(a.cols()) >= engine::SLICE_PAR_MIN_ELEMS {
        engine::matvec_into(a, x, y, engine::threads());
    } else {
        matvec_into_serial(a, x, y);
    }
}

/// Serial `y = A · x`.
pub fn matvec_into_serial(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for (i, yi) in y.iter_mut().enumerate() {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for (&r, &v) in row.iter().zip(x) {
            acc += r * v;
        }
        *yi = acc;
    }
}

/// `y = Aᵀ · x`. No zero-skip on `x[i]` — see the §Perf note in the module
/// docs; engine and serial paths are bitwise equal.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows(), x.len(), "matvec_t shape mismatch");
    let mut y = vec![0.0f32; a.cols()];
    if a.rows().saturating_mul(a.cols()) >= engine::SLICE_PAR_MIN_ELEMS {
        engine::matvec_t_into(a, x, &mut y, engine::threads());
    } else {
        matvec_t_into_serial(a, x, &mut y);
    }
    y
}

/// Serial `y = Aᵀ · x` (row-outer so A streams contiguously).
pub fn matvec_t_into_serial(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    y.fill(0.0);
    for i in 0..a.rows() {
        let xi = x[i];
        let row = a.row(i);
        for (yj, &r) in y.iter_mut().zip(row) {
            *yj += xi * r;
        }
    }
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// Outer product `x yᵀ`.
pub fn outer(x: &[f32], y: &[f32]) -> Matrix {
    let mut m = Matrix::zeros(x.len(), y.len());
    for (i, &xi) in x.iter().enumerate() {
        let row = m.row_mut(i);
        for (rv, &yj) in row.iter_mut().zip(y) {
            *rv = xi * yj;
        }
    }
    m
}

/// Fused symmetric rank-1 update `A = alpha*A + beta * u uᵀ`.
///
/// This is the SM-update hot loop (lines 7–8 of Algorithm 1 after the matvec
/// `u = J⁻¹g` is computed): one pass over A, no temporary d×d allocation.
/// Engine and serial paths are bitwise equal.
pub fn scaled_rank1_update(a: &mut Matrix, alpha: f32, beta: f32, u: &[f32]) {
    assert!(a.is_square());
    assert_eq!(a.rows(), u.len());
    let n = u.len();
    if n.saturating_mul(n) >= engine::SLICE_PAR_MIN_ELEMS {
        engine::scaled_rank1_update(a, alpha, beta, u, engine::threads());
    } else {
        scaled_rank1_update_serial(a, alpha, beta, u);
    }
}

/// Serial fused rank-1 update.
pub fn scaled_rank1_update_serial(a: &mut Matrix, alpha: f32, beta: f32, u: &[f32]) {
    assert!(a.is_square());
    assert_eq!(a.rows(), u.len());
    let n = u.len();
    for i in 0..n {
        let bu = beta * u[i];
        let row = a.row_mut(i);
        for (j, rv) in row.iter_mut().enumerate().take(n) {
            *rv = alpha * *rv + bu * u[j];
        }
    }
}

/// Mean of the columns of `A` (d×b → d) — the paper's rank-1 approximation
/// of a batch (lines 2–3 of Algorithm 1). Engine and serial paths are
/// bitwise equal.
pub fn col_mean(a: &Matrix) -> Vec<f32> {
    let (d, b) = (a.rows(), a.cols());
    assert!(b > 0);
    let mut out = vec![0.0f32; d];
    if d.saturating_mul(b) >= engine::SLICE_PAR_MIN_ELEMS {
        engine::col_mean_into(a, &mut out, engine::threads());
    } else {
        col_mean_into_serial(a, &mut out);
    }
    out
}

/// Serial column mean (f64 row accumulation).
pub fn col_mean_into_serial(a: &Matrix, out: &mut [f32]) {
    let (d, b) = (a.rows(), a.cols());
    assert!(b > 0);
    assert_eq!(out.len(), d);
    for (i, o) in out.iter_mut().enumerate() {
        let row = a.row(i);
        *o = (row.iter().map(|&x| x as f64).sum::<f64>() / b as f64) as f32;
    }
}

/// Mean of the rows of `A` (b×d → d).
pub fn row_mean(a: &Matrix) -> Vec<f32> {
    let (b, d) = (a.rows(), a.cols());
    assert!(b > 0);
    let mut acc = vec![0.0f64; d];
    for i in 0..b {
        for (a_ij, s) in a.row(i).iter().zip(acc.iter_mut()) {
            *s += *a_ij as f64;
        }
    }
    acc.iter().map(|&s| (s / b as f64) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for p in 0..a.cols() {
                    s += a[(i, p)] as f64 * b[(p, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (70, 70, 70), (128, 64, 130)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_dispatches_to_engine_above_threshold() {
        // 160³ = 4.1M ≥ GEMM_PAR_MIN_WORK: exercises the engine path
        // through the public entry point (and the serial baseline agrees).
        let mut rng = Rng::new(9);
        let a = Matrix::randn(160, 160, 1.0, &mut rng);
        let b = Matrix::randn(160, 160, 1.0, &mut rng);
        assert!(160 * 160 * 160 >= engine::GEMM_PAR_MIN_WORK);
        let c = matmul(&a, &b);
        let mut serial = Matrix::zeros(160, 160);
        matmul_into_serial(&a, &b, &mut serial);
        assert!(c.max_abs_diff(&serial) < 1e-2);
    }

    #[test]
    fn matmul_nt_tn_consistent() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(13, 7, 1.0, &mut rng);
        let b = Matrix::randn(11, 7, 1.0, &mut rng);
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-4);

        let d = Matrix::randn(7, 13, 1.0, &mut rng);
        let e = Matrix::randn(7, 5, 1.0, &mut rng);
        let f1 = matmul_tn(&d, &e);
        let f2 = matmul(&d.transpose(), &e);
        assert!(f1.max_abs_diff(&f2) < 1e-4);
    }

    #[test]
    fn zero_heavy_inputs_multiply_through() {
        // The zero-skip branches are gone; sparse-ish inputs must still be
        // exactly right (zeros contribute zero, not skipped bookkeeping).
        let mut rng = Rng::new(8);
        let mut a = Matrix::randn(9, 6, 1.0, &mut rng);
        let b = Matrix::randn(9, 5, 1.0, &mut rng);
        for i in 0..9 {
            for j in 0..6 {
                if (i + j) % 2 == 0 {
                    a[(i, j)] = 0.0;
                }
            }
        }
        let c = matmul_tn(&a, &b);
        assert!(c.max_abs_diff(&naive_matmul(&a.transpose(), &b)) < 1e-4);

        let mut x = vec![0.0f32; 9];
        for (i, v) in x.iter_mut().enumerate() {
            *v = if i % 3 == 0 { 0.0 } else { i as f32 };
        }
        let y = matvec_t(&a, &x);
        let ym = matmul_tn(&a, &Matrix::from_vec(9, 1, x.clone()));
        for j in 0..6 {
            assert!((y[j] - ym[(j, 0)]).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let x: Vec<f32> = (0..14).map(|_| rng.gaussian_f32()).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(14, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-4);
        }
        // transposed variant
        let z = matvec_t(&a, &y);
        let zm = matmul_tn(&a, &Matrix::from_vec(9, 1, y.clone()));
        for j in 0..14 {
            assert!((z[j] - zm[(j, 0)]).abs() < 1e-3);
        }
    }

    #[test]
    fn outer_and_rank1_update() {
        let mut rng = Rng::new(4);
        let n = 12;
        let mut a = Matrix::rand_spd(n, 0.1, &mut rng);
        let u: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut want = a.clone();
        want.scale(0.9);
        let mut o = outer(&u, &u);
        o.scale(0.2);
        for i in 0..n {
            for j in 0..n {
                want[(i, j)] += o[(i, j)];
            }
        }
        scaled_rank1_update(&mut a, 0.9, 0.2, &u);
        assert!(a.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn means() {
        let a = Matrix::from_rows(&[&[1.0, 3.0], &[2.0, 4.0]]);
        assert_eq!(col_mean(&a), vec![2.0, 3.0]);
        assert_eq!(row_mean(&a), vec![1.5, 3.5]);
    }

    #[test]
    fn dot_norm_axpy() {
        let x = [1.0f32, 2.0, 2.0];
        let mut y = [1.0f32, 1.0, 1.0];
        assert!((norm2(&x) - 3.0).abs() < 1e-9);
        assert!((dot(&x, &y) - 5.0).abs() < 1e-9);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 5.0]);
    }
}
