//! Matrix/vector kernels: cache-blocked matmul (plain and transposed
//! variants), matvec, outer products, and the fused rank-1 symmetric update
//! at the heart of MKOR's Sherman–Morrison step.
//!
//! These are the L3 hot paths: the preconditioning step (Equation 2) is two
//! matmuls, and the SM factor update (Equations 5/6) is one matvec + one
//! scaled outer product. The matmul is written j-innermost so the compiler
//! auto-vectorizes the contiguous row updates; `matmul_nt` packs nothing and
//! is used when the right operand is logically transposed.

use super::Matrix;

/// Tile edge for the blocked matmul. Swept in the §Perf pass (32/64/128):
/// 128 wins slightly at d≤256 and ties above, and keeps three f32 tiles
/// ≈192KB — within this host's L2. See EXPERIMENTS.md §Perf.
const BLOCK: usize = 128;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` writing into a preallocated output (hot-loop variant; the
/// coordinator reuses buffers to keep allocation out of the step path).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    c.data_mut().fill(0.0);
    // i-k-j loop with blocking over all three dims: the inner j loop is a
    // contiguous FMA over C's row and B's row, which LLVM vectorizes.
    for ii in (0..m).step_by(BLOCK) {
        let i_end = (ii + BLOCK).min(m);
        for kk in (0..k).step_by(BLOCK) {
            let k_end = (kk + BLOCK).min(k);
            for jj in (0..n).step_by(BLOCK) {
                let j_end = (jj + BLOCK).min(n);
                for i in ii..i_end {
                    // 2-way k-unroll: two broadcast FMAs per pass over C's
                    // row keeps more of the loop in registers. No zero-skip
                    // branch — it blocks vectorization (§Perf: removing it
                    // was a 1.3-3x win).
                    let mut p = kk;
                    while p + 1 < k_end {
                        let aip0 = a[(i, p)];
                        let aip1 = a[(i, p + 1)];
                        let (b0, b1) = {
                            let (lo, hi) = b.data().split_at((p + 1) * n);
                            (&lo[p * n + jj..p * n + j_end], &hi[jj..j_end])
                        };
                        let crow = &mut c.row_mut(i)[jj..j_end];
                        for ((cv, &bv0), &bv1) in crow.iter_mut().zip(b0).zip(b1) {
                            *cv += aip0 * bv0 + aip1 * bv1;
                        }
                        p += 2;
                    }
                    if p < k_end {
                        let aip = a[(i, p)];
                        let brow = &b.row(p)[jj..j_end];
                        let crow = &mut c.row_mut(i)[jj..j_end];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aip * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = A · Bᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            c[(i, j)] = acc;
        }
    }
    c
}

/// `C = Aᵀ · B` without materializing the transpose.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// `y = A · x`.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), x.len(), "matvec shape mismatch");
    let mut y = vec![0.0; a.rows()];
    matvec_into(a, x, &mut y);
    y
}

/// `y = A · x` into a preallocated output.
pub fn matvec_into(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for (i, yi) in y.iter_mut().enumerate() {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for (&r, &v) in row.iter().zip(x) {
            acc += r * v;
        }
        *yi = acc;
    }
}

/// `y = Aᵀ · x`.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows(), x.len(), "matvec_t shape mismatch");
    let mut y = vec![0.0f32; a.cols()];
    for i in 0..a.rows() {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (yj, &r) in y.iter_mut().zip(row) {
            *yj += xi * r;
        }
    }
    y
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// Outer product `x yᵀ`.
pub fn outer(x: &[f32], y: &[f32]) -> Matrix {
    let mut m = Matrix::zeros(x.len(), y.len());
    for (i, &xi) in x.iter().enumerate() {
        let row = m.row_mut(i);
        for (rv, &yj) in row.iter_mut().zip(y) {
            *rv = xi * yj;
        }
    }
    m
}

/// Fused symmetric rank-1 update `A = alpha*A + beta * u uᵀ`.
///
/// This is the SM-update hot loop (lines 7–8 of Algorithm 1 after the matvec
/// `u = J⁻¹g` is computed): one pass over A, no temporary d×d allocation.
pub fn scaled_rank1_update(a: &mut Matrix, alpha: f32, beta: f32, u: &[f32]) {
    assert!(a.is_square());
    assert_eq!(a.rows(), u.len());
    let n = u.len();
    for i in 0..n {
        let bu = beta * u[i];
        let row = a.row_mut(i);
        for (j, rv) in row.iter_mut().enumerate().take(n) {
            *rv = alpha * *rv + bu * u[j];
        }
    }
}

/// Mean of the columns of `A` (d×b → d) — the paper's rank-1 approximation
/// of a batch (lines 2–3 of Algorithm 1).
pub fn col_mean(a: &Matrix) -> Vec<f32> {
    let (d, b) = (a.rows(), a.cols());
    assert!(b > 0);
    let mut out = vec![0.0f32; d];
    for i in 0..d {
        let row = a.row(i);
        out[i] = (row.iter().map(|&x| x as f64).sum::<f64>() / b as f64) as f32;
    }
    out
}

/// Mean of the rows of `A` (b×d → d).
pub fn row_mean(a: &Matrix) -> Vec<f32> {
    let (b, d) = (a.rows(), a.cols());
    assert!(b > 0);
    let mut acc = vec![0.0f64; d];
    for i in 0..b {
        for (a_ij, s) in a.row(i).iter().zip(acc.iter_mut()) {
            *s += *a_ij as f64;
        }
    }
    acc.iter().map(|&s| (s / b as f64) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for p in 0..a.cols() {
                    s += a[(i, p)] as f64 * b[(p, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (70, 70, 70), (128, 64, 130)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_nt_tn_consistent() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(13, 7, 1.0, &mut rng);
        let b = Matrix::randn(11, 7, 1.0, &mut rng);
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-4);

        let d = Matrix::randn(7, 13, 1.0, &mut rng);
        let e = Matrix::randn(7, 5, 1.0, &mut rng);
        let f1 = matmul_tn(&d, &e);
        let f2 = matmul(&d.transpose(), &e);
        assert!(f1.max_abs_diff(&f2) < 1e-4);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let x: Vec<f32> = (0..14).map(|_| rng.gaussian_f32()).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(14, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-4);
        }
        // transposed variant
        let z = matvec_t(&a, &y);
        let zm = matmul_tn(&a, &Matrix::from_vec(9, 1, y.clone()));
        for j in 0..14 {
            assert!((z[j] - zm[(j, 0)]).abs() < 1e-3);
        }
    }

    #[test]
    fn outer_and_rank1_update() {
        let mut rng = Rng::new(4);
        let n = 12;
        let mut a = Matrix::rand_spd(n, 0.1, &mut rng);
        let u: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut want = a.clone();
        want.scale(0.9);
        let mut o = outer(&u, &u);
        o.scale(0.2);
        for i in 0..n {
            for j in 0..n {
                want[(i, j)] += o[(i, j)];
            }
        }
        scaled_rank1_update(&mut a, 0.9, 0.2, &u);
        assert!(a.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn means() {
        let a = Matrix::from_rows(&[&[1.0, 3.0], &[2.0, 4.0]]);
        assert_eq!(col_mean(&a), vec![2.0, 3.0]);
        assert_eq!(row_mean(&a), vec![1.5, 3.5]);
    }

    #[test]
    fn dot_norm_axpy() {
        let x = [1.0f32, 2.0, 2.0];
        let mut y = [1.0f32, 1.0, 1.0];
        assert!((norm2(&x) - 3.0).abs() < 1e-9);
        assert!((dot(&x, &y) - 5.0).abs() < 1e-9);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 5.0]);
    }
}
