//! Symmetric eigensolver (cyclic Jacobi) and power iteration.
//!
//! The Jacobi solver backs (a) the KFAC/KAISA baseline's eigendecomposition
//! path (the original KFAC implementation masks near-zero eigenvalues), and
//! (b) the Figure 8 condition-number experiment. Power iteration gives the
//! top eigenpair cheaply for the rank-1 approximation-error experiments
//! (Figures 5/10) where a full decomposition would dwarf the training run.

use super::ops::{dot, matvec, norm2};
use super::Matrix;
use crate::util::Rng;

/// Eigendecomposition of a symmetric matrix: `A = V diag(w) Vᵀ`.
#[derive(Clone, Debug)]
pub struct EigenDecomposition {
    /// Eigenvalues in descending order.
    pub values: Vec<f64>,
    /// Column i of `vectors` is the eigenvector for `values[i]`.
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigendecomposition (f64 internal).
///
/// Complexity O(d³) per sweep; fine for the ≤1024-dim factors these
/// experiments examine. `tol` bounds the off-diagonal Frobenius mass.
pub fn jacobi_eigen(a: &Matrix, tol: f64, max_sweeps: usize) -> EigenDecomposition {
    assert!(a.is_square(), "eigen of non-square matrix");
    let n = a.rows();
    let mut m = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            // Symmetrize on input to tolerate f32 asymmetry.
            m[i * n + j] = 0.5 * (a[(i, j)] as f64 + a[(j, i)] as f64);
        }
    }
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let off = |m: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    s += m[i * n + j] * m[i * n + j];
                }
            }
        }
        s.sqrt()
    };

    for _ in 0..max_sweeps {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p and q.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract, sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vectors[(r, new_col)] = v[r * n + old_col] as f32;
        }
    }
    EigenDecomposition { values, vectors }
}

/// Condition number from the eigenvalues of a symmetric PSD matrix
/// (|λ|max / |λ|min). Returns `f64::INFINITY` for singular matrices.
pub fn condition_number(values: &[f64]) -> f64 {
    let max = values.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    let min = values.iter().fold(f64::INFINITY, |m, &x| m.min(x.abs()));
    if min <= 0.0 {
        f64::INFINITY
    } else {
        max / min
    }
}

/// Top eigenpair of a symmetric PSD matrix via power iteration.
///
/// Returns `(lambda, v)` with `‖v‖ = 1`. This is what the optimal rank-1
/// approximation of a covariance matrix is built from (Eckart–Young: the
/// best rank-1 approximation of symmetric PSD `C` is `λ₁ v₁ v₁ᵀ`).
pub fn power_iteration(a: &Matrix, iters: usize, seed: u64) -> (f64, Vec<f32>) {
    assert!(a.is_square());
    let n = a.rows();
    let mut rng = Rng::new(seed);
    let mut v: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let norm = norm2(&v).max(1e-30);
    for x in v.iter_mut() {
        *x = (*x as f64 / norm) as f32;
    }
    let mut lambda = 0.0f64;
    for _ in 0..iters {
        let w = matvec(a, &v);
        let wnorm = norm2(&w);
        if wnorm < 1e-30 {
            return (0.0, v); // zero matrix
        }
        for (vi, &wi) in v.iter_mut().zip(&w) {
            *vi = (wi as f64 / wnorm) as f32;
        }
        lambda = dot(&v, &matvec(a, &v));
    }
    (lambda, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::matmul;

    #[test]
    fn eigen_of_diagonal() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let e = jacobi_eigen(&a, 1e-12, 50);
        assert!((e.values[0] - 3.0).abs() < 1e-9);
        assert!((e.values[1] - 2.0).abs() < 1e-9);
        assert!((e.values[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn eigen_reconstructs() {
        let mut rng = Rng::new(21);
        let a = Matrix::rand_spd(12, 0.2, &mut rng);
        let e = jacobi_eigen(&a, 1e-12, 100);
        // V diag(w) Vᵀ == A
        let mut d = Matrix::zeros(12, 12);
        for i in 0..12 {
            d[(i, i)] = e.values[i] as f32;
        }
        let rec = matmul(&matmul(&e.vectors, &d), &e.vectors.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-3);
        // Orthonormal V
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.max_abs_diff(&Matrix::identity(12)) < 1e-3);
    }

    #[test]
    fn eigen_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3 and 1
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = jacobi_eigen(&a, 1e-14, 50);
        assert!((e.values[0] - 3.0).abs() < 1e-9);
        assert!((e.values[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn condition_number_cases() {
        assert!((condition_number(&[4.0, 2.0, 1.0]) - 4.0).abs() < 1e-12);
        assert!(condition_number(&[1.0, 0.0]).is_infinite());
    }

    #[test]
    fn power_iteration_matches_jacobi() {
        let mut rng = Rng::new(33);
        let a = Matrix::rand_spd(20, 0.1, &mut rng);
        let e = jacobi_eigen(&a, 1e-12, 100);
        let (lam, v) = power_iteration(&a, 200, 7);
        assert!(
            (lam - e.values[0]).abs() / e.values[0] < 1e-4,
            "power {lam} vs jacobi {}",
            e.values[0]
        );
        // v is an eigenvector: Av ≈ λv
        let av = matvec(&a, &v);
        for i in 0..20 {
            assert!((av[i] as f64 - lam * v[i] as f64).abs() < 1e-2);
        }
    }
}
