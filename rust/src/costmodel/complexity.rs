//! Per-step FLOP / byte / memory accounting per optimizer (Table 1 made
//! concrete).
//!
//! All formulas are per *worker* per *step*, parameterized by the model's
//! layer shapes and the effective batch `b` (for transformers b is
//! batch×sequence-length — the scaling the paper's §1 argument hinges on).
//! Factor work is charged only on factor-update steps; amortized variants
//! divide by the inversion frequency `f`.

use crate::model::specs::ModelSpec;
use crate::model::LayerShape;

/// The optimizer families the cost model knows how to price.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptimizerKind {
    Mkor,
    MkorH,
    Kfac,
    Sngd,
    Eva,
    Sgd,
    Adam,
    Lamb,
}

impl OptimizerKind {
    pub fn parse(name: &str) -> Option<Self> {
        Some(match name {
            "mkor" => OptimizerKind::Mkor,
            "mkor-h" => OptimizerKind::MkorH,
            "kfac" | "kaisa" => OptimizerKind::Kfac,
            "sngd" | "hylo" => OptimizerKind::Sngd,
            "eva" => OptimizerKind::Eva,
            "sgd" => OptimizerKind::Sgd,
            "adam" => OptimizerKind::Adam,
            "lamb" => OptimizerKind::Lamb,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            OptimizerKind::Mkor => "MKOR",
            OptimizerKind::MkorH => "MKOR-H",
            OptimizerKind::Kfac => "KFAC (KAISA)",
            OptimizerKind::Sngd => "SNGD (HyLo)",
            OptimizerKind::Eva => "Eva",
            OptimizerKind::Sgd => "SGD (Momentum)",
            OptimizerKind::Adam => "ADAM",
            OptimizerKind::Lamb => "LAMB",
        }
    }

    pub fn is_second_order(&self) -> bool {
        matches!(
            self,
            OptimizerKind::Mkor
                | OptimizerKind::MkorH
                | OptimizerKind::Kfac
                | OptimizerKind::Sngd
                | OptimizerKind::Eva
        )
    }

    /// Asymptotic strings for the Table 1 printout.
    pub fn asymptotics(&self) -> (&'static str, &'static str, &'static str) {
        match self {
            OptimizerKind::Mkor | OptimizerKind::MkorH => {
                ("O(d^2 + bd)", "O(2d^2/2)", "O(2d/2)")
            }
            OptimizerKind::Kfac => ("O(d^3)", "O(4d^2)", "O(4d^2)"),
            OptimizerKind::Sngd => ("O(b^3)", "O(2bd + b^2)", "O(2bd + b^2)"),
            OptimizerKind::Eva => ("O(d^2 + bd)", "O(2d)", "O(2d)"),
            OptimizerKind::Sgd => ("-", "O(d^2)", "-"),
            OptimizerKind::Adam | OptimizerKind::Lamb => ("-", "O(d^2)", "-"),
        }
    }
}

/// FLOPs/bytes of one optimizer step over one model (per worker).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    /// Factor computation + inversion FLOPs on a factor-update step.
    pub factor_flops: f64,
    /// Preconditioning FLOPs (every step).
    pub precond_flops: f64,
    /// Weight-update FLOPs (every step).
    pub update_flops: f64,
    /// Second-order sync bytes on a factor-update step (excl. gradients).
    pub sync_bytes: f64,
    /// Gradient all-reduce payload bytes (all optimizers, every step).
    pub grad_bytes: f64,
    /// Optimizer state resident bytes.
    pub state_bytes: f64,
}

impl StepCost {
    /// Average per-step optimizer FLOPs with factor work amortized over
    /// the inversion frequency `f` (Figure 4a's x-axis).
    pub fn amortized_flops(&self, f: usize) -> f64 {
        self.factor_flops / f.max(1) as f64 + self.precond_flops + self.update_flops
    }

    /// Average per-step sync bytes amortized over `f`.
    pub fn amortized_sync_bytes(&self, f: usize) -> f64 {
        self.sync_bytes / f.max(1) as f64
    }
}

/// Layers wider than this are treated first-order by every second-order
/// optimizer (embedding/vocab projections): KAISA, HyLo and MKOR's
/// reference implementation all skip embeddings — a 30522² factor would be
/// larger than the model itself.
pub const SECOND_ORDER_DIM_CAP: usize = 8192;

fn per_layer(kind: OptimizerKind, s: &LayerShape, b: usize) -> StepCost {
    let din = s.d_in as f64;
    let dout = s.d_out as f64;
    let bf = b as f64;
    let params = din * dout;
    let precond_kron = 2.0 * (dout * dout * din + dout * din * din);
    // Embedding-scale layers fall back to the first-order backend
    // (momentum SGD) under every second-order method.
    if kind.is_second_order() && s.d_in.max(s.d_out) > SECOND_ORDER_DIM_CAP {
        return StepCost {
            update_flops: 2.0 * params,
            grad_bytes: 4.0 * params,
            state_bytes: 4.0 * params, // backend momentum
            ..Default::default()
        };
    }
    match kind {
        OptimizerKind::Mkor | OptimizerKind::MkorH => StepCost {
            // Rank-1 means (bd) + two matvecs + two rank-1 updates (2d²+2d² each).
            factor_flops: bf * (din + dout) + 4.0 * (din * din + dout * dout),
            precond_flops: precond_kron,
            update_flops: 2.0 * params,
            // Two rank-1 vectors in fp16 (Table 1's ÷2).
            sync_bytes: 2.0 * (din + dout),
            grad_bytes: 4.0 * params,
            // Two factor inverses in half precision (2 bytes/elem) + the
            // rank-1 vectors + the fp32 backend momentum.
            state_bytes: 2.0 * (din * din + dout * dout)
                + 2.0 * (din + dout)
                + 4.0 * params,
        },
        OptimizerKind::Kfac => StepCost {
            // Covariance updates 2b(d_in²+d_out²) + two d³ inversions.
            factor_flops: 2.0 * bf * (din * din + dout * dout)
                + 2.0 * (din * din * din + dout * dout * dout),
            precond_flops: precond_kron,
            update_flops: 2.0 * params,
            // Covariances + inverses, fp32 (Table 1's 4d²).
            sync_bytes: 2.0 * (din * din + dout * dout) * 4.0,
            grad_bytes: 4.0 * params,
            state_bytes: 2.0 * (din * din + dout * dout) * 4.0 + 4.0 * params,
        },
        OptimizerKind::Sngd => StepCost {
            // Kernel build 2b²(d_in+d_out) + b³ inversion (×2 for GJ).
            factor_flops: 2.0 * bf * bf * (din + dout) + 2.0 * bf * bf * bf,
            // SMW application: ~4·b·d_in·d_out + 2b².
            precond_flops: 4.0 * bf * din * dout + 2.0 * bf * bf,
            update_flops: 2.0 * params,
            sync_bytes: (bf * (din + dout) + bf * bf) * 4.0,
            grad_bytes: 4.0 * params,
            state_bytes: (bf * (din + dout) + bf * bf) * 4.0 + 4.0 * params,
        },
        OptimizerKind::Eva => StepCost {
            factor_flops: bf * (din + dout),
            // Four rank-1 SMW applications over the gradient.
            precond_flops: 6.0 * din * dout,
            update_flops: 2.0 * params,
            sync_bytes: (din + dout) * 4.0,
            grad_bytes: 4.0 * params,
            state_bytes: (din + dout) * 4.0 + 4.0 * params,
        },
        OptimizerKind::Sgd => StepCost {
            update_flops: 2.0 * params,
            grad_bytes: 4.0 * params,
            state_bytes: 4.0 * params,
            ..Default::default()
        },
        OptimizerKind::Adam | OptimizerKind::Lamb => StepCost {
            update_flops: 10.0 * params,
            grad_bytes: 4.0 * params,
            state_bytes: 8.0 * params,
            ..Default::default()
        },
    }
}

/// Sum the per-layer costs over a whole model spec.
pub fn model_step_cost(kind: OptimizerKind, spec: &ModelSpec) -> StepCost {
    let mut total = StepCost::default();
    for s in &spec.layers {
        let c = per_layer(kind, s, spec.effective_batch);
        total.factor_flops += c.factor_flops;
        total.precond_flops += c.precond_flops;
        total.update_flops += c.update_flops;
        total.sync_bytes += c.sync_bytes;
        total.grad_bytes += c.grad_bytes;
        total.state_bytes += c.state_bytes;
    }
    total
}

/// Forward+backward FLOPs for one step of a model (per worker): the
/// standard 6·params·batch estimate (2 forward + 4 backward).
pub fn fwd_bwd_flops(spec: &ModelSpec, samples_per_worker: usize) -> f64 {
    6.0 * spec.params() as f64 * samples_per_worker as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::specs;

    #[test]
    fn mkor_factor_cost_is_quadratic_kfac_cubic() {
        let small = LayerShape::new(256, 256);
        let large = LayerShape::new(1024, 1024);
        let m_small = per_layer(OptimizerKind::Mkor, &small, 128).factor_flops;
        let m_large = per_layer(OptimizerKind::Mkor, &large, 128).factor_flops;
        let k_small = per_layer(OptimizerKind::Kfac, &small, 128).factor_flops;
        let k_large = per_layer(OptimizerKind::Kfac, &large, 128).factor_flops;
        // 4× dim: quadratic ⇒ ~16×, cubic ⇒ ~64×.
        let m_ratio = m_large / m_small;
        let k_ratio = k_large / k_small;
        assert!(m_ratio > 12.0 && m_ratio < 20.0, "mkor ratio {m_ratio}");
        assert!(k_ratio > 40.0, "kfac ratio {k_ratio}");
    }

    #[test]
    fn sngd_cost_is_cubic_in_batch() {
        let s = LayerShape::new(512, 512);
        let c1 = per_layer(OptimizerKind::Sngd, &s, 512).factor_flops;
        let c2 = per_layer(OptimizerKind::Sngd, &s, 4096).factor_flops;
        // 8× batch: kernel build term is 64×, the b³ inversion 512× — the
        // blend must exceed quadratic scaling by a wide margin.
        assert!(c2 / c1 > 100.0, "ratio {}", c2 / c1);
    }

    #[test]
    fn mkor_sync_is_linear_and_smallest_of_second_order() {
        let spec = specs::bert_large();
        let mkor = model_step_cost(OptimizerKind::Mkor, &spec).sync_bytes;
        let kfac = model_step_cost(OptimizerKind::Kfac, &spec).sync_bytes;
        let sngd = model_step_cost(OptimizerKind::Sngd, &spec).sync_bytes;
        let eva = model_step_cost(OptimizerKind::Eva, &spec).sync_bytes;
        assert!(mkor < eva); // fp16 vs fp32 vectors
        assert!(eva < kfac);
        assert!(mkor < sngd);
        // Orders of magnitude, as the paper claims: d vs d².
        assert!(kfac / mkor > 100.0, "kfac/mkor = {}", kfac / mkor);
    }

    #[test]
    fn bert_memory_ranking_matches_table6() {
        // Table 6: MKOR 23.34 GB < KFAC 29.97 GB on BERT (total incl.
        // model+grads+activations; here we compare optimizer state only,
        // which must preserve the ordering MKOR < KFAC).
        let spec = specs::bert_large();
        let mkor = model_step_cost(OptimizerKind::Mkor, &spec).state_bytes;
        let kfac = model_step_cost(OptimizerKind::Kfac, &spec).state_bytes;
        let lamb = model_step_cost(OptimizerKind::Lamb, &spec).state_bytes;
        assert!(mkor < kfac);
        assert!(lamb < mkor, "lamb {lamb} vs mkor {mkor}"); // first-order cheapest
        assert!(kfac / mkor > 1.5 && kfac / mkor < 5.0, "{}", kfac / mkor);
    }

    #[test]
    fn amortization_divides_factor_work() {
        let spec = specs::resnet50();
        let c = model_step_cost(OptimizerKind::Kfac, &spec);
        let f1 = c.amortized_flops(1);
        let f100 = c.amortized_flops(100);
        assert!(f1 > 8.0 * f100, "f1={f1} f100={f100}");
        // MKOR barely cares about f (Figure 4a's flat curve).
        let m = model_step_cost(OptimizerKind::Mkor, &spec);
        let m1 = m.amortized_flops(1);
        let m100 = m.amortized_flops(100);
        assert!(m1 < 2.0 * m100, "m1={m1} m100={m100}");
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(OptimizerKind::parse("kaisa"), Some(OptimizerKind::Kfac));
        assert_eq!(OptimizerKind::parse("hylo"), Some(OptimizerKind::Sngd));
        assert!(OptimizerKind::parse("nope").is_none());
        assert!(OptimizerKind::Mkor.is_second_order());
        assert!(!OptimizerKind::Lamb.is_second_order());
    }

    #[test]
    fn fwd_bwd_flops_scale() {
        let spec = specs::bert_large();
        let f = fwd_bwd_flops(&spec, 8);
        // ~336M params × 6 × 8 samples ≈ 1.6e10.
        assert!(f > 1e10 && f < 1e11, "f={f}");
    }
}
