//! Analytic cost model: FLOPs, bytes and seconds per training step for
//! every optimizer at *paper scale* (BERT-Large on 64 A100s, ResNet-50 on
//! 64 V100s), calibrated against the complexity formulas of Table 1.
//!
//! The proxy convergence runs measure *steps-to-target*; this model prices
//! each optimizer's *seconds-per-step* on the paper's testbed, and the
//! product regenerates the end-to-end time/speedup columns of Tables 2/3,
//! the per-step breakdown of Figure 3, the inversion-frequency sensitivity
//! of Figure 4a and the scaling curves of Figure 9.

pub mod complexity;
pub mod timing;

pub use complexity::{OptimizerKind, StepCost};
pub use timing::{DeviceModel, StepTime};
