//! FLOPs → seconds: device throughput model + collective pricing.
//!
//! Different phases run at very different efficiencies on a GPU: dense
//! matmul (fwd/bwd, preconditioning, covariance products) streams through
//! tensor cores, while factor *inversions* (Cholesky/SVD/GJ) are
//! latency-bound with tiny parallel sections. The paper quantifies this
//! gap implicitly: a KAISA inversion iteration costs ~150× an SGD
//! iteration on ResNet-50 (§3.3) — which our default rates reproduce (see
//! the `kaisa_inversion_step_is_two_orders_costlier` test).

use super::complexity::{fwd_bwd_flops, model_step_cost, OptimizerKind};
use crate::collective::ClusterModel;
use crate::model::specs::ModelSpec;

/// Throughput parameters of one device class.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// Dense matmul effective FLOP/s (fwd/bwd, precondition, covariances).
    pub matmul_flops: f64,
    /// Matrix-inversion effective FLOP/s (Cholesky/GJ/SVD kernels).
    pub inversion_flops: f64,
    /// Elementwise/update effective FLOP/s (bandwidth-bound).
    pub elementwise_flops: f64,
}

impl DeviceModel {
    /// A100 (TF32 matmul ≈ 60 TF effective of 156 peak, inversions a few
    /// hundred GF — cuSOLVER-style, bandwidth/latency bound).
    pub fn a100() -> Self {
        DeviceModel { matmul_flops: 60e12, inversion_flops: 0.35e12, elementwise_flops: 3e12 }
    }

    /// V100 (fp16/fp32 mixed ≈ 25 TF effective).
    pub fn v100() -> Self {
        DeviceModel { matmul_flops: 25e12, inversion_flops: 0.2e12, elementwise_flops: 2e12 }
    }
}

/// The per-step time breakdown at paper scale (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTime {
    pub fwd_bwd: f64,
    pub factor: f64,
    pub precond: f64,
    pub update: f64,
    pub grad_comm: f64,
    pub sync_comm: f64,
}

impl StepTime {
    pub fn total(&self) -> f64 {
        self.fwd_bwd + self.factor + self.precond + self.update + self.grad_comm + self.sync_comm
    }

    /// Optimizer-only time (the Figure 3 bars: factor + precond + update).
    pub fn optimizer_time(&self) -> f64 {
        self.factor + self.precond + self.update
    }
}

/// Price one step on `workers` devices with factor work on this step
/// (`factor_step = true`) or skipped (stale factors).
pub fn step_time(
    kind: OptimizerKind,
    spec: &ModelSpec,
    samples_per_worker: usize,
    workers: usize,
    device: &DeviceModel,
    cluster: &ClusterModel,
    factor_step: bool,
) -> StepTime {
    let c = model_step_cost(kind, spec);
    // Inversion-heavy optimizers split factor work: covariance/kernel
    // products run at matmul rate, the d³/b³ inversion at inversion rate.
    let (factor_matmul, factor_inv) = match kind {
        OptimizerKind::Kfac => {
            let b = spec.effective_batch as f64;
            let cov: f64 = spec
                .layers
                .iter()
                .map(|s| 2.0 * b * ((s.d_in * s.d_in + s.d_out * s.d_out) as f64))
                .sum();
            (cov, c.factor_flops - cov)
        }
        OptimizerKind::Sngd => {
            let b = spec.effective_batch as f64;
            let kernel_build: f64 = spec
                .layers
                .iter()
                .map(|s| 2.0 * b * b * ((s.d_in + s.d_out) as f64))
                .sum();
            (kernel_build, c.factor_flops - kernel_build)
        }
        // MKOR/Eva factor work is matvec/rank-1 — runs at elementwise-ish
        // rate but is so small it hardly matters; charge matmul rate.
        _ => (c.factor_flops, 0.0),
    };

    // KAISA distributes factor inversions layer-wise across workers (each
    // GPU inverts a subset and broadcasts); HyLo does the same for kernels.
    // MKOR/Eva's factor work is replicated (it's cheaper than distributing).
    let inv_parallel = match kind {
        OptimizerKind::Kfac | OptimizerKind::Sngd => {
            workers.min(spec.layers.len()).max(1) as f64
        }
        _ => 1.0,
    };
    let factor = if factor_step {
        factor_matmul / device.matmul_flops
            + factor_inv / device.inversion_flops / inv_parallel
    } else {
        0.0
    };
    let sync_comm = if factor_step {
        cluster.allreduce_time(c.sync_bytes as usize, workers)
    } else {
        0.0
    };

    StepTime {
        fwd_bwd: fwd_bwd_flops(spec, samples_per_worker) / device.matmul_flops,
        factor,
        precond: c.precond_flops / device.matmul_flops,
        update: c.update_flops / device.elementwise_flops,
        grad_comm: cluster.allreduce_time(c.grad_bytes as usize, workers),
        sync_comm,
    }
}

/// Average per-step time with factor steps every `f` iterations.
pub fn amortized_step_time(
    kind: OptimizerKind,
    spec: &ModelSpec,
    samples_per_worker: usize,
    workers: usize,
    device: &DeviceModel,
    cluster: &ClusterModel,
    f: usize,
) -> StepTime {
    let with = step_time(kind, spec, samples_per_worker, workers, device, cluster, true);
    let without = step_time(kind, spec, samples_per_worker, workers, device, cluster, false);
    let f = f.max(1) as f64;
    StepTime {
        fwd_bwd: without.fwd_bwd,
        factor: with.factor / f,
        precond: without.precond,
        update: without.update,
        grad_comm: without.grad_comm,
        sync_comm: with.sync_comm / f,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::specs;

    fn setup() -> (ModelSpec, DeviceModel, ClusterModel) {
        (specs::resnet50(), DeviceModel::v100(), ClusterModel::mist_v100())
    }

    #[test]
    fn kaisa_inversion_step_is_two_orders_costlier_than_sgd() {
        // §3.3: "in an iteration that the inversion of factors is executed,
        // the cost of KAISA and HyLo is 150× more than an SGD iteration"
        // (full iteration, ResNet-50, 4 V100s). Our calibration should land
        // in the same two-orders-of-magnitude regime.
        let (spec, dev, cl) = setup();
        let kfac = step_time(OptimizerKind::Kfac, &spec, 32, 4, &dev, &cl, true);
        let sgd = step_time(OptimizerKind::Sgd, &spec, 32, 4, &dev, &cl, true);
        let ratio = kfac.total() / sgd.total().max(1e-9);
        assert!(ratio > 30.0 && ratio < 3000.0, "ratio={ratio}");
        // And the overwhelming share of the optimizer time is the
        // inversion (§3.3: "more than 98%").
        assert!(kfac.factor / kfac.optimizer_time() > 0.9);
    }

    #[test]
    fn mkor_factor_step_is_cheap() {
        let (spec, dev, cl) = setup();
        let mkor = step_time(OptimizerKind::Mkor, &spec, 32, 4, &dev, &cl, true);
        let kfac = step_time(OptimizerKind::Kfac, &spec, 32, 4, &dev, &cl, true);
        assert!(kfac.factor > 20.0 * mkor.factor, "kfac={} mkor={}", kfac.factor, mkor.factor);
    }

    #[test]
    fn mkor_amortized_time_is_flat_in_f_kaisa_is_not() {
        // Figure 4a: KAISA's average iteration cost depends strongly on f;
        // MKOR's barely moves.
        let (spec, dev, cl) = setup();
        let m1 = amortized_step_time(OptimizerKind::Mkor, &spec, 32, 4, &dev, &cl, 1).total();
        let m100 = amortized_step_time(OptimizerKind::Mkor, &spec, 32, 4, &dev, &cl, 100).total();
        let k1 = amortized_step_time(OptimizerKind::Kfac, &spec, 32, 4, &dev, &cl, 1).total();
        let k100 = amortized_step_time(OptimizerKind::Kfac, &spec, 32, 4, &dev, &cl, 100).total();
        assert!(m1 / m100 < 1.3, "mkor f-sensitivity {}", m1 / m100);
        assert!(k1 / k100 > 3.0, "kaisa f-sensitivity {}", k1 / k100);
    }

    #[test]
    fn bert_factor_cost_dominates_kaisa_more_than_resnet() {
        // Figure 3's contrast: on BERT-Large (large d) KAISA's inversion
        // share is larger than on ResNet-50.
        let dev = DeviceModel::a100();
        let cl = ClusterModel::polaris_a100();
        let bert = specs::bert_large();
        let rn = specs::resnet50();
        let kb = step_time(OptimizerKind::Kfac, &bert, 8, 64, &dev, &cl, true);
        let kr = step_time(OptimizerKind::Kfac, &rn, 32, 64, &dev, &cl, true);
        assert!(kb.factor > kr.factor);
    }

    #[test]
    fn mkor_scales_better_than_kaisa_at_64_workers() {
        // Figure 9's mechanism: at 64 workers KFAC's O(d²) factor sync is
        // expensive, MKOR's O(d) is negligible.
        let dev = DeviceModel::a100();
        let cl = ClusterModel::polaris_a100();
        let bert = specs::bert_large();
        let m = step_time(OptimizerKind::Mkor, &bert, 8, 64, &dev, &cl, true);
        let k = step_time(OptimizerKind::Kfac, &bert, 8, 64, &dev, &cl, true);
        assert!(k.sync_comm > 100.0 * m.sync_comm.max(1e-12));
    }
}
