//! Performance benchmark subsystem behind `mkor perf`.
//!
//! Three pieces, cleanly layered:
//!
//! * [`harness`] — warmup/repeat/median-of-k timers; every reported figure
//!   is a median over repeated timed passes.
//! * [`suite`] — what gets measured: GEMM GFLOP/s (serial blocked kernels
//!   vs. the tiled engine, all transpose forms), per-optimizer steps/sec
//!   through the spec registry, and ring all-reduce GB/s (fp32 + bf16).
//! * [`report`] — the versioned JSON schema (`schema_version`, host and
//!   timer metadata, one array per section) with parse-back and validation;
//!   `BENCH_mkor.json` at the repo root is a committed instance.
//!
//! CLI: `mkor perf [--quick] [--json PATH] [--threads N]`. `--quick` is the
//! CI smoke policy (fewer repeats, smaller sweeps); `--threads` pins the
//! engine pool (results are bitwise independent of it — only speed moves).

pub mod harness;
pub mod report;
pub mod suite;

pub use harness::{throughput, time_median, TimerConfig, Timing};
pub use report::{PerfReport, SCHEMA_VERSION};
pub use suite::run_suite;
