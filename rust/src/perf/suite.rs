//! The benchmark suite: what `mkor perf` measures.
//!
//! Three sections, matching the three performance-critical layers:
//!
//! * **GEMM** — GFLOP/s of the serial blocked kernels vs. the tiled engine
//!   at the same sizes (`nn`/`nt`/`tn` forms), the direct measure of the
//!   engine's win on the preconditioning matmuls (Equation 2).
//! * **Optimizers** — end-to-end steps/sec for every name in the spec
//!   registry ([`ALL_OPTIMIZERS`]) on the proxy-GLUE workload, through the
//!   same [`TrainerBuilder`] path `mkor sim` uses.
//! * **All-reduce** — effective GB/s of the ring collective
//!   ([`crate::collective::ring`]) in fp32 and bf16 wire formats.
//!
//! Every figure is a median-of-k measurement via [`harness`]; the suite
//! only *collects* numbers — layout/serialization live in [`super::report`].

use super::harness::{self, throughput, TimerConfig};
use super::report::PerfReport;
use crate::collective::ring::{allreduce_mean, allreduce_mean_bf16};
use crate::coordinator::{Target, TrainerBuilder};
use crate::data::classification::{Dataset, TaskConfig};
use crate::linalg::{engine, ops, Matrix};
use crate::model::{Activation, Mlp};
use crate::optim::{OptimizerSpec, ALL_OPTIMIZERS};
use crate::util::Rng;

/// One GEMM operating point: serial vs. engine at a square size.
#[derive(Clone, Debug)]
pub struct GemmPoint {
    /// `"nn"`, `"nt"` or `"tn"` — which transpose form was multiplied.
    pub kind: String,
    /// Square problem edge (`d×d·d×d`).
    pub d: usize,
    pub serial_gflops: f64,
    pub engine_gflops: f64,
    /// `engine_gflops / serial_gflops`.
    pub speedup: f64,
}

/// Steps/sec for one optimizer from the spec registry.
#[derive(Clone, Debug)]
pub struct OptPoint {
    pub name: String,
    pub steps_per_sec: f64,
}

/// Ring all-reduce throughput at one (workers, payload) point.
#[derive(Clone, Debug)]
pub struct RingPoint {
    pub workers: usize,
    /// Elements per worker buffer.
    pub elems: usize,
    pub fp32_gbps: f64,
    pub bf16_gbps: f64,
}

/// GEMM sizes the suite sweeps (quick keeps the tail off CI).
pub fn gemm_sizes(quick: bool) -> &'static [usize] {
    if quick {
        &[64, 128, 256]
    } else {
        &[64, 128, 256, 384, 512]
    }
}

fn gflops(d: usize, t: &harness::Timing) -> f64 {
    throughput(2.0 * (d * d * d) as f64, t) / 1e9
}

/// Measure serial-vs-engine GFLOP/s for all three transpose forms.
pub fn run_gemm(cfg: TimerConfig, threads: usize, quick: bool) -> Vec<GemmPoint> {
    let mut rng = Rng::new(2024);
    let mut out = Vec::new();
    for &d in gemm_sizes(quick) {
        let a = Matrix::randn(d, d, 1.0, &mut rng);
        let b = Matrix::randn(d, d, 1.0, &mut rng);
        let mut c = Matrix::zeros(d, d);
        for kind in ["nn", "nt", "tn"] {
            let serial = time_serial(kind, cfg, &a, &b, &mut c);
            let engine_t = time_engine(kind, cfg, threads, &a, &b, &mut c);
            let (sg, eg) = (gflops(d, &serial), gflops(d, &engine_t));
            out.push(GemmPoint {
                kind: kind.to_string(),
                d,
                serial_gflops: sg,
                engine_gflops: eg,
                speedup: if sg > 0.0 { eg / sg } else { 0.0 },
            });
        }
    }
    out
}

fn time_serial(
    kind: &str,
    cfg: TimerConfig,
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
) -> harness::Timing {
    match kind {
        "nn" => harness::time_median(cfg, || ops::matmul_into_serial(a, b, c)),
        "nt" => harness::time_median(cfg, || ops::matmul_nt_into_serial(a, b, c)),
        _ => harness::time_median(cfg, || ops::matmul_tn_into_serial(a, b, c)),
    }
}

fn time_engine(
    kind: &str,
    cfg: TimerConfig,
    threads: usize,
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
) -> harness::Timing {
    match kind {
        "nn" => harness::time_median(cfg, || engine::gemm_into(a.view(), b.view(), c, threads)),
        "nt" => harness::time_median(cfg, || engine::gemm_into(a.view(), b.t_view(), c, threads)),
        _ => harness::time_median(cfg, || engine::gemm_into(a.t_view(), b.view(), c, threads)),
    }
}

/// Measure end-to-end steps/sec for every registered optimizer on the
/// proxy-GLUE task (same model family and trainer path as `mkor sim`).
pub fn run_optimizers(cfg: TimerConfig, quick: bool) -> Vec<OptPoint> {
    let steps_per_pass = if quick { 2 } else { 5 };
    let mut task_cfg = TaskConfig::new("qnli-proxy", 64, 2);
    task_cfg.seed = 7;
    let ds = Dataset::generate(task_cfg);
    let batches = ds.epoch_batches(64, 0);
    let mut out = Vec::new();
    for &name in ALL_OPTIMIZERS {
        let spec = OptimizerSpec::parse(name).expect("registry name parses");
        let mut rng = Rng::new(7);
        let model = Mlp::new(&[64, 96, 48, 2], Activation::Relu, &mut rng);
        let mut trainer = TrainerBuilder::new(model)
            .optimizer(spec)
            .constant_lr(0.05)
            .workers(2)
            .run_name(format!("perf-{name}"))
            .try_build()
            .expect("perf trainer builds");
        let mut cursor = 0usize;
        let t = harness::time_median(cfg, || {
            for _ in 0..steps_per_pass {
                let b = &batches[cursor % batches.len()];
                cursor += 1;
                let _ = trainer.step(&b.x, &Target::Labels(b.labels.clone()));
            }
        });
        out.push(OptPoint {
            name: name.to_string(),
            steps_per_sec: throughput(steps_per_pass as f64, &t),
        });
    }
    out
}

/// (workers, elements-per-buffer) points the ring sweep measures.
pub fn ring_shapes(quick: bool) -> &'static [(usize, usize)] {
    if quick {
        &[(4, 16384)]
    } else {
        &[(4, 65536), (8, 1048576)]
    }
}

/// Measure ring all-reduce throughput (fp32 and bf16 wire). Reported GB/s
/// is total bytes moved across the ring per second (`bytes_per_worker × W`).
/// The timed passes re-reduce the same buffers — the data movement and
/// arithmetic per pass are identical regardless of the values.
pub fn run_ring(cfg: TimerConfig) -> Vec<RingPoint> {
    run_ring_shaped(cfg, ring_shapes(false))
}

/// [`run_ring`] over explicit shapes (the quick path narrows the sweep).
pub fn run_ring_shaped(cfg: TimerConfig, shapes: &[(usize, usize)]) -> Vec<RingPoint> {
    let mut out = Vec::new();
    for &(w, n) in shapes {
        let mut rng = Rng::new(99);
        let mut bufs: Vec<Vec<f32>> =
            (0..w).map(|_| (0..n).map(|_| rng.gaussian_f32()).collect()).collect();
        let stats = allreduce_mean(&mut bufs);
        let total_bytes = (stats.bytes_per_worker * w) as f64;
        let t32 = harness::time_median(cfg, || {
            allreduce_mean(&mut bufs);
        });
        let t16 = harness::time_median(cfg, || {
            allreduce_mean_bf16(&mut bufs);
        });
        out.push(RingPoint {
            workers: w,
            elems: n,
            fp32_gbps: throughput(total_bytes, &t32) / 1e9,
            // bf16 moves half the bytes; report its own wire volume.
            bf16_gbps: throughput(total_bytes / 2.0, &t16) / 1e9,
        });
    }
    out
}

/// Run the whole suite and assemble the versioned report.
pub fn run_suite(quick: bool, threads: usize) -> PerfReport {
    let cfg = if quick { TimerConfig::quick() } else { TimerConfig::full() };
    engine::set_threads(threads);
    PerfReport {
        schema_version: super::report::SCHEMA_VERSION,
        quick,
        threads,
        hw_threads: engine::hw_threads(),
        os: std::env::consts::OS.to_string(),
        arch: std::env::consts::ARCH.to_string(),
        warmup: cfg.warmup,
        repeats: cfg.repeats,
        gemm: run_gemm(cfg, threads, quick),
        optimizers: run_optimizers(cfg, quick),
        allreduce: run_ring_shaped(cfg, ring_shapes(quick)),
        trace: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_section_covers_all_kinds_and_sizes() {
        // Smallest possible measurement: 1 repeat, tiny sizes — checks the
        // plumbing, not the numbers.
        let cfg = TimerConfig { warmup: 0, repeats: 1 };
        let mut rng = Rng::new(1);
        let a = Matrix::randn(32, 32, 1.0, &mut rng);
        let b = Matrix::randn(32, 32, 1.0, &mut rng);
        let mut c = Matrix::zeros(32, 32);
        for kind in ["nn", "nt", "tn"] {
            let t = time_serial(kind, cfg, &a, &b, &mut c);
            assert!(t.median_secs >= 0.0, "{kind}");
            let t = time_engine(kind, cfg, 2, &a, &b, &mut c);
            assert!(t.median_secs >= 0.0, "{kind}");
        }
    }

    #[test]
    fn ring_section_reports_finite_throughput() {
        let cfg = TimerConfig { warmup: 0, repeats: 1 };
        let pts = run_ring_shaped(cfg, &[(2, 256)]);
        assert_eq!(pts.len(), 1);
        assert!(pts[0].fp32_gbps.is_finite() && pts[0].fp32_gbps >= 0.0);
        assert!(pts[0].bf16_gbps.is_finite() && pts[0].bf16_gbps >= 0.0);
    }
}
