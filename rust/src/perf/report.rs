//! Versioned perf-report schema: serialize, parse back, validate.
//!
//! `mkor perf --json` emits exactly this layout (schema_version 1):
//!
//! ```json
//! {
//!   "allreduce": [{"bf16_gbps": ..., "elems": ..., "fp32_gbps": ..., "workers": ...}],
//!   "gemm": [{"d": ..., "engine_gflops": ..., "kind": "nn", "serial_gflops": ..., "speedup": ...}],
//!   "host": {"arch": "...", "hw_threads": ..., "os": "...", "threads": ...},
//!   "optimizers": [{"name": "sgd", "steps_per_sec": ...}],
//!   "quick": false,
//!   "schema_version": 1,
//!   "timer": {"repeats": 9, "warmup": 3}
//! }
//! ```
//!
//! Keys are alphabetical (the JSON writer sorts objects), so committed
//! reports diff cleanly. [`PerfReport::from_json`] round-trips the schema
//! and [`PerfReport::validate`] enforces the invariants CI's perf-smoke job
//! checks: version match, thread count recorded, non-empty sections, every
//! number finite.

use super::suite::{GemmPoint, OptPoint, RingPoint};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Current report schema version. Bump when the layout changes shape.
pub const SCHEMA_VERSION: u64 = 1;

/// Everything one `mkor perf` run measured, plus host/timer metadata.
#[derive(Clone, Debug)]
pub struct PerfReport {
    pub schema_version: u64,
    pub quick: bool,
    /// Engine thread count the run was pinned to.
    pub threads: usize,
    pub hw_threads: usize,
    pub os: String,
    pub arch: String,
    pub warmup: usize,
    pub repeats: usize,
    pub gemm: Vec<GemmPoint>,
    pub optimizers: Vec<OptPoint>,
    pub allreduce: Vec<RingPoint>,
    /// Path of the JSONL trace written alongside this run (`--trace`),
    /// when one was. Absent from the JSON when `None`, so untraced
    /// reports keep their exact historical byte layout.
    pub trace: Option<String>,
}

impl PerfReport {
    pub fn to_json(&self) -> Json {
        let mut host = Json::obj();
        host.set("os", Json::Str(self.os.clone()))
            .set("arch", Json::Str(self.arch.clone()))
            .set("threads", Json::Num(self.threads as f64))
            .set("hw_threads", Json::Num(self.hw_threads as f64));
        let mut timer = Json::obj();
        timer
            .set("warmup", Json::Num(self.warmup as f64))
            .set("repeats", Json::Num(self.repeats as f64));
        let gemm = self
            .gemm
            .iter()
            .map(|g| {
                let mut o = Json::obj();
                o.set("kind", Json::Str(g.kind.clone()))
                    .set("d", Json::Num(g.d as f64))
                    .set("serial_gflops", Json::Num(g.serial_gflops))
                    .set("engine_gflops", Json::Num(g.engine_gflops))
                    .set("speedup", Json::Num(g.speedup));
                o
            })
            .collect();
        let opts = self
            .optimizers
            .iter()
            .map(|o| {
                let mut j = Json::obj();
                j.set("name", Json::Str(o.name.clone()))
                    .set("steps_per_sec", Json::Num(o.steps_per_sec));
                j
            })
            .collect();
        let ring = self
            .allreduce
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("workers", Json::Num(r.workers as f64))
                    .set("elems", Json::Num(r.elems as f64))
                    .set("fp32_gbps", Json::Num(r.fp32_gbps))
                    .set("bf16_gbps", Json::Num(r.bf16_gbps));
                o
            })
            .collect();
        let mut root = Json::obj();
        root.set("schema_version", Json::Num(self.schema_version as f64))
            .set("quick", Json::Bool(self.quick))
            .set("host", host)
            .set("timer", timer)
            .set("gemm", Json::Arr(gemm))
            .set("optimizers", Json::Arr(opts))
            .set("allreduce", Json::Arr(ring));
        if let Some(trace) = &self.trace {
            root.set("trace", Json::Str(trace.clone()));
        }
        root
    }

    /// Parse a report back from its JSON form (round-trip of [`to_json`]).
    pub fn from_json(j: &Json) -> Result<PerfReport> {
        let version = j.require_usize("schema_version")? as u64;
        if version != SCHEMA_VERSION {
            bail!("unsupported perf schema version {version} (expected {SCHEMA_VERSION})");
        }
        let host = j.get("host").ok_or_else(|| anyhow!("missing `host`"))?;
        let timer = j.get("timer").ok_or_else(|| anyhow!("missing `timer`"))?;
        let num = |o: &Json, key: &str| -> Result<f64> {
            o.get(key).and_then(Json::as_f64).ok_or_else(|| anyhow!("missing/invalid `{key}`"))
        };
        let arr = |key: &str| -> Result<Vec<Json>> {
            Ok(j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing/invalid `{key}`"))?
                .to_vec())
        };
        let mut gemm = Vec::new();
        for g in arr("gemm")? {
            gemm.push(GemmPoint {
                kind: g.require_str("kind")?.to_string(),
                d: g.require_usize("d")?,
                serial_gflops: num(&g, "serial_gflops")?,
                engine_gflops: num(&g, "engine_gflops")?,
                speedup: num(&g, "speedup")?,
            });
        }
        let mut optimizers = Vec::new();
        for o in arr("optimizers")? {
            optimizers.push(OptPoint {
                name: o.require_str("name")?.to_string(),
                steps_per_sec: num(&o, "steps_per_sec")?,
            });
        }
        let mut allreduce = Vec::new();
        for r in arr("allreduce")? {
            allreduce.push(RingPoint {
                workers: r.require_usize("workers")?,
                elems: r.require_usize("elems")?,
                fp32_gbps: num(&r, "fp32_gbps")?,
                bf16_gbps: num(&r, "bf16_gbps")?,
            });
        }
        Ok(PerfReport {
            schema_version: version,
            quick: j.get("quick").and_then(Json::as_bool).unwrap_or(false),
            threads: host.require_usize("threads")?,
            hw_threads: host.require_usize("hw_threads")?,
            os: host.require_str("os")?.to_string(),
            arch: host.require_str("arch")?.to_string(),
            warmup: timer.require_usize("warmup")?,
            repeats: timer.require_usize("repeats")?,
            gemm,
            optimizers,
            allreduce,
            trace: j.get("trace").and_then(Json::as_str).map(str::to_string),
        })
    }

    /// The invariants CI's perf-smoke job enforces on emitted reports.
    pub fn validate(&self) -> Result<()> {
        if self.schema_version != SCHEMA_VERSION {
            bail!("schema_version {} != {SCHEMA_VERSION}", self.schema_version);
        }
        if self.threads == 0 || self.hw_threads == 0 {
            bail!("thread metadata not recorded");
        }
        if self.gemm.is_empty() || self.optimizers.is_empty() || self.allreduce.is_empty() {
            bail!("empty report section");
        }
        for g in &self.gemm {
            for (label, v) in
                [("serial", g.serial_gflops), ("engine", g.engine_gflops), ("speedup", g.speedup)]
            {
                if !v.is_finite() || v < 0.0 {
                    bail!("gemm {} d={}: non-finite {label} figure {v}", g.kind, g.d);
                }
            }
        }
        for o in &self.optimizers {
            if !o.steps_per_sec.is_finite() || o.steps_per_sec < 0.0 {
                bail!("optimizer {}: non-finite steps/sec {}", o.name, o.steps_per_sec);
            }
        }
        for r in &self.allreduce {
            if !r.fp32_gbps.is_finite() || !r.bf16_gbps.is_finite() {
                bail!("allreduce w={} n={}: non-finite throughput", r.workers, r.elems);
            }
        }
        Ok(())
    }

    /// Validate, then pretty-print to `path`.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        self.validate()?;
        self.to_json().to_file(path).with_context(|| format!("writing {}", path.display()))
    }

    /// Human-readable console rendering.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "perf report (schema v{}, {} threads of {}, {}/{}, {} warmup + {} repeats{})",
            self.schema_version,
            self.threads,
            self.hw_threads,
            self.os,
            self.arch,
            self.warmup,
            self.repeats,
            if self.quick { ", quick" } else { "" }
        );
        let _ = writeln!(s, "\nGEMM (GFLOP/s, serial vs engine):");
        for g in &self.gemm {
            let _ = writeln!(
                s,
                "  {:>2} d={:<4} serial {:>7.2}  engine {:>7.2}  ({:>5.2}x)",
                g.kind, g.d, g.serial_gflops, g.engine_gflops, g.speedup
            );
        }
        let _ = writeln!(s, "\nOptimizer steps/sec (proxy-GLUE, spec registry):");
        for o in &self.optimizers {
            let _ = writeln!(s, "  {:<8} {:>9.1}", o.name, o.steps_per_sec);
        }
        let _ = writeln!(s, "\nRing all-reduce (GB/s wire throughput):");
        for r in &self.allreduce {
            let _ = writeln!(
                s,
                "  w={} n={:<8} fp32 {:>6.2}  bf16 {:>6.2}",
                r.workers, r.elems, r.fp32_gbps, r.bf16_gbps
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PerfReport {
        PerfReport {
            schema_version: SCHEMA_VERSION,
            quick: true,
            threads: 4,
            hw_threads: 8,
            os: "linux".into(),
            arch: "x86_64".into(),
            warmup: 1,
            repeats: 3,
            gemm: vec![GemmPoint {
                kind: "nn".into(),
                d: 256,
                serial_gflops: 5.5,
                engine_gflops: 20.25,
                speedup: 20.25 / 5.5,
            }],
            optimizers: vec![OptPoint { name: "mkor".into(), steps_per_sec: 750.5 }],
            allreduce: vec![RingPoint {
                workers: 4,
                elems: 65536,
                fp32_gbps: 5.75,
                bf16_gbps: 3.125,
            }],
            trace: None,
        }
    }

    #[test]
    fn schema_round_trips() {
        let r = sample();
        let j = r.to_json();
        let text = format!("{j:#}");
        let back = PerfReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.schema_version, r.schema_version);
        assert_eq!(back.threads, 4);
        assert_eq!(back.gemm.len(), 1);
        assert_eq!(back.gemm[0].kind, "nn");
        assert_eq!(back.gemm[0].d, 256);
        assert_eq!(back.gemm[0].engine_gflops, 20.25);
        assert_eq!(back.optimizers[0].name, "mkor");
        assert_eq!(back.optimizers[0].steps_per_sec, 750.5);
        assert_eq!(back.allreduce[0].elems, 65536);
        assert_eq!(back.allreduce[0].bf16_gbps, 3.125);
        back.validate().unwrap();
    }

    #[test]
    fn trace_field_round_trips_and_is_omitted_when_none() {
        let r = sample();
        assert!(r.to_json().get("trace").is_none(), "None must not change the layout");
        let mut r = sample();
        r.trace = Some("perf.trace.jsonl".into());
        let back = PerfReport::from_json(&r.to_json()).unwrap();
        assert_eq!(back.trace.as_deref(), Some("perf.trace.jsonl"));
        back.validate().unwrap();
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut j = sample().to_json();
        j.set("schema_version", Json::Num(99.0));
        assert!(PerfReport::from_json(&j).is_err());
    }

    #[test]
    fn validate_rejects_bad_reports() {
        let mut r = sample();
        r.threads = 0;
        assert!(r.validate().is_err());

        let mut r = sample();
        r.gemm.clear();
        assert!(r.validate().is_err());

        let mut r = sample();
        r.optimizers[0].steps_per_sec = f64::NAN;
        assert!(r.validate().is_err());
    }
}
