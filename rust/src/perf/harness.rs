//! Measurement harness: warmup + repeat + median-of-k wall-clock timers.
//!
//! Every number the perf suite reports comes through [`time_median`]: the
//! workload runs `warmup` untimed passes (page in buffers, spin up the
//! engine pool, settle the branch predictors), then `repeats` timed passes,
//! and the **median** is the headline figure — robust to the occasional
//! descheduling blip that poisons means and minima on shared hosts. Min and
//! max ride along so a report reader can judge spread. The per-pass samples
//! fold through [`Hist`] — the same quantile implementation `mkor trace
//! summarize` uses — so the two subsystems can never disagree on what a
//! median is.

use crate::obs::Hist;
use std::time::Instant;

/// Warmup/repeat policy for one measurement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimerConfig {
    /// Untimed passes before measurement starts.
    pub warmup: usize,
    /// Timed passes; the median of these is the reported figure.
    pub repeats: usize,
}

impl TimerConfig {
    /// CI-friendly: enough to smoke-test the plumbing, not to publish.
    pub fn quick() -> TimerConfig {
        TimerConfig { warmup: 1, repeats: 3 }
    }

    /// Publication policy for `BENCH_mkor.json`.
    pub fn full() -> TimerConfig {
        TimerConfig { warmup: 3, repeats: 9 }
    }
}

/// One measurement: median/min/max seconds over the timed repeats.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    pub median_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub repeats: usize,
}

/// Run `f` under `cfg` (warmup passes untimed, then `repeats` timed) and
/// summarize the per-pass wall-clock times.
pub fn time_median(cfg: TimerConfig, mut f: impl FnMut()) -> Timing {
    for _ in 0..cfg.warmup {
        f();
    }
    let repeats = cfg.repeats.max(1);
    let mut samples = Hist::new();
    for _ in 0..repeats {
        let t0 = Instant::now();
        f();
        samples.add(t0.elapsed().as_secs_f64());
    }
    Timing {
        median_secs: samples.quantile(0.5).unwrap_or(0.0),
        min_secs: samples.min().unwrap_or(0.0),
        max_secs: samples.max().unwrap_or(0.0),
        repeats,
    }
}

/// `units / median_secs`, guarding the degenerate zero-duration case (a
/// sub-resolution workload reports 0 throughput rather than inf — callers
/// treat that as "too small to measure").
pub fn throughput(units: f64, t: &Timing) -> f64 {
    if t.median_secs > 0.0 {
        units / t.median_secs
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_is_within_min_max_and_counts_repeats() {
        let mut n = 0u64;
        let t = time_median(TimerConfig { warmup: 2, repeats: 5 }, || {
            n += 1;
            // A tiny but nonzero workload.
            std::hint::black_box((0..500).sum::<u64>());
        });
        assert_eq!(n, 7, "warmup + repeats passes");
        assert_eq!(t.repeats, 5);
        assert!(t.min_secs <= t.median_secs && t.median_secs <= t.max_secs);
        assert!(t.min_secs >= 0.0);
    }

    #[test]
    fn throughput_guards_zero_duration() {
        let zero = Timing::default();
        assert_eq!(throughput(1e9, &zero), 0.0);
        let t = Timing { median_secs: 0.5, min_secs: 0.4, max_secs: 0.6, repeats: 3 };
        assert!((throughput(3.0, &t) - 6.0).abs() < 1e-12);
    }
}
