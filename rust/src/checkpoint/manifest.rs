//! Checkpoint directories: a manifest JSON plus one `.bin` state blob per
//! component.
//!
//! Layout of a checkpoint directory (blob names carry the step they were
//! written at — `save` never overwrites the files the previous manifest
//! references):
//!
//! ```text
//! <dir>/
//!   manifest.json     format version, step, canonical OptimizerSpec string,
//!                     task, run name, per-component file + FNV-1a content
//!                     hash + byte count
//!   model-<N>.bin     leader model weights (StateDict binary codec)
//!   optimizer-<N>.bin optimizer state (factor inverses, moments, counters)
//!   trainer-<N>.bin   step counter, divergence flag, LR-schedule state
//!   record-<N>.json   full per-step RunRecord so a resumed run's loss
//!                     series continues the original seamlessly
//! ```
//!
//! New blobs land under fresh names, the manifest is swapped in by a
//! temp-file rename, and only then are the previous snapshot's files
//! garbage-collected — so a kill at any point during a periodic save
//! leaves a readable manifest whose blobs are intact. Every load failure —
//! missing manifest, missing manifest key, unsupported version, hash
//! mismatch, truncated/corrupt blob, wrong spec — is a distinct
//! [`CheckpointError`].
//!
//! A *retention* policy can additionally stamp whole checkpoint
//! directories: `step-<N>/` subdirectories (named by [`retained_dir_name`])
//! under the rolling checkpoint directory survive the rolling save's
//! file-level GC, and [`gc_retained`] prunes them to the `k` best by
//! [`retained_metric`] (latest eval metric, else negated final loss).

use crate::checkpoint::state::{fnv1a64, StateDict, StateError};
use crate::coordinator::RunRecord;
use crate::obs::{self, EventKind, TraceEvent};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Manifest format version written by this build.
pub const CHECKPOINT_FORMAT_VERSION: u32 = 1;

/// Name of the manifest file inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Why a checkpoint failed to save, load, or restore.
#[derive(Debug, thiserror::Error)]
pub enum CheckpointError {
    #[error("{}: {source}", path.display())]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },
    #[error("no checkpoint manifest at {}", .0.display())]
    MissingManifest(PathBuf),
    #[error("{}: invalid manifest: {msg}", path.display())]
    BadManifest { path: PathBuf, msg: String },
    #[error("manifest is missing key `{key}`")]
    MissingManifestKey { key: String },
    #[error(
        "unsupported checkpoint format version {found} (this build reads version {supported})"
    )]
    BadVersion { found: u32, supported: u32 },
    #[error("checkpoint has no `{name}` component")]
    MissingComponent { name: String },
    #[error("component `{name}`: content hash mismatch (file corrupted or truncated?)")]
    HashMismatch { name: String },
    #[error("component `{name}`: {source}")]
    State {
        name: String,
        #[source]
        source: StateError,
    },
    #[error("checkpoint run record: {msg}")]
    BadRecord { msg: String },
    #[error("checkpoint was written by spec `{found}`, but this run uses `{expected}`")]
    SpecMismatch { expected: String, found: String },
    #[error("checkpoint was written on task `{found}`, but this run is on `{expected}`")]
    TaskMismatch { expected: String, found: String },
}

impl CheckpointError {
    fn io(path: &Path, source: std::io::Error) -> CheckpointError {
        CheckpointError::Io {
            path: path.to_path_buf(),
            source,
        }
    }
}

/// An in-memory checkpoint: identity metadata plus one [`StateDict`] per
/// component and (optionally) the run record so far.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    /// Completed training steps at the time of the snapshot.
    pub step: usize,
    /// Canonical optimizer spec string — resume validates it against the
    /// resuming run's spec before any state is loaded.
    pub spec: String,
    /// Optimizer name (`spec`'s head; kept for human-readable manifests).
    pub optimizer: String,
    /// Task label the run trained on ("" when unknown).
    pub task: String,
    /// Run name from the trainer config.
    pub run_name: String,
    /// One state dict per component (`model`, `optimizer`, `trainer`, and
    /// any extras like a harness `rng`).
    pub components: BTreeMap<String, StateDict>,
    /// Per-step record so far; a resumed run appends to it, keeping the
    /// loss series identical to an uninterrupted run's.
    pub record: Option<RunRecord>,
}

impl Checkpoint {
    /// Does `dir` contain a checkpoint manifest?
    pub fn exists(dir: &Path) -> bool {
        dir.join(MANIFEST_FILE).is_file()
    }

    /// The component named `name`, or a [`CheckpointError::MissingComponent`].
    pub fn component(&self, name: &str) -> Result<&StateDict, CheckpointError> {
        self.components
            .get(name)
            .ok_or_else(|| CheckpointError::MissingComponent {
                name: name.to_string(),
            })
    }

    /// Write the checkpoint into `dir` (created if needed), crash-safely:
    /// blob and record filenames are step-stamped (`model-200.bin`), so
    /// writing never touches the files the previous manifest references;
    /// the manifest is swapped in atomically (temp file + rename) last;
    /// and only then are files the new manifest does not reference
    /// garbage-collected. A kill at ANY point leaves the directory with a
    /// readable manifest whose blobs are intact — either the old
    /// checkpoint or the new one.
    pub fn save(&self, dir: &Path) -> Result<(), CheckpointError> {
        let t0 = obs::enabled().then(std::time::Instant::now);
        std::fs::create_dir_all(dir).map_err(|e| CheckpointError::io(dir, e))?;
        let mut keep: Vec<String> = Vec::new();
        let mut components = Json::obj();
        let mut total_bytes = 0usize;
        for (name, sd) in &self.components {
            let file = format!("{name}-{}.bin", self.step);
            let bytes = sd.to_bytes();
            total_bytes += bytes.len();
            let path = dir.join(&file);
            std::fs::write(&path, &bytes).map_err(|e| CheckpointError::io(&path, e))?;
            let mut meta = Json::obj();
            meta.set("file", Json::Str(file.clone()))
                .set("hash", Json::Str(format!("{:016x}", fnv1a64(&bytes))))
                .set("bytes", Json::Num(bytes.len() as f64));
            components.set(name, meta);
            keep.push(file);
        }
        let mut manifest = Json::obj();
        manifest
            .set("format_version", Json::Num(CHECKPOINT_FORMAT_VERSION as f64))
            .set("step", Json::Num(self.step as f64))
            .set("spec", Json::Str(self.spec.clone()))
            .set("optimizer", Json::Str(self.optimizer.clone()))
            .set("task", Json::Str(self.task.clone()))
            .set("run_name", Json::Str(self.run_name.clone()))
            .set("components", components);
        if let Some(record) = &self.record {
            let file = format!("record-{}.json", self.step);
            record
                .to_json_full()
                .to_file(&dir.join(&file))
                .map_err(|e| CheckpointError::BadRecord { msg: e.to_string() })?;
            manifest.set("record", Json::Str(file.clone()));
            keep.push(file);
        }
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, format!("{manifest:#}")).map_err(|e| CheckpointError::io(&tmp, e))?;
        let final_path = dir.join(MANIFEST_FILE);
        std::fs::rename(&tmp, &final_path).map_err(|e| CheckpointError::io(&final_path, e))?;
        // Best-effort GC of files the fresh manifest no longer references
        // (the previous snapshot's blobs/record). Failures are harmless:
        // orphans are ignored by load.
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                let is_blob = name.ends_with(".bin")
                    || (name.starts_with("record-") && name.ends_with(".json"));
                if is_blob && !keep.iter().any(|k| *k == name) {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        if let Some(t0) = t0 {
            obs::emit(
                TraceEvent::new(EventKind::CkptSave)
                    .num("step", self.step as f64)
                    .num("components", self.components.len() as f64)
                    .num("bytes", total_bytes as f64)
                    .num("secs", t0.elapsed().as_secs_f64()),
            );
            obs::registry::with_global(|r| r.inc("checkpoint.saves", 1));
        }
        Ok(())
    }

    /// Load and validate a checkpoint from `dir`: manifest present and
    /// well-formed, version supported, every component blob present with a
    /// matching content hash and a decodable state dict.
    pub fn load(dir: &Path) -> Result<Checkpoint, CheckpointError> {
        let t0 = obs::enabled().then(std::time::Instant::now);
        let manifest_path = dir.join(MANIFEST_FILE);
        if !manifest_path.is_file() {
            return Err(CheckpointError::MissingManifest(dir.to_path_buf()));
        }
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| CheckpointError::io(&manifest_path, e))?;
        let manifest = Json::parse(&text).map_err(|e| CheckpointError::BadManifest {
            path: manifest_path.clone(),
            msg: e.to_string(),
        })?;

        let missing = |key: &str| CheckpointError::MissingManifestKey {
            key: key.to_string(),
        };
        let req_str = |key: &str| -> Result<String, CheckpointError> {
            Ok(manifest
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| missing(key))?
                .to_string())
        };
        let version = manifest
            .get("format_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| missing("format_version"))? as u32;
        if version != CHECKPOINT_FORMAT_VERSION {
            return Err(CheckpointError::BadVersion {
                found: version,
                supported: CHECKPOINT_FORMAT_VERSION,
            });
        }
        let step = manifest
            .get("step")
            .and_then(Json::as_usize)
            .ok_or_else(|| missing("step"))?;
        let spec = req_str("spec")?;
        let optimizer = req_str("optimizer")?;
        let task = req_str("task")?;
        let run_name = req_str("run_name")?;

        let comp_obj = manifest.get("components").ok_or_else(|| missing("components"))?;
        let mut components = BTreeMap::new();
        let names: Vec<String> = match comp_obj {
            Json::Obj(m) => m.keys().cloned().collect(),
            _ => {
                return Err(CheckpointError::BadManifest {
                    path: manifest_path.clone(),
                    msg: "`components` is not an object".to_string(),
                });
            }
        };
        for name in names {
            let meta = comp_obj.get(&name).unwrap();
            let file = meta
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| missing(&format!("components.{name}.file")))?;
            let want_hash = meta
                .get("hash")
                .and_then(Json::as_str)
                .ok_or_else(|| missing(&format!("components.{name}.hash")))?;
            let path = dir.join(file);
            let bytes = std::fs::read(&path).map_err(|e| CheckpointError::io(&path, e))?;
            if format!("{:016x}", fnv1a64(&bytes)) != want_hash {
                return Err(CheckpointError::HashMismatch { name });
            }
            let sd = StateDict::from_bytes(&bytes)
                .map_err(|source| CheckpointError::State { name: name.clone(), source })?;
            components.insert(name, sd);
        }

        let record = match manifest.get("record").and_then(Json::as_str) {
            None => None,
            Some(file) => {
                let path = dir.join(file);
                let j = Json::from_file(&path)
                    .map_err(|e| CheckpointError::BadRecord { msg: e.to_string() })?;
                Some(RunRecord::from_json(&j).map_err(|msg| CheckpointError::BadRecord { msg })?)
            }
        };

        if let Some(t0) = t0 {
            obs::emit(
                TraceEvent::new(EventKind::CkptRestore)
                    .num("step", step as f64)
                    .num("components", components.len() as f64)
                    .num("secs", t0.elapsed().as_secs_f64()),
            );
            obs::registry::with_global(|r| r.inc("checkpoint.restores", 1));
        }
        Ok(Checkpoint {
            step,
            spec,
            optimizer,
            task,
            run_name,
            components,
            record,
        })
    }
}

/// Name of the step-stamped retention subdirectory for `step`
/// (`step-200`). Retained checkpoints live *under* the rolling checkpoint
/// directory; the rolling save's GC only removes stamped files, so these
/// subdirectories survive every later snapshot.
pub fn retained_dir_name(step: usize) -> String {
    format!("step-{step}")
}

/// Every retained checkpoint under `root`, as `(step, path)` pairs sorted
/// by step. Entries that are not directories or do not parse as
/// `step-<N>` are ignored (the rolling snapshot's blobs live alongside).
pub fn list_retained(root: &Path) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(root) {
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            let Some(step) = name.strip_prefix("step-").and_then(|s| s.parse::<usize>().ok())
            else {
                continue;
            };
            if entry.path().is_dir() {
                out.push((step, entry.path()));
            }
        }
    }
    out.sort();
    out
}

/// Ranking metric of one retained checkpoint (higher is better): the most
/// recent finite `eval_metric` in the directory's run record, falling
/// back to the negated `final_loss` for runs that never evaluated. `None`
/// when the directory has no readable record — [`gc_retained`] ranks such
/// directories last.
pub fn retained_metric(dir: &Path) -> Option<f64> {
    let record_path = std::fs::read_dir(dir).ok()?.flatten().find_map(|e| {
        let name = e.file_name().to_string_lossy().into_owned();
        (name.starts_with("record-") && name.ends_with(".json")).then(|| e.path())
    })?;
    let record = Json::from_file(&record_path).ok()?;
    if let Some(steps) = record.get("steps").and_then(Json::as_arr) {
        for s in steps.iter().rev() {
            let m = s.get("eval_metric").and_then(Json::as_f64).filter(|m| m.is_finite());
            if let Some(m) = m {
                return Some(m);
            }
        }
    }
    record
        .get("final_loss")
        .and_then(Json::as_f64)
        .filter(|l| l.is_finite())
        .map(|l| -l)
}

/// Prune retained checkpoints under `root` to the `keep_best` best by
/// [`retained_metric`], ties broken toward the newest step; directories
/// without a metric rank last. Returns the directories removed.
/// `keep_best == 0` means keep everything.
pub fn gc_retained(root: &Path, keep_best: usize) -> anyhow::Result<Vec<PathBuf>> {
    if keep_best == 0 {
        return Ok(Vec::new());
    }
    let mut ranked: Vec<(f64, usize, PathBuf)> = list_retained(root)
        .into_iter()
        .map(|(step, path)| (retained_metric(&path).unwrap_or(f64::NEG_INFINITY), step, path))
        .collect();
    // Best metric first; among equals, the newest step survives.
    ranked.sort_by(|a, b| b.0.total_cmp(&a.0).then(b.1.cmp(&a.1)));
    let mut removed = Vec::new();
    for (_, _, path) in ranked.into_iter().skip(keep_best) {
        std::fs::remove_dir_all(&path)
            .map_err(|e| anyhow::anyhow!("removing {}: {e}", path.display()))?;
        removed.push(path);
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::state::Value;

    fn temp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mkor-ckpt-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample() -> Checkpoint {
        let mut model = StateDict::new();
        model.put_vector("w", &[1.0, 2.5, -3.0]);
        let mut opt = StateDict::new();
        opt.put_u64("t", 17).put_f64("ema", 0.25);
        let mut components = BTreeMap::new();
        components.insert("model".to_string(), model);
        components.insert("optimizer".to_string(), opt);
        Checkpoint {
            step: 17,
            spec: "mkor:f=5".to_string(),
            optimizer: "mkor".to_string(),
            task: "glue".to_string(),
            run_name: "t".to_string(),
            components,
            record: None,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = temp_dir("roundtrip");
        let ckpt = sample();
        ckpt.save(&dir).unwrap();
        assert!(Checkpoint::exists(&dir));
        let re = Checkpoint::load(&dir).unwrap();
        assert_eq!(re.step, 17);
        assert_eq!(re.spec, "mkor:f=5");
        assert_eq!(re.task, "glue");
        assert_eq!(re.components.len(), 2);
        assert_eq!(re.component("optimizer").unwrap().u64v("t").unwrap(), 17);
        assert_eq!(
            re.component("model").unwrap().vector("w", 3).unwrap(),
            vec![1.0, 2.5, -3.0]
        );
        assert!(matches!(
            re.component("rng").unwrap_err(),
            CheckpointError::MissingComponent { .. }
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_and_missing_key_are_distinct_errors() {
        let dir = temp_dir("missing");
        assert!(!Checkpoint::exists(&dir));
        assert!(matches!(
            Checkpoint::load(&dir).unwrap_err(),
            CheckpointError::MissingManifest(_)
        ));
        // A manifest without `step` fails with the key name.
        let ckpt = sample();
        ckpt.save(&dir).unwrap();
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"step\"", "\"stepp\"")).unwrap();
        let e = Checkpoint::load(&dir).unwrap_err();
        assert!(
            matches!(&e, CheckpointError::MissingManifestKey { key } if key == "step"),
            "{e:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Resolve a component's blob path through the manifest (filenames are
    /// step-stamped).
    fn blob_path(dir: &Path, component: &str) -> PathBuf {
        let manifest = Json::from_file(&dir.join(MANIFEST_FILE)).unwrap();
        let comp = manifest.get("components").unwrap().get(component).unwrap();
        dir.join(comp.get("file").and_then(Json::as_str).unwrap())
    }

    #[test]
    fn corrupted_and_truncated_blobs_are_rejected() {
        let dir = temp_dir("corrupt");
        sample().save(&dir).unwrap();
        let bin = blob_path(&dir, "model");
        let bytes = std::fs::read(&bin).unwrap();
        // Truncation changes the content hash → HashMismatch.
        std::fs::write(&bin, &bytes[..bytes.len() - 2]).unwrap();
        let e = Checkpoint::load(&dir).unwrap_err();
        assert!(
            matches!(&e, CheckpointError::HashMismatch { name } if name == "model"),
            "{e:?}"
        );
        // A truncated blob that is *re-hashed into the manifest* still
        // fails, now at the codec layer — the decode error names the cause.
        let truncated = &bytes[..bytes.len() - 2];
        let e = StateDict::from_bytes(truncated).unwrap_err();
        assert!(matches!(e, StateError::Truncated { .. }), "{e:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resaving_gcs_old_blobs_and_never_touches_referenced_files() {
        let dir = temp_dir("gc");
        let mut ckpt = sample();
        ckpt.save(&dir).unwrap();
        let old_model = blob_path(&dir, "model");
        assert!(old_model.is_file());
        // A later snapshot writes fresh names, then GCs the old ones.
        ckpt.step = 18;
        ckpt.save(&dir).unwrap();
        let new_model = blob_path(&dir, "model");
        assert_ne!(old_model, new_model, "blob names are step-stamped");
        assert!(!old_model.exists(), "previous blob garbage-collected");
        assert!(new_model.is_file());
        assert_eq!(Checkpoint::load(&dir).unwrap().step, 18);
        // Orphans from a crashed save are ignored by load and collected by
        // the next successful save.
        std::fs::write(dir.join("optimizer-99.bin"), b"partial").unwrap();
        assert_eq!(Checkpoint::load(&dir).unwrap().step, 18);
        ckpt.step = 19;
        ckpt.save(&dir).unwrap();
        assert!(!dir.join("optimizer-99.bin").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn future_format_version_is_rejected() {
        let dir = temp_dir("version");
        sample().save(&dir).unwrap();
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"format_version\": 1", "\"format_version\": 9"))
            .unwrap();
        let e = Checkpoint::load(&dir).unwrap_err();
        assert!(matches!(e, CheckpointError::BadVersion { found: 9, .. }), "{e:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Fabricate a retained `step-<N>/` directory holding only a run
    /// record (all [`retained_metric`] reads).
    fn retained_record(root: &Path, step: usize, eval: Option<f64>, final_loss: f64) {
        let dir = root.join(retained_dir_name(step));
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Json::obj();
        s.set("step", Json::Num(step as f64))
            .set("loss", Json::Num(final_loss))
            .set("eval_metric", eval.map_or(Json::Null, Json::Num));
        let mut rec = Json::obj();
        rec.set("final_loss", Json::Num(final_loss))
            .set("steps", Json::Arr(vec![s]));
        rec.to_file(&dir.join(format!("record-{step}.json"))).unwrap();
    }

    #[test]
    fn retained_gc_keeps_best_k() {
        let dir = temp_dir("retention");
        std::fs::create_dir_all(&dir).unwrap();
        // Four retention points: three with eval metrics plus one that
        // never evaluated (ranked by negated loss — below any accuracy).
        retained_record(&dir, 2, Some(0.6), 1.4);
        retained_record(&dir, 4, Some(0.9), 1.1);
        retained_record(&dir, 6, Some(0.8), 1.0);
        retained_record(&dir, 8, None, 0.9);
        assert_eq!(list_retained(&dir).len(), 4);
        assert_eq!(retained_metric(&dir.join("step-4")), Some(0.9));
        assert_eq!(retained_metric(&dir.join("step-8")), Some(-0.9));
        // keep_best = 0 keeps everything.
        assert!(gc_retained(&dir, 0).unwrap().is_empty());
        assert_eq!(list_retained(&dir).len(), 4);
        // Keep the 2 best by eval metric: steps 4 (0.9) and 6 (0.8).
        let removed = gc_retained(&dir, 2).unwrap();
        assert_eq!(removed.len(), 2);
        let kept: Vec<usize> = list_retained(&dir).into_iter().map(|(s, _)| s).collect();
        assert_eq!(kept, vec![4, 6]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_ties_keep_the_newest_step() {
        let dir = temp_dir("retention-ties");
        std::fs::create_dir_all(&dir).unwrap();
        retained_record(&dir, 10, Some(0.5), 2.0);
        retained_record(&dir, 20, Some(0.5), 2.0);
        retained_record(&dir, 30, Some(0.5), 2.0);
        gc_retained(&dir, 1).unwrap();
        let kept: Vec<usize> = list_retained(&dir).into_iter().map(|(s, _)| s).collect();
        assert_eq!(kept, vec![30]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rolling_save_gc_spares_retained_subdirectories() {
        let dir = temp_dir("spare");
        let mut ckpt = sample();
        ckpt.save(&dir).unwrap();
        retained_record(&dir, 17, Some(0.7), 1.0);
        // A later rolling save GCs stamped *files* only — the retained
        // subdirectory (and the record inside it) survives.
        ckpt.step = 18;
        ckpt.save(&dir).unwrap();
        assert_eq!(list_retained(&dir), vec![(17, dir.join("step-17"))]);
        assert_eq!(retained_metric(&dir.join("step-17")), Some(0.7));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_records_hashes_and_sizes() {
        let dir = temp_dir("meta");
        sample().save(&dir).unwrap();
        let manifest = Json::from_file(&dir.join(MANIFEST_FILE)).unwrap();
        let comp = manifest.get("components").unwrap().get("model").unwrap();
        let file = comp.get("file").and_then(Json::as_str).unwrap();
        let bytes = std::fs::read(dir.join(file)).unwrap();
        assert_eq!(
            comp.get("hash").and_then(Json::as_str).unwrap(),
            format!("{:016x}", fnv1a64(&bytes))
        );
        assert_eq!(comp.get("bytes").and_then(Json::as_usize).unwrap(), bytes.len());
        // The saved state blob also survives a value-level inspection.
        let sd = StateDict::from_bytes(&bytes).unwrap();
        assert!(matches!(sd.get("w"), Some(Value::Tensor(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
