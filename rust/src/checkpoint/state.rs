//! `StateDict`: the tree of named tensors and counters that IS a training
//! run's durable state, plus its versioned binary codec.
//!
//! Every [`Checkpointable`](crate::checkpoint::Checkpointable) component
//! (optimizers, model, RNG, schedules) serializes to a [`StateDict`] — a
//! nested map of named f32 tensors and scalar counters. The binary codec is
//! versioned and endian-stable (everything little-endian, f32/f64 stored as
//! raw bits), so a checkpoint written on one host restores *bitwise* on
//! another: restoring and continuing a run reproduces the exact loss series
//! the uninterrupted run would have produced. A lossy-but-readable JSON
//! debug dump (via [`crate::util::json`]) is available for inspection.
//!
//! Keys are sorted (BTreeMap), so encoding is deterministic: the same state
//! always produces the same bytes, which is what makes the manifest's
//! content hashes meaningful.

use crate::linalg::Matrix;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Magic prefix of every `.bin` state blob.
pub const STATE_MAGIC: &[u8; 8] = b"MKORCKPT";

/// Binary format version written by this build (bump on layout changes).
pub const STATE_FORMAT_VERSION: u32 = 1;

/// Why a state dict failed to decode or load into a component.
#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum StateError {
    #[error("missing state key `{key}`")]
    MissingKey { key: String },
    #[error("unexpected state key `{key}`")]
    UnexpectedKey { key: String },
    #[error("state key `{key}`: expected a {expected}, found a {found}")]
    TypeMismatch {
        key: String,
        expected: &'static str,
        found: &'static str,
    },
    #[error(
        "state key `{key}`: shape mismatch: expected {expected_rows}x{expected_cols}, \
         found {found_rows}x{found_cols}"
    )]
    ShapeMismatch {
        key: String,
        expected_rows: usize,
        expected_cols: usize,
        found_rows: usize,
        found_cols: usize,
    },
    #[error("state key `{key}`: {reason}")]
    Invalid { key: String, reason: String },
    #[error("not a state blob (bad magic)")]
    BadMagic,
    #[error("unsupported state format version {found} (this build reads version {supported})")]
    BadVersion { found: u32, supported: u32 },
    #[error("truncated state blob at byte {at}")]
    Truncated { at: usize },
    #[error("bad value tag {tag} at byte {at}")]
    BadTag { tag: u8, at: usize },
    #[error("{extra} trailing bytes after state blob")]
    TrailingBytes { extra: usize },
}

impl StateError {
    /// Shorthand for [`StateError::Invalid`].
    pub fn invalid(key: &str, reason: impl Into<String>) -> StateError {
        StateError::Invalid {
            key: key.to_string(),
            reason: reason.into(),
        }
    }
}

/// A dense f32 tensor with explicit shape (vectors are `len × 1`).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn from_matrix(m: &Matrix) -> Tensor {
        Tensor {
            rows: m.rows(),
            cols: m.cols(),
            data: m.data().to_vec(),
        }
    }

    pub fn from_slice(v: &[f32]) -> Tensor {
        Tensor {
            rows: v.len(),
            cols: 1,
            data: v.to_vec(),
        }
    }

    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.clone())
    }
}

/// One value of a [`StateDict`] tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Dense f32 tensor (factor inverses, moments, weights).
    Tensor(Tensor),
    /// Unsigned counter (step counts, trigger counts, RNG words, flags).
    U64(u64),
    /// f64 scalar (EMA accumulators, losses); stored as raw bits, so the
    /// round-trip is bitwise.
    F64(f64),
    /// Nested dict (per-layer state, sub-components).
    Dict(StateDict),
}

impl Value {
    fn kind(&self) -> &'static str {
        match self {
            Value::Tensor(_) => "tensor",
            Value::U64(_) => "u64",
            Value::F64(_) => "f64",
            Value::Dict(_) => "dict",
        }
    }
}

/// A nested map of named tensors and counters — the serialized state of one
/// [`Checkpointable`](crate::checkpoint::Checkpointable) component.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct StateDict {
    entries: BTreeMap<String, Value>,
}

impl StateDict {
    pub fn new() -> StateDict {
        StateDict::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    // ---- insertion ----------------------------------------------------

    pub fn put(&mut self, key: &str, value: Value) -> &mut Self {
        self.entries.insert(key.to_string(), value);
        self
    }

    pub fn put_matrix(&mut self, key: &str, m: &Matrix) -> &mut Self {
        self.put(key, Value::Tensor(Tensor::from_matrix(m)))
    }

    pub fn put_vector(&mut self, key: &str, v: &[f32]) -> &mut Self {
        self.put(key, Value::Tensor(Tensor::from_slice(v)))
    }

    pub fn put_u64(&mut self, key: &str, v: u64) -> &mut Self {
        self.put(key, Value::U64(v))
    }

    pub fn put_usize(&mut self, key: &str, v: usize) -> &mut Self {
        self.put_u64(key, v as u64)
    }

    pub fn put_f64(&mut self, key: &str, v: f64) -> &mut Self {
        self.put(key, Value::F64(v))
    }

    pub fn put_dict(&mut self, key: &str, d: StateDict) -> &mut Self {
        self.put(key, Value::Dict(d))
    }

    /// Store `Some` values only; `None` leaves the key absent (read back
    /// with [`StateDict::opt_u64`]).
    pub fn put_opt_u64(&mut self, key: &str, v: Option<u64>) -> &mut Self {
        if let Some(v) = v {
            self.put_u64(key, v);
        }
        self
    }

    /// Store `Some` values only; `None` leaves the key absent.
    pub fn put_opt_f64(&mut self, key: &str, v: Option<f64>) -> &mut Self {
        if let Some(v) = v {
            self.put_f64(key, v);
        }
        self
    }

    // ---- typed access -------------------------------------------------

    fn require(&self, key: &str) -> Result<&Value, StateError> {
        self.entries.get(key).ok_or_else(|| StateError::MissingKey {
            key: key.to_string(),
        })
    }

    fn mismatch(key: &str, expected: &'static str, found: &Value) -> StateError {
        StateError::TypeMismatch {
            key: key.to_string(),
            expected,
            found: found.kind(),
        }
    }

    /// The raw tensor under `key` (no shape check — callers with partially
    /// data-dependent shapes, e.g. SNGD's stored batches, validate the
    /// dimensions they do know).
    pub fn tensor(&self, key: &str) -> Result<&Tensor, StateError> {
        match self.require(key)? {
            Value::Tensor(t) => Ok(t),
            other => Err(StateDict::mismatch(key, "tensor", other)),
        }
    }

    /// The tensor under `key` as a [`Matrix`], checked against the expected
    /// shape.
    pub fn matrix(&self, key: &str, rows: usize, cols: usize) -> Result<Matrix, StateError> {
        let t = self.tensor(key)?;
        if t.rows != rows || t.cols != cols {
            return Err(StateError::ShapeMismatch {
                key: key.to_string(),
                expected_rows: rows,
                expected_cols: cols,
                found_rows: t.rows,
                found_cols: t.cols,
            });
        }
        Ok(t.to_matrix())
    }

    /// The tensor under `key` as a flat vector of the expected length.
    pub fn vector(&self, key: &str, len: usize) -> Result<Vec<f32>, StateError> {
        let t = self.tensor(key)?;
        if t.rows != len || t.cols != 1 {
            return Err(StateError::ShapeMismatch {
                key: key.to_string(),
                expected_rows: len,
                expected_cols: 1,
                found_rows: t.rows,
                found_cols: t.cols,
            });
        }
        Ok(t.data.clone())
    }

    pub fn u64v(&self, key: &str) -> Result<u64, StateError> {
        match self.require(key)? {
            Value::U64(v) => Ok(*v),
            other => Err(StateDict::mismatch(key, "u64", other)),
        }
    }

    pub fn usizev(&self, key: &str) -> Result<usize, StateError> {
        Ok(self.u64v(key)? as usize)
    }

    pub fn f64v(&self, key: &str) -> Result<f64, StateError> {
        match self.require(key)? {
            Value::F64(v) => Ok(*v),
            other => Err(StateDict::mismatch(key, "f64", other)),
        }
    }

    pub fn dict(&self, key: &str) -> Result<&StateDict, StateError> {
        match self.require(key)? {
            Value::Dict(d) => Ok(d),
            other => Err(StateDict::mismatch(key, "dict", other)),
        }
    }

    /// An optional counter (absent key → `None`).
    pub fn opt_u64(&self, key: &str) -> Result<Option<u64>, StateError> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(Value::U64(v)) => Ok(Some(*v)),
            Some(other) => Err(StateDict::mismatch(key, "u64", other)),
        }
    }

    /// An optional f64 scalar (absent key → `None`).
    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>, StateError> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(Value::F64(v)) => Ok(Some(*v)),
            Some(other) => Err(StateDict::mismatch(key, "f64", other)),
        }
    }

    /// An optional tensor (absent key → `None`; no shape check).
    pub fn opt_tensor(&self, key: &str) -> Result<Option<&Tensor>, StateError> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(Value::Tensor(t)) => Ok(Some(t)),
            Some(other) => Err(StateDict::mismatch(key, "tensor", other)),
        }
    }

    /// Error unless this dict's key set is exactly `required` plus any
    /// subset of `optional` — the missing-/unexpected-key contract of every
    /// `load_state_dict` implementation.
    pub fn check_keys(&self, required: &[&str], optional: &[&str]) -> Result<(), StateError> {
        for key in required {
            if !self.contains(key) {
                return Err(StateError::MissingKey {
                    key: key.to_string(),
                });
            }
        }
        for key in self.keys() {
            if !required.contains(&key) && !optional.contains(&key) {
                return Err(StateError::UnexpectedKey {
                    key: key.to_string(),
                });
            }
        }
        Ok(())
    }

    /// [`StateDict::check_keys`] for dynamically-built key lists (per-layer
    /// indices).
    pub fn check_keys_exact(&self, required: &[String]) -> Result<(), StateError> {
        for key in required {
            if !self.contains(key) {
                return Err(StateError::MissingKey { key: key.clone() });
            }
        }
        for key in self.keys() {
            if !required.iter().any(|r| r == key) {
                return Err(StateError::UnexpectedKey {
                    key: key.to_string(),
                });
            }
        }
        Ok(())
    }

    // ---- binary codec --------------------------------------------------

    /// Encode to the versioned binary format. Deterministic: sorted keys,
    /// little-endian throughout, floats as raw bits.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(STATE_MAGIC);
        out.extend_from_slice(&STATE_FORMAT_VERSION.to_le_bytes());
        encode_dict(self, &mut out);
        out
    }

    /// Decode a blob produced by [`StateDict::to_bytes`]. Every failure
    /// mode (bad magic, unknown version, truncation, bad tags, trailing
    /// garbage) is a distinct [`StateError`].
    pub fn from_bytes(bytes: &[u8]) -> Result<StateDict, StateError> {
        let mut c = Cursor { b: bytes, pos: 0 };
        let magic = c.take(STATE_MAGIC.len())?;
        if magic != STATE_MAGIC {
            return Err(StateError::BadMagic);
        }
        let version = c.u32()?;
        if version != STATE_FORMAT_VERSION {
            return Err(StateError::BadVersion {
                found: version,
                supported: STATE_FORMAT_VERSION,
            });
        }
        let dict = decode_dict(&mut c)?;
        if c.pos != c.b.len() {
            return Err(StateError::TrailingBytes {
                extra: c.b.len() - c.pos,
            });
        }
        Ok(dict)
    }

    // ---- JSON debug dump -----------------------------------------------

    /// Human-readable JSON dump for debugging. Lossy (u64 counters beyond
    /// 2^53 and f64 bit patterns degrade through JSON numbers) — the binary
    /// codec is the round-trip format; this is for eyeballs.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (k, v) in &self.entries {
            o.set(k, value_json(v));
        }
        o
    }
}

fn value_json(v: &Value) -> Json {
    match v {
        Value::Tensor(t) => {
            let mut o = Json::obj();
            o.set("rows", Json::Num(t.rows as f64))
                .set("cols", Json::Num(t.cols as f64))
                .set("data", Json::from_f32s(&t.data));
            o
        }
        Value::U64(n) => Json::Num(*n as f64),
        Value::F64(x) => Json::Num(*x),
        Value::Dict(d) => d.to_json(),
    }
}

// Value tags of the binary format.
const TAG_TENSOR: u8 = 1;
const TAG_U64: u8 = 2;
const TAG_F64: u8 = 3;
const TAG_DICT: u8 = 4;

fn encode_dict(d: &StateDict, out: &mut Vec<u8>) {
    out.extend_from_slice(&(d.entries.len() as u32).to_le_bytes());
    for (k, v) in &d.entries {
        out.extend_from_slice(&(k.len() as u32).to_le_bytes());
        out.extend_from_slice(k.as_bytes());
        encode_value(v, out);
    }
}

fn encode_value(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Tensor(t) => {
            out.push(TAG_TENSOR);
            out.extend_from_slice(&(t.rows as u32).to_le_bytes());
            out.extend_from_slice(&(t.cols as u32).to_le_bytes());
            for x in &t.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Value::U64(n) => {
            out.push(TAG_U64);
            out.extend_from_slice(&n.to_le_bytes());
        }
        Value::F64(x) => {
            out.push(TAG_F64);
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        Value::Dict(d) => {
            out.push(TAG_DICT);
            encode_dict(d, out);
        }
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], StateError> {
        // checked_add: corrupted length fields must not overflow-panic.
        let end = self
            .pos
            .checked_add(n)
            .filter(|&end| end <= self.b.len())
            .ok_or(StateError::Truncated { at: self.b.len() })?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, StateError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, StateError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, StateError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode_dict(c: &mut Cursor) -> Result<StateDict, StateError> {
    let n = c.u32()? as usize;
    let mut d = StateDict::new();
    for _ in 0..n {
        let klen = c.u32()? as usize;
        let key = std::str::from_utf8(c.take(klen)?)
            .map_err(|_| StateError::invalid("<key>", "non-utf8 key bytes"))?
            .to_string();
        let value = decode_value(c)?;
        d.entries.insert(key, value);
    }
    Ok(d)
}

fn decode_value(c: &mut Cursor) -> Result<Value, StateError> {
    let at = c.pos;
    let tag = c.u8()?;
    match tag {
        TAG_TENSOR => {
            let rows = c.u32()? as usize;
            let cols = c.u32()? as usize;
            let n = rows
                .checked_mul(cols)
                .and_then(|n| n.checked_mul(4))
                .ok_or(StateError::Truncated { at })?;
            let raw = c.take(n)?;
            let data = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Ok(Value::Tensor(Tensor { rows, cols, data }))
        }
        TAG_U64 => Ok(Value::U64(c.u64()?)),
        TAG_F64 => Ok(Value::F64(f64::from_bits(c.u64()?))),
        TAG_DICT => Ok(Value::Dict(decode_dict(c)?)),
        tag => Err(StateError::BadTag { tag, at }),
    }
}

/// FNV-1a 64-bit content hash — the manifest's integrity check over each
/// component's encoded bytes.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StateDict {
        let mut layer = StateDict::new();
        layer
            .put_matrix("w", &Matrix::from_rows(&[&[1.0, 2.0], &[3.0, -4.5]]))
            .put_vector("bias", &[0.5, -0.25]);
        let mut sd = StateDict::new();
        sd.put_u64("t", 42)
            .put_f64("ema", 0.123456789012345)
            .put_opt_f64("last_loss", Some(std::f64::consts::PI / 3.0))
            .put_dict("layer0", layer);
        sd
    }

    #[test]
    fn binary_roundtrip_is_exact() {
        let sd = sample();
        let bytes = sd.to_bytes();
        let re = StateDict::from_bytes(&bytes).unwrap();
        assert_eq!(re, sd);
        // Deterministic encoding: same dict → same bytes.
        assert_eq!(re.to_bytes(), bytes);
    }

    #[test]
    fn typed_getters_and_shape_checks() {
        let sd = sample();
        assert_eq!(sd.u64v("t").unwrap(), 42);
        assert!((sd.f64v("ema").unwrap() - 0.123456789012345).abs() == 0.0);
        let layer = sd.dict("layer0").unwrap();
        let w = layer.matrix("w", 2, 2).unwrap();
        assert_eq!(w[(1, 1)], -4.5);
        assert_eq!(layer.vector("bias", 2).unwrap(), vec![0.5, -0.25]);
        // Wrong shape is a ShapeMismatch naming the key.
        let e = layer.matrix("w", 3, 2).unwrap_err();
        assert!(matches!(e, StateError::ShapeMismatch { .. }), "{e:?}");
        assert!(e.to_string().contains("`w`"), "{e}");
        // Wrong type is a TypeMismatch.
        let e = sd.matrix("t", 1, 1).unwrap_err();
        assert!(matches!(e, StateError::TypeMismatch { .. }), "{e:?}");
        // Missing key is a MissingKey.
        let e = sd.u64v("nope").unwrap_err();
        assert!(matches!(e, StateError::MissingKey { .. }), "{e:?}");
    }

    #[test]
    fn optional_values_roundtrip_presence() {
        let mut sd = StateDict::new();
        sd.put_opt_u64("present", Some(7)).put_opt_u64("absent", None);
        assert_eq!(sd.opt_u64("present").unwrap(), Some(7));
        assert_eq!(sd.opt_u64("absent").unwrap(), None);
        let re = StateDict::from_bytes(&sd.to_bytes()).unwrap();
        assert_eq!(re.opt_u64("present").unwrap(), Some(7));
        assert_eq!(re.opt_u64("absent").unwrap(), None);
    }

    #[test]
    fn check_keys_flags_missing_and_unexpected() {
        let sd = sample();
        sd.check_keys(&["t", "ema", "layer0"], &["last_loss"]).unwrap();
        let e = sd.check_keys(&["t", "ema"], &["last_loss"]).unwrap_err();
        assert!(matches!(&e, StateError::UnexpectedKey { key } if key == "layer0"), "{e:?}");
        let e = sd
            .check_keys(&["t", "ema", "layer0", "gone"], &["last_loss"])
            .unwrap_err();
        assert!(matches!(&e, StateError::MissingKey { key } if key == "gone"), "{e:?}");
    }

    #[test]
    fn decode_rejects_corruption() {
        let sd = sample();
        let bytes = sd.to_bytes();
        // Truncation at any prefix fails with Truncated (never panics).
        for cut in [3, 8, 12, 20, bytes.len() - 1] {
            let e = StateDict::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(e, StateError::Truncated { .. } | StateError::BadMagic),
                "cut={cut}: {e:?}"
            );
        }
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert_eq!(StateDict::from_bytes(&bad), Err(StateError::BadMagic));
        // Future version.
        let mut newer = bytes.clone();
        newer[8..12].copy_from_slice(&99u32.to_le_bytes());
        assert!(matches!(
            StateDict::from_bytes(&newer),
            Err(StateError::BadVersion { found: 99, .. })
        ));
        // Trailing garbage.
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(
            StateDict::from_bytes(&long),
            Err(StateError::TrailingBytes { extra: 1 })
        ));
    }

    #[test]
    fn f64_bits_survive_exactly() {
        // The codec must round-trip every bit pattern, including ones JSON
        // would mangle.
        for x in [0.1f64, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -0.0] {
            let mut sd = StateDict::new();
            sd.put_f64("x", x);
            let re = StateDict::from_bytes(&sd.to_bytes()).unwrap();
            assert_eq!(re.f64v("x").unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn json_dump_is_readable() {
        let j = sample().to_json();
        assert_eq!(j.get("t").unwrap().as_usize(), Some(42));
        let w = j.get("layer0").unwrap().get("w").unwrap();
        assert_eq!(w.get("rows").unwrap().as_usize(), Some(2));
        assert_eq!(w.get("data").unwrap().as_arr().unwrap().len(), 4);
        // The dump parses back as JSON.
        assert!(Json::parse(&format!("{j:#}")).is_ok());
    }

    #[test]
    fn fnv_hash_is_stable_and_content_sensitive() {
        let a = fnv1a64(b"hello");
        assert_eq!(a, fnv1a64(b"hello"));
        assert_ne!(a, fnv1a64(b"hellp"));
        // Known FNV-1a test vector.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
    }
}
