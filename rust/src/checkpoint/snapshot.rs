//! The [`Checkpointable`] trait: `state_dict()` / `load_state_dict()` for
//! every stateful training component.
//!
//! Implementations live next to the state they serialize: each optimizer
//! implements the trait in its own module (`optim/*`), the LR schedules in
//! [`crate::optim::schedule`], and this module covers the model
//! ([`Dense`] / [`Mlp`]) and the harness RNG ([`Rng`]). The contract every
//! implementation honors:
//!
//! * `state_dict()` captures everything the component needs to continue a
//!   run bitwise — restoring into a freshly-constructed component (same
//!   configuration) and stepping on must produce the exact trajectory the
//!   uninterrupted component would have;
//! * `load_state_dict()` validates: a missing key is
//!   [`StateError::MissingKey`], a key the component doesn't know is
//!   [`StateError::UnexpectedKey`], and a tensor of the wrong shape is
//!   [`StateError::ShapeMismatch`] — configuration mismatches fail loudly
//!   instead of silently corrupting a resumed run.
//!
//! Hyperparameters are deliberately NOT in state dicts: they live in the
//! [`OptimizerSpec`](crate::optim::OptimizerSpec) recorded by the
//! checkpoint manifest, which reconstructs the component before the state
//! is loaded into it.

use crate::checkpoint::state::{StateDict, StateError};
use crate::linalg::Matrix;
use crate::model::{Dense, Mlp, Transformer};
use crate::util::Rng;

/// Save/restore interface for stateful training components.
pub trait Checkpointable {
    /// Serialize the component's mutable state (not its configuration).
    fn state_dict(&self) -> StateDict;

    /// Restore state captured by [`Checkpointable::state_dict`] on a
    /// component with the same configuration. Errors (and leaves the
    /// component in an unspecified but memory-safe state) on missing /
    /// unexpected keys and shape mismatches.
    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError>;
}

/// Store an indexed list of matrices under `key` (entries `"0"`, `"1"`, …).
pub fn put_matrices<'a>(
    sd: &mut StateDict,
    key: &str,
    items: impl IntoIterator<Item = &'a Matrix>,
) {
    let mut d = StateDict::new();
    for (i, m) in items.into_iter().enumerate() {
        d.put_matrix(&i.to_string(), m);
    }
    sd.put_dict(key, d);
}

/// Store an indexed list of vectors under `key`.
pub fn put_vectors<'a>(
    sd: &mut StateDict,
    key: &str,
    items: impl IntoIterator<Item = &'a Vec<f32>>,
) {
    let mut d = StateDict::new();
    for (i, v) in items.into_iter().enumerate() {
        d.put_vector(&i.to_string(), v);
    }
    sd.put_dict(key, d);
}

/// Read back what [`put_matrices`] stored, validating the entry count and
/// every shape against `shapes` (so a checkpoint from a differently-sized
/// model fails with a named error instead of loading garbage).
pub fn matrices_from(
    sd: &StateDict,
    key: &str,
    shapes: &[(usize, usize)],
) -> Result<Vec<Matrix>, StateError> {
    let d = sd.dict(key)?;
    let expected: Vec<String> = (0..shapes.len()).map(|i| i.to_string()).collect();
    d.check_keys_exact(&expected)?;
    shapes
        .iter()
        .enumerate()
        .map(|(i, &(rows, cols))| d.matrix(&i.to_string(), rows, cols))
        .collect()
}

/// Read back what [`put_vectors`] stored, validating count and lengths.
pub fn vectors_from(
    sd: &StateDict,
    key: &str,
    lens: &[usize],
) -> Result<Vec<Vec<f32>>, StateError> {
    let d = sd.dict(key)?;
    let expected: Vec<String> = (0..lens.len()).map(|i| i.to_string()).collect();
    d.check_keys_exact(&expected)?;
    lens.iter()
        .enumerate()
        .map(|(i, &len)| d.vector(&i.to_string(), len))
        .collect()
}

impl Checkpointable for Dense {
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.put_matrix("w", &self.w).put_vector("bias", &self.bias);
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(&["w", "bias"], &[])?;
        self.w = state.matrix("w", self.w.rows(), self.w.cols())?;
        self.bias = state.vector("bias", self.bias.len())?;
        Ok(())
    }
}

impl Checkpointable for Mlp {
    fn state_dict(&self) -> StateDict {
        // Forward caches are per-batch scratch, not run state.
        let mut sd = StateDict::new();
        for (i, layer) in self.layers.iter().enumerate() {
            sd.put_dict(&format!("layer{i}"), layer.state_dict());
        }
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        let expected: Vec<String> = (0..self.layers.len()).map(|i| format!("layer{i}")).collect();
        state.check_keys_exact(&expected)?;
        for (i, layer) in self.layers.iter_mut().enumerate() {
            layer.load_state_dict(state.dict(&format!("layer{i}"))?)?;
        }
        Ok(())
    }
}

impl Checkpointable for Transformer {
    fn state_dict(&self) -> StateDict {
        // Same layer{i} layout as the MLP: the learnable state IS the flat
        // Dense list (the positional table is configuration — rebuilt from
        // TransformerConfig — and forward caches are per-batch scratch).
        let mut sd = StateDict::new();
        for (i, layer) in self.layers.iter().enumerate() {
            sd.put_dict(&format!("layer{i}"), layer.state_dict());
        }
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        let expected: Vec<String> = (0..self.layers.len()).map(|i| format!("layer{i}")).collect();
        state.check_keys_exact(&expected)?;
        for (i, layer) in self.layers.iter_mut().enumerate() {
            layer.load_state_dict(state.dict(&format!("layer{i}"))?)?;
        }
        Ok(())
    }
}

impl Checkpointable for Rng {
    fn state_dict(&self) -> StateDict {
        let (s, spare) = self.state();
        let mut sd = StateDict::new();
        for (i, word) in s.iter().enumerate() {
            sd.put_u64(&format!("s{i}"), *word);
        }
        sd.put_opt_f64("gauss_spare", spare);
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(&["s0", "s1", "s2", "s3"], &["gauss_spare"])?;
        let s = [
            state.u64v("s0")?,
            state.u64v("s1")?,
            state.u64v("s2")?,
            state.u64v("s3")?,
        ];
        self.set_state(s, state.opt_f64("gauss_spare")?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;

    #[test]
    fn mlp_roundtrip_restores_exact_weights() {
        let mut rng = Rng::new(3);
        let net = Mlp::new(&[4, 6, 2], Activation::Tanh, &mut rng);
        let sd = net.state_dict();
        // Perturb, then restore.
        let mut other = Mlp::new(&[4, 6, 2], Activation::Tanh, &mut rng);
        other.load_state_dict(&sd).unwrap();
        for (a, b) in net.layers.iter().zip(&other.layers) {
            assert_eq!(a.w.data(), b.w.data());
            assert_eq!(a.bias, b.bias);
        }
        // And the round-tripped dict is identical.
        assert_eq!(other.state_dict(), sd);
        // A differently-shaped model rejects the load with a shape error.
        let mut wrong = Mlp::new(&[4, 8, 2], Activation::Tanh, &mut rng);
        let e = wrong.load_state_dict(&sd).unwrap_err();
        assert!(matches!(e, StateError::ShapeMismatch { .. }), "{e:?}");
        // A model with a different layer count rejects by key set.
        let mut deeper = Mlp::new(&[4, 6, 6, 2], Activation::Tanh, &mut rng);
        let e = deeper.load_state_dict(&sd).unwrap_err();
        assert!(matches!(e, StateError::MissingKey { .. }), "{e:?}");
    }

    #[test]
    fn transformer_roundtrip_restores_exact_weights() {
        use crate::model::TransformerConfig;
        let cfg = TransformerConfig {
            vocab: 9,
            d_model: 8,
            n_heads: 2,
            n_blocks: 1,
            d_ff: 12,
            seq_len: 4,
        };
        let mut rng = Rng::new(5);
        let net = Transformer::new(cfg, &mut rng);
        let sd = net.state_dict();
        let mut other = Transformer::new(cfg, &mut rng);
        other.load_state_dict(&sd).unwrap();
        for (a, b) in net.layers.iter().zip(&other.layers) {
            assert_eq!(a.w.data(), b.w.data());
            assert_eq!(a.bias, b.bias);
        }
        assert_eq!(other.state_dict(), sd);
        // A deeper model rejects the load by key set (layer count).
        let mut deeper =
            Transformer::new(TransformerConfig { n_blocks: 2, ..cfg }, &mut rng);
        assert!(deeper.load_state_dict(&sd).is_err());
    }

    #[test]
    fn rng_roundtrip_continues_the_stream_bitwise() {
        let mut a = Rng::new(99);
        // Consume an odd number of gaussians so the Box–Muller spare is
        // populated — the tricky half of the state.
        let _ = a.gaussian();
        let _ = a.next_u64();
        let sd = a.state_dict();
        let mut b = Rng::new(0);
        b.load_state_dict(&sd).unwrap();
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
        }
    }

    #[test]
    fn indexed_helpers_validate_count_and_shape() {
        let mut sd = StateDict::new();
        let ms = [Matrix::identity(2), Matrix::zeros(3, 2)];
        put_matrices(&mut sd, "m", ms.iter());
        let got = matrices_from(&sd, "m", &[(2, 2), (3, 2)]).unwrap();
        assert_eq!(got[1].rows(), 3);
        // Wrong count → missing/unexpected key.
        assert!(matrices_from(&sd, "m", &[(2, 2)]).is_err());
        assert!(matrices_from(&sd, "m", &[(2, 2), (3, 2), (1, 1)]).is_err());
        // Wrong shape → ShapeMismatch.
        let e = matrices_from(&sd, "m", &[(2, 2), (2, 3)]).unwrap_err();
        assert!(matches!(e, StateError::ShapeMismatch { .. }), "{e:?}");
        // Vectors behave the same.
        let vs = [vec![1.0f32, 2.0], vec![3.0]];
        put_vectors(&mut sd, "v", vs.iter());
        assert_eq!(vectors_from(&sd, "v", &[2, 1]).unwrap()[0], vec![1.0, 2.0]);
        assert!(vectors_from(&sd, "v", &[2, 2]).is_err());
    }
}
