//! Checkpoint subsystem: durable save/restore of training state, resumable
//! runs and sweeps.
//!
//! MKOR's whole value proposition is *frequent* second-order updates — the
//! factor inverses accumulated by rank-1 updates ARE the optimizer, so a
//! killed run used to lose them all. This subsystem makes training state
//! durable in three layers:
//!
//! 1. [`state`] — [`StateDict`], a nested map of named f32 tensors and
//!    scalar counters, with a versioned, endian-stable binary codec
//!    (bitwise round-trips) and a JSON debug dump;
//! 2. [`snapshot`] — the [`Checkpointable`] trait
//!    (`state_dict()` / `load_state_dict()` with missing-/unexpected-key
//!    and shape-mismatch errors), implemented by every optimizer, the
//!    model, the LR schedules and the harness RNG;
//! 3. [`manifest`] — [`Checkpoint`] directories: a manifest JSON carrying
//!    the canonical `OptimizerSpec` string, step count, task and
//!    per-component content hashes, plus one `.bin` blob per component,
//!    validated on load.
//!
//! The acceptance property is **bitwise resume equivalence**: training 2N
//! steps straight and training N steps, checkpointing, restoring into a
//! fresh process and training N more produce identical loss series and
//! final weights (`rust/tests/checkpoint_resume.rs` asserts this for mkor,
//! mkor-h, kfac and lamb).
//!
//! Entry points: `TrainerBuilder::checkpoint_every/checkpoint_dir/
//! resume_from` (plus `keep_every`/`keep_best` for step-stamped retention
//! pruned to the best eval metrics — see [`manifest::gc_retained`]), the
//! `RunOpts` checkpoint knobs in [`crate::experiments::convergence`], and
//! the CLI (`mkor sim --checkpoint-every N --checkpoint-dir D
//! --resume-from D --keep-every N --keep-best K`, `mkor sweep --resume`,
//! `mkor ckpt inspect D` to print a checkpoint's manifest and state).
//!
//! The state layer is plain data and can be used directly:
//!
//! ```
//! use mkor::checkpoint::StateDict;
//!
//! let mut sd = StateDict::new();
//! sd.put_u64("t", 7).put_f64("ema", 0.5);
//! sd.put_vector("w", &[1.0, -2.5]);
//! let bytes = sd.to_bytes(); // versioned binary codec, bitwise round-trip
//! let re = StateDict::from_bytes(&bytes).unwrap();
//! assert_eq!(re.u64v("t").unwrap(), 7);
//! assert_eq!(re.vector("w", 2).unwrap(), vec![1.0, -2.5]);
//! ```

pub mod manifest;
pub mod snapshot;
pub mod state;

pub use manifest::{
    gc_retained, list_retained, retained_dir_name, retained_metric, Checkpoint, CheckpointError,
    CHECKPOINT_FORMAT_VERSION, MANIFEST_FILE,
};
pub use snapshot::Checkpointable;
pub use state::{fnv1a64, StateDict, StateError, Tensor, Value, STATE_FORMAT_VERSION};
