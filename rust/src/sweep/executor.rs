//! Bounded thread-pool fan-out with per-cell panic isolation.
//!
//! [`run_sweep`] fans a [`SweepGrid`]'s cells out across `jobs` worker
//! threads (std threads + channels — no external dependencies). Every cell
//! builds its own trainer via
//! [`run_record`](crate::experiments::convergence::run_record), so a
//! diverged or panicked cell becomes a failed [`CellResult`] instead of a
//! dead sweep, and results are reassembled in grid order: because each
//! cell seeds its own RNGs and shares no state, the merged report's
//! results are identical for any `jobs` width.

use crate::experiments::convergence::{run_record, RunOpts};
use crate::sweep::grid::SweepGrid;
use crate::sweep::report::{CellResult, SweepReport};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

/// How a sweep runs: per-cell harness options plus the fan-out width.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Worker threads for the fan-out (≥ 1; capped at the cell count).
    pub jobs: usize,
    /// Per-cell run options. `seed` — and `lr`, for cells carrying an `lr`
    /// axis — is overridden per cell. The `inv_freq`/`gamma` override
    /// fields are ignored: cells run through
    /// [`run_record`](crate::experiments::convergence::run_record), which
    /// is driven by the spec alone.
    pub run: RunOpts,
    /// Print one progress line per completed cell.
    pub verbose: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            jobs: 1,
            run: RunOpts::default(),
            verbose: true,
        }
    }
}

/// Extract a human-readable message from a panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

/// Run `f(i)` for every `i in 0..n` across at most `jobs` threads, with
/// per-call panic isolation. Results come back ordered by index, no matter
/// how the calls were scheduled; a panicked call yields `Err(message)`.
pub fn fan_out<T, F>(n: usize, jobs: usize, f: F) -> Vec<Result<T, String>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = jobs.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Result<T, String>)>();
    thread::scope(|scope| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let run = catch_unwind(AssertUnwindSafe(|| f(i)));
                let out = run.map_err(panic_message);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Result<T, String>>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            slots[i] = Some(out);
        }
        slots
            .into_iter()
            .map(|s| s.expect("executor: worker dropped a cell"))
            .collect()
    })
}

/// Run every cell of `grid` and merge the results into a [`SweepReport`].
///
/// Cells are scheduled dynamically over `opts.jobs` threads; the report is
/// always in grid order, with per-cell results independent of scheduling.
pub fn run_sweep(grid: &SweepGrid, opts: &SweepOptions) -> SweepReport {
    let n = grid.cells.len();
    let done = AtomicUsize::new(0);
    let results = fan_out(n, opts.jobs, |i| {
        let cell = &grid.cells[i];
        let mut run = opts.run.clone();
        run.seed = cell.seed;
        if let Some(lr) = cell.lr {
            run.lr = lr;
        }
        let name = format!("{}#s{}", cell.spec.canonical(), cell.seed);
        let record = run_record(&cell.task, &cell.spec, &name, &run);
        let k = done.fetch_add(1, Ordering::SeqCst) + 1;
        if opts.verbose {
            let status = if record.diverged { "DIVERGED" } else { "ok" };
            println!(
                "[{k}/{n}] {} seed={} lr={} → {status}, loss {:.5} after {} steps",
                cell.spec.canonical(),
                cell.seed,
                run.lr,
                record.final_loss(),
                record.steps.len()
            );
        }
        record
    });
    let cells = grid
        .cells
        .iter()
        .zip(results)
        .map(|(cell, out)| {
            let lr = cell.lr.unwrap_or(opts.run.lr);
            match out {
                Ok(record) => CellResult::from_record(cell, lr, record),
                Err(msg) => CellResult::panicked(cell, lr, msg),
            }
        })
        .collect();
    SweepReport { cells }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::convergence::TaskKind;
    use crate::sweep::report::CellStatus;

    #[test]
    fn fan_out_preserves_order_and_isolates_panics() {
        let out = fan_out(8, 3, |i| {
            if i == 5 {
                panic!("boom {i}");
            }
            i * 10
        });
        assert_eq!(out.len(), 8);
        for (i, r) in out.iter().enumerate() {
            if i == 5 {
                assert!(r.as_ref().unwrap_err().contains("boom"), "{r:?}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 10);
            }
        }
    }

    #[test]
    fn fan_out_handles_zero_cells_and_oversized_job_counts() {
        let out: Vec<Result<usize, String>> = fan_out(0, 4, |i| i);
        assert!(out.is_empty());
        let out = fan_out(3, 64, |i| i);
        let out: Vec<usize> = out.into_iter().map(Result::unwrap).collect();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn sweep_runs_cells_and_merges_in_grid_order() {
        let task = TaskKind::Images;
        let specs = "sgd:momentum={0.5,0.9};adam:lr={0.01}";
        let grid = SweepGrid::parse(specs, &task, 3).unwrap();
        assert_eq!(grid.len(), 3);
        let opts = SweepOptions {
            jobs: 2,
            run: RunOpts {
                steps: 4,
                workers: 1,
                batch: 16,
                eval_every: 0,
                hidden: vec![8],
                ..Default::default()
            },
            verbose: false,
        };
        let report = run_sweep(&grid, &opts);
        assert_eq!(report.cells.len(), 3);
        for (cell, res) in grid.cells.iter().zip(&report.cells) {
            assert_eq!(res.spec, cell.spec.canonical());
            assert_eq!(res.seed, 3);
            assert_eq!(res.status, CellStatus::Ok);
            assert_eq!(res.steps_run(), 4);
        }
        // The lr axis reached the harness; the spec stayed clean.
        assert_eq!(report.cells[2].lr, 0.01);
        assert_eq!(report.cells[2].spec, "adam");
    }
}
