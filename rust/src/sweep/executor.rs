//! Bounded thread-pool fan-out with per-cell panic isolation.
//!
//! [`run_sweep`] fans a [`SweepGrid`]'s cells out across `jobs` worker
//! threads (std threads + channels — no external dependencies). Every cell
//! builds its own trainer via
//! [`run_record`](crate::experiments::convergence::run_record), so a
//! diverged or panicked cell becomes a failed [`CellResult`] instead of a
//! dead sweep, and results are reassembled in grid order: because each
//! cell seeds its own RNGs and shares no state, the merged report's
//! results are identical for any `jobs` width.

use crate::coordinator::metrics::sweep_progress_line;
use crate::experiments::convergence::{run_record, RunOpts};
use crate::obs::{self, EventKind, TraceEvent};
use crate::sweep::grid::{SweepCell, SweepGrid};
use crate::sweep::report::{CellResult, SweepReport};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

/// How a sweep runs: per-cell harness options plus the fan-out width.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Worker threads for the fan-out (≥ 1; capped at the cell count).
    pub jobs: usize,
    /// Per-cell run options. `seed` — and `lr`, for cells carrying an `lr`
    /// axis — is overridden per cell. The `inv_freq`/`gamma` override
    /// fields are ignored: cells run through
    /// [`run_record`](crate::experiments::convergence::run_record), which
    /// is driven by the spec alone.
    pub run: RunOpts,
    /// Print one progress line per completed cell.
    pub verbose: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            jobs: 1,
            run: RunOpts::default(),
            verbose: true,
        }
    }
}

impl SweepOptions {
    /// The run options one cell actually trains with: the cell's seed —
    /// and, for cells carrying an `lr` axis, its learning rate — override
    /// the shared options, and when the shared options request
    /// checkpointing (`run.checkpoint_every > 0` with a `checkpoint_dir`),
    /// each cell snapshots into its own `cell-<index>` subdirectory with
    /// resume enabled, so an interrupted cell continues mid-run instead of
    /// restarting. Both the in-process executor and the multi-process
    /// workers derive per-cell options through this one method — that is
    /// what keeps `--jobs` and `--workers` results identical.
    pub fn run_for_cell(&self, cell: &SweepCell) -> RunOpts {
        let mut run = self.run.clone();
        run.seed = cell.seed;
        if let Some(lr) = cell.lr {
            run.lr = lr;
        }
        if run.checkpoint_every > 0 {
            if let Some(root) = &self.run.checkpoint_dir {
                run.checkpoint_dir = Some(root.join(format!("cell-{}", cell.index)));
                run.resume = true;
            }
        }
        run
    }
}

/// Extract a human-readable message from a panic payload.
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

/// Run `f(i)` for every `i in 0..n` across at most `jobs` threads, with
/// per-call panic isolation. Results come back ordered by index, no matter
/// how the calls were scheduled; a panicked call yields `Err(message)`.
pub fn fan_out<T, F>(n: usize, jobs: usize, f: F) -> Vec<Result<T, String>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = jobs.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Result<T, String>)>();
    thread::scope(|scope| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let run = catch_unwind(AssertUnwindSafe(|| f(i)));
                let out = run.map_err(panic_message);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Result<T, String>>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            slots[i] = Some(out);
        }
        slots
            .into_iter()
            .map(|s| s.expect("executor: worker dropped a cell"))
            .collect()
    })
}

/// Run every cell of `grid` and merge the results into a [`SweepReport`].
///
/// Cells are scheduled dynamically over `opts.jobs` threads; the report is
/// always in grid order, with per-cell results independent of scheduling.
pub fn run_sweep(grid: &SweepGrid, opts: &SweepOptions) -> SweepReport {
    run_sweep_resumed(grid, opts, None)
}

/// [`run_sweep`] with resume: cells already present in `prior` — keyed by
/// canonical spec string + task label + seed + lr, the same columns the
/// CSV rows carry — are *skipped* (reported as `skipped` in the progress
/// lines, `skipped: true` on the cell) and their prior results merged into
/// the report unchanged. Panicked prior cells re-run: they never
/// completed. This is what lets an interrupted 1000-cell sweep continue
/// instead of restarting (`mkor sweep --resume` loads `--out` via
/// [`SweepReport::load_csv`]).
pub fn run_sweep_resumed(
    grid: &SweepGrid,
    opts: &SweepOptions,
    prior: Option<&SweepReport>,
) -> SweepReport {
    let n = grid.cells.len();
    let done = AtomicUsize::new(0);
    let results = fan_out(n, opts.jobs, |i| {
        let cell = &grid.cells[i];
        let run = opts.run_for_cell(cell);
        let spec = cell.spec.canonical();
        let task = crate::sweep::grid::task_label(&cell.task);
        let reused =
            prior.and_then(|p| p.reuse_keyed(&spec, &task, cell.seed, run.lr, cell.index));
        if let Some(reused) = reused {
            let k = done.fetch_add(1, Ordering::SeqCst) + 1;
            if opts.verbose {
                let outcome =
                    format!("skipped ({} in prior report)", reused.status.label());
                obs::log::progress(&sweep_progress_line(
                    k, n, &spec, cell.seed, run.lr, &outcome,
                ));
            }
            if obs::enabled() {
                obs::emit(
                    TraceEvent::new(EventKind::CellDone)
                        .label("spec", &spec)
                        .num("cell", cell.index as f64)
                        .num("seed", cell.seed as f64)
                        .num("skipped", 1.0),
                );
            }
            return reused;
        }
        let name = format!("{spec}#s{}", cell.seed);
        let t_cell = std::time::Instant::now();
        let record = run_record(&cell.task, &cell.spec, &name, &run);
        let result = CellResult::from_record(cell, run.lr, record);
        let k = done.fetch_add(1, Ordering::SeqCst) + 1;
        if opts.verbose {
            obs::log::progress(&sweep_progress_line(
                k,
                n,
                &spec,
                cell.seed,
                run.lr,
                &result.outcome_line(),
            ));
        }
        if obs::enabled() {
            obs::emit(
                TraceEvent::new(EventKind::CellDone)
                    .label("spec", &spec)
                    .label("status", result.status.label())
                    .num("cell", cell.index as f64)
                    .num("seed", cell.seed as f64)
                    .num("secs", t_cell.elapsed().as_secs_f64()),
            );
            obs::registry::with_global(|r| {
                r.inc("sweep.cells_done", 1);
                r.observe("sweep.cell_secs", t_cell.elapsed().as_secs_f64());
            });
            // Run-health pulse: `mkor tail` renders the freshest one, so
            // every completion refreshes the sweep's live progress.
            obs::emit(
                TraceEvent::new(EventKind::Heartbeat)
                    .num("cells_done", k as f64)
                    .num("cells", n as f64),
            );
        }
        result
    });
    let cells = grid
        .cells
        .iter()
        .zip(results)
        .map(|(cell, out)| {
            let lr = cell.lr.unwrap_or(opts.run.lr);
            match out {
                Ok(result) => result,
                Err(msg) => CellResult::panicked(cell, lr, msg),
            }
        })
        .collect();
    SweepReport { cells }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::convergence::TaskKind;
    use crate::sweep::report::CellStatus;

    #[test]
    fn fan_out_preserves_order_and_isolates_panics() {
        let out = fan_out(8, 3, |i| {
            if i == 5 {
                panic!("boom {i}");
            }
            i * 10
        });
        assert_eq!(out.len(), 8);
        for (i, r) in out.iter().enumerate() {
            if i == 5 {
                assert!(r.as_ref().unwrap_err().contains("boom"), "{r:?}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 10);
            }
        }
    }

    #[test]
    fn fan_out_handles_zero_cells_and_oversized_job_counts() {
        let out: Vec<Result<usize, String>> = fan_out(0, 4, |i| i);
        assert!(out.is_empty());
        let out = fan_out(3, 64, |i| i);
        let out: Vec<usize> = out.into_iter().map(Result::unwrap).collect();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn sweep_runs_cells_and_merges_in_grid_order() {
        let task = TaskKind::Images;
        let specs = "sgd:momentum={0.5,0.9};adam:lr={0.01}";
        let grid = SweepGrid::parse(specs, &task, 3).unwrap();
        assert_eq!(grid.len(), 3);
        let opts = SweepOptions {
            jobs: 2,
            run: RunOpts {
                steps: 4,
                workers: 1,
                batch: 16,
                eval_every: 0,
                hidden: vec![8],
                ..Default::default()
            },
            verbose: false,
        };
        let report = run_sweep(&grid, &opts);
        assert_eq!(report.cells.len(), 3);
        for (cell, res) in grid.cells.iter().zip(&report.cells) {
            assert_eq!(res.spec, cell.spec.canonical());
            assert_eq!(res.seed, 3);
            assert_eq!(res.status, CellStatus::Ok);
            assert_eq!(res.steps_run(), 4);
        }
        // The lr axis reached the harness; the spec stayed clean.
        assert_eq!(report.cells[2].lr, 0.01);
        assert_eq!(report.cells[2].spec, "adam");
    }

    #[test]
    fn run_for_cell_overrides_seed_lr_and_checkpoint_dir() {
        let task = TaskKind::Images;
        let grid = SweepGrid::parse("sgd:lr={1,0.1} x seed=0..2", &task, 9).unwrap();
        let mut opts = SweepOptions::default();
        opts.run.checkpoint_every = 5;
        opts.run.checkpoint_dir = Some(std::path::PathBuf::from("ckpt"));
        let run = opts.run_for_cell(&grid.cells[3]);
        assert_eq!(run.seed, 1);
        assert_eq!(run.lr, 0.1);
        assert!(run.resume, "per-cell checkpoints resume an interrupted cell");
        assert_eq!(
            run.checkpoint_dir.as_deref(),
            Some(std::path::Path::new("ckpt/cell-3"))
        );
        // Without checkpointing requested, the knobs pass through untouched.
        let plain = SweepOptions::default().run_for_cell(&grid.cells[0]);
        assert_eq!((plain.seed, plain.lr), (0, 1.0));
        assert!(!plain.resume && plain.checkpoint_dir.is_none());
    }

    #[test]
    fn resume_skips_prior_cells_and_reruns_the_rest() {
        let task = TaskKind::Images;
        let grid = SweepGrid::parse("sgd:momentum={0.5,0.9};adam", &task, 3).unwrap();
        let opts = SweepOptions {
            jobs: 2,
            run: RunOpts {
                steps: 4,
                workers: 1,
                batch: 16,
                eval_every: 0,
                hidden: vec![8],
                ..Default::default()
            },
            verbose: false,
        };
        let full = run_sweep(&grid, &opts);

        // Prior report holding only the first and last cell (as if the
        // middle cell was lost to an interruption).
        let prior = SweepReport {
            cells: vec![full.cells[0].clone(), full.cells[2].clone()],
        };
        let resumed = run_sweep_resumed(&grid, &opts, Some(&prior));
        assert_eq!(resumed.cells.len(), 3);
        assert!(resumed.cells[0].skipped);
        assert!(!resumed.cells[1].skipped, "missing cell must re-run");
        assert!(resumed.cells[2].skipped);
        // Deterministic per-cell results: the re-run middle cell matches
        // the full sweep, and reused cells are carried through unchanged.
        assert_eq!(resumed.to_csv_deterministic(), full.to_csv_deterministic());

        // A panicked prior cell is NOT treated as done: it re-runs.
        let mut prior = prior;
        prior.cells[0].status = CellStatus::Panicked("boom".to_string());
        prior.cells[0].record = None;
        let resumed = run_sweep_resumed(&grid, &opts, Some(&prior));
        assert!(!resumed.cells[0].skipped);
        assert_eq!(resumed.cells[0].status, CellStatus::Ok);
    }

    #[test]
    fn resume_key_includes_the_task() {
        // Multi-task grids (SweepGrid::for_tasks) repeat the same
        // spec/seed/lr per task — only the matching task's prior row may
        // satisfy the resume lookup.
        let tasks = [TaskKind::Images, TaskKind::Autoencoder];
        let grid = SweepGrid::for_tasks("sgd", &tasks, 1).unwrap();
        let opts = SweepOptions {
            jobs: 2,
            run: RunOpts {
                steps: 3,
                workers: 1,
                batch: 16,
                eval_every: 0,
                hidden: vec![8],
                ..Default::default()
            },
            verbose: false,
        };
        let full = run_sweep(&grid, &opts);
        let prior = SweepReport { cells: vec![full.cells[0].clone()] };
        let resumed = run_sweep_resumed(&grid, &opts, Some(&prior));
        assert!(resumed.cells[0].skipped);
        assert!(
            !resumed.cells[1].skipped,
            "same spec/seed/lr on a different task must re-run"
        );
        assert_eq!(resumed.cells[1].task, "autoencoder");
        assert_eq!(resumed.to_csv_deterministic(), full.to_csv_deterministic());
    }
}
