//! Merged sweep reports: one artifact per sweep, one row per cell.
//!
//! A [`SweepReport`] aggregates the per-cell
//! [`RunRecord`](crate::coordinator::RunRecord)s of one sweep into a
//! single table keyed by canonical spec string + seed, and writes it as
//! CSV (one data row per cell) and JSON (cell summaries plus loss
//! series). The deterministic variants omit wall-clock timing, so their
//! bytes depend only on the grid and the seeds — never on `--jobs`.

use crate::bench_utils::Table;
use crate::coordinator::RunRecord;
use crate::sweep::grid::{task_label, SweepCell};
use crate::util::json::Json;
use std::path::Path;

/// Terminal state of one cell.
#[derive(Clone, Debug, PartialEq)]
pub enum CellStatus {
    /// Ran its full step budget.
    Ok,
    /// Training diverged (non-finite loss/weights); the record is kept.
    Diverged,
    /// The cell panicked; the message is kept, the record is lost.
    Panicked(String),
}

impl CellStatus {
    pub fn label(&self) -> &'static str {
        match self {
            CellStatus::Ok => "ok",
            CellStatus::Diverged => "diverged",
            CellStatus::Panicked(_) => "panicked",
        }
    }
}

/// Summary columns of a cell loaded back from a saved CSV — everything a
/// resumed sweep needs to re-emit the row unchanged (the loss series lives
/// only in JSON artifacts and is not recoverable from CSV).
#[derive(Clone, Debug, PartialEq)]
pub struct CellSummary {
    pub steps: usize,
    pub final_loss: Option<f64>,
    pub converged_at: Option<usize>,
    pub best_eval: Option<f64>,
    pub wall_secs: f64,
}

/// One cell's outcome: identity (spec/task/seed/lr) + status + record.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Grid position (row order of the merged report).
    pub index: usize,
    /// Canonical spec string — the cell's key in CSV/JSON artifacts.
    pub spec: String,
    pub task: String,
    pub seed: u64,
    /// The harness learning rate this cell actually ran with.
    pub lr: f32,
    pub status: CellStatus,
    /// The full run record (absent for panicked cells and for cells loaded
    /// back from a CSV).
    pub record: Option<RunRecord>,
    /// Summary columns for cells loaded from a CSV (consulted when
    /// `record` is absent, so re-saving reproduces the original row).
    pub loaded: Option<CellSummary>,
    /// True when a resumed sweep reused this cell from a prior report
    /// instead of re-running it.
    pub skipped: bool,
}

impl CellResult {
    /// Wrap a completed (possibly diverged) run.
    pub fn from_record(cell: &SweepCell, lr: f32, record: RunRecord) -> CellResult {
        let status = if record.diverged {
            CellStatus::Diverged
        } else {
            CellStatus::Ok
        };
        CellResult {
            index: cell.index,
            spec: cell.spec.canonical(),
            task: task_label(&cell.task),
            seed: cell.seed,
            lr,
            status,
            record: Some(record),
            loaded: None,
            skipped: false,
        }
    }

    /// Wrap a cell whose worker panicked.
    pub fn panicked(cell: &SweepCell, lr: f32, message: String) -> CellResult {
        CellResult {
            index: cell.index,
            spec: cell.spec.canonical(),
            task: task_label(&cell.task),
            seed: cell.seed,
            lr,
            status: CellStatus::Panicked(message),
            record: None,
            loaded: None,
            skipped: false,
        }
    }

    /// Final training loss, if the cell produced any steps.
    pub fn final_loss(&self) -> Option<f64> {
        if let Some(record) = &self.record {
            return if record.steps.is_empty() {
                None
            } else {
                Some(record.final_loss())
            };
        }
        self.loaded.as_ref().and_then(|s| s.final_loss)
    }

    /// Step at which the run first hit its target metric, if ever.
    pub fn converged_at(&self) -> Option<usize> {
        if let Some(record) = &self.record {
            return record.converged_at;
        }
        self.loaded.as_ref().and_then(|s| s.converged_at)
    }

    /// Best eval metric seen over the run.
    pub fn best_eval(&self) -> Option<f64> {
        if let Some(record) = &self.record {
            return record.best_eval();
        }
        self.loaded.as_ref().and_then(|s| s.best_eval)
    }

    /// Steps the cell recorded (including a diverged final step).
    pub fn steps_run(&self) -> usize {
        if let Some(record) = &self.record {
            return record.steps.len();
        }
        self.loaded.as_ref().map_or(0, |s| s.steps)
    }

    /// Total wall seconds of the cell's own steps.
    pub fn wall_secs(&self) -> f64 {
        if let Some(record) = &self.record {
            return record.total_wall_secs();
        }
        self.loaded.as_ref().map_or(0.0, |s| s.wall_secs)
    }

    /// The outcome half of a progress line
    /// (`ok, loss 0.52341 after 40 steps`) — shared by the in-process
    /// executor and the multi-process dispatcher so `--jobs` and
    /// `--workers` sweeps report identically.
    pub fn outcome_line(&self) -> String {
        match &self.status {
            CellStatus::Panicked(msg) => format!("PANICKED: {msg}"),
            status => {
                let label = match status {
                    CellStatus::Diverged => "DIVERGED",
                    _ => "ok",
                };
                match self.final_loss() {
                    Some(l) => {
                        format!("{label}, loss {l:.5} after {} steps", self.steps_run())
                    }
                    None => format!("{label}, no recorded steps"),
                }
            }
        }
    }

    /// Serialize for the per-worker result stream of `mkor sweep
    /// --workers N` (one compact JSON object per line): the cell identity
    /// (index/spec/task/seed/lr), the status (plus the panic message when
    /// panicked), and — for completed cells — the full lossless
    /// [`RunRecord`] via [`RunRecord::to_json_full`], so the coordinator's
    /// merged CSV/JSON artifacts are byte-identical to an in-process run's.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("index", Json::Num(self.index as f64))
            .set("spec", Json::Str(self.spec.clone()))
            .set("task", Json::Str(self.task.clone()))
            // Seeds are u64; JSON numbers are f64 and corrupt > 2^53, so
            // they travel as strings (the resume key must match exactly).
            .set("seed", seed_to_json(self.seed))
            .set("lr", Json::Num(self.lr as f64))
            .set("status", Json::Str(self.status.label().to_string()));
        if let CellStatus::Panicked(msg) = &self.status {
            j.set("panic", Json::Str(msg.clone()));
        }
        if let Some(record) = &self.record {
            j.set("record", record.to_json_full());
        }
        j
    }

    /// Parse a result written by [`CellResult::to_json`]. Completed
    /// (ok/diverged) results must carry their record — every report column
    /// derives from it — while panicked results never do.
    pub fn from_json(j: &Json) -> Result<CellResult, String> {
        let num = |key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("cell result: missing/invalid `{key}`"))
        };
        let str_field = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("cell result: missing/invalid `{key}`"))
        };
        let status = match str_field("status")?.as_str() {
            "ok" => CellStatus::Ok,
            "diverged" => CellStatus::Diverged,
            "panicked" => CellStatus::Panicked(
                j.get("panic").and_then(Json::as_str).unwrap_or("").to_string(),
            ),
            other => return Err(format!("cell result: unknown status `{other}`")),
        };
        let record = match j.get("record") {
            Some(r) => Some(RunRecord::from_json(r)?),
            None => None,
        };
        if record.is_none() && !matches!(status, CellStatus::Panicked(_)) {
            return Err("cell result: completed cell without a record".to_string());
        }
        let seed = seed_from_json(j.get("seed"))
            .ok_or_else(|| "cell result: missing/invalid `seed`".to_string())?;
        Ok(CellResult {
            index: num("index")? as usize,
            spec: str_field("spec")?,
            task: str_field("task")?,
            seed,
            lr: num("lr")? as f32,
            status,
            record,
            loaded: None,
            skipped: false,
        })
    }
}

/// The merged artifact of one sweep.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    /// One result per cell, in grid order (independent of scheduling).
    pub cells: Vec<CellResult>,
}

impl SweepReport {
    /// `(ok, diverged, panicked)` cell counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut ok = 0;
        let mut diverged = 0;
        let mut panicked = 0;
        for c in &self.cells {
            match c.status {
                CellStatus::Ok => ok += 1,
                CellStatus::Diverged => diverged += 1,
                CellStatus::Panicked(_) => panicked += 1,
            }
        }
        (ok, diverged, panicked)
    }

    /// Look up a cell by canonical spec string and seed. Cells that
    /// differ only in the reserved `lr` axis share this key (lr is not
    /// part of the spec string) — use [`SweepReport::find_with_lr`] there.
    pub fn find(&self, spec: &str, seed: u64) -> Option<&CellResult> {
        self.cells.iter().find(|c| c.spec == spec && c.seed == seed)
    }

    /// [`SweepReport::find`] disambiguated by the harness learning rate,
    /// for grids that sweep the reserved `lr` axis.
    pub fn find_with_lr(&self, spec: &str, seed: u64, lr: f32) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.spec == spec && c.seed == seed && c.lr == lr)
    }

    /// Full-key lookup — canonical spec + task label + seed + lr — the
    /// resume key of [`run_sweep_resumed`](crate::sweep::run_sweep_resumed).
    /// The task matters on multi-task grids
    /// ([`SweepGrid::for_tasks`](crate::sweep::SweepGrid::for_tasks)),
    /// where every task's cell shares the same spec/seed/lr.
    pub fn find_keyed(&self, spec: &str, task: &str, seed: u64, lr: f32) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.spec == spec && c.task == task && c.seed == seed && c.lr == lr)
    }

    /// The resume reuse both executors share: a *completed* (non-panicked
    /// — panicked rows re-run) prior cell under the full resume key,
    /// cloned, renumbered to `index` and marked `skipped`. Keeping this in
    /// one place is what keeps `--jobs` and `--workers` resume skipping
    /// the exact same cells.
    pub fn reuse_keyed(
        &self,
        spec: &str,
        task: &str,
        seed: u64,
        lr: f32,
        index: usize,
    ) -> Option<CellResult> {
        self.find_keyed(spec, task, seed, lr)
            .filter(|c| !matches!(c.status, CellStatus::Panicked(_)))
            .map(|c| {
                let mut reused = c.clone();
                reused.index = index;
                reused.skipped = true;
                reused
            })
    }

    /// Build the report table; `wall` appends the wall-clock column.
    fn table(&self, wall: bool) -> Table {
        let mut headers = vec![
            "cell",
            "spec",
            "task",
            "seed",
            "lr",
            "status",
            "steps",
            "final_loss",
            "converged_at",
            "best_eval",
        ];
        if wall {
            headers.push("wall_secs");
        }
        let mut t = Table::new(&headers);
        for c in &self.cells {
            let fmt_opt = |v: Option<String>| v.unwrap_or_default();
            let mut row = vec![
                c.index.to_string(),
                c.spec.clone(),
                c.task.clone(),
                c.seed.to_string(),
                c.lr.to_string(),
                c.status.label().to_string(),
                c.steps_run().to_string(),
                fmt_opt(c.final_loss().map(|v| v.to_string())),
                fmt_opt(c.converged_at().map(|v| v.to_string())),
                fmt_opt(c.best_eval().map(|v| v.to_string())),
            ];
            if wall {
                row.push(format!("{:.3}", c.wall_secs()));
            }
            t.row(&row);
        }
        t
    }

    /// Pretty table for terminal summaries.
    pub fn render_table(&self) -> String {
        self.table(true).render()
    }

    /// CSV, one row per cell, keyed by canonical spec string; includes the
    /// measured `wall_secs` column.
    pub fn to_csv(&self) -> String {
        self.table(true).to_csv()
    }

    /// CSV without the wall-clock column: byte-identical for any `--jobs`
    /// width, because cell results depend only on each cell's own seed.
    pub fn to_csv_deterministic(&self) -> String {
        self.table(false).to_csv()
    }

    /// JSON form; `deterministic` omits wall-clock timing so the artifact
    /// is byte-identical for any `--jobs` width.
    pub fn to_json_with(&self, deterministic: bool) -> Json {
        let (ok, diverged, panicked) = self.counts();
        let mut o = Json::obj();
        o.set("n_cells", Json::Num(self.cells.len() as f64))
            .set("ok", Json::Num(ok as f64))
            .set("diverged", Json::Num(diverged as f64))
            .set("panicked", Json::Num(panicked as f64));
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let final_loss = c.final_loss().map_or(Json::Null, Json::Num);
                let conv = c.converged_at().map_or(Json::Null, |s| Json::Num(s as f64));
                let best = c.best_eval().map_or(Json::Null, Json::Num);
                let mut j = Json::obj();
                j.set("cell", Json::Num(c.index as f64))
                    .set("spec", Json::Str(c.spec.clone()))
                    .set("task", Json::Str(c.task.clone()))
                    // Seeds are u64 and an f64 JSON number corrupts
                    // > 2^53; the artifact carries them exactly, as the
                    // CSV already does.
                    .set("seed", seed_to_json(c.seed))
                    .set("lr", Json::Num(c.lr as f64))
                    .set("status", Json::Str(c.status.label().to_string()))
                    .set("steps", Json::Num(c.steps_run() as f64))
                    .set("final_loss", final_loss)
                    .set("converged_at", conv)
                    .set("best_eval", best);
                if let Some(r) = &c.record {
                    j.set("loss", Json::from_f64s(&r.loss_series()));
                }
                if let CellStatus::Panicked(msg) = &c.status {
                    j.set("panic", Json::Str(msg.clone()));
                }
                if !deterministic {
                    j.set("wall_secs", Json::Num(c.wall_secs()));
                }
                j
            })
            .collect();
        o.set("cells", Json::Arr(cells));
        o
    }

    /// JSON with wall-clock timing included.
    pub fn to_json(&self) -> Json {
        self.to_json_with(false)
    }

    /// Write CSV; `deterministic` drops the wall-clock column so the
    /// artifact's bytes depend only on the grid and the seeds.
    pub fn save_csv_with(&self, path: &Path, deterministic: bool) -> anyhow::Result<()> {
        let csv = if deterministic {
            self.to_csv_deterministic()
        } else {
            self.to_csv()
        };
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, csv)?;
        Ok(())
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        self.save_csv_with(path, false)
    }

    /// Write JSON; `deterministic` as in [`SweepReport::save_csv_with`].
    pub fn save_json_with(&self, path: &Path, deterministic: bool) -> anyhow::Result<()> {
        self.to_json_with(deterministic).to_file(path)
    }

    pub fn save_json(&self, path: &Path) -> anyhow::Result<()> {
        self.save_json_with(path, false)
    }

    /// Load a report back from a CSV written by [`SweepReport::save_csv`]
    /// (with or without the wall-clock column) — the prior-results source
    /// for `mkor sweep --resume`. Loaded cells carry the summary columns
    /// (not the loss series), keyed exactly as written: canonical spec
    /// string + seed + lr. Numeric columns round-trip exactly because both
    /// the writer and `parse` use shortest-round-trip float formatting.
    pub fn load_csv(path: &Path) -> anyhow::Result<SweepReport> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let mut lines = text.lines();
        let header = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("{}: empty CSV", path.display()))?;
        let cols = split_csv_line(header);
        let col = |name: &str| -> anyhow::Result<usize> {
            cols.iter()
                .position(|c| c == name)
                .ok_or_else(|| anyhow::anyhow!("{}: missing column `{name}`", path.display()))
        };
        let c_cell = col("cell")?;
        let c_spec = col("spec")?;
        let c_task = col("task")?;
        let c_seed = col("seed")?;
        let c_lr = col("lr")?;
        let c_status = col("status")?;
        let c_steps = col("steps")?;
        let c_final = col("final_loss")?;
        let c_conv = col("converged_at")?;
        let c_best = col("best_eval")?;
        let c_wall = cols.iter().position(|c| c == "wall_secs");

        let mut cells = Vec::new();
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f = split_csv_line(line);
            let bad = |what: &str| {
                anyhow::anyhow!("{}:{}: invalid {what}: `{line}`", path.display(), lineno + 2)
            };
            let field = |i: usize| f.get(i).map(String::as_str).unwrap_or("");
            let opt_f64 = |i: usize| -> Option<f64> { field(i).parse().ok() };
            let status = match field(c_status) {
                "ok" => CellStatus::Ok,
                "diverged" => CellStatus::Diverged,
                "panicked" => CellStatus::Panicked(String::new()),
                other => return Err(bad(&format!("status `{other}`"))),
            };
            cells.push(CellResult {
                index: field(c_cell).parse().map_err(|_| bad("cell index"))?,
                spec: field(c_spec).to_string(),
                task: field(c_task).to_string(),
                seed: field(c_seed).parse().map_err(|_| bad("seed"))?,
                lr: field(c_lr).parse().map_err(|_| bad("lr"))?,
                status,
                record: None,
                loaded: Some(CellSummary {
                    steps: field(c_steps).parse().unwrap_or(0),
                    final_loss: opt_f64(c_final),
                    converged_at: field(c_conv).parse().ok(),
                    best_eval: opt_f64(c_best),
                    wall_secs: c_wall.and_then(opt_f64).unwrap_or(0.0),
                }),
                skipped: false,
            });
        }
        Ok(SweepReport { cells })
    }
}

/// Encode a u64 seed for the worker wire formats: JSON numbers are f64
/// and corrupt values above 2^53, so seeds travel as decimal strings —
/// the resume key (canonical spec + task + seed + lr) must match exactly.
pub(crate) fn seed_to_json(seed: u64) -> Json {
    Json::Str(seed.to_string())
}

/// Decode a seed written by [`seed_to_json`]; plain numbers are accepted
/// too (hand-written batch files).
pub(crate) fn seed_from_json(j: Option<&Json>) -> Option<u64> {
    match j {
        Some(Json::Str(s)) => s.parse().ok(),
        Some(Json::Num(n)) => Some(*n as u64),
        _ => None,
    }
}

/// Split one CSV line into fields, honoring the quoting
/// [`Table::to_csv`](crate::bench_utils::Table::to_csv) produces (fields
/// containing commas/quotes are double-quoted, embedded quotes doubled).
fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' if cur.is_empty() => in_quotes = true,
            ',' if !in_quotes => out.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StepRecord;
    use crate::experiments::convergence::TaskKind;
    use crate::optim::OptimizerSpec;

    fn toy_cell(index: usize, spec: &str, seed: u64) -> SweepCell {
        SweepCell {
            index,
            spec: OptimizerSpec::parse(spec).unwrap(),
            seed,
            lr: None,
            task: TaskKind::Images,
        }
    }

    fn toy_record(spec: &str, losses: &[f64]) -> RunRecord {
        RunRecord {
            name: "t".to_string(),
            optimizer: "mkor".to_string(),
            spec: spec.to_string(),
            steps: losses
                .iter()
                .enumerate()
                .map(|(i, &loss)| StepRecord {
                    step: i,
                    loss,
                    eval_metric: None,
                    lr: 0.1,
                    wall_secs: 0.25,
                    grad_comm_bytes: 0,
                    sync_comm_bytes: 0,
                    inverse_updated: false,
                    second_order_secs: 0.0,
                })
                .collect(),
            diverged: false,
            converged_at: Some(1),
            switched_at: None,
        }
    }

    fn toy_report() -> SweepReport {
        let a = toy_cell(0, "mkor:f=25,backend=lamb", 0);
        let b = toy_cell(1, "sgd", 1);
        let rec = toy_record("mkor:f=25,backend=lamb", &[2.0, 1.0]);
        SweepReport {
            cells: vec![
                CellResult::from_record(&a, 0.1, rec),
                CellResult::panicked(&b, 0.1, "boom".to_string()),
            ],
        }
    }

    #[test]
    fn csv_has_one_row_per_cell_with_quoted_specs() {
        let r = toy_report();
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 3, "{csv}");
        assert!(lines[0].starts_with("cell,spec,task,seed,lr,status,"));
        assert!(lines[0].ends_with(",wall_secs"));
        // Spec strings contain commas, so they must be CSV-quoted.
        assert!(lines[1].contains("\"mkor:f=25,backend=lamb\""), "{csv}");
        assert!(lines[2].contains("panicked"));
        // The deterministic form drops only the wall column.
        let det = r.to_csv_deterministic();
        assert!(!det.contains("wall_secs"));
        assert_eq!(det.trim().lines().count(), 3);
    }

    #[test]
    fn summaries_and_lookup() {
        let r = toy_report();
        assert_eq!(r.counts(), (1, 0, 1));
        let cell = r.find("mkor:f=25,backend=lamb", 0).unwrap();
        assert_eq!(cell.final_loss(), Some(1.0));
        assert_eq!(cell.converged_at(), Some(1));
        assert_eq!(cell.steps_run(), 2);
        assert!((cell.wall_secs() - 0.5).abs() < 1e-12);
        assert!(r.find("sgd", 0).is_none(), "seed is part of the key");
        assert!(r.find_with_lr("sgd", 1, 0.1).is_some());
        assert!(r.find_with_lr("sgd", 1, 0.2).is_none(), "lr disambiguates");
        let failed = r.find("sgd", 1).unwrap();
        assert_eq!(failed.final_loss(), None);
        assert_eq!(failed.steps_run(), 0);
    }

    #[test]
    fn json_carries_statuses_loss_series_and_panics() {
        let r = toy_report();
        let j = r.to_json();
        assert_eq!(j.get("n_cells").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("panicked").unwrap().as_usize(), Some(1));
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells[0].require_str("status").unwrap(), "ok");
        assert_eq!(cells[0].get("loss").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(cells[1].require_str("panic").unwrap(), "boom");
        assert_eq!(cells[1].get("final_loss"), Some(&Json::Null));
        // Deterministic JSON has no wall timing; both forms re-parse.
        let det = r.to_json_with(true);
        let det_cells = det.get("cells").unwrap().as_arr().unwrap();
        assert!(det_cells[0].get("wall_secs").is_none());
        let re = Json::parse(&format!("{det:#}")).unwrap();
        assert_eq!(re.get("ok").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn render_table_is_aligned() {
        let s = toy_report().render_table();
        assert!(s.contains("| spec"));
        let first = s.lines().next().unwrap().len();
        assert!(s.lines().all(|l| l.len() == first));
    }

    #[test]
    fn csv_roundtrip_preserves_rows_byte_for_byte() {
        // save → load_csv → save must reproduce the exact same CSV: that
        // is what lets `--resume` merge completed cells "unchanged".
        let dir = std::env::temp_dir()
            .join(format!("mkor-report-csv-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let r = toy_report();
        for deterministic in [false, true] {
            let path = dir.join(format!("sweep-{deterministic}.csv"));
            r.save_csv_with(&path, deterministic).unwrap();
            let loaded = SweepReport::load_csv(&path).unwrap();
            assert_eq!(loaded.cells.len(), 2);
            // Quoted spec strings (containing commas) survive.
            assert_eq!(loaded.cells[0].spec, "mkor:f=25,backend=lamb");
            assert_eq!(loaded.cells[0].status, CellStatus::Ok);
            assert_eq!(loaded.cells[0].final_loss(), Some(1.0));
            assert_eq!(loaded.cells[0].converged_at(), Some(1));
            assert_eq!(loaded.cells[0].steps_run(), 2);
            assert_eq!(loaded.cells[1].status, CellStatus::Panicked(String::new()));
            assert_eq!(loaded.cells[1].final_loss(), None);
            // Re-saving the loaded report reproduces the bytes exactly.
            let original = std::fs::read_to_string(&path).unwrap();
            let resaved = if deterministic {
                loaded.to_csv_deterministic()
            } else {
                loaded.to_csv()
            };
            assert_eq!(resaved, original, "deterministic={deterministic}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_csv_rejects_malformed_input() {
        let dir = std::env::temp_dir()
            .join(format!("mkor-report-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        // Missing a required column.
        std::fs::write(&path, "cell,spec,task\n0,sgd,images\n").unwrap();
        let e = SweepReport::load_csv(&path).unwrap_err();
        assert!(e.to_string().contains("seed"), "{e}");
        // Unknown status value.
        std::fs::write(
            &path,
            "cell,spec,task,seed,lr,status,steps,final_loss,converged_at,best_eval\n\
             0,sgd,images,0,0.1,weird,5,1.0,,\n",
        )
        .unwrap();
        let e = SweepReport::load_csv(&path).unwrap_err();
        assert!(e.to_string().contains("weird"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cell_result_json_roundtrips_for_the_worker_stream() {
        let r = toy_report();
        for cell in &r.cells {
            // Compact one-line form, as written to the worker .jsonl files.
            let line = cell.to_json().to_string();
            assert!(!line.contains('\n'), "{line}");
            let re = CellResult::from_json(&Json::parse(&line).unwrap())
                .unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(re.index, cell.index);
            assert_eq!(re.spec, cell.spec);
            assert_eq!(re.task, cell.task);
            assert_eq!(re.seed, cell.seed);
            assert_eq!(re.lr.to_bits(), cell.lr.to_bits());
            assert_eq!(re.status, cell.status);
            assert_eq!(re.steps_run(), cell.steps_run());
            assert_eq!(re.final_loss(), cell.final_loss());
        }
        // The reconstructed report renders the exact same artifacts.
        let re = SweepReport {
            cells: r
                .cells
                .iter()
                .map(|c| CellResult::from_json(&c.to_json()).unwrap())
                .collect(),
        };
        assert_eq!(re.to_csv_deterministic(), r.to_csv_deterministic());
        let (a, b) = (re.to_json_with(true), r.to_json_with(true));
        assert_eq!(format!("{a:#}"), format!("{b:#}"));
    }

    #[test]
    fn huge_seeds_roundtrip_in_the_worker_stream() {
        // Seeds above 2^53 would round through an f64 JSON number; the
        // wire format carries them as strings instead.
        let cell = toy_cell(0, "sgd", 9007199254740993);
        let r = CellResult::from_record(&cell, 0.1, toy_record("sgd", &[1.0]));
        let re = CellResult::from_json(&r.to_json()).unwrap();
        assert_eq!(re.seed, 9007199254740993);
    }

    #[test]
    fn cell_result_from_json_rejects_incomplete_results() {
        let r = toy_report();
        // A completed cell without its record is unusable for merging.
        let mut j = r.cells[0].to_json();
        j.set("record", Json::Null);
        let j = Json::parse(&j.to_string().replace(",\"record\":null", "")).unwrap();
        assert!(CellResult::from_json(&j).unwrap_err().contains("record"));
        // Unknown statuses are named in the error.
        let mut j = r.cells[0].to_json();
        j.set("status", Json::Str("weird".to_string()));
        assert!(CellResult::from_json(&j).unwrap_err().contains("weird"));
    }

    #[test]
    fn outcome_lines_cover_every_status() {
        let r = toy_report();
        assert!(r.cells[0].outcome_line().starts_with("ok, loss 1.00000"));
        assert!(r.cells[1].outcome_line().contains("PANICKED: boom"));
        let mut diverged = r.cells[0].clone();
        diverged.status = CellStatus::Diverged;
        assert!(diverged.outcome_line().starts_with("DIVERGED"));
    }

    #[test]
    fn csv_field_splitter_handles_quotes() {
        assert_eq!(split_csv_line("a,b,c"), vec!["a", "b", "c"]);
        assert_eq!(
            split_csv_line("0,\"mkor:f=25,backend=lamb\",images"),
            vec!["0", "mkor:f=25,backend=lamb", "images"]
        );
        assert_eq!(split_csv_line("\"he said \"\"hi\"\"\",x"), vec!["he said \"hi\"", "x"]);
        assert_eq!(split_csv_line("a,,b"), vec!["a", "", "b"]);
    }
}
