//! Merged sweep reports: one artifact per sweep, one row per cell.
//!
//! A [`SweepReport`] aggregates the per-cell
//! [`RunRecord`](crate::coordinator::RunRecord)s of one sweep into a
//! single table keyed by canonical spec string + seed, and writes it as
//! CSV (one data row per cell) and JSON (cell summaries plus loss
//! series). The deterministic variants omit wall-clock timing, so their
//! bytes depend only on the grid and the seeds — never on `--jobs`.

use crate::bench_utils::Table;
use crate::coordinator::RunRecord;
use crate::sweep::grid::{task_label, SweepCell};
use crate::util::json::Json;
use std::path::Path;

/// Terminal state of one cell.
#[derive(Clone, Debug, PartialEq)]
pub enum CellStatus {
    /// Ran its full step budget.
    Ok,
    /// Training diverged (non-finite loss/weights); the record is kept.
    Diverged,
    /// The cell panicked; the message is kept, the record is lost.
    Panicked(String),
}

impl CellStatus {
    pub fn label(&self) -> &'static str {
        match self {
            CellStatus::Ok => "ok",
            CellStatus::Diverged => "diverged",
            CellStatus::Panicked(_) => "panicked",
        }
    }
}

/// One cell's outcome: identity (spec/task/seed/lr) + status + record.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Grid position (row order of the merged report).
    pub index: usize,
    /// Canonical spec string — the cell's key in CSV/JSON artifacts.
    pub spec: String,
    pub task: String,
    pub seed: u64,
    /// The harness learning rate this cell actually ran with.
    pub lr: f32,
    pub status: CellStatus,
    /// The full run record (absent only for panicked cells).
    pub record: Option<RunRecord>,
}

impl CellResult {
    /// Wrap a completed (possibly diverged) run.
    pub fn from_record(cell: &SweepCell, lr: f32, record: RunRecord) -> CellResult {
        let status = if record.diverged {
            CellStatus::Diverged
        } else {
            CellStatus::Ok
        };
        CellResult {
            index: cell.index,
            spec: cell.spec.canonical(),
            task: task_label(&cell.task),
            seed: cell.seed,
            lr,
            status,
            record: Some(record),
        }
    }

    /// Wrap a cell whose worker panicked.
    pub fn panicked(cell: &SweepCell, lr: f32, message: String) -> CellResult {
        CellResult {
            index: cell.index,
            spec: cell.spec.canonical(),
            task: task_label(&cell.task),
            seed: cell.seed,
            lr,
            status: CellStatus::Panicked(message),
            record: None,
        }
    }

    /// Final training loss, if the cell produced any steps.
    pub fn final_loss(&self) -> Option<f64> {
        let record = self.record.as_ref()?;
        if record.steps.is_empty() {
            None
        } else {
            Some(record.final_loss())
        }
    }

    /// Step at which the run first hit its target metric, if ever.
    pub fn converged_at(&self) -> Option<usize> {
        self.record.as_ref().and_then(|r| r.converged_at)
    }

    /// Best eval metric seen over the run.
    pub fn best_eval(&self) -> Option<f64> {
        self.record.as_ref().and_then(|r| r.best_eval())
    }

    /// Steps the cell recorded (including a diverged final step).
    pub fn steps_run(&self) -> usize {
        self.record.as_ref().map_or(0, |r| r.steps.len())
    }

    /// Total wall seconds of the cell's own steps.
    pub fn wall_secs(&self) -> f64 {
        self.record.as_ref().map_or(0.0, |r| r.total_wall_secs())
    }
}

/// The merged artifact of one sweep.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    /// One result per cell, in grid order (independent of scheduling).
    pub cells: Vec<CellResult>,
}

impl SweepReport {
    /// `(ok, diverged, panicked)` cell counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut ok = 0;
        let mut diverged = 0;
        let mut panicked = 0;
        for c in &self.cells {
            match c.status {
                CellStatus::Ok => ok += 1,
                CellStatus::Diverged => diverged += 1,
                CellStatus::Panicked(_) => panicked += 1,
            }
        }
        (ok, diverged, panicked)
    }

    /// Look up a cell by canonical spec string and seed. Cells that
    /// differ only in the reserved `lr` axis share this key (lr is not
    /// part of the spec string) — use [`SweepReport::find_with_lr`] there.
    pub fn find(&self, spec: &str, seed: u64) -> Option<&CellResult> {
        self.cells.iter().find(|c| c.spec == spec && c.seed == seed)
    }

    /// [`SweepReport::find`] disambiguated by the harness learning rate,
    /// for grids that sweep the reserved `lr` axis.
    pub fn find_with_lr(&self, spec: &str, seed: u64, lr: f32) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.spec == spec && c.seed == seed && c.lr == lr)
    }

    /// Build the report table; `wall` appends the wall-clock column.
    fn table(&self, wall: bool) -> Table {
        let mut headers = vec![
            "cell",
            "spec",
            "task",
            "seed",
            "lr",
            "status",
            "steps",
            "final_loss",
            "converged_at",
            "best_eval",
        ];
        if wall {
            headers.push("wall_secs");
        }
        let mut t = Table::new(&headers);
        for c in &self.cells {
            let fmt_opt = |v: Option<String>| v.unwrap_or_default();
            let mut row = vec![
                c.index.to_string(),
                c.spec.clone(),
                c.task.clone(),
                c.seed.to_string(),
                c.lr.to_string(),
                c.status.label().to_string(),
                c.steps_run().to_string(),
                fmt_opt(c.final_loss().map(|v| v.to_string())),
                fmt_opt(c.converged_at().map(|v| v.to_string())),
                fmt_opt(c.best_eval().map(|v| v.to_string())),
            ];
            if wall {
                row.push(format!("{:.3}", c.wall_secs()));
            }
            t.row(&row);
        }
        t
    }

    /// Pretty table for terminal summaries.
    pub fn render_table(&self) -> String {
        self.table(true).render()
    }

    /// CSV, one row per cell, keyed by canonical spec string; includes the
    /// measured `wall_secs` column.
    pub fn to_csv(&self) -> String {
        self.table(true).to_csv()
    }

    /// CSV without the wall-clock column: byte-identical for any `--jobs`
    /// width, because cell results depend only on each cell's own seed.
    pub fn to_csv_deterministic(&self) -> String {
        self.table(false).to_csv()
    }

    /// JSON form; `deterministic` omits wall-clock timing so the artifact
    /// is byte-identical for any `--jobs` width.
    pub fn to_json_with(&self, deterministic: bool) -> Json {
        let (ok, diverged, panicked) = self.counts();
        let mut o = Json::obj();
        o.set("n_cells", Json::Num(self.cells.len() as f64))
            .set("ok", Json::Num(ok as f64))
            .set("diverged", Json::Num(diverged as f64))
            .set("panicked", Json::Num(panicked as f64));
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let final_loss = c.final_loss().map_or(Json::Null, Json::Num);
                let conv = c.converged_at().map_or(Json::Null, |s| Json::Num(s as f64));
                let best = c.best_eval().map_or(Json::Null, Json::Num);
                let mut j = Json::obj();
                j.set("cell", Json::Num(c.index as f64))
                    .set("spec", Json::Str(c.spec.clone()))
                    .set("task", Json::Str(c.task.clone()))
                    .set("seed", Json::Num(c.seed as f64))
                    .set("lr", Json::Num(c.lr as f64))
                    .set("status", Json::Str(c.status.label().to_string()))
                    .set("steps", Json::Num(c.steps_run() as f64))
                    .set("final_loss", final_loss)
                    .set("converged_at", conv)
                    .set("best_eval", best);
                if let Some(r) = &c.record {
                    j.set("loss", Json::from_f64s(&r.loss_series()));
                }
                if let CellStatus::Panicked(msg) = &c.status {
                    j.set("panic", Json::Str(msg.clone()));
                }
                if !deterministic {
                    j.set("wall_secs", Json::Num(c.wall_secs()));
                }
                j
            })
            .collect();
        o.set("cells", Json::Arr(cells));
        o
    }

    /// JSON with wall-clock timing included.
    pub fn to_json(&self) -> Json {
        self.to_json_with(false)
    }

    /// Write CSV; `deterministic` drops the wall-clock column so the
    /// artifact's bytes depend only on the grid and the seeds.
    pub fn save_csv_with(&self, path: &Path, deterministic: bool) -> anyhow::Result<()> {
        let csv = if deterministic {
            self.to_csv_deterministic()
        } else {
            self.to_csv()
        };
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, csv)?;
        Ok(())
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        self.save_csv_with(path, false)
    }

    /// Write JSON; `deterministic` as in [`SweepReport::save_csv_with`].
    pub fn save_json_with(&self, path: &Path, deterministic: bool) -> anyhow::Result<()> {
        self.to_json_with(deterministic).to_file(path)
    }

    pub fn save_json(&self, path: &Path) -> anyhow::Result<()> {
        self.save_json_with(path, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StepRecord;
    use crate::experiments::convergence::TaskKind;
    use crate::optim::OptimizerSpec;

    fn toy_cell(index: usize, spec: &str, seed: u64) -> SweepCell {
        SweepCell {
            index,
            spec: OptimizerSpec::parse(spec).unwrap(),
            seed,
            lr: None,
            task: TaskKind::Images,
        }
    }

    fn toy_record(spec: &str, losses: &[f64]) -> RunRecord {
        RunRecord {
            name: "t".to_string(),
            optimizer: "mkor".to_string(),
            spec: spec.to_string(),
            steps: losses
                .iter()
                .enumerate()
                .map(|(i, &loss)| StepRecord {
                    step: i,
                    loss,
                    eval_metric: None,
                    lr: 0.1,
                    wall_secs: 0.25,
                    grad_comm_bytes: 0,
                    sync_comm_bytes: 0,
                })
                .collect(),
            diverged: false,
            converged_at: Some(1),
            switched_at: None,
        }
    }

    fn toy_report() -> SweepReport {
        let a = toy_cell(0, "mkor:f=25,backend=lamb", 0);
        let b = toy_cell(1, "sgd", 1);
        let rec = toy_record("mkor:f=25,backend=lamb", &[2.0, 1.0]);
        SweepReport {
            cells: vec![
                CellResult::from_record(&a, 0.1, rec),
                CellResult::panicked(&b, 0.1, "boom".to_string()),
            ],
        }
    }

    #[test]
    fn csv_has_one_row_per_cell_with_quoted_specs() {
        let r = toy_report();
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 3, "{csv}");
        assert!(lines[0].starts_with("cell,spec,task,seed,lr,status,"));
        assert!(lines[0].ends_with(",wall_secs"));
        // Spec strings contain commas, so they must be CSV-quoted.
        assert!(lines[1].contains("\"mkor:f=25,backend=lamb\""), "{csv}");
        assert!(lines[2].contains("panicked"));
        // The deterministic form drops only the wall column.
        let det = r.to_csv_deterministic();
        assert!(!det.contains("wall_secs"));
        assert_eq!(det.trim().lines().count(), 3);
    }

    #[test]
    fn summaries_and_lookup() {
        let r = toy_report();
        assert_eq!(r.counts(), (1, 0, 1));
        let cell = r.find("mkor:f=25,backend=lamb", 0).unwrap();
        assert_eq!(cell.final_loss(), Some(1.0));
        assert_eq!(cell.converged_at(), Some(1));
        assert_eq!(cell.steps_run(), 2);
        assert!((cell.wall_secs() - 0.5).abs() < 1e-12);
        assert!(r.find("sgd", 0).is_none(), "seed is part of the key");
        assert!(r.find_with_lr("sgd", 1, 0.1).is_some());
        assert!(r.find_with_lr("sgd", 1, 0.2).is_none(), "lr disambiguates");
        let failed = r.find("sgd", 1).unwrap();
        assert_eq!(failed.final_loss(), None);
        assert_eq!(failed.steps_run(), 0);
    }

    #[test]
    fn json_carries_statuses_loss_series_and_panics() {
        let r = toy_report();
        let j = r.to_json();
        assert_eq!(j.get("n_cells").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("panicked").unwrap().as_usize(), Some(1));
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells[0].require_str("status").unwrap(), "ok");
        assert_eq!(cells[0].get("loss").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(cells[1].require_str("panic").unwrap(), "boom");
        assert_eq!(cells[1].get("final_loss"), Some(&Json::Null));
        // Deterministic JSON has no wall timing; both forms re-parse.
        let det = r.to_json_with(true);
        let det_cells = det.get("cells").unwrap().as_arr().unwrap();
        assert!(det_cells[0].get("wall_secs").is_none());
        let re = Json::parse(&format!("{det:#}")).unwrap();
        assert_eq!(re.get("ok").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn render_table_is_aligned() {
        let s = toy_report().render_table();
        assert!(s.contains("| spec"));
        let first = s.lines().next().unwrap().len();
        assert!(s.lines().all(|l| l.len() == first));
    }
}
