//! Spec-driven sweep engine: grid expansion → thread-pool fan-out →
//! merged reports.
//!
//! MKOR's headline results are sweeps — over optimizers, inversion
//! frequency `f`, learning rate and damping (Tables 2/3/5, Figure 4).
//! This subsystem turns one sweep string into one merged artifact:
//!
//! 1. [`grid`] expands axis notation in spec strings into a
//!    deterministic, ordered list of [`SweepCell`]s. Braced keys
//!    cross-multiply (`kfac:damping={0.01,0.1},lr={1,0.1}` → 4 cells),
//!    ` x seed=0..4` repeats every expanded spec per seed, and `lr`/`seed`
//!    are reserved harness axes that never reach the optimizer grammar.
//! 2. [`executor`] fans the cells out over a bounded pool of worker
//!    threads, each building its own trainer; a diverged or panicked cell
//!    becomes a failed [`CellResult`], never a dead sweep.
//! 3. [`dispatch`] is the process-level tier of the same fan-out:
//!    `mkor sweep --workers N` shards the grid into cell batches, runs
//!    each in a crash-isolated `mkor sweep-worker` subprocess, streams
//!    per-cell JSON results back, re-dispatches what a killed worker
//!    left unfinished, and resumes across coordinator restarts.
//! 4. [`report`] merges the per-cell run records into one [`SweepReport`]
//!    with per-cell final-loss / converged-at / wall-time, written as CSV
//!    (one row per cell, canonical spec string as key) and JSON.
//!
//! The CLI front-end is `mkor sweep`:
//!
//! ```text
//! mkor sweep --specs "mkor:f={1,10,100};lamb;kfac:damping={0.01,0.1}" \
//!     --task glue --steps 300 --jobs 8 --out results/sweep.csv
//! # same grid, fanned out over 4 crash-isolated worker processes:
//! mkor sweep --specs "..." --task glue --workers 4 --out results/sweep.csv
//! ```
//!
//! and the library path is three calls:
//!
//! ```ignore
//! let task = task_by_name("glue")?;
//! let grid = SweepGrid::parse("mkor:f={1,10,100};lamb", &task, 0)?;
//! let report = run_sweep(&grid, &SweepOptions::default());
//! report.save_csv(Path::new("results/sweep.csv"))?;
//! ```
//!
//! Determinism contract: the grid order and every cell's results depend
//! only on the sweep string and the seeds — `--jobs 8`, `--workers 4`
//! and `--jobs 1` produce identical cells
//! (`SweepReport::to_csv_deterministic` is byte-identical; only measured
//! wall-clock columns differ). Grid expansion itself is pure and cheap:
//!
//! ```
//! use mkor::experiments::convergence::TaskKind;
//! use mkor::sweep::SweepGrid;
//!
//! let grid = SweepGrid::parse("mkor:f={1,10};lamb x seed=0..2", &TaskKind::Images, 0).unwrap();
//! let specs: Vec<String> = grid.cells.iter().map(|c| c.spec.canonical()).collect();
//! assert_eq!(specs, ["mkor:f=1", "mkor:f=10", "lamb", "lamb"]);
//! assert_eq!(grid.cells[3].seed, 1);
//! ```

pub mod dispatch;
pub mod executor;
pub mod grid;
pub mod report;

pub use dispatch::{run_sweep_mp, run_worker, shard_batches, MpOptions};
pub use executor::{fan_out, run_sweep, run_sweep_resumed, SweepOptions};
pub use grid::{task_by_name, task_label, SweepCell, SweepError, SweepGrid};
pub use report::{CellResult, CellStatus, CellSummary, SweepReport};
