//! Multi-process sweep dispatch: coordinator + crash-isolated workers.
//!
//! The in-process executor ([`run_sweep`](crate::sweep::run_sweep)) fans
//! cells out over threads of one process — one OOM or runaway cell can
//! still take the whole sweep down, and one process is the ceiling the
//! paper's scalability argument warns about. This module adds the
//! process-level tier: [`run_sweep_mp`] shards the expanded grid into
//! cell batches, launches one subprocess per batch (`mkor sweep-worker`,
//! a hidden subcommand re-entering the same binary), streams per-cell
//! JSON results back through per-worker files, and merges them into the
//! same [`SweepReport`] in deterministic grid order — so `--jobs N`,
//! `--workers N` and straight-line runs all produce byte-identical
//! deterministic CSV/JSON artifacts.
//!
//! ```text
//! coordinator (mkor sweep --workers N)          scratch dir (<out>.workers/)
//!   grid ── shard_batches ──► queue             cells-<pid>-<k>.json   batch input
//!   spawn ≤ N × `mkor sweep-worker` ──────────► out-<pid>-<k>.jsonl    one result/line
//!   poll: stream lines ──► progress + merge ◄── (appended + flushed per cell)
//!   reap: dead worker ──► re-dispatch batch minus completed cells
//!   end : SweepReport in grid order ──► CSV/JSON, scratch GC'd
//! ```
//!
//! Crash recovery is layered on PR 3's resumable sweeps:
//!
//! * a **worker** that dies mid-batch (kill, OOM, crash — per-cell panics
//!   are caught and reported as data, they do not kill the worker) has its
//!   unfinished cells re-dispatched as a fresh batch, minus the cells its
//!   result file already carries;
//! * a **coordinator** that dies leaves the worker result files behind;
//!   `mkor sweep --resume` scans them (and the prior `--out` CSV) and
//!   re-runs only the cells missing from both — resume works across
//!   process boundaries;
//! * a **cell** interrupted mid-run continues from its own
//!   `cell-<index>` checkpoint when the sweep sets the checkpoint knobs
//!   (`--checkpoint-every N --checkpoint-dir D`), via
//!   [`SweepOptions::run_for_cell`].
//!
//! Determinism contract: a worker derives each cell's options through the
//! same [`SweepOptions::run_for_cell`] as the thread executor, runs the
//! same [`run_record`](crate::experiments::convergence::run_record), and
//! ships the full lossless [`RunRecord`](crate::coordinator::RunRecord)
//! back (floats as shortest-round-trip JSON, non-finite losses as
//! strings), so the merged report is indistinguishable from an
//! in-process run's.
//!
//! Test hook: setting `MKOR_SWEEP_WORKER_EXIT_AFTER=<k>` makes the first
//! worker (per scratch directory) exit hard after completing `k` cells —
//! the crash-injection used by `rust/tests/sweep_mp.rs` to prove that a
//! killed worker's batch is re-dispatched and the artifacts stay
//! byte-identical.

use crate::coordinator::metrics::sweep_progress_line;
use crate::experiments::convergence::{run_record, RunOpts};
use crate::obs::{self, EventKind, TraceEvent};
use crate::optim::OptimizerSpec;
use crate::sweep::executor::{panic_message, SweepOptions};
use crate::sweep::grid::{task_by_name, task_label, SweepCell, SweepGrid};
use crate::sweep::report::{seed_from_json, seed_to_json, CellResult, SweepReport};
use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Format version of the worker batch/result files.
pub const WORKER_FORMAT_VERSION: usize = 1;

/// Crash-injection env var: a worker exits with code 101 after completing
/// this many cells — once per scratch directory (a sentinel file keeps
/// retries alive), so tests can prove re-dispatch without flaky timing.
pub const WORKER_EXIT_AFTER_ENV: &str = "MKOR_SWEEP_WORKER_EXIT_AFTER";

/// Crash-injection env var for the **coordinator**: the dispatch loop of
/// [`run_sweep_mp`] exits hard (code 101) once it has absorbed this many
/// cell results — once per scratch directory (`coord-died.once` sentinel),
/// the same first-come-first-die discipline as [`WORKER_EXIT_AFTER_ENV`].
/// This is how `rust/tests/serve_recovery.rs` kills the serve daemon
/// mid-job at a deterministic point; restarting with
/// [`MpOptions::recover`] then resumes from the worker result files.
pub const COORD_EXIT_AFTER_ENV: &str = "MKOR_SWEEP_COORD_EXIT_AFTER";

const DIED_SENTINEL: &str = "worker-died.once";
const COORD_DIED_SENTINEL: &str = "coord-died.once";

/// How the multi-process coordinator runs.
#[derive(Clone, Debug)]
pub struct MpOptions {
    /// Worker subprocesses kept busy at once (≥ 1).
    pub workers: usize,
    /// Cells per dispatched batch; 0 = `ceil(pending / workers)` (one
    /// batch per worker — lowest process overhead). Smaller batches give
    /// better dynamic balance on straggler-heavy grids.
    pub batch: usize,
    /// Scratch directory for batch inputs and per-worker result files
    /// (the CLI defaults to `<out>.workers/`). Removed after a fully
    /// successful sweep unless [`MpOptions::keep_scratch`] is set.
    pub scratch: PathBuf,
    /// Dispatch attempts per batch lineage before the cell the worker
    /// kept dying on is reported as panicked and the rest of the batch
    /// restarts fresh (first run + retries; ≥ 1). Panicked *cells* are
    /// data and never retried — this bounds retries of *dying workers*.
    pub max_attempts: usize,
    /// Scan leftover worker result files in `scratch` before dispatching
    /// and reuse their cells (`--resume`): this is what makes resume work
    /// across coordinator kills, with full records (the prior CSV alone
    /// cannot carry loss series).
    pub recover: bool,
    /// Keep the scratch directory after the sweep (debugging).
    pub keep_scratch: bool,
}

impl MpOptions {
    /// Defaults: auto batch size, 2 attempts, no recovery scan.
    pub fn new(scratch: impl Into<PathBuf>, workers: usize) -> MpOptions {
        MpOptions {
            workers: workers.max(1),
            batch: 0,
            scratch: scratch.into(),
            max_attempts: 2,
            recover: false,
            keep_scratch: false,
        }
    }
}

/// Shard the still-pending grid positions into dispatch batches:
/// contiguous runs of `batch` cells (`batch == 0` ⇒ `ceil(n / workers)`,
/// i.e. one batch per worker). Grid order is preserved within and across
/// batches; the merged report is re-sorted by cell index anyway, so
/// sharding only affects load balance, never results.
pub fn shard_batches(indices: &[usize], workers: usize, batch: usize) -> Vec<Vec<usize>> {
    if indices.is_empty() {
        return Vec::new();
    }
    let workers = workers.max(1);
    let size = if batch > 0 {
        batch
    } else {
        (indices.len() + workers - 1) / workers
    };
    indices.chunks(size.max(1)).map(<[usize]>::to_vec).collect()
}

// ---- batch files (coordinator → worker) --------------------------------

fn run_to_json(run: &RunOpts) -> Json {
    let mut o = Json::obj();
    o.set("lr", Json::Num(run.lr as f64))
        .set("steps", Json::Num(run.steps as f64))
        .set("workers", Json::Num(run.workers as f64))
        .set("batch", Json::Num(run.batch as f64))
        .set("eval_every", Json::Num(run.eval_every as f64))
        .set(
            "target_metric",
            run.target_metric.map_or(Json::Null, Json::Num),
        )
        .set("hidden", Json::from_usizes(&run.hidden))
        .set("checkpoint_every", Json::Num(run.checkpoint_every as f64))
        .set(
            "checkpoint_dir",
            run.checkpoint_dir.as_ref().map_or(Json::Null, |d| {
                Json::Str(d.to_string_lossy().into_owned())
            }),
        );
    o
}

fn run_from_json(j: &Json) -> Result<RunOpts, String> {
    let num = |key: &str| -> Result<f64, String> {
        j.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("worker batch: missing/invalid `{key}`"))
    };
    let hidden = j
        .get("hidden")
        .and_then(Json::as_arr)
        .ok_or_else(|| "worker batch: missing/invalid `hidden`".to_string())?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| "worker batch: bad `hidden` entry".to_string()))
        .collect::<Result<Vec<usize>, String>>()?;
    Ok(RunOpts {
        lr: num("lr")? as f32,
        steps: num("steps")? as usize,
        workers: num("workers")? as usize,
        batch: num("batch")? as usize,
        eval_every: num("eval_every")? as usize,
        target_metric: j.get("target_metric").and_then(Json::as_f64),
        hidden,
        checkpoint_every: num("checkpoint_every")? as usize,
        checkpoint_dir: j
            .get("checkpoint_dir")
            .and_then(Json::as_str)
            .map(PathBuf::from),
        // Per-cell fields (`seed`, per-cell lr/resume/checkpoint subdir)
        // are derived by `SweepOptions::run_for_cell`, exactly as in the
        // in-process executor; `inv_freq`/`gamma` are ignored by the
        // spec-driven cell path.
        ..RunOpts::default()
    })
}

fn cell_to_json(cell: &SweepCell) -> Json {
    let mut o = Json::obj();
    o.set("index", Json::Num(cell.index as f64))
        .set("spec", Json::Str(cell.spec.canonical()))
        .set("task", Json::Str(task_label(&cell.task)))
        .set("seed", seed_to_json(cell.seed))
        .set("lr", cell.lr.map_or(Json::Null, |lr| Json::Num(lr as f64)));
    o
}

fn cell_from_json(j: &Json) -> Result<SweepCell, String> {
    let num = |key: &str| -> Result<f64, String> {
        j.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("worker batch cell: missing/invalid `{key}`"))
    };
    let spec_str = j
        .get("spec")
        .and_then(Json::as_str)
        .ok_or_else(|| "worker batch cell: missing `spec`".to_string())?;
    let spec = OptimizerSpec::parse(spec_str).map_err(|e| format!("cell spec: {e}"))?;
    let task_name = j
        .get("task")
        .and_then(Json::as_str)
        .ok_or_else(|| "worker batch cell: missing `task`".to_string())?;
    let task = task_by_name(task_name).map_err(|e| format!("cell task: {e}"))?;
    let seed = seed_from_json(j.get("seed"))
        .ok_or_else(|| "worker batch cell: missing/invalid `seed`".to_string())?;
    Ok(SweepCell {
        index: num("index")? as usize,
        spec,
        seed,
        lr: j.get("lr").and_then(Json::as_f64).map(|lr| lr as f32),
        task,
    })
}

/// Write the batch input file one worker consumes: the shared run options
/// plus the selected cells (global grid indices preserved, so per-cell
/// checkpoint directories and report rows line up across any sharding).
pub fn write_batch_file(
    path: &Path,
    grid: &SweepGrid,
    indices: &[usize],
    run: &RunOpts,
) -> anyhow::Result<()> {
    let cells: Vec<Json> = indices
        .iter()
        .map(|&i| cell_to_json(&grid.cells[i]))
        .collect();
    let mut o = Json::obj();
    o.set("format", Json::Num(WORKER_FORMAT_VERSION as f64))
        .set("run", run_to_json(run))
        .set("cells", Json::Arr(cells));
    o.to_file(path)
}

/// Parse a batch input file back into the shared options and its cells.
pub fn read_batch_file(path: &Path) -> anyhow::Result<(RunOpts, Vec<SweepCell>)> {
    let j = Json::from_file(path)?;
    let format = j.require_usize("format")?;
    anyhow::ensure!(
        format == WORKER_FORMAT_VERSION,
        "{}: unsupported worker batch format {format} (this build speaks {WORKER_FORMAT_VERSION})",
        path.display()
    );
    let run = j
        .get("run")
        .ok_or_else(|| anyhow::anyhow!("{}: missing `run`", path.display()))
        .and_then(|r| run_from_json(r).map_err(|e| anyhow::anyhow!("{}: {e}", path.display())))?;
    let cells = j
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("{}: missing `cells`", path.display()))?
        .iter()
        .map(|c| cell_from_json(c).map_err(|e| anyhow::anyhow!("{}: {e}", path.display())))
        .collect::<anyhow::Result<Vec<SweepCell>>>()?;
    Ok((run, cells))
}

// ---- the worker process ------------------------------------------------

/// Should this worker honor the crash-injection hook and die now?
/// First-come-first-die: the sentinel file makes exactly one worker per
/// scratch directory exit, so the retried batch completes.
fn claim_injected_death(out: &Path, cells_done: usize) -> bool {
    let Some(after) = std::env::var(WORKER_EXIT_AFTER_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    else {
        return false;
    };
    if cells_done < after {
        return false;
    }
    let dir = out.parent().map(Path::to_path_buf).unwrap_or_default();
    std::fs::OpenOptions::new()
        .write(true)
        .create_new(true)
        .open(dir.join(DIED_SENTINEL))
        .is_ok()
}

/// The coordinator-side twin of [`claim_injected_death`]: should the
/// dispatch loop die now? Claimed at most once per scratch directory.
fn claim_coordinator_death(scratch: &Path, completed: usize) -> bool {
    let Some(after) = std::env::var(COORD_EXIT_AFTER_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    else {
        return false;
    };
    if completed < after {
        return false;
    }
    std::fs::OpenOptions::new()
        .write(true)
        .create_new(true)
        .open(scratch.join(COORD_DIED_SENTINEL))
        .is_ok()
}

/// The body of the hidden `mkor sweep-worker` subcommand: run every cell
/// of the batch file sequentially, appending one compact JSON result line
/// per completed cell to `out` (flushed per line, so a killed worker
/// loses at most the cell it was on). Per-cell panics are caught and
/// reported as panicked results; the exit code reflects only whether the
/// batch file itself was usable.
pub fn run_worker(cells_json: &Path, out: &Path) -> anyhow::Result<()> {
    let (run, cells) = read_batch_file(cells_json)?;
    let opts = SweepOptions {
        jobs: 1,
        run,
        verbose: false,
    };
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(out)
        .map_err(|e| anyhow::anyhow!("opening {}: {e}", out.display()))?;
    for (k, cell) in cells.iter().enumerate() {
        if claim_injected_death(out, k) {
            std::process::exit(101);
        }
        let run = opts.run_for_cell(cell);
        let spec = cell.spec.canonical();
        let name = format!("{spec}#s{}", cell.seed);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            run_record(&cell.task, &cell.spec, &name, &run)
        }));
        let result = match outcome {
            Ok(record) => CellResult::from_record(cell, run.lr, record),
            Err(payload) => CellResult::panicked(cell, run.lr, panic_message(payload)),
        };
        writeln!(file, "{}", result.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", out.display()))?;
        file.flush()?;
    }
    Ok(())
}

// ---- result streaming (worker → coordinator) ---------------------------

/// Read the complete result lines appended to `path` since `offset`
/// (advanced past everything consumed). Only the new bytes are read each
/// call — the coordinator polls these append-only files frequently, and
/// each line carries a full record, so re-reading from byte 0 would be
/// quadratic over a sweep. Torn trailing lines — a worker killed
/// mid-write — stay unconsumed until a newline lands; lines that still
/// fail to parse are dropped, so their cells simply re-run.
fn drain_results(path: &Path, offset: &mut usize) -> Vec<CellResult> {
    use std::io::{Read, Seek, SeekFrom};
    let Ok(mut file) = std::fs::File::open(path) else {
        return Vec::new(); // worker has not created its file yet
    };
    let mut fresh = Vec::new();
    if file.seek(SeekFrom::Start(*offset as u64)).is_err()
        || file.read_to_end(&mut fresh).is_err()
    {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut consumed = 0;
    while let Some(pos) = fresh[consumed..].iter().position(|&b| b == b'\n') {
        let line = &fresh[consumed..consumed + pos];
        consumed += pos + 1;
        let Ok(line) = std::str::from_utf8(line) else {
            continue;
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Some(result) = Json::parse(line)
            .ok()
            .and_then(|j| CellResult::from_json(&j).ok())
        {
            out.push(result);
        }
    }
    *offset += consumed;
    out
}

/// Collect every result any previous coordinator's workers left in
/// `dir` — the cross-process half of `--resume`.
fn scan_worker_files(dir: &Path) -> Vec<CellResult> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    let mut paths: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            name.starts_with("out-") && name.ends_with(".jsonl")
        })
        .collect();
    paths.sort();
    for path in paths {
        let mut offset = 0;
        out.extend(drain_results(&path, &mut offset));
    }
    out
}

/// Remove this module's files from the scratch directory (batch inputs,
/// result streams, the crash-injection sentinel) — never anything else,
/// since `--worker-dir` may point at a shared directory.
fn clear_scratch(dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let ours = (name.starts_with("cells-") && name.ends_with(".json"))
            || (name.starts_with("out-") && name.ends_with(".jsonl"))
            || name == DIED_SENTINEL
            || name == COORD_DIED_SENTINEL;
        if ours {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

// ---- the coordinator ---------------------------------------------------

/// One in-flight worker subprocess and the batch it owns.
struct Running {
    child: Child,
    indices: Vec<usize>,
    attempt: usize,
    out: PathBuf,
    offset: usize,
    /// When this worker last streamed a result (spawn time until then) —
    /// the coordinator heartbeat reports the stalest worker's age.
    last_seen: Instant,
}

/// Merge freshly streamed results into the done-map, printing one
/// aggregated progress line per new cell. Returns whether anything new
/// landed. Duplicates (a retried batch re-running a cell whose first
/// result line arrived late) and out-of-range indices are ignored.
fn absorb(
    results: Vec<CellResult>,
    done: &mut BTreeMap<usize, CellResult>,
    completed: &mut usize,
    n: usize,
    verbose: bool,
) -> bool {
    let mut progressed = false;
    for result in results {
        if result.index >= n || done.contains_key(&result.index) {
            continue;
        }
        *completed += 1;
        progressed = true;
        if verbose {
            obs::log::progress(&sweep_progress_line(
                *completed,
                n,
                &result.spec,
                result.seed,
                result.lr,
                &result.outcome_line(),
            ));
        }
        if obs::enabled() {
            obs::emit(
                TraceEvent::new(EventKind::CellDone)
                    .label("spec", &result.spec)
                    .label("status", result.status.label())
                    .num("cell", result.index as f64)
                    .num("seed", result.seed as f64),
            );
            obs::registry::with_global(|r| r.inc("sweep.cells_done", 1));
        }
        done.insert(result.index, result);
    }
    progressed
}

/// Run a sweep across worker subprocesses and merge the results into a
/// [`SweepReport`] in deterministic grid order.
///
/// Cells already present in `prior` (the reloaded `--out` CSV) or — with
/// [`MpOptions::recover`] — in leftover worker result files are reused
/// and marked `skipped`, exactly like
/// [`run_sweep_resumed`](crate::sweep::run_sweep_resumed); everything
/// else is sharded into batches and dispatched to `mkor sweep-worker`
/// subprocesses of the **current executable** (this function is only
/// meaningful from the `mkor` binary). A worker that dies mid-batch has
/// its unfinished cells re-dispatched up to [`MpOptions::max_attempts`]
/// times; cells still unfinished after that are reported as panicked
/// rows, never a dead sweep.
pub fn run_sweep_mp(
    grid: &SweepGrid,
    opts: &SweepOptions,
    mp: &MpOptions,
    prior: Option<&SweepReport>,
) -> anyhow::Result<SweepReport> {
    let n = grid.cells.len();
    // Workers rebuild each cell from (spec, task label, seed, lr); every
    // task must survive the label → TaskKind round-trip EXACTLY — a glue
    // task with a custom TaskConfig shares the label of the default one
    // but would train a different workload in the workers. TaskKind has
    // no PartialEq; the derived Debug form covers every field.
    for cell in &grid.cells {
        let label = task_label(&cell.task);
        let rebuilt = task_by_name(&label).map_err(|_| {
            anyhow::anyhow!(
                "multi-process sweeps need CLI-resolvable task names; `{label}` is not one"
            )
        })?;
        anyhow::ensure!(
            format!("{rebuilt:?}") == format!("{:?}", cell.task),
            "multi-process sweeps can only run tasks exactly as `--task {label}` builds \
             them; this grid's `{label}` task has a custom configuration ({:?}) that \
             would not survive the worker round-trip — use the in-process executor",
            cell.task
        );
    }
    std::fs::create_dir_all(&mp.scratch)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", mp.scratch.display()))?;
    let recovered = SweepReport {
        cells: if mp.recover {
            scan_worker_files(&mp.scratch)
        } else {
            clear_scratch(&mp.scratch);
            Vec::new()
        },
    };

    let mut done: BTreeMap<usize, CellResult> = BTreeMap::new();
    let mut completed = 0usize;
    for cell in &grid.cells {
        let run = opts.run_for_cell(cell);
        let spec = cell.spec.canonical();
        let task = task_label(&cell.task);
        // One resume key everywhere: SweepReport::reuse_keyed, the same
        // lookup-and-mark run_sweep_resumed uses (panicked rows re-run).
        // Worker result files carry full records and win over bare CSV
        // summary rows.
        let hit = recovered
            .reuse_keyed(&spec, &task, cell.seed, run.lr, cell.index)
            .or_else(|| {
                prior.and_then(|p| p.reuse_keyed(&spec, &task, cell.seed, run.lr, cell.index))
            });
        if let Some(prev) = hit {
            completed += 1;
            if opts.verbose {
                let outcome = format!("skipped ({} in prior report)", prev.status.label());
                obs::log::progress(&sweep_progress_line(
                    completed, n, &spec, cell.seed, run.lr, &outcome,
                ));
            }
            done.insert(cell.index, prev);
        }
    }

    let pending: Vec<usize> = (0..n).filter(|i| !done.contains_key(i)).collect();
    // MpOptions::new clamps, but the fields are pub — a literal with
    // workers: 0 would otherwise busy-spin below without ever spawning.
    let worker_cap = mp.workers.max(1);
    let mut queue: VecDeque<(Vec<usize>, usize)> = shard_batches(&pending, worker_cap, mp.batch)
        .into_iter()
        .map(|batch| (batch, 1))
        .collect();

    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving the worker executable: {e}"))?;
    let pid = std::process::id();
    let mut running: Vec<Running> = Vec::new();
    let mut next_id = 0usize;
    let mut last_hb = obs::enabled().then(Instant::now);

    // The dispatch loop runs in a closure so that any error path reaps
    // the still-running workers below — a failed coordinator must not
    // leave orphaned subprocesses training into the scratch directory.
    let mut dispatch = || -> anyhow::Result<()> {
        while !queue.is_empty() || !running.is_empty() {
            // Keep `worker_cap` subprocesses busy.
            while running.len() < worker_cap {
                let Some((indices, attempt)) = queue.pop_front() else {
                    break;
                };
                let id = next_id;
                next_id += 1;
                let cells_path = mp.scratch.join(format!("cells-{pid}-{id}.json"));
                let out_path = mp.scratch.join(format!("out-{pid}-{id}.jsonl"));
                write_batch_file(&cells_path, grid, &indices, &opts.run)?;
                let child = Command::new(&exe)
                    .arg("sweep-worker")
                    .arg("--cells-json")
                    .arg(&cells_path)
                    .arg("--out")
                    .arg(&out_path)
                    .stdout(Stdio::null())
                    .spawn()
                    .map_err(|e| anyhow::anyhow!("spawning {}: {e}", exe.display()))?;
                if obs::enabled() {
                    obs::emit(
                        TraceEvent::new(EventKind::WorkerSpawn)
                            .num("worker", id as f64)
                            .num("cells", indices.len() as f64)
                            .num("attempt", attempt as f64),
                    );
                    obs::registry::with_global(|r| r.inc("sweep.workers_spawned", 1));
                }
                running.push(Running {
                    child,
                    indices,
                    attempt,
                    out: out_path,
                    offset: 0,
                    last_seen: Instant::now(),
                });
            }

            // Stream completed cells out of every live worker's result file.
            let mut progressed = false;
            for r in &mut running {
                let fresh = drain_results(&r.out, &mut r.offset);
                if !fresh.is_empty() {
                    r.last_seen = Instant::now();
                }
                progressed |= absorb(fresh, &mut done, &mut completed, n, opts.verbose);
            }

            // Crash injection: die mid-dispatch at a deterministic point.
            // Workers keep streaming into the scratch files, which is
            // exactly what a recover-mode restart picks back up.
            if claim_coordinator_death(&mp.scratch, completed) {
                std::process::exit(101);
            }

            // Reap exited workers; re-dispatch whatever a dead one left undone.
            let mut still = Vec::new();
            for mut r in running.drain(..) {
                match r.child.try_wait() {
                    Ok(None) => still.push(r),
                    Ok(Some(status)) => {
                        progressed = true;
                        let fresh = drain_results(&r.out, &mut r.offset);
                        absorb(fresh, &mut done, &mut completed, n, opts.verbose);
                        let missing: Vec<usize> = r
                            .indices
                            .iter()
                            .copied()
                            .filter(|i| !done.contains_key(i))
                            .collect();
                        if missing.is_empty() {
                            continue;
                        }
                        if obs::enabled() {
                            obs::emit(
                                TraceEvent::new(EventKind::WorkerDead)
                                    .num("unfinished", missing.len() as f64)
                                    .num("attempt", r.attempt as f64),
                            );
                            obs::registry::with_global(|r| r.inc("sweep.workers_dead", 1));
                        }
                        if r.attempt < mp.max_attempts {
                            if opts.verbose {
                                obs::log::progress(&format!(
                                    "worker exited ({status}) with {} cells unfinished; \
                                     re-dispatching (attempt {}/{})",
                                    missing.len(),
                                    r.attempt + 1,
                                    mp.max_attempts
                                ));
                            }
                            if obs::enabled() {
                                obs::emit(
                                    TraceEvent::new(EventKind::Redispatch)
                                        .num("cells", missing.len() as f64)
                                        .num("attempt", (r.attempt + 1) as f64),
                                );
                            }
                            queue.push_back((missing, r.attempt + 1));
                        } else {
                            // Workers run their batch sequentially, so the
                            // first missing cell is the one the worker kept
                            // dying on. Condemn only it; the rest were
                            // never attempted this lineage and restart
                            // fresh — one deterministically-crashing cell
                            // must not take its whole batch down. Each
                            // exhausted lineage retires exactly one cell,
                            // so this always terminates.
                            let culprit = missing[0];
                            let cell = &grid.cells[culprit];
                            let lr = opts.run_for_cell(cell).lr;
                            let msg = format!(
                                "worker died ({status}) on every one of {} dispatch attempts",
                                mp.max_attempts
                            );
                            let lost = vec![CellResult::panicked(cell, lr, msg)];
                            absorb(lost, &mut done, &mut completed, n, opts.verbose);
                            if missing.len() > 1 {
                                queue.push_back((missing[1..].to_vec(), 1));
                            }
                        }
                    }
                    Err(e) => {
                        return Err(anyhow::anyhow!("waiting on a sweep worker: {e}"));
                    }
                }
            }
            running = still;

            // Run-health pulse (~1 Hz): progress, live worker count, and
            // how long the quietest worker has been silent — the fields
            // `mkor tail` renders to spot a stalled sweep.
            if let Some(mark) = &mut last_hb {
                if mark.elapsed() >= Duration::from_secs(1) {
                    let stalest = running
                        .iter()
                        .map(|r| r.last_seen.elapsed().as_secs_f64())
                        .fold(0.0f64, f64::max);
                    obs::emit(
                        TraceEvent::new(EventKind::Heartbeat)
                            .num("completed", completed as f64)
                            .num("cells", n as f64)
                            .num("workers", running.len() as f64)
                            .num("stalest_secs", stalest),
                    );
                    *mark = Instant::now();
                }
            }

            if !progressed && !running.is_empty() {
                std::thread::sleep(Duration::from_millis(40));
            }
        }
        Ok(())
    };
    if let Err(e) = dispatch() {
        for r in &mut running {
            let _ = r.child.kill();
            let _ = r.child.wait();
        }
        return Err(e);
    }

    let cells: Vec<CellResult> = grid
        .cells
        .iter()
        .map(|cell| {
            done.remove(&cell.index).unwrap_or_else(|| {
                // Unreachable by construction (every pending index is
                // dispatched until done or marked panicked), but a lost
                // cell must surface as a failed row, never a crash.
                let lr = opts.run_for_cell(cell).lr;
                CellResult::panicked(cell, lr, "cell was never dispatched".to_string())
            })
        })
        .collect();

    if !mp.keep_scratch {
        clear_scratch(&mp.scratch);
        let _ = std::fs::remove_dir(&mp.scratch); // only if now empty
    }
    Ok(SweepReport { cells })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::convergence::TaskKind;
    use crate::sweep::executor::run_sweep;

    fn tmp_dir(name: &str) -> PathBuf {
        let pid = std::process::id();
        let dir = std::env::temp_dir().join(format!("mkor-dispatch-{pid}-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_opts() -> SweepOptions {
        SweepOptions {
            jobs: 2,
            run: RunOpts {
                steps: 4,
                workers: 1,
                batch: 16,
                eval_every: 2,
                hidden: vec![8],
                ..Default::default()
            },
            verbose: false,
        }
    }

    #[test]
    fn shard_batches_covers_every_index_in_order() {
        // Auto batch size: one batch per worker, remainder up front.
        let idx: Vec<usize> = (0..9).collect();
        let b = shard_batches(&idx, 2, 0);
        assert_eq!(b, vec![(0..5).collect::<Vec<_>>(), (5..9).collect()]);
        // Explicit batch size wins over the worker count.
        let b = shard_batches(&idx, 2, 4);
        assert_eq!(b.len(), 3);
        assert_eq!(b[2], vec![8]);
        let flat: Vec<usize> = b.into_iter().flatten().collect();
        assert_eq!(flat, idx, "order preserved across batches");
        // Degenerate shapes.
        assert!(shard_batches(&[], 4, 0).is_empty());
        assert_eq!(shard_batches(&[3], 0, 0), vec![vec![3]]);
        assert_eq!(shard_batches(&idx, 100, 0).len(), 9);
    }

    #[test]
    fn batch_files_roundtrip_cells_and_run_options() {
        let dir = tmp_dir("batchfile");
        let task = TaskKind::Images;
        let grid = SweepGrid::parse("sgd:momentum={0.5,0.9},lr={1,0.1};adam", &task, 3).unwrap();
        let mut run = tiny_opts().run;
        run.target_metric = Some(0.25);
        run.checkpoint_every = 2;
        run.checkpoint_dir = Some(dir.join("ckpt"));
        let path = dir.join("cells.json");
        write_batch_file(&path, &grid, &[1, 4], &run).unwrap();
        let (re_run, cells) = read_batch_file(&path).unwrap();
        assert_eq!(re_run.steps, run.steps);
        assert_eq!(re_run.hidden, run.hidden);
        assert_eq!(re_run.target_metric, Some(0.25));
        assert_eq!(re_run.checkpoint_every, 2);
        assert_eq!(re_run.checkpoint_dir, run.checkpoint_dir);
        assert_eq!(cells.len(), 2);
        // Global indices, specs, seeds and the lr axis all survive.
        assert_eq!(cells[0].index, 1);
        assert_eq!(cells[0].spec, grid.cells[1].spec);
        assert_eq!(cells[0].lr, grid.cells[1].lr);
        assert_eq!(cells[1].index, 4);
        assert_eq!(cells[1].spec.canonical(), "adam");
        assert_eq!(cells[1].seed, 3);
        assert_eq!(cells[1].lr, None);
        assert_eq!(task_label(&cells[0].task), "images");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn huge_seeds_survive_the_wire_format_exactly() {
        // 2^53 + 1 is not representable as f64; seeds travel as strings.
        let dir = tmp_dir("bigseed");
        let task = TaskKind::Images;
        let grid = SweepGrid::parse("sgd:seed={9007199254740993}", &task, 0).unwrap();
        assert_eq!(grid.cells[0].seed, 9007199254740993);
        let path = dir.join("cells.json");
        write_batch_file(&path, &grid, &[0], &tiny_opts().run).unwrap();
        let (_, cells) = read_batch_file(&path).unwrap();
        assert_eq!(cells[0].seed, 9007199254740993, "seed must not round");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_batch_file_rejects_version_skew_and_garbage() {
        let dir = tmp_dir("badbatch");
        let path = dir.join("cells.json");
        std::fs::write(&path, "{\"format\": 99, \"run\": {}, \"cells\": []}").unwrap();
        let e = read_batch_file(&path).unwrap_err().to_string();
        assert!(e.contains("format 99"), "{e}");
        std::fs::write(&path, "{\"format\": 1, \"cells\": []}").unwrap();
        let e = read_batch_file(&path).unwrap_err().to_string();
        assert!(e.contains("run"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_results_merge_byte_identically_with_the_thread_executor() {
        // The core determinism contract, in-process: run_worker over the
        // full grid, parse its result stream, and the reassembled report
        // must produce the same deterministic artifacts as run_sweep.
        let dir = tmp_dir("workerparity");
        let task = TaskKind::Images;
        let grid = SweepGrid::parse("sgd:momentum={0.5,0.9};adam x seed=0..2", &task, 3).unwrap();
        let opts = tiny_opts();
        let reference = run_sweep(&grid, &opts);

        let cells_path = dir.join("cells.json");
        let out_path = dir.join("out-0.jsonl");
        let all: Vec<usize> = (0..grid.len()).collect();
        write_batch_file(&cells_path, &grid, &all, &opts.run).unwrap();
        run_worker(&cells_path, &out_path).unwrap();

        let mut offset = 0;
        let mut results = drain_results(&out_path, &mut offset);
        assert_eq!(results.len(), grid.len());
        results.sort_by_key(|r| r.index);
        let merged = SweepReport { cells: results };
        assert_eq!(
            merged.to_csv_deterministic(),
            reference.to_csv_deterministic()
        );
        let (a, b) = (merged.to_json_with(true), reference.to_json_with(true));
        assert_eq!(format!("{a:#}"), format!("{b:#}"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_results_skips_torn_lines_until_completed() {
        let dir = tmp_dir("torn");
        let task = TaskKind::Images;
        let grid = SweepGrid::parse("sgd;adam", &task, 0).unwrap();
        let opts = tiny_opts();
        let report = run_sweep(&grid, &opts);
        let full: Vec<String> = report.cells.iter().map(|c| c.to_json().to_string()).collect();

        let path = dir.join("out-0.jsonl");
        // One complete line plus the torn prefix of a second (killed
        // mid-write): only the complete line is consumed.
        let torn = &full[1][..full[1].len() / 2];
        std::fs::write(&path, format!("{}\n{torn}", full[0])).unwrap();
        let mut offset = 0;
        let got = drain_results(&path, &mut offset);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].index, 0);
        // The retry appends the full line; a later drain picks it up
        // and the garbage line is dropped without consuming the cell.
        std::fs::write(&path, format!("{}\n{torn}\n{}\n", full[0], full[1])).unwrap();
        let got = drain_results(&path, &mut offset);
        assert_eq!(got.len(), 1, "torn line dropped, full line parsed");
        assert_eq!(got[0].index, 1);
        // Scan-from-scratch (coordinator resume) sees both complete cells.
        let all = scan_worker_files(&dir);
        assert_eq!(all.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_scratch_only_touches_dispatch_files() {
        let dir = tmp_dir("clear");
        std::fs::write(dir.join("cells-1-0.json"), "{}").unwrap();
        std::fs::write(dir.join("out-1-0.jsonl"), "").unwrap();
        std::fs::write(dir.join(DIED_SENTINEL), "").unwrap();
        std::fs::write(dir.join("keep.csv"), "precious").unwrap();
        clear_scratch(&dir);
        let left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(left, vec!["keep.csv"]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
