//! Grid expansion: sweep spec strings → a deterministic, ordered cell list.
//!
//! The sweep grammar extends the optimizer-spec grammar
//! (`name[:key=val,...]`, see [`crate::optim::spec`]) with three
//! constructs:
//!
//! ```text
//! sweep    := template (';' template)*
//! template := spec [' x ' 'seed=' range]
//! spec     := name [':' axis (',' axis)*]
//! axis     := key '=' value                      // fixed value
//!           | key '={' value (',' value)* '}'    // braced value list
//! range    := A '..' B | A '..=' B
//! ```
//!
//! Braced keys cross-multiply in the order they appear, rightmost varying
//! fastest; the ` x seed=0..4` repeat suffix runs every expanded spec once
//! per seed and always varies fastest of all. Two keys are *reserved* and
//! never reach [`OptimizerSpec::parse`]: `seed` (u64 values, or a single
//! `A..B` range) and `lr` (the harness learning rate — a training knob,
//! not an optimizer hyperparameter). Everything else must be a valid key
//! for the template's optimizer; every failure mode is a [`SweepError`]
//! naming the offending template, key, or part.
//!
//! Examples (one per axis type):
//!
//! * braced key: `mkor:f={1,10,100}` → 3 cells;
//! * cross-product: `kfac:damping={0.01,0.1},lr={1,0.1}` → 4 cells;
//! * seed repeat: `mkor:f=10 x seed=0..4` → 4 cells (seeds 0–3);
//! * template list: `mkor;lamb;kfac:damping={0.01,0.1}` → 4 cells.

use crate::data::classification::TaskConfig;
use crate::experiments::convergence::TaskKind;
use crate::optim::{OptimizerSpec, SpecError};
use std::fmt;

/// Why a sweep spec string failed to expand.
#[derive(Clone, Debug, PartialEq)]
pub enum SweepError {
    /// The sweep string contains no templates.
    Empty,
    /// `key={}`, or an empty element as in `key={1,}`.
    EmptyBraces { key: String },
    /// A `{` without `}` (or vice versa), or nested/misplaced braces.
    UnmatchedBrace { part: String },
    /// The same key appears twice in one template.
    DuplicateKey { key: String },
    /// A seed range that contains no values (e.g. `seed=4..1`).
    BadRange { value: String },
    /// A reserved key (`seed`, `lr`) carries an unparseable value.
    BadValue {
        key: String,
        value: String,
        expected: &'static str,
    },
    /// An expanded spec string failed optimizer-spec parsing.
    Spec { template: String, err: SpecError },
    /// Unknown task name.
    UnknownTask { name: String },
}

impl fmt::Display for SweepError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SweepError::Empty => {
                write!(f, "empty sweep: expected `template[;template...]`")
            }
            SweepError::EmptyBraces { key } => write!(
                f,
                "empty value list for `{key}`: braces need at least one \
                 value, e.g. `{key}={{1,10}}`"
            ),
            SweepError::UnmatchedBrace { part } => write!(
                f,
                "unbalanced or nested braces in `{part}`: expected \
                 `key={{v1,v2,...}}`"
            ),
            SweepError::DuplicateKey { key } => write!(
                f,
                "duplicate key `{key}` in one template; give each key once \
                 (brace the values to sweep it)"
            ),
            SweepError::BadRange { value } => write!(
                f,
                "empty seed range `{value}`: expected `A..B` with A < B, \
                 or `A..=B` with A <= B"
            ),
            SweepError::BadValue { key, value, expected } => {
                write!(f, "bad value `{value}` for `{key}`: expected {expected}")
            }
            SweepError::Spec { template, err } => {
                write!(f, "in template `{template}`: {err}")
            }
            SweepError::UnknownTask { name } => write!(
                f,
                "unknown task `{name}`; valid tasks: glue, images, \
                 autoencoder, text, charlm"
            ),
        }
    }
}

impl std::error::Error for SweepError {}

// Constructors, so call sites stay one-liners.
impl SweepError {
    fn empty_braces(key: &str) -> SweepError {
        SweepError::EmptyBraces {
            key: key.to_string(),
        }
    }

    fn unmatched(part: &str) -> SweepError {
        SweepError::UnmatchedBrace {
            part: part.trim().to_string(),
        }
    }

    fn duplicate(key: &str) -> SweepError {
        SweepError::DuplicateKey {
            key: key.to_string(),
        }
    }

    fn bad_range(value: &str) -> SweepError {
        SweepError::BadRange {
            value: value.to_string(),
        }
    }

    fn bad_value(key: &str, value: &str, expected: &'static str) -> SweepError {
        SweepError::BadValue {
            key: key.to_string(),
            value: value.to_string(),
            expected,
        }
    }

    fn in_template(template: &str, err: SpecError) -> SweepError {
        SweepError::Spec {
            template: template.to_string(),
            err,
        }
    }

    fn unknown_task(name: &str) -> SweepError {
        SweepError::UnknownTask {
            name: name.to_string(),
        }
    }
}

/// One expanded configuration: everything a worker needs to run one cell.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Position in the grid's deterministic order (report row order).
    pub index: usize,
    /// Fully-typed optimizer configuration for this cell.
    pub spec: OptimizerSpec,
    /// RNG seed for model init, data generation and shuffling.
    pub seed: u64,
    /// Harness learning rate from a reserved `lr` axis, if any.
    pub lr: Option<f32>,
    /// The workload this cell trains on.
    pub task: TaskKind,
}

/// The expanded grid: cells in template order, axes rightmost-fastest.
///
/// The order — and every cell's result — depends only on the sweep string
/// and the base seed, never on how the executor schedules the cells.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    pub cells: Vec<SweepCell>,
}

impl SweepGrid {
    /// Expand a sweep string into its deterministic, ordered cell list.
    /// Templates are `;`-separated; see the module docs for the grammar.
    /// Cells without a seed axis use `base_seed`.
    pub fn parse(specs: &str, task: &TaskKind, base_seed: u64) -> Result<SweepGrid, SweepError> {
        let mut cells = Vec::new();
        for template in split_depth0(specs, ';')? {
            let template = template.trim();
            if template.is_empty() {
                continue;
            }
            expand_template(template, task, base_seed, &mut cells)?;
        }
        if cells.is_empty() {
            return Err(SweepError::Empty);
        }
        Ok(SweepGrid { cells })
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// A grid that runs one spec template over several tasks — the shape
    /// of the Table 2/3 benches, where the same optimizer configuration is
    /// evaluated on every task of a suite. `template` may use the full
    /// axis grammar; the expansion is repeated per task, tasks outermost.
    pub fn for_tasks(
        template: &str,
        tasks: &[TaskKind],
        base_seed: u64,
    ) -> Result<SweepGrid, SweepError> {
        let mut cells = Vec::new();
        for task in tasks {
            let sub = SweepGrid::parse(template, task, base_seed)?;
            for mut cell in sub.cells {
                cell.index = cells.len();
                cells.push(cell);
            }
        }
        if cells.is_empty() {
            return Err(SweepError::Empty);
        }
        Ok(SweepGrid { cells })
    }
}

/// Resolve a CLI task name to its proxy workload.
pub fn task_by_name(name: &str) -> Result<TaskKind, SweepError> {
    match name {
        "glue" => Ok(TaskKind::Glue(TaskConfig::new("glue", 64, 2))),
        "images" => Ok(TaskKind::Images),
        "autoencoder" => Ok(TaskKind::Autoencoder),
        "text" => Ok(TaskKind::TextClass {
            feat_dim: 96,
            vocab: 64,
        }),
        "charlm" => Ok(TaskKind::CharLm {
            vocab: 48,
            seq_len: 16,
        }),
        _ => Err(SweepError::unknown_task(name)),
    }
}

/// Short label for a task (report rows).
pub fn task_label(task: &TaskKind) -> String {
    match task {
        TaskKind::Glue(cfg) => cfg.name.clone(),
        TaskKind::Images => "images".to_string(),
        TaskKind::Autoencoder => "autoencoder".to_string(),
        TaskKind::TextClass { .. } => "text".to_string(),
        TaskKind::CharLm { .. } => "charlm".to_string(),
    }
}

/// One sweep axis of a template.
enum Axis {
    /// `key=value(s)` substituted into the spec string.
    Spec { key: String, values: Vec<String> },
    /// Reserved: harness learning rate.
    Lr(Vec<f32>),
    /// Reserved: run seed.
    Seed(Vec<u64>),
}

impl Axis {
    fn len(&self) -> usize {
        match self {
            Axis::Spec { values, .. } => values.len(),
            Axis::Lr(v) => v.len(),
            Axis::Seed(v) => v.len(),
        }
    }
}

/// Split `s` on `sep` at brace depth 0, rejecting unbalanced/nested braces.
fn split_depth0(s: &str, sep: char) -> Result<Vec<String>, SweepError> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut depth = 0usize;
    for c in s.chars() {
        match c {
            '{' => {
                depth += 1;
                if depth > 1 {
                    return Err(SweepError::unmatched(s));
                }
                cur.push(c);
            }
            '}' => {
                if depth == 0 {
                    return Err(SweepError::unmatched(s));
                }
                depth -= 1;
                cur.push(c);
            }
            c if c == sep && depth == 0 => out.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    if depth != 0 {
        return Err(SweepError::unmatched(s));
    }
    out.push(cur);
    Ok(out)
}

/// Expand `val` into its value list: `{a,b,c}` → `[a, b, c]`, plain `v` →
/// `[v]`. `part` is the whole `key=val` text, for error messages.
fn brace_values(key: &str, val: &str, part: &str) -> Result<Vec<String>, SweepError> {
    if !val.contains('{') && !val.contains('}') {
        return Ok(vec![val.to_string()]);
    }
    let stripped = val.strip_prefix('{').and_then(|v| v.strip_suffix('}'));
    let Some(inner) = stripped else {
        return Err(SweepError::unmatched(part));
    };
    if inner.contains('{') || inner.contains('}') {
        return Err(SweepError::unmatched(part));
    }
    let values: Vec<String> = inner.split(',').map(|v| v.trim().to_string()).collect();
    if values.iter().any(String::is_empty) {
        return Err(SweepError::empty_braces(key));
    }
    Ok(values)
}

fn parse_lrs(key: &str, values: &[String]) -> Result<Vec<f32>, SweepError> {
    let mut out = Vec::with_capacity(values.len());
    for v in values {
        match v.parse::<f32>() {
            Ok(lr) => out.push(lr),
            Err(_) => return Err(SweepError::bad_value(key, v, "a float learning rate")),
        }
    }
    Ok(out)
}

fn parse_seeds(key: &str, values: &[String]) -> Result<Vec<u64>, SweepError> {
    // A single `A..B` / `A..=B` value is a range of seeds.
    if values.len() == 1 && values[0].contains("..") {
        return seed_range(&values[0]);
    }
    let expected = "an unsigned integer (or a single `A..B` range)";
    let mut out = Vec::with_capacity(values.len());
    for v in values {
        match v.parse::<u64>() {
            Ok(seed) => out.push(seed),
            Err(_) => return Err(SweepError::bad_value(key, v, expected)),
        }
    }
    Ok(out)
}

fn seed_range(value: &str) -> Result<Vec<u64>, SweepError> {
    let expected = "a range `A..B` (half-open) or `A..=B` (inclusive)";
    let bad = || SweepError::bad_value("seed", value, expected);
    // `..=` must be tried first: splitting `0..=4` on `..` leaves `=4`.
    let (a, b, inclusive) = match value.split_once("..=") {
        Some((a, b)) => (a, b, true),
        None => match value.split_once("..") {
            Some((a, b)) => (a, b, false),
            None => return Err(bad()),
        },
    };
    let a: u64 = a.trim().parse().map_err(|_| bad())?;
    let b: u64 = b.trim().parse().map_err(|_| bad())?;
    let seeds: Vec<u64> = if inclusive {
        (a..=b).collect()
    } else {
        (a..b).collect()
    };
    if seeds.is_empty() {
        return Err(SweepError::bad_range(value));
    }
    Ok(seeds)
}

/// Parse one `key=val`/`key={...}` part into an axis of `axes`.
fn parse_axis(
    template: &str,
    part: &str,
    axes: &mut Vec<Axis>,
    seen: &mut Vec<String>,
) -> Result<(), SweepError> {
    let Some((key, val)) = part.split_once('=') else {
        let err = SpecError::Malformed {
            part: part.to_string(),
        };
        return Err(SweepError::in_template(template, err));
    };
    let (key, val) = (key.trim(), val.trim());
    if seen.iter().any(|k| k == key) {
        return Err(SweepError::duplicate(key));
    }
    seen.push(key.to_string());
    let values = brace_values(key, val, part)?;
    let axis = match key {
        "lr" => Axis::Lr(parse_lrs(key, &values)?),
        "seed" => Axis::Seed(parse_seeds(key, &values)?),
        _ => {
            let key = key.to_string();
            Axis::Spec { key, values }
        }
    };
    axes.push(axis);
    Ok(())
}

/// Parse one template's axes and append its expanded cells to `out`.
fn expand_template(
    template: &str,
    task: &TaskKind,
    base_seed: u64,
    out: &mut Vec<SweepCell>,
) -> Result<(), SweepError> {
    // Optional ` x seed=A..B` repeat suffix (always the fastest axis).
    let (spec_part, repeat) = match template.rsplit_once(" x ") {
        Some((head, tail)) => (head.trim_end(), Some(tail.trim())),
        None => (template, None),
    };
    let (name, rest) = match spec_part.split_once(':') {
        Some((n, r)) => (n.trim(), r.trim()),
        None => (spec_part.trim(), ""),
    };

    let mut axes: Vec<Axis> = Vec::new();
    let mut seen: Vec<String> = Vec::new();
    for part in split_depth0(rest, ',')? {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        parse_axis(template, part, &mut axes, &mut seen)?;
    }
    if let Some(rep) = repeat {
        if !rep.starts_with("seed=") {
            let expected = "a repeat axis of the form `x seed=A..B`";
            return Err(SweepError::bad_value("seed", rep, expected));
        }
        parse_axis(template, rep, &mut axes, &mut seen)?;
    }

    // Cross-product, rightmost axis fastest (mixed-radix decode of n).
    let total: usize = axes.iter().map(Axis::len).product();
    for n in 0..total.max(1) {
        let mut rem = n;
        let mut choice = vec![0usize; axes.len()];
        for k in (0..axes.len()).rev() {
            let len = axes[k].len();
            choice[k] = rem % len;
            rem /= len;
        }
        let mut pairs: Vec<String> = Vec::new();
        let mut seed = base_seed;
        let mut lr = None;
        for (axis, &c) in axes.iter().zip(&choice) {
            match axis {
                Axis::Spec { key, values } => pairs.push(format!("{key}={}", values[c])),
                Axis::Lr(v) => lr = Some(v[c]),
                Axis::Seed(v) => seed = v[c],
            }
        }
        let spec_str = if pairs.is_empty() {
            name.to_string()
        } else {
            format!("{}:{}", name, pairs.join(","))
        };
        let spec = match OptimizerSpec::parse(&spec_str) {
            Ok(spec) => spec,
            Err(err) => return Err(SweepError::in_template(template, err)),
        };
        out.push(SweepCell {
            index: out.len(),
            spec,
            seed,
            lr,
            task: task.clone(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cells(s: &str) -> Vec<SweepCell> {
        SweepGrid::parse(s, &TaskKind::Images, 7)
            .unwrap_or_else(|e| panic!("`{s}`: {e}"))
            .cells
    }

    fn err(s: &str) -> SweepError {
        match SweepGrid::parse(s, &TaskKind::Images, 7) {
            Ok(g) => panic!("`{s}` expanded to {} cells, expected error", g.len()),
            Err(e) => e,
        }
    }

    fn spec(s: &str) -> OptimizerSpec {
        OptimizerSpec::parse(s).unwrap()
    }

    #[test]
    fn braced_axis_expands_in_order() {
        let c = cells("mkor:f={1,10,100}");
        assert_eq!(c.len(), 3);
        for (i, f) in ["1", "10", "100"].iter().enumerate() {
            assert_eq!(c[i].index, i);
            assert_eq!(c[i].spec, spec(&format!("mkor:f={f}")));
            assert_eq!(c[i].seed, 7, "base seed applies without a seed axis");
            assert_eq!(c[i].lr, None);
        }
    }

    #[test]
    fn cross_product_is_rightmost_fastest() {
        let c = cells("kfac:damping={0.01,0.1},f={5,50}");
        let want = [
            "kfac:f=5,damping=0.01",
            "kfac:f=50,damping=0.01",
            "kfac:f=5,damping=0.1",
            "kfac:f=50,damping=0.1",
        ];
        assert_eq!(c.len(), want.len());
        for (cell, w) in c.iter().zip(want) {
            assert_eq!(cell.spec, spec(w));
        }
    }

    #[test]
    fn seed_repeat_axis_varies_fastest() {
        let c = cells("mkor:f={1,10} x seed=0..2");
        let want = [
            ("mkor:f=1", 0),
            ("mkor:f=1", 1),
            ("mkor:f=10", 0),
            ("mkor:f=10", 1),
        ];
        assert_eq!(c.len(), want.len());
        for (cell, (s, seed)) in c.iter().zip(want) {
            assert_eq!(cell.spec, spec(s));
            assert_eq!(cell.seed, seed);
        }
    }

    #[test]
    fn inclusive_range_and_inline_seed_list() {
        let c = cells("sgd x seed=3..=5");
        assert_eq!(c.iter().map(|c| c.seed).collect::<Vec<_>>(), vec![3, 4, 5]);
        let c = cells("sgd:seed={2,9}");
        assert_eq!(c.iter().map(|c| c.seed).collect::<Vec<_>>(), vec![2, 9]);
    }

    #[test]
    fn lr_axis_is_reserved_and_not_a_spec_key() {
        let c = cells("sgd:lr={1,0.1}");
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].lr, Some(1.0));
        assert_eq!(c[1].lr, Some(0.1));
        assert_eq!(c[0].spec, spec("sgd"));
    }

    #[test]
    fn multiple_templates_concatenate_in_order() {
        let c = cells("mkor:f={1,10};lamb;kfac:damping={0.01,0.1}");
        let names: Vec<&str> = c.iter().map(|c| c.spec.name()).collect();
        assert_eq!(names, vec!["mkor", "mkor", "lamb", "kfac", "kfac"]);
        assert_eq!(c.last().unwrap().index, 4);
    }

    #[test]
    fn single_element_braces_are_fine() {
        let c = cells("mkor:f={10}");
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].spec, spec("mkor:f=10"));
    }

    #[test]
    fn empty_braces_are_an_actionable_error() {
        for s in ["mkor:f={}", "mkor:f={1,}", "mkor:f={,1}"] {
            let e = err(s);
            let hit = matches!(&e, SweepError::EmptyBraces { key } if key == "f");
            assert!(hit, "{s}: {e:?}");
            assert!(e.to_string().contains("`f`"), "{e}");
        }
    }

    #[test]
    fn duplicate_keys_are_an_error() {
        let e = err("mkor:f={1,2},f={3}");
        let hit = matches!(&e, SweepError::DuplicateKey { key } if key == "f");
        assert!(hit, "{e:?}");
        // The repeat suffix counts as a second `seed` key.
        let e = err("mkor:seed=1 x seed=0..2");
        let hit = matches!(&e, SweepError::DuplicateKey { key } if key == "seed");
        assert!(hit, "{e:?}");
    }

    #[test]
    fn malformed_braces_are_an_error() {
        for s in ["mkor:f={1,10", "mkor:f=1}", "mkor:f={{1}}", "mkor:f=1{2}"] {
            match err(s) {
                SweepError::UnmatchedBrace { .. } => {}
                other => panic!("`{s}`: expected UnmatchedBrace, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_seed_ranges_are_an_error() {
        for s in ["sgd x seed=4..1", "sgd x seed=4..4"] {
            let e = err(s);
            assert!(matches!(&e, SweepError::BadRange { .. }), "{s}: {e:?}");
            assert!(e.to_string().contains("4.."), "{e}");
        }
        assert!(matches!(err("sgd x seed=abc"), SweepError::BadValue { .. }));
        assert!(matches!(err("sgd x lr=0..2"), SweepError::BadValue { .. }));
    }

    #[test]
    fn spec_errors_carry_the_template() {
        let e = err("bogus:f={1}");
        let msg = e.to_string();
        assert!(msg.contains("bogus") && msg.contains("mkor"), "{msg}");
        let e = err("mkor:nope={1}");
        assert!(e.to_string().contains("nope"), "{e}");
        // A part without `=` is the spec grammar's Malformed error.
        let e = err("mkor:f");
        assert!(e.to_string().contains("key=val"), "{e}");
    }

    #[test]
    fn empty_sweeps_are_an_error() {
        assert_eq!(err(""), SweepError::Empty);
        assert_eq!(err(" ; "), SweepError::Empty);
    }

    #[test]
    fn for_tasks_repeats_the_template_per_task() {
        let tasks = [TaskKind::Images, TaskKind::Autoencoder];
        let g = SweepGrid::for_tasks("mkor:f={1,10}", &tasks, 5).unwrap();
        assert_eq!(g.len(), 4);
        let labels: Vec<String> = g.cells.iter().map(|c| task_label(&c.task)).collect();
        assert_eq!(labels, vec!["images", "images", "autoencoder", "autoencoder"]);
        for (i, c) in g.cells.iter().enumerate() {
            assert_eq!(c.index, i, "indices re-numbered across tasks");
            assert_eq!(c.seed, 5);
        }
        assert!(SweepGrid::for_tasks("mkor", &[], 0).is_err());
    }

    #[test]
    fn tasks_resolve_by_name() {
        for name in ["glue", "images", "autoencoder", "text", "charlm"] {
            let task = task_by_name(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(task_label(&task), name);
        }
        let e = task_by_name("mnist").unwrap_err();
        assert!(e.to_string().contains("glue"), "{e}");
    }
}
