//! The L3 coordinator: synchronous data-parallel training.
//!
//! This is the distributed-training runtime the paper's system lives in:
//! worker replicas compute forward/backward on their batch shards (real
//! threads), gradients are combined with a real ring all-reduce
//! ([`crate::collective::ring`], optionally bf16 on the wire), the
//! optimizer — MKOR or any baseline — runs its factor/precondition/update
//! phases on the leader with phase timing and communication accounting,
//! MKOR-H's loss-rate switch and the knee-point LR scheduler observe the
//! loss stream, and divergence is detected and reported (Table 5's "D"
//! entries).
//!
//! Two frontends:
//! * [`trainer::Trainer`] — drives the Rust-native [`crate::model::Mlp`]
//!   proxies (all convergence figures/tables). Construct it with
//!   [`trainer::TrainerBuilder`], which routes optimizer construction
//!   through [`crate::optim::OptimizerSpec`];
//! * `runtime::XlaTrainer` (see [`crate::runtime`]) — drives the AOT
//!   transformer artifacts for the end-to-end example.

pub mod metrics;
pub mod trainer;

pub use metrics::{sweep_progress_line, RunRecord, StepRecord};
pub use trainer::{Target, Trainer, TrainerBuilder, TrainerConfig};
