//! The synchronous data-parallel trainer over the Rust-native model.
//!
//! One step:
//! 1. split the global batch into per-worker shards (columns);
//! 2. worker threads run forward/backward on their replica, producing
//!    per-layer captures;
//! 3. weight gradients are combined with a real ring all-reduce (fp32 or
//!    bf16 wire), activations/gradients are concatenated (a leader-view of
//!    the global batch, as KFAC-family math expects);
//! 4. the optimizer steps the leader replica (factor / precondition /
//!    update phases, timed) and observes the loss (MKOR-H switching);
//! 5. the leader's weights are broadcast back to the replicas.
//!
//! Divergence (non-finite loss or weights) halts the run and is recorded —
//! those are the "D" entries of Table 5.

use crate::collective::ring::{allreduce_mean, allreduce_mean_bf16};
use crate::coordinator::metrics::{RunRecord, StepRecord};
use crate::linalg::Matrix;
use crate::model::{accuracy, mse_loss, softmax_xent, Capture, Mlp};
use crate::optim::schedule::{Constant, LrSchedule};
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;

/// What a batch is labeled with.
#[derive(Clone, Debug)]
pub enum Target {
    /// Classification labels (softmax cross-entropy + accuracy).
    Labels(Vec<usize>),
    /// Dense regression targets (MSE; the autoencoder experiments).
    Dense(Matrix),
}

/// Trainer configuration.
pub struct TrainerConfig {
    /// Simulated data-parallel width (worker threads).
    pub workers: usize,
    /// bf16 wire format for the gradient all-reduce.
    pub quantized_grads: bool,
    /// Stop early when eval metric ≥ target (classification) or loss ≤
    /// target (dense).
    pub target_metric: Option<f64>,
    /// Run an eval every n steps (0 = never).
    pub eval_every: usize,
    /// Name recorded in the run record.
    pub run_name: String,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            workers: 4,
            quantized_grads: false,
            target_metric: None,
            eval_every: 0,
            run_name: String::from("run"),
        }
    }
}

/// Builder for [`Trainer`]: model → optimizer spec → schedule →
/// workers/wire-format → [`TrainerBuilder::build`].
///
/// The one construction path for trainers in benches, examples, tests and
/// the CLI — the optimizer is always built from an [`OptimizerSpec`], so
/// the resulting [`RunRecord`] carries the canonical spec string of the
/// exact configuration that ran.
///
/// ```ignore
/// let trainer = TrainerBuilder::new(model)
///     .optimizer(OptimizerSpec::parse("mkor:f=10,backend=lamb")?)
///     .constant_lr(0.05)
///     .workers(4)
///     .build();
/// ```
pub struct TrainerBuilder {
    model: Mlp,
    spec: OptimizerSpec,
    schedule: Box<dyn LrSchedule + Send>,
    cfg: TrainerConfig,
}

impl TrainerBuilder {
    /// Start from a model; defaults: SGD-momentum, constant LR 0.1, and
    /// [`TrainerConfig::default`] (4 workers, fp32 wire).
    pub fn new(model: Mlp) -> Self {
        TrainerBuilder {
            model,
            spec: OptimizerSpec::default(),
            schedule: Box::new(Constant(0.1)),
            cfg: TrainerConfig::default(),
        }
    }

    /// Set the optimizer from a typed spec.
    pub fn optimizer(mut self, spec: OptimizerSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Set the optimizer from a spec string (`name[:key=val,...]`).
    pub fn optimizer_str(self, s: &str) -> Result<Self, crate::optim::SpecError> {
        Ok(self.optimizer(OptimizerSpec::parse(s)?))
    }

    /// Set an arbitrary LR schedule.
    pub fn schedule(mut self, schedule: Box<dyn LrSchedule + Send>) -> Self {
        self.schedule = schedule;
        self
    }

    /// Shorthand for a constant learning rate.
    pub fn constant_lr(self, lr: f32) -> Self {
        self.schedule(Box::new(Constant(lr)))
    }

    /// Data-parallel width (worker threads).
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// bf16 wire format for the gradient all-reduce.
    pub fn quantized_grads(mut self, quantized: bool) -> Self {
        self.cfg.quantized_grads = quantized;
        self
    }

    /// Stop-early target (accuracy for labeled targets, loss for dense).
    pub fn target_metric(mut self, target: f64) -> Self {
        self.cfg.target_metric = Some(target);
        self
    }

    /// Run an eval every `n` steps (0 = never).
    pub fn eval_every(mut self, n: usize) -> Self {
        self.cfg.eval_every = n;
        self
    }

    /// Name recorded in the run record.
    pub fn run_name(mut self, name: impl Into<String>) -> Self {
        self.cfg.run_name = name.into();
        self
    }

    /// Replace the whole [`TrainerConfig`] at once (keeps any builder
    /// fields set afterwards).
    pub fn config(mut self, cfg: TrainerConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Build the trainer: constructs the optimizer from the spec against
    /// the model's layer shapes.
    pub fn build(self) -> Trainer {
        let shapes = self.model.shapes();
        let opt = self.spec.build(&shapes);
        Trainer::from_parts(self.model, opt, self.schedule, self.cfg)
    }
}

/// The trainer. Owns the worker replicas, the optimizer and the schedule.
pub struct Trainer {
    cfg: TrainerConfig,
    /// replicas[0] is the leader.
    replicas: Vec<Mlp>,
    opt: Box<dyn Optimizer + Send>,
    schedule: Box<dyn LrSchedule + Send>,
    pub phases: PhaseTimer,
    pub record: RunRecord,
    t: usize,
    diverged: bool,
}

impl Trainer {
    /// Positional constructor, superseded by [`TrainerBuilder`] (which also
    /// routes optimizer construction through [`OptimizerSpec`]).
    #[deprecated(
        since = "0.2.0",
        note = "use TrainerBuilder::new(model).optimizer(spec)...build()"
    )]
    pub fn new(
        model: Mlp,
        opt: Box<dyn Optimizer + Send>,
        schedule: Box<dyn LrSchedule + Send>,
        cfg: TrainerConfig,
    ) -> Self {
        Trainer::from_parts(model, opt, schedule, cfg)
    }

    fn from_parts(
        model: Mlp,
        opt: Box<dyn Optimizer + Send>,
        schedule: Box<dyn LrSchedule + Send>,
        cfg: TrainerConfig,
    ) -> Self {
        assert!(cfg.workers >= 1);
        let replicas = vec![model; cfg.workers];
        let record = RunRecord {
            name: cfg.run_name.clone(),
            optimizer: opt.name().to_string(),
            spec: opt.spec().canonical(),
            ..Default::default()
        };
        Trainer {
            cfg,
            replicas,
            opt,
            schedule,
            phases: PhaseTimer::new(),
            record,
            t: 0,
            diverged: false,
        }
    }

    pub fn diverged(&self) -> bool {
        self.diverged
    }

    pub fn steps_done(&self) -> usize {
        self.t
    }

    pub fn leader(&self) -> &Mlp {
        &self.replicas[0]
    }

    pub fn optimizer(&self) -> &dyn Optimizer {
        self.opt.as_ref()
    }

    /// Column ranges of the per-worker shards.
    fn shard_ranges(&self, b: usize) -> Vec<(usize, usize)> {
        let w = self.cfg.workers;
        let base = b / w;
        let rem = b % w;
        let mut out = Vec::with_capacity(w);
        let mut start = 0;
        for r in 0..w {
            let len = base + usize::from(r < rem);
            out.push((start, start + len));
            start += len;
        }
        out
    }

    /// One synchronous data-parallel step on a global batch. Returns the
    /// (global) training loss, or `None` if the run has diverged.
    pub fn step(&mut self, x: &Matrix, target: &Target) -> Option<f64> {
        if self.diverged {
            return None;
        }
        let t0 = std::time::Instant::now();
        let b = x.cols();
        let ranges = self.shard_ranges(b);
        let lr = self.schedule.lr(self.t);

        // ---- per-worker forward/backward (threads) ----------------------
        let shards: Vec<(Matrix, Target)> = ranges
            .iter()
            .map(|&(lo, hi)| {
                let mut sx = Matrix::zeros(x.rows(), hi - lo);
                for r in 0..x.rows() {
                    sx.row_mut(r).copy_from_slice(&x.row(r)[lo..hi]);
                }
                let st = match target {
                    Target::Labels(l) => Target::Labels(l[lo..hi].to_vec()),
                    Target::Dense(y) => {
                        let mut sy = Matrix::zeros(y.rows(), hi - lo);
                        for r in 0..y.rows() {
                            sy.row_mut(r).copy_from_slice(&y.row(r)[lo..hi]);
                        }
                        Target::Dense(sy)
                    }
                };
                (sx, st)
            })
            .collect();

        let results: Vec<(f64, Vec<Capture>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .zip(&shards)
                .map(|(replica, (sx, st))| {
                    scope.spawn(move || {
                        if sx.cols() == 0 {
                            return (0.0f64, Vec::new());
                        }
                        let out = replica.forward(sx);
                        let (loss, dldy) = match st {
                            Target::Labels(l) => softmax_xent(&out, l),
                            Target::Dense(y) => mse_loss(&out, y),
                        };
                        (loss, replica.backward(&dldy))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });

        // ---- combine: loss mean + gradient all-reduce + A/G concat ------
        let mut loss = 0.0f64;
        let mut weight = 0.0f64;
        for ((lo, hi), (l, _)) in ranges.iter().zip(&results) {
            let n = (hi - lo) as f64;
            loss += l * n;
            weight += n;
        }
        loss /= weight.max(1.0);
        if !loss.is_finite() {
            self.mark_diverged(loss, lr, t0.elapsed().as_secs_f64());
            return None;
        }

        let n_layers = self.replicas[0].layers.len();
        let mut grad_bytes = 0usize;
        let mut caps: Vec<Capture> = Vec::with_capacity(n_layers);
        let t_comm = std::time::Instant::now();
        for layer in 0..n_layers {
            // All-reduce the per-worker weight gradients (real ring).
            let mut bufs: Vec<Vec<f32>> = results
                .iter()
                .map(|(_, c)| {
                    if c.is_empty() {
                        vec![0.0; self.replicas[0].layers[layer].w.len()]
                    } else {
                        c[layer].dw.data().to_vec()
                    }
                })
                .collect();
            let stats = if self.cfg.quantized_grads {
                allreduce_mean_bf16(&mut bufs)
            } else {
                allreduce_mean(&mut bufs)
            };
            grad_bytes += stats.bytes_per_worker;
            let dw = Matrix::from_vec(
                self.replicas[0].layers[layer].w.rows(),
                self.replicas[0].layers[layer].w.cols(),
                bufs[0].clone(),
            );
            // Bias gradients: plain mean (small).
            let dout = self.replicas[0].layers[layer].w.rows();
            let mut db = vec![0.0f32; dout];
            let mut contributors = 0usize;
            for (_, c) in &results {
                if !c.is_empty() {
                    contributors += 1;
                    for (s, &v) in db.iter_mut().zip(&c[layer].db) {
                        *s += v;
                    }
                }
            }
            for v in db.iter_mut() {
                *v /= contributors.max(1) as f32;
            }
            // Concatenate A and G across workers (leader's global view).
            let din = self.replicas[0].layers[layer].w.cols();
            let total_cols: usize = results
                .iter()
                .filter(|(_, c)| !c.is_empty())
                .map(|(_, c)| c[layer].a.cols())
                .sum();
            let mut a = Matrix::zeros(din, total_cols);
            let mut g = Matrix::zeros(dout, total_cols);
            let mut at = 0usize;
            for (_, c) in &results {
                if c.is_empty() {
                    continue;
                }
                let ca = &c[layer].a;
                let cg = &c[layer].g;
                for col in 0..ca.cols() {
                    for r in 0..din {
                        a[(r, at + col)] = ca[(r, col)];
                    }
                    for r in 0..dout {
                        g[(r, at + col)] = cg[(r, col)];
                    }
                }
                at += ca.cols();
            }
            caps.push(Capture { a, g, dw, db });
        }
        self.phases.add("allreduce", t_comm.elapsed());

        // ---- optimizer step on the leader -------------------------------
        {
            // Split so the optimizer borrows only the leader replica.
            let (leader, _rest) = self.replicas.split_first_mut().unwrap();
            self.opt.step(&mut leader.layers, &caps, lr, &mut self.phases);
        }
        self.opt.observe_loss(loss);
        self.schedule.observe(self.t, loss);

        if self.replicas[0].diverged() {
            self.mark_diverged(loss, lr, t0.elapsed().as_secs_f64());
            return None;
        }

        // ---- broadcast leader weights back to replicas ------------------
        let t_bc = std::time::Instant::now();
        let (leader, rest) = self.replicas.split_first_mut().unwrap();
        for replica in rest {
            for (dst, src) in replica.layers.iter_mut().zip(&leader.layers) {
                dst.w.data_mut().copy_from_slice(src.w.data());
                dst.bias.copy_from_slice(&src.bias);
            }
        }
        self.phases.add("broadcast", t_bc.elapsed());

        self.record.steps.push(StepRecord {
            step: self.t,
            loss,
            eval_metric: None,
            lr,
            wall_secs: t0.elapsed().as_secs_f64(),
            grad_comm_bytes: grad_bytes,
            sync_comm_bytes: self.opt.sync_bytes_last_step(),
        });
        self.t += 1;
        Some(loss)
    }

    fn mark_diverged(&mut self, loss: f64, lr: f32, wall: f64) {
        self.diverged = true;
        self.record.diverged = true;
        self.record.steps.push(StepRecord {
            step: self.t,
            loss,
            eval_metric: None,
            lr,
            wall_secs: wall,
            grad_comm_bytes: 0,
            sync_comm_bytes: 0,
        });
        self.t += 1;
    }

    /// Evaluate on a held-out batch: returns (loss, accuracy-if-labeled)
    /// and records the metric against the current step.
    pub fn evaluate(&mut self, x: &Matrix, target: &Target) -> (f64, Option<f64>) {
        let out = self.replicas[0].infer(x);
        let (loss, metric) = match target {
            Target::Labels(l) => {
                let (loss, _) = softmax_xent(&out, l);
                (loss, Some(accuracy(&out, l)))
            }
            Target::Dense(y) => {
                let (loss, _) = mse_loss(&out, y);
                (loss, None)
            }
        };
        if let Some(rec) = self.record.steps.last_mut() {
            rec.eval_metric = metric.or(Some(-loss));
        }
        // Track convergence against the target.
        if self.record.converged_at.is_none() {
            if let Some(target_m) = self.cfg.target_metric {
                let reached = match target {
                    Target::Labels(_) => metric.map_or(false, |m| m >= target_m),
                    Target::Dense(_) => loss <= target_m,
                };
                if reached {
                    self.record.converged_at = Some(self.t);
                }
            }
        }
        (loss, metric)
    }

    /// Whether the configured target has been reached.
    pub fn converged(&self) -> bool {
        self.record.converged_at.is_some()
    }

    /// Finish: fold phase totals into the record and return it.
    pub fn finish(self) -> RunRecord {
        self.record
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classification::{Dataset, TaskConfig};
    use crate::model::Activation;
    use crate::util::Rng;

    fn make_trainer_lr(
        opt_name: &str,
        workers: usize,
        seed: u64,
        lr: f32,
    ) -> (Trainer, Dataset) {
        let mut cfg = TaskConfig::new("t", 16, 3);
        cfg.train = 256;
        cfg.test = 128;
        cfg.separation = 2.5;
        cfg.seed = seed;
        let ds = Dataset::generate(cfg);
        let mut rng = Rng::new(seed);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let trainer = TrainerBuilder::new(model)
            .optimizer_str(opt_name)
            .unwrap()
            .constant_lr(lr)
            .workers(workers)
            .target_metric(0.8)
            .build();
        (trainer, ds)
    }

    fn make_trainer(opt_name: &str, workers: usize, seed: u64) -> (Trainer, Dataset) {
        make_trainer_lr(opt_name, workers, seed, 0.1)
    }

    #[test]
    fn trainer_and_builder_are_send() {
        // The sweep executor builds one Trainer per worker thread; this is
        // the compile-time proof that every part (boxed optimizer and
        // schedule included) can cross a thread boundary.
        fn assert_send<T: Send>() {}
        assert_send::<Trainer>();
        assert_send::<TrainerBuilder>();
        assert_send::<RunRecord>();
    }

    #[test]
    fn builder_records_canonical_spec() {
        let mut rng = Rng::new(8);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let tr = TrainerBuilder::new(model)
            .optimizer_str("mkor:f=25,backend=lamb")
            .unwrap()
            .constant_lr(0.05)
            .workers(2)
            .run_name("spec-check")
            .build();
        assert_eq!(tr.record.optimizer, "mkor");
        assert_eq!(tr.record.spec, "mkor:f=25,backend=lamb");
        // The recorded spec re-parses to the configuration that ran.
        let re = OptimizerSpec::parse(&tr.record.spec).unwrap();
        assert_eq!(re, tr.optimizer().spec());
        // And the JSON dump carries it.
        let j = tr.record.to_json();
        assert_eq!(j.require_str("spec").unwrap(), "mkor:f=25,backend=lamb");
    }

    #[test]
    fn unknown_spec_string_is_rejected() {
        let mut rng = Rng::new(9);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let err = match TrainerBuilder::new(model).optimizer_str("bogus") {
            Ok(_) => panic!("`bogus` should not parse"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("mkor"), "{err}");
    }

    #[test]
    fn trains_classification_to_high_accuracy() {
        let (mut tr, ds) = make_trainer("sgd", 4, 1);
        for epoch in 0..30 {
            for b in ds.epoch_batches(64, epoch) {
                tr.step(&b.x, &Target::Labels(b.labels.clone()));
            }
        }
        let test = ds.test_batch();
        let (_, acc) = tr.evaluate(&test.x, &Target::Labels(test.labels.clone()));
        assert!(acc.unwrap() > 0.85, "acc={:?}", acc);
    }

    #[test]
    fn worker_count_does_not_change_the_math() {
        // Same seed, 1 vs 4 workers: identical loss trajectory (all-reduce
        // mean of shard gradients == global batch gradient).
        let (mut t1, ds) = make_trainer("sgd", 1, 2);
        let (mut t4, _) = make_trainer("sgd", 4, 2);
        let mut l1 = Vec::new();
        let mut l4 = Vec::new();
        for b in ds.epoch_batches(64, 0) {
            l1.push(t1.step(&b.x, &Target::Labels(b.labels.clone())).unwrap());
            l4.push(t4.step(&b.x, &Target::Labels(b.labels.clone())).unwrap());
        }
        for (a, b) in l1.iter().zip(&l4) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn mkor_trains_and_records_sync_bytes() {
        let (mut tr, ds) = make_trainer("mkor", 2, 3);
        let mut first_loss = None;
        let mut last = 0.0;
        for epoch in 0..10 {
            for b in ds.epoch_batches(64, epoch) {
                if let Some(l) = tr.step(&b.x, &Target::Labels(b.labels.clone())) {
                    first_loss.get_or_insert(l);
                    last = l;
                }
            }
        }
        assert!(!tr.diverged());
        assert!(last < 0.7 * first_loss.unwrap(), "{last} vs {first_loss:?}");
        // Factor steps synced rank-1 vectors.
        let synced: usize = tr.record.steps.iter().map(|s| s.sync_comm_bytes).sum();
        assert!(synced > 0);
        // Phase timer saw all three optimizer phases.
        assert!(tr.phases.count("factor") > 0);
        assert!(tr.phases.count("precond") > 0);
        assert!(tr.phases.count("update") > 0);
    }

    #[test]
    fn divergence_is_detected_and_halts() {
        let (_, ds) = make_trainer("sgd", 2, 4);
        // Absurd LR forces divergence.
        let mut rng = Rng::new(4);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let mut tr = TrainerBuilder::new(model)
            .optimizer_str("sgd")
            .unwrap()
            .constant_lr(1e6)
            .workers(2)
            .build();
        let mut steps = 0;
        'outer: for epoch in 0..50 {
            for b in ds.epoch_batches(64, epoch) {
                if tr.step(&b.x, &Target::Labels(b.labels.clone())).is_none() {
                    break 'outer;
                }
                steps += 1;
            }
        }
        assert!(tr.diverged(), "did not diverge after {steps} steps");
        assert!(tr.record.diverged);
        // Further steps are refused.
        let b = &ds.epoch_batches(64, 0)[0];
        assert!(tr.step(&b.x, &Target::Labels(b.labels.clone())).is_none());
    }

    #[test]
    fn target_metric_marks_convergence() {
        // Adam wants a much smaller LR than SGD on this task.
        let (mut tr, ds) = make_trainer_lr("adam", 2, 5, 0.01);
        let test = ds.test_batch();
        for epoch in 0..40 {
            for b in ds.epoch_batches(64, epoch) {
                tr.step(&b.x, &Target::Labels(b.labels.clone()));
            }
            tr.evaluate(&test.x, &Target::Labels(test.labels.clone()));
            if tr.converged() {
                break;
            }
        }
        assert!(tr.converged(), "never reached 0.8 accuracy");
    }

    #[test]
    fn quantized_gradient_allreduce_still_trains() {
        let mut cfg = TaskConfig::new("t", 16, 3);
        cfg.train = 256;
        cfg.seed = 6;
        let ds = Dataset::generate(cfg);
        let mut rng = Rng::new(6);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let mut tr = TrainerBuilder::new(model)
            .optimizer_str("sgd")
            .unwrap()
            .constant_lr(0.1)
            .workers(4)
            .quantized_grads(true)
            .build();
        let mut first = None;
        let mut last = 0.0;
        for epoch in 0..15 {
            for b in ds.epoch_batches(64, epoch) {
                if let Some(l) = tr.step(&b.x, &Target::Labels(b.labels.clone())) {
                    first.get_or_insert(l);
                    last = l;
                }
            }
        }
        assert!(last < 0.8 * first.unwrap());
    }
}
