//! The synchronous data-parallel trainer over the Rust-native model.
//!
//! One step:
//! 1. split the global batch into per-worker shards (columns);
//! 2. worker threads run forward/backward on their replica, producing
//!    per-layer captures;
//! 3. weight gradients are combined with a real ring all-reduce (fp32 or
//!    bf16 wire), activations/gradients are concatenated (a leader-view of
//!    the global batch, as KFAC-family math expects);
//! 4. the optimizer steps the leader replica (factor / precondition /
//!    update phases, timed) and observes the loss (MKOR-H switching);
//! 5. the leader's weights are broadcast back to the replicas.
//!
//! Divergence (non-finite loss or weights) halts the run and is recorded —
//! those are the "D" entries of Table 5.

use crate::checkpoint::{Checkpoint, CheckpointError, Checkpointable, StateDict};
use crate::collective::ring::{allreduce_mean, allreduce_mean_bf16};
use crate::coordinator::metrics::{RunRecord, StepRecord};
use crate::linalg::Matrix;
use crate::model::{accuracy, mse_loss, softmax_xent, Capture, Model};
use crate::obs::{self, EventKind, TraceEvent};
use crate::optim::schedule::{Constant, LrSchedule};
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// What a batch is labeled with.
#[derive(Clone, Debug)]
pub enum Target {
    /// Classification labels (softmax cross-entropy + accuracy).
    Labels(Vec<usize>),
    /// Dense regression targets (MSE; the autoencoder experiments).
    Dense(Matrix),
}

/// Trainer configuration.
pub struct TrainerConfig {
    /// Simulated data-parallel width (worker threads).
    pub workers: usize,
    /// bf16 wire format for the gradient all-reduce.
    pub quantized_grads: bool,
    /// Stop early when eval metric ≥ target (classification) or loss ≤
    /// target (dense).
    pub target_metric: Option<f64>,
    /// Run an eval every n steps (0 = never).
    pub eval_every: usize,
    /// Name recorded in the run record.
    pub run_name: String,
    /// Write a checkpoint every n completed steps (0 = never). Requires
    /// `checkpoint_dir`; the driving loop triggers the write by calling
    /// [`Trainer::checkpoint_tick`] at the end of each iteration.
    pub checkpoint_every: usize,
    /// Directory checkpoints are written into (overwritten in place — the
    /// directory always holds the latest snapshot).
    pub checkpoint_dir: Option<PathBuf>,
    /// Task label recorded in the checkpoint manifest; resume validates it
    /// against the resuming run's label when both are non-empty.
    pub checkpoint_task: String,
    /// Additionally *retain* a step-stamped checkpoint (`step-<t>/` under
    /// `checkpoint_dir`) every n completed steps (0 = never). Unlike the
    /// rolling snapshot, retained directories are not overwritten — they
    /// are the restore points a best-k policy ranks.
    pub keep_every: usize,
    /// Keep only the `k` best retained checkpoints by eval metric,
    /// garbage-collecting the rest after each retention save (0 = keep
    /// all). Requires `keep_every`.
    pub keep_best: usize,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            workers: 4,
            quantized_grads: false,
            target_metric: None,
            eval_every: 0,
            run_name: String::from("run"),
            checkpoint_every: 0,
            checkpoint_dir: None,
            checkpoint_task: String::new(),
            keep_every: 0,
            keep_best: 0,
        }
    }
}

/// Builder for [`Trainer`]: model → optimizer spec → schedule →
/// workers/wire-format → [`TrainerBuilder::build`].
///
/// The one construction path for trainers in benches, examples, tests and
/// the CLI — the optimizer is always built from an [`OptimizerSpec`], so
/// the resulting [`RunRecord`] carries the canonical spec string of the
/// exact configuration that ran.
///
/// ```ignore
/// let trainer = TrainerBuilder::new(model)
///     .optimizer(OptimizerSpec::parse("mkor:f=10,backend=lamb")?)
///     .constant_lr(0.05)
///     .workers(4)
///     .build();
/// ```
pub struct TrainerBuilder {
    model: Box<dyn Model>,
    spec: OptimizerSpec,
    schedule: Box<dyn LrSchedule + Send>,
    cfg: TrainerConfig,
    resume: Option<PathBuf>,
}

impl TrainerBuilder {
    /// Start from a model; defaults: SGD-momentum, constant LR 0.1, and
    /// [`TrainerConfig::default`] (4 workers, fp32 wire).
    pub fn new(model: impl Model + 'static) -> Self {
        TrainerBuilder::new_boxed(Box::new(model))
    }

    /// [`TrainerBuilder::new`] for an already-boxed model (the task
    /// dispatchers pick the substrate at runtime).
    pub fn new_boxed(model: Box<dyn Model>) -> Self {
        TrainerBuilder {
            model,
            spec: OptimizerSpec::default(),
            schedule: Box::new(Constant(0.1)),
            cfg: TrainerConfig::default(),
            resume: None,
        }
    }

    /// Set the optimizer from a typed spec.
    pub fn optimizer(mut self, spec: OptimizerSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Set the optimizer from a spec string (`name[:key=val,...]`).
    pub fn optimizer_str(self, s: &str) -> Result<Self, crate::optim::SpecError> {
        Ok(self.optimizer(OptimizerSpec::parse(s)?))
    }

    /// Set an arbitrary LR schedule.
    pub fn schedule(mut self, schedule: Box<dyn LrSchedule + Send>) -> Self {
        self.schedule = schedule;
        self
    }

    /// Shorthand for a constant learning rate.
    pub fn constant_lr(self, lr: f32) -> Self {
        self.schedule(Box::new(Constant(lr)))
    }

    /// Data-parallel width (worker threads).
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// bf16 wire format for the gradient all-reduce.
    pub fn quantized_grads(mut self, quantized: bool) -> Self {
        self.cfg.quantized_grads = quantized;
        self
    }

    /// Stop-early target (accuracy for labeled targets, loss for dense).
    pub fn target_metric(mut self, target: f64) -> Self {
        self.cfg.target_metric = Some(target);
        self
    }

    /// Run an eval every `n` steps (0 = never).
    pub fn eval_every(mut self, n: usize) -> Self {
        self.cfg.eval_every = n;
        self
    }

    /// Name recorded in the run record.
    pub fn run_name(mut self, name: impl Into<String>) -> Self {
        self.cfg.run_name = name.into();
        self
    }

    /// Replace the whole [`TrainerConfig`] at once (keeps any builder
    /// fields set afterwards).
    pub fn config(mut self, cfg: TrainerConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Write a checkpoint into `checkpoint_dir` every `n` completed steps
    /// (0 disables).
    pub fn checkpoint_every(mut self, n: usize) -> Self {
        self.cfg.checkpoint_every = n;
        self
    }

    /// Directory for periodic checkpoints (see
    /// [`TrainerBuilder::checkpoint_every`]; also usable with manual
    /// [`Trainer::save_checkpoint`] calls).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.checkpoint_dir = Some(dir.into());
        self
    }

    /// Task label for the checkpoint manifest (resume cross-checks it).
    pub fn checkpoint_task(mut self, label: impl Into<String>) -> Self {
        self.cfg.checkpoint_task = label.into();
        self
    }

    /// Retain a step-stamped checkpoint (`step-<t>/` under the checkpoint
    /// dir) every `n` completed steps (0 disables).
    pub fn keep_every(mut self, n: usize) -> Self {
        self.cfg.keep_every = n;
        self
    }

    /// Keep only the `k` best retained checkpoints by eval metric
    /// (0 keeps all). Only meaningful with [`TrainerBuilder::keep_every`].
    pub fn keep_best(mut self, k: usize) -> Self {
        self.cfg.keep_best = k;
        self
    }

    /// Restore model/optimizer/schedule state and the run record from a
    /// checkpoint directory at build time. The checkpoint's canonical spec
    /// string must match this builder's spec; shapes are validated as the
    /// state loads. Use [`TrainerBuilder::try_build`] for a `Result`.
    pub fn resume_from(mut self, dir: impl Into<PathBuf>) -> Self {
        self.resume = Some(dir.into());
        self
    }

    /// Build the trainer: constructs the optimizer from the spec against
    /// the model's layer shapes. Panics if a [`TrainerBuilder::resume_from`]
    /// checkpoint fails validation — harness code wants the loud failure;
    /// CLI paths use [`TrainerBuilder::try_build`].
    pub fn build(self) -> Trainer {
        self.try_build()
            .unwrap_or_else(|e| panic!("TrainerBuilder::build: {e}"))
    }

    /// [`TrainerBuilder::build`], with checkpoint-resume failures as
    /// errors instead of panics.
    pub fn try_build(self) -> Result<Trainer, CheckpointError> {
        let resume = self.resume;
        let shapes = self.model.shapes();
        let opt = self.spec.build(&shapes);
        let mut trainer = Trainer::from_parts(self.model, opt, self.schedule, self.cfg);
        if let Some(dir) = resume {
            let ckpt = Checkpoint::load(&dir)?;
            trainer.restore_from(&ckpt)?;
        }
        Ok(trainer)
    }
}

/// The trainer. Owns the worker replicas, the optimizer and the schedule.
pub struct Trainer {
    cfg: TrainerConfig,
    /// replicas[0] is the leader.
    replicas: Vec<Box<dyn Model>>,
    opt: Box<dyn Optimizer + Send>,
    schedule: Box<dyn LrSchedule + Send>,
    pub phases: PhaseTimer,
    pub record: RunRecord,
    t: usize,
    diverged: bool,
    /// EMA of the training loss (β = 0.9), reported by heartbeats only —
    /// it never enters the step records or any artifact.
    loss_ema: Option<f64>,
    /// Last heartbeat (emit instant, step count then); telemetry-gated
    /// state, only touched when tracing is enabled.
    heartbeat_mark: Option<(std::time::Instant, usize)>,
}

impl Trainer {
    /// Positional constructor, superseded by [`TrainerBuilder`] (which also
    /// routes optimizer construction through [`OptimizerSpec`]).
    #[deprecated(
        since = "0.2.0",
        note = "use TrainerBuilder::new(model).optimizer(spec)...build()"
    )]
    pub fn new(
        model: impl Model + 'static,
        opt: Box<dyn Optimizer + Send>,
        schedule: Box<dyn LrSchedule + Send>,
        cfg: TrainerConfig,
    ) -> Self {
        Trainer::from_parts(Box::new(model), opt, schedule, cfg)
    }

    fn from_parts(
        model: Box<dyn Model>,
        opt: Box<dyn Optimizer + Send>,
        schedule: Box<dyn LrSchedule + Send>,
        cfg: TrainerConfig,
    ) -> Self {
        assert!(cfg.workers >= 1);
        let mut replicas = Vec::with_capacity(cfg.workers);
        replicas.push(model);
        for _ in 1..cfg.workers {
            replicas.push(replicas[0].clone_model());
        }
        let record = RunRecord {
            name: cfg.run_name.clone(),
            optimizer: opt.name().to_string(),
            spec: opt.spec().canonical(),
            ..Default::default()
        };
        Trainer {
            cfg,
            replicas,
            opt,
            schedule,
            phases: PhaseTimer::new(),
            record,
            t: 0,
            diverged: false,
            loss_ema: None,
            heartbeat_mark: None,
        }
    }

    pub fn diverged(&self) -> bool {
        self.diverged
    }

    pub fn steps_done(&self) -> usize {
        self.t
    }

    pub fn leader(&self) -> &dyn Model {
        self.replicas[0].as_ref()
    }

    pub fn optimizer(&self) -> &dyn Optimizer {
        self.opt.as_ref()
    }

    /// Copy the leader's weights into every worker replica (resume does
    /// exactly what the post-step broadcast does).
    fn broadcast_leader(&mut self) {
        let (leader, rest) = self.replicas.split_first_mut().unwrap();
        for replica in rest {
            for (dst, src) in replica.layers_mut().iter_mut().zip(leader.layers()) {
                dst.w.data_mut().copy_from_slice(src.w.data());
                dst.bias.copy_from_slice(&src.bias);
            }
        }
    }

    /// Counters + LR-schedule state (the `trainer.bin` component; model
    /// and optimizer are separate components of the checkpoint).
    fn counters_state(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t)
            .put_u64("diverged", self.diverged as u64)
            .put_dict("schedule", self.schedule.state_dict());
        sd
    }

    /// Snapshot the full training state into `dir`: leader model weights,
    /// optimizer state (factor inverses / moments / counters), trainer
    /// counters + schedule state, and the run record so far. The directory
    /// is overwritten in place — it always holds the latest snapshot.
    pub fn save_checkpoint(&self, dir: &Path) -> Result<(), CheckpointError> {
        let mut components = BTreeMap::new();
        components.insert("model".to_string(), self.replicas[0].state_dict());
        components.insert("optimizer".to_string(), self.opt.state_dict());
        components.insert("trainer".to_string(), self.counters_state());
        let ckpt = Checkpoint {
            step: self.t,
            spec: self.opt.spec().canonical(),
            optimizer: self.opt.name().to_string(),
            task: self.cfg.checkpoint_task.clone(),
            run_name: self.cfg.run_name.clone(),
            components,
            record: Some(self.record.clone()),
        };
        ckpt.save(dir)
    }

    /// Restore state saved by [`Trainer::save_checkpoint`]. Validates the
    /// spec (canonical string equality) and, when both sides carry one, the
    /// task label, then loads model weights (broadcast to all replicas),
    /// optimizer state, schedule state, counters and the run record.
    /// Stepping on from here reproduces the uninterrupted run bitwise.
    pub fn restore_from(&mut self, ckpt: &Checkpoint) -> Result<(), CheckpointError> {
        let expected = self.opt.spec().canonical();
        if ckpt.spec != expected {
            return Err(CheckpointError::SpecMismatch {
                expected,
                found: ckpt.spec.clone(),
            });
        }
        if !self.cfg.checkpoint_task.is_empty()
            && !ckpt.task.is_empty()
            && ckpt.task != self.cfg.checkpoint_task
        {
            return Err(CheckpointError::TaskMismatch {
                expected: self.cfg.checkpoint_task.clone(),
                found: ckpt.task.clone(),
            });
        }
        let state_err = |name: &str| {
            let name = name.to_string();
            move |source| CheckpointError::State { name, source }
        };
        self.replicas[0]
            .load_state_dict(ckpt.component("model")?)
            .map_err(state_err("model"))?;
        self.broadcast_leader();
        self.opt
            .load_state_dict(ckpt.component("optimizer")?)
            .map_err(state_err("optimizer"))?;
        let counters = ckpt.component("trainer")?;
        counters
            .check_keys(&["t", "diverged", "schedule"], &[])
            .map_err(state_err("trainer"))?;
        self.schedule
            .load_state_dict(counters.dict("schedule").map_err(state_err("trainer"))?)
            .map_err(state_err("trainer"))?;
        self.t = counters.usizev("t").map_err(state_err("trainer"))?;
        self.diverged = counters.u64v("diverged").map_err(state_err("trainer"))? != 0;
        if let Some(record) = &ckpt.record {
            self.record = record.clone();
        }
        Ok(())
    }

    /// Periodic checkpoint hook: writes a snapshot when `checkpoint_every`
    /// divides the completed-step count. The driving loop calls this at
    /// the END of each iteration — after any [`Trainer::evaluate`] — so a
    /// checkpoint landing on an eval boundary captures that step's eval
    /// metric in the record (checkpointing inside `step` would save the
    /// record one eval short and break bitwise resume equivalence). A
    /// write failure warns and keeps training: losing a snapshot must not
    /// kill the run that produces the next.
    ///
    /// Two independent cadences share the hook: the rolling snapshot
    /// (`checkpoint_every`, overwritten in place) and retention
    /// (`keep_every`, step-stamped `step-<t>/` subdirectories pruned to
    /// the `keep_best` best eval metrics).
    pub fn checkpoint_tick(&self) {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return;
        };
        let due = |every: usize| every > 0 && self.t > 0 && self.t % every == 0;
        // Rolling snapshot: overwritten in place, always the latest.
        if due(self.cfg.checkpoint_every) {
            if let Err(e) = self.save_checkpoint(dir) {
                eprintln!(
                    "warning: checkpoint at step {} into {} failed: {e}",
                    self.t,
                    dir.display()
                );
            }
        }
        // Retention: a step-stamped subdirectory that survives later
        // rolling saves (the manifest GC removes stamped *files* only),
        // then best-k garbage collection over all retained steps.
        if due(self.cfg.keep_every) {
            let retained = dir.join(crate::checkpoint::retained_dir_name(self.t));
            if let Err(e) = self.save_checkpoint(&retained) {
                eprintln!(
                    "warning: retained checkpoint at step {} into {} failed: {e}",
                    self.t,
                    retained.display()
                );
            } else if self.cfg.keep_best > 0 {
                match crate::checkpoint::gc_retained(dir, self.cfg.keep_best) {
                    Ok(removed) => {
                        for gone in removed {
                            obs::log::debug(&format!("retention gc: {}", gone.display()));
                        }
                    }
                    Err(e) => eprintln!("warning: retention gc under {}: {e}", dir.display()),
                }
            }
        }
    }

    /// Column ranges of the per-worker shards.
    fn shard_ranges(&self, b: usize) -> Vec<(usize, usize)> {
        let w = self.cfg.workers;
        let base = b / w;
        let rem = b % w;
        let mut out = Vec::with_capacity(w);
        let mut start = 0;
        for r in 0..w {
            let len = base + usize::from(r < rem);
            out.push((start, start + len));
            start += len;
        }
        out
    }

    /// One synchronous data-parallel step on a global batch. Returns the
    /// (global) training loss, or `None` if the run has diverged.
    pub fn step(&mut self, x: &Matrix, target: &Target) -> Option<f64> {
        if self.diverged {
            return None;
        }
        let t0 = std::time::Instant::now();
        // Root span of everything this step does; the guard closes when
        // the function returns (divergence exits included). Phase spans
        // and leaf events (gemm/allreduce/inverse_update) nest under it.
        let step_span = obs::span::span("step");
        let step_parent = step_span.id();
        let b = x.cols();
        let ranges = self.shard_ranges(b);
        let lr = self.schedule.lr(self.t);
        // Targets index OUTPUT columns: one input column yields `k` of them
        // (k = seq_len for the transformer, whose positions unroll into the
        // batch), so target shards scale the column ranges by k.
        let k = self.replicas[0].cols_per_sample();

        // ---- per-worker forward/backward (threads) ----------------------
        let shards: Vec<(Matrix, Target)> = ranges
            .iter()
            .map(|&(lo, hi)| {
                let mut sx = Matrix::zeros(x.rows(), hi - lo);
                for r in 0..x.rows() {
                    sx.row_mut(r).copy_from_slice(&x.row(r)[lo..hi]);
                }
                let st = match target {
                    Target::Labels(l) => Target::Labels(l[lo * k..hi * k].to_vec()),
                    Target::Dense(y) => {
                        let mut sy = Matrix::zeros(y.rows(), (hi - lo) * k);
                        for r in 0..y.rows() {
                            sy.row_mut(r).copy_from_slice(&y.row(r)[lo * k..hi * k]);
                        }
                        Target::Dense(sy)
                    }
                };
                (sx, st)
            })
            .collect();

        let results: Vec<(f64, Vec<Capture>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .zip(&shards)
                .map(|(replica, (sx, st))| {
                    scope.spawn(move || {
                        if sx.cols() == 0 {
                            return (0.0f64, Vec::new());
                        }
                        // Fresh threads have empty span stacks, so the
                        // step span is handed off explicitly; engine
                        // dispatches inside forward/backward then nest
                        // under these phase spans automatically.
                        let forward_span = obs::span::span_under("forward", step_parent);
                        let out = replica.forward(sx);
                        let (loss, dldy) = match st {
                            Target::Labels(l) => softmax_xent(&out, l),
                            Target::Dense(y) => mse_loss(&out, y),
                        };
                        drop(forward_span);
                        let _backward_span = obs::span::span_under("backward", step_parent);
                        (loss, replica.backward(&dldy))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });

        // ---- combine: loss mean + gradient all-reduce + A/G concat ------
        let mut loss = 0.0f64;
        let mut weight = 0.0f64;
        for ((lo, hi), (l, _)) in ranges.iter().zip(&results) {
            let n = (hi - lo) as f64;
            loss += l * n;
            weight += n;
        }
        loss /= weight.max(1.0);
        if !loss.is_finite() {
            self.mark_diverged(loss, lr, t0.elapsed().as_secs_f64());
            return None;
        }
        // Heartbeat bookkeeping (reported only; never enters artifacts).
        self.loss_ema = Some(match self.loss_ema {
            None => loss,
            Some(ema) => 0.9 * ema + 0.1 * loss,
        });

        let n_layers = self.replicas[0].layers().len();
        let mut grad_bytes = 0usize;
        let mut caps: Vec<Capture> = Vec::with_capacity(n_layers);
        let t_comm = std::time::Instant::now();
        let comm_span = obs::span::span("allreduce");
        for layer in 0..n_layers {
            // All-reduce the per-worker weight gradients (real ring).
            let mut bufs: Vec<Vec<f32>> = results
                .iter()
                .map(|(_, c)| {
                    if c.is_empty() {
                        vec![0.0; self.replicas[0].layers()[layer].w.len()]
                    } else {
                        c[layer].dw.data().to_vec()
                    }
                })
                .collect();
            let stats = if self.cfg.quantized_grads {
                allreduce_mean_bf16(&mut bufs)
            } else {
                allreduce_mean(&mut bufs)
            };
            grad_bytes += stats.bytes_per_worker;
            let dw = Matrix::from_vec(
                self.replicas[0].layers()[layer].w.rows(),
                self.replicas[0].layers()[layer].w.cols(),
                bufs[0].clone(),
            );
            // Bias gradients: plain mean (small).
            let dout = self.replicas[0].layers()[layer].w.rows();
            let mut db = vec![0.0f32; dout];
            let mut contributors = 0usize;
            for (_, c) in &results {
                if !c.is_empty() {
                    contributors += 1;
                    for (s, &v) in db.iter_mut().zip(&c[layer].db) {
                        *s += v;
                    }
                }
            }
            for v in db.iter_mut() {
                *v /= contributors.max(1) as f32;
            }
            // Concatenate A and G across workers (leader's global view).
            let din = self.replicas[0].layers()[layer].w.cols();
            let total_cols: usize = results
                .iter()
                .filter(|(_, c)| !c.is_empty())
                .map(|(_, c)| c[layer].a.cols())
                .sum();
            let mut a = Matrix::zeros(din, total_cols);
            let mut g = Matrix::zeros(dout, total_cols);
            let mut at = 0usize;
            for (_, c) in &results {
                if c.is_empty() {
                    continue;
                }
                let ca = &c[layer].a;
                let cg = &c[layer].g;
                for col in 0..ca.cols() {
                    for r in 0..din {
                        a[(r, at + col)] = ca[(r, col)];
                    }
                    for r in 0..dout {
                        g[(r, at + col)] = cg[(r, col)];
                    }
                }
                at += ca.cols();
            }
            caps.push(Capture { a, g, dw, db });
        }
        drop(comm_span);
        self.phases.add("allreduce", t_comm.elapsed());

        // ---- optimizer step on the leader -------------------------------
        // Bracket the optimizer call with phase-timer snapshots so the
        // step record carries its second-order share (factor + precond)
        // and whether a factor inversion ran — pure reads of timing the
        // optimizer already does, never a perturbation of it.
        let so_before =
            self.phases.total_secs("factor") + self.phases.total_secs("precond");
        let factor_steps_before = self.phases.count("factor");
        {
            // Split so the optimizer borrows only the leader replica.
            let (leader, _rest) = self.replicas.split_first_mut().unwrap();
            self.opt.step(leader.layers_mut(), &caps, lr, &mut self.phases);
        }
        let second_order_secs =
            self.phases.total_secs("factor") + self.phases.total_secs("precond") - so_before;
        let inverse_updated = self.phases.count("factor") > factor_steps_before;
        self.opt.observe_loss(loss);
        self.schedule.observe(self.t, loss);

        if self.replicas[0].diverged() {
            self.mark_diverged(loss, lr, t0.elapsed().as_secs_f64());
            return None;
        }

        // ---- broadcast leader weights back to replicas ------------------
        let t_bc = std::time::Instant::now();
        {
            let _broadcast_span = obs::span::span("broadcast");
            let (leader, rest) = self.replicas.split_first_mut().unwrap();
            for replica in rest {
                for (dst, src) in replica.layers_mut().iter_mut().zip(leader.layers()) {
                    dst.w.data_mut().copy_from_slice(src.w.data());
                    dst.bias.copy_from_slice(&src.bias);
                }
            }
        }
        self.phases.add("broadcast", t_bc.elapsed());

        let wall_secs = t0.elapsed().as_secs_f64();
        let sync_bytes = self.opt.sync_bytes_last_step();
        if obs::enabled() {
            let mut ev = TraceEvent::new(EventKind::Step)
                .num("step", self.t as f64)
                .num("secs", wall_secs)
                .num("loss", loss)
                .num("second_order_secs", second_order_secs)
                .num("grad_bytes", grad_bytes as f64)
                .num("sync_bytes", sync_bytes as f64)
                .maybe_under(obs::span::current());
            if !self.cfg.checkpoint_task.is_empty() {
                ev = ev.label("task", &self.cfg.checkpoint_task);
            }
            obs::emit(ev);
            let state_bytes = self.opt.state_bytes();
            obs::registry::with_global(|r| {
                r.inc("trainer.steps", 1);
                r.observe("trainer.step_secs", wall_secs);
                r.observe("trainer.second_order_secs", second_order_secs);
                r.gauge("trainer.state_bytes", state_bytes as f64);
            });
            // Liveness beacon every 10 steps: steps/sec since the last
            // beacon, the loss EMA and the optimizer state footprint.
            if self.t % 10 == 0 {
                let steps_per_sec = match self.heartbeat_mark {
                    Some((at, t_then)) => {
                        (self.t - t_then) as f64 / at.elapsed().as_secs_f64().max(1e-9)
                    }
                    None => 0.0,
                };
                self.heartbeat_mark = Some((std::time::Instant::now(), self.t));
                obs::emit(
                    TraceEvent::new(EventKind::Heartbeat)
                        .num("step", self.t as f64)
                        .num("steps_per_sec", steps_per_sec)
                        .num("loss_ema", self.loss_ema.unwrap_or(loss))
                        .num("state_bytes", state_bytes as f64),
                );
            }
        }
        self.record.steps.push(StepRecord {
            step: self.t,
            loss,
            eval_metric: None,
            lr,
            wall_secs,
            grad_comm_bytes: grad_bytes,
            sync_comm_bytes: sync_bytes,
            inverse_updated,
            second_order_secs,
        });
        self.t += 1;
        Some(loss)
    }

    fn mark_diverged(&mut self, loss: f64, lr: f32, wall: f64) {
        self.diverged = true;
        self.record.diverged = true;
        self.record.steps.push(StepRecord {
            step: self.t,
            loss,
            eval_metric: None,
            lr,
            wall_secs: wall,
            grad_comm_bytes: 0,
            sync_comm_bytes: 0,
            inverse_updated: false,
            second_order_secs: 0.0,
        });
        self.t += 1;
    }

    /// Evaluate on a held-out batch: returns (loss, accuracy-if-labeled)
    /// and records the metric against the current step.
    pub fn evaluate(&mut self, x: &Matrix, target: &Target) -> (f64, Option<f64>) {
        let _eval_span = obs::span::span("eval");
        let out = self.replicas[0].infer(x);
        let (loss, metric) = match target {
            Target::Labels(l) => {
                let (loss, _) = softmax_xent(&out, l);
                (loss, Some(accuracy(&out, l)))
            }
            Target::Dense(y) => {
                let (loss, _) = mse_loss(&out, y);
                (loss, None)
            }
        };
        if obs::enabled() {
            let mut ev = TraceEvent::new(EventKind::Eval)
                .num("step", self.t as f64)
                .num("loss", loss)
                .maybe_under(obs::span::current());
            if let Some(m) = metric {
                ev = ev.num("metric", m);
            }
            obs::emit(ev);
        }
        if let Some(rec) = self.record.steps.last_mut() {
            rec.eval_metric = metric.or(Some(-loss));
        }
        // Track convergence against the target.
        if self.record.converged_at.is_none() {
            if let Some(target_m) = self.cfg.target_metric {
                let reached = match target {
                    Target::Labels(_) => metric.map_or(false, |m| m >= target_m),
                    Target::Dense(_) => loss <= target_m,
                };
                if reached {
                    self.record.converged_at = Some(self.t);
                }
            }
        }
        (loss, metric)
    }

    /// Whether the configured target has been reached.
    pub fn converged(&self) -> bool {
        self.record.converged_at.is_some()
    }

    /// Finish: fold phase totals into the record and return it.
    pub fn finish(self) -> RunRecord {
        self.record
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classification::{Dataset, TaskConfig};
    use crate::model::{Activation, Mlp};
    use crate::util::Rng;

    fn make_trainer_lr(
        opt_name: &str,
        workers: usize,
        seed: u64,
        lr: f32,
    ) -> (Trainer, Dataset) {
        let mut cfg = TaskConfig::new("t", 16, 3);
        cfg.train = 256;
        cfg.test = 128;
        cfg.separation = 2.5;
        cfg.seed = seed;
        let ds = Dataset::generate(cfg);
        let mut rng = Rng::new(seed);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let trainer = TrainerBuilder::new(model)
            .optimizer_str(opt_name)
            .unwrap()
            .constant_lr(lr)
            .workers(workers)
            .target_metric(0.8)
            .build();
        (trainer, ds)
    }

    fn make_trainer(opt_name: &str, workers: usize, seed: u64) -> (Trainer, Dataset) {
        make_trainer_lr(opt_name, workers, seed, 0.1)
    }

    #[test]
    fn trainer_and_builder_are_send() {
        // The sweep executor builds one Trainer per worker thread; this is
        // the compile-time proof that every part (boxed optimizer and
        // schedule included) can cross a thread boundary.
        fn assert_send<T: Send>() {}
        assert_send::<Trainer>();
        assert_send::<TrainerBuilder>();
        assert_send::<RunRecord>();
    }

    #[test]
    fn builder_records_canonical_spec() {
        let mut rng = Rng::new(8);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let tr = TrainerBuilder::new(model)
            .optimizer_str("mkor:f=25,backend=lamb")
            .unwrap()
            .constant_lr(0.05)
            .workers(2)
            .run_name("spec-check")
            .build();
        assert_eq!(tr.record.optimizer, "mkor");
        assert_eq!(tr.record.spec, "mkor:f=25,backend=lamb");
        // The recorded spec re-parses to the configuration that ran.
        let re = OptimizerSpec::parse(&tr.record.spec).unwrap();
        assert_eq!(re, tr.optimizer().spec());
        // And the JSON dump carries it.
        let j = tr.record.to_json();
        assert_eq!(j.require_str("spec").unwrap(), "mkor:f=25,backend=lamb");
    }

    #[test]
    fn unknown_spec_string_is_rejected() {
        let mut rng = Rng::new(9);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let err = match TrainerBuilder::new(model).optimizer_str("bogus") {
            Ok(_) => panic!("`bogus` should not parse"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("mkor"), "{err}");
    }

    fn temp_dir(name: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mkor-trainer-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn checkpoint_resume_is_bitwise_equivalent() {
        // 2N straight steps vs N + save + restore-into-fresh-trainer + N:
        // identical loss series and identical final weights.
        let dir = temp_dir("resume");
        let (mut straight, ds) = make_trainer("mkor", 2, 31);
        let batches = ds.epoch_batches(64, 0);
        let n = batches.len() / 2;
        let mut straight_losses = Vec::new();
        for b in &batches {
            straight_losses.push(straight.step(&b.x, &Target::Labels(b.labels.clone())).unwrap());
        }

        let (mut first, _) = make_trainer("mkor", 2, 31);
        for b in &batches[..n] {
            first.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
        }
        first.save_checkpoint(&dir).unwrap();

        // A fresh process would rebuild the model the same way; its random
        // init is then overwritten by the restored weights.
        let mut rng = Rng::new(31);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let mut resumed = TrainerBuilder::new(model)
            .optimizer_str("mkor")
            .unwrap()
            .constant_lr(0.1)
            .workers(2)
            .target_metric(0.8)
            .resume_from(&dir)
            .try_build()
            .unwrap();
        assert_eq!(resumed.steps_done(), n);
        for b in &batches[n..] {
            resumed.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
        }

        let resumed_losses: Vec<f64> = resumed.record.steps.iter().map(|s| s.loss).collect();
        assert_eq!(straight_losses.len(), resumed_losses.len());
        for (i, (a, b)) in straight_losses.iter().zip(&resumed_losses).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "loss diverged at step {i}");
        }
        for (a, b) in straight.leader().layers().iter().zip(resumed.leader().layers()) {
            assert_eq!(a.w.data(), b.w.data());
            assert_eq!(a.bias, b.bias);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rejects_wrong_spec_and_wrong_shapes() {
        let dir = temp_dir("reject");
        let (mut tr, ds) = make_trainer("mkor", 2, 32);
        let b = &ds.epoch_batches(64, 0)[0];
        tr.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
        tr.save_checkpoint(&dir).unwrap();

        // Different optimizer spec → SpecMismatch naming both specs.
        let mut rng = Rng::new(32);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let err = TrainerBuilder::new(model)
            .optimizer_str("mkor:f=25")
            .unwrap()
            .resume_from(&dir)
            .try_build()
            .unwrap_err();
        assert!(
            matches!(&err, crate::checkpoint::CheckpointError::SpecMismatch { .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("mkor:f=25"), "{err}");

        // Different model width → shape mismatch from the state layer.
        let model = Mlp::new(&[16, 48, 3], Activation::Relu, &mut rng);
        let err = TrainerBuilder::new(model)
            .optimizer_str("mkor")
            .unwrap()
            .resume_from(&dir)
            .try_build()
            .unwrap_err();
        assert!(
            matches!(&err, crate::checkpoint::CheckpointError::State { .. }),
            "{err:?}"
        );

        // build() panics on the same failure (documented loud-failure path).
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            TrainerBuilder::new(model)
                .optimizer_str("kfac")
                .unwrap()
                .resume_from(&dir)
                .build()
        }));
        assert!(caught.is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn periodic_checkpoints_are_written_and_contain_the_record() {
        let dir = temp_dir("periodic");
        let mut cfg = TaskConfig::new("t", 16, 3);
        cfg.train = 256;
        cfg.seed = 33;
        let ds = Dataset::generate(cfg);
        let mut rng = Rng::new(33);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let mut tr = TrainerBuilder::new(model)
            .optimizer_str("lamb")
            .unwrap()
            .constant_lr(0.05)
            .workers(1)
            .checkpoint_every(2)
            .checkpoint_dir(&dir)
            .checkpoint_task("glue")
            .build();
        let batches = ds.epoch_batches(64, 0);
        for b in batches.iter().take(4) {
            tr.step(&b.x, &Target::Labels(b.labels.clone()));
            tr.checkpoint_tick();
        }
        // Latest snapshot is from step 4 and carries 4 step records.
        let ckpt = crate::checkpoint::Checkpoint::load(&dir).unwrap();
        assert_eq!(ckpt.step, 4);
        assert_eq!(ckpt.spec, "lamb");
        assert_eq!(ckpt.task, "glue");
        assert_eq!(ckpt.record.as_ref().unwrap().steps.len(), 4);
        for name in ["model", "optimizer", "trainer"] {
            assert!(ckpt.components.contains_key(name), "{name}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trains_classification_to_high_accuracy() {
        let (mut tr, ds) = make_trainer("sgd", 4, 1);
        for epoch in 0..30 {
            for b in ds.epoch_batches(64, epoch) {
                tr.step(&b.x, &Target::Labels(b.labels.clone()));
            }
        }
        let test = ds.test_batch();
        let (_, acc) = tr.evaluate(&test.x, &Target::Labels(test.labels.clone()));
        assert!(acc.unwrap() > 0.85, "acc={:?}", acc);
    }

    #[test]
    fn worker_count_does_not_change_the_math() {
        // Same seed, 1 vs 4 workers: identical loss trajectory (all-reduce
        // mean of shard gradients == global batch gradient).
        let (mut t1, ds) = make_trainer("sgd", 1, 2);
        let (mut t4, _) = make_trainer("sgd", 4, 2);
        let mut l1 = Vec::new();
        let mut l4 = Vec::new();
        for b in ds.epoch_batches(64, 0) {
            l1.push(t1.step(&b.x, &Target::Labels(b.labels.clone())).unwrap());
            l4.push(t4.step(&b.x, &Target::Labels(b.labels.clone())).unwrap());
        }
        for (a, b) in l1.iter().zip(&l4) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn mkor_trains_and_records_sync_bytes() {
        let (mut tr, ds) = make_trainer("mkor", 2, 3);
        let mut first_loss = None;
        let mut last = 0.0;
        for epoch in 0..10 {
            for b in ds.epoch_batches(64, epoch) {
                if let Some(l) = tr.step(&b.x, &Target::Labels(b.labels.clone())) {
                    first_loss.get_or_insert(l);
                    last = l;
                }
            }
        }
        assert!(!tr.diverged());
        assert!(last < 0.7 * first_loss.unwrap(), "{last} vs {first_loss:?}");
        // Factor steps synced rank-1 vectors.
        let synced: usize = tr.record.steps.iter().map(|s| s.sync_comm_bytes).sum();
        assert!(synced > 0);
        // Phase timer saw all three optimizer phases.
        assert!(tr.phases.count("factor") > 0);
        assert!(tr.phases.count("precond") > 0);
        assert!(tr.phases.count("update") > 0);
        // The step records agree with the phase timers: every step where a
        // factor inversion ran is flagged, and its record carries the
        // second-order timing.
        let inv_steps: Vec<usize> = tr
            .record
            .steps
            .iter()
            .filter(|s| s.inverse_updated)
            .map(|s| s.step)
            .collect();
        // The "factor" phase is timed once per layer per factor step.
        let n_layers = tr.leader().layers().len();
        assert_eq!(inv_steps.len() * n_layers, tr.phases.count("factor"));
        assert!(inv_steps.contains(&0), "step 0 is always a factor step");
        assert!(tr.record.steps.iter().all(|s| s.second_order_secs >= 0.0));
        assert!(
            tr.record.steps.iter().any(|s| s.second_order_secs > 0.0),
            "precond time must land in the step records"
        );
    }

    #[test]
    fn divergence_is_detected_and_halts() {
        let (_, ds) = make_trainer("sgd", 2, 4);
        // Absurd LR forces divergence.
        let mut rng = Rng::new(4);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let mut tr = TrainerBuilder::new(model)
            .optimizer_str("sgd")
            .unwrap()
            .constant_lr(1e6)
            .workers(2)
            .build();
        let mut steps = 0;
        'outer: for epoch in 0..50 {
            for b in ds.epoch_batches(64, epoch) {
                if tr.step(&b.x, &Target::Labels(b.labels.clone())).is_none() {
                    break 'outer;
                }
                steps += 1;
            }
        }
        assert!(tr.diverged(), "did not diverge after {steps} steps");
        assert!(tr.record.diverged);
        // Further steps are refused.
        let b = &ds.epoch_batches(64, 0)[0];
        assert!(tr.step(&b.x, &Target::Labels(b.labels.clone())).is_none());
    }

    #[test]
    fn target_metric_marks_convergence() {
        // Adam wants a much smaller LR than SGD on this task.
        let (mut tr, ds) = make_trainer_lr("adam", 2, 5, 0.01);
        let test = ds.test_batch();
        for epoch in 0..40 {
            for b in ds.epoch_batches(64, epoch) {
                tr.step(&b.x, &Target::Labels(b.labels.clone()));
            }
            tr.evaluate(&test.x, &Target::Labels(test.labels.clone()));
            if tr.converged() {
                break;
            }
        }
        assert!(tr.converged(), "never reached 0.8 accuracy");
    }

    #[test]
    fn quantized_gradient_allreduce_still_trains() {
        let mut cfg = TaskConfig::new("t", 16, 3);
        cfg.train = 256;
        cfg.seed = 6;
        let ds = Dataset::generate(cfg);
        let mut rng = Rng::new(6);
        let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
        let mut tr = TrainerBuilder::new(model)
            .optimizer_str("sgd")
            .unwrap()
            .constant_lr(0.1)
            .workers(4)
            .quantized_grads(true)
            .build();
        let mut first = None;
        let mut last = 0.0;
        for epoch in 0..15 {
            for b in ds.epoch_batches(64, epoch) {
                if let Some(l) = tr.step(&b.x, &Target::Labels(b.labels.clone())) {
                    first.get_or_insert(l);
                    last = l;
                }
            }
        }
        assert!(last < 0.8 * first.unwrap());
    }
}
