//! Run metrics: per-step records, summaries, JSON/CSV export under
//! `results/`.

use crate::util::json::Json;
use std::path::Path;

/// One `[k/n] spec seed=S lr=LR → outcome` sweep progress line — the one
/// format shared by the in-process executor
/// ([`crate::sweep::run_sweep`]) and the multi-process dispatcher
/// ([`crate::sweep::run_sweep_mp`]), so `--jobs` and `--workers` sweeps
/// report identically and aggregated coordinator output reads like a
/// single-process run.
pub fn sweep_progress_line(
    done: usize,
    total: usize,
    spec: &str,
    seed: u64,
    lr: f32,
    outcome: &str,
) -> String {
    format!("[{done}/{total}] {spec} seed={seed} lr={lr} → {outcome}")
}

/// One training step's observables.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    /// Eval metric (accuracy) when an eval ran this step.
    pub eval_metric: Option<f64>,
    pub lr: f32,
    /// Wall seconds of this step (local measurement).
    pub wall_secs: f64,
    /// Gradient all-reduce payload bytes (per worker).
    pub grad_comm_bytes: usize,
    /// Second-order sync bytes (per worker).
    pub sync_comm_bytes: usize,
    /// Whether the optimizer ran a factor-inversion update this step
    /// (MKOR's Sherman–Morrison rank-1 step, KFAC's re-inversion, …) —
    /// so records and traces agree on when inversions happened.
    pub inverse_updated: bool,
    /// Wall seconds this step spent in second-order phases (factor
    /// update + preconditioning), from the trainer's phase timers.
    pub second_order_secs: f64,
}

/// A whole run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub name: String,
    pub optimizer: String,
    /// Canonical optimizer spec string (`OptimizerSpec::canonical`) — the
    /// exact configuration that produced this run; re-parse it with
    /// `OptimizerSpec::parse` to reproduce.
    pub spec: String,
    pub steps: Vec<StepRecord>,
    pub diverged: bool,
    /// Step at which the target metric was first reached, if ever.
    pub converged_at: Option<usize>,
    /// MKOR-H switch step, if applicable.
    pub switched_at: Option<usize>,
}

impl RunRecord {
    pub fn final_loss(&self) -> f64 {
        self.steps.last().map_or(f64::NAN, |s| s.loss)
    }

    pub fn best_eval(&self) -> Option<f64> {
        self.steps
            .iter()
            .filter_map(|s| s.eval_metric)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.wall_secs).sum()
    }

    pub fn total_comm_bytes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| s.grad_comm_bytes + s.sync_comm_bytes)
            .sum()
    }

    /// Loss series (for figure CSVs).
    pub fn loss_series(&self) -> Vec<f64> {
        self.steps.iter().map(|s| s.loss).collect()
    }

    /// First step at which the train loss ≤ target, smoothed over a
    /// trailing window of 5 (the same smoothing the convergence harness
    /// uses, so sweep-based benches report comparable steps-to-target).
    pub fn steps_to_loss(&self, target: f64) -> Option<usize> {
        crate::util::stats::first_at_or_below(&self.loss_series(), target, 5)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("optimizer", Json::Str(self.optimizer.clone()))
            .set("spec", Json::Str(self.spec.clone()))
            .set("diverged", Json::Bool(self.diverged))
            .set(
                "converged_at",
                self.converged_at.map_or(Json::Null, |s| Json::Num(s as f64)),
            )
            .set(
                "switched_at",
                self.switched_at.map_or(Json::Null, |s| Json::Num(s as f64)),
            )
            .set("final_loss", Json::Num(self.final_loss()))
            .set("total_wall_secs", Json::Num(self.total_wall_secs()))
            .set("total_comm_bytes", Json::Num(self.total_comm_bytes() as f64))
            .set("loss", Json::from_f64s(&self.loss_series()));
        let evals: Vec<Json> = self
            .steps
            .iter()
            .filter_map(|s| {
                s.eval_metric.map(|m| {
                    let mut e = Json::obj();
                    e.set("step", Json::Num(s.step as f64))
                        .set("metric", Json::Num(m));
                    e
                })
            })
            .collect();
        o.set("evals", Json::Arr(evals));
        o
    }

    pub fn save_json(&self, path: &Path) -> anyhow::Result<()> {
        self.to_json().to_file(path)
    }

    /// Lossless JSON: [`RunRecord::to_json`] plus the full per-step field
    /// set, so [`RunRecord::from_json`] round-trips the record exactly.
    /// This is what checkpoints store — a resumed run appends to the
    /// restored record and its final loss series is indistinguishable from
    /// an uninterrupted run's. (f64 values survive because the JSON writer
    /// prints shortest-round-trip representations; non-finite losses —
    /// a diverged run records the NaN/inf step that killed it — are
    /// written as the strings `"NaN"`/`"inf"`/`"-inf"`, since JSON numbers
    /// cannot carry them.)
    pub fn to_json_full(&self) -> Json {
        let mut o = self.to_json();
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let loss = if s.loss.is_finite() {
                    Json::Num(s.loss)
                } else {
                    Json::Str(s.loss.to_string())
                };
                // Same treatment for eval metrics: a diverging eval loss
                // records -inf/NaN, which a JSON number cannot carry
                // (null already means "no eval this step").
                let eval = match s.eval_metric {
                    None => Json::Null,
                    Some(m) if m.is_finite() => Json::Num(m),
                    Some(m) => Json::Str(m.to_string()),
                };
                let mut j = Json::obj();
                j.set("step", Json::Num(s.step as f64))
                    .set("loss", loss)
                    .set("eval_metric", eval)
                    .set("lr", Json::Num(s.lr as f64))
                    .set("wall_secs", Json::Num(s.wall_secs))
                    .set("grad_comm_bytes", Json::Num(s.grad_comm_bytes as f64))
                    .set("sync_comm_bytes", Json::Num(s.sync_comm_bytes as f64))
                    .set("inverse_updated", Json::Bool(s.inverse_updated))
                    .set("second_order_secs", Json::Num(s.second_order_secs));
                j
            })
            .collect();
        o.set("steps", Json::Arr(steps));
        o
    }

    /// Parse a record written by [`RunRecord::to_json_full`].
    pub fn from_json(j: &Json) -> Result<RunRecord, String> {
        let str_field = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing/invalid field `{key}`"))
        };
        let steps_json = j
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing/invalid field `steps`".to_string())?;
        let mut steps = Vec::with_capacity(steps_json.len());
        for (i, s) in steps_json.iter().enumerate() {
            let num = |key: &str| -> Result<f64, String> {
                s.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("steps[{i}]: missing/invalid `{key}`"))
            };
            // Non-finite losses travel as strings ("NaN"/"inf"/"-inf");
            // older records (or hand-edited ones) may carry `null`, which
            // reads back as NaN.
            let loss = match s.get("loss") {
                Some(Json::Str(v)) => v
                    .parse::<f64>()
                    .map_err(|_| format!("steps[{i}]: invalid `loss` string `{v}`"))?,
                Some(Json::Null) => f64::NAN,
                _ => num("loss")?,
            };
            steps.push(StepRecord {
                step: num("step")? as usize,
                loss,
                eval_metric: match s.get("eval_metric") {
                    None | Some(Json::Null) => None,
                    Some(Json::Str(v)) => Some(v.parse::<f64>().map_err(|_| {
                        format!("steps[{i}]: invalid `eval_metric` string `{v}`")
                    })?),
                    Some(v) => Some(
                        v.as_f64()
                            .ok_or_else(|| format!("steps[{i}]: invalid `eval_metric`"))?,
                    ),
                },
                lr: num("lr")? as f32,
                wall_secs: num("wall_secs")?,
                grad_comm_bytes: num("grad_comm_bytes")? as usize,
                sync_comm_bytes: num("sync_comm_bytes")? as usize,
                // Absent in pre-observability records (old checkpoints):
                // default rather than fail, like legacy `null` losses.
                inverse_updated: s
                    .get("inverse_updated")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                second_order_secs: s
                    .get("second_order_secs")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
            });
        }
        Ok(RunRecord {
            name: str_field("name")?,
            optimizer: str_field("optimizer")?,
            spec: str_field("spec")?,
            steps,
            diverged: j.get("diverged").and_then(Json::as_bool).unwrap_or(false),
            converged_at: j.get("converged_at").and_then(Json::as_usize),
            switched_at: j.get("switched_at").and_then(Json::as_usize),
        })
    }

    /// CSV "step,loss,lr,eval" (for plotting the figure series).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,lr,eval_metric\n");
        for r in &self.steps {
            s.push_str(&format!(
                "{},{},{},{}\n",
                r.step,
                r.loss,
                r.lr,
                r.eval_metric.map_or(String::new(), |m| m.to_string())
            ));
        }
        s
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_run() -> RunRecord {
        RunRecord {
            name: "t".into(),
            optimizer: "mkor".into(),
            spec: "mkor:f=25".into(),
            steps: vec![
                StepRecord {
                    step: 0,
                    loss: 2.0,
                    eval_metric: None,
                    lr: 0.1,
                    wall_secs: 0.5,
                    grad_comm_bytes: 100,
                    sync_comm_bytes: 10,
                    inverse_updated: true,
                    second_order_secs: 0.125,
                },
                StepRecord {
                    step: 1,
                    loss: 1.0,
                    eval_metric: Some(0.8),
                    lr: 0.1,
                    wall_secs: 0.5,
                    grad_comm_bytes: 100,
                    sync_comm_bytes: 0,
                    inverse_updated: false,
                    second_order_secs: 0.0,
                },
            ],
            diverged: false,
            converged_at: Some(1),
            switched_at: None,
        }
    }

    #[test]
    fn summaries() {
        let r = sample_run();
        assert_eq!(r.final_loss(), 1.0);
        assert_eq!(r.best_eval(), Some(0.8));
        assert_eq!(r.total_wall_secs(), 1.0);
        assert_eq!(r.total_comm_bytes(), 210);
    }

    #[test]
    fn json_roundtrip_fields() {
        let j = sample_run().to_json();
        assert_eq!(j.require_str("optimizer").unwrap(), "mkor");
        assert_eq!(j.require_str("spec").unwrap(), "mkor:f=25");
        assert_eq!(j.get("converged_at").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("loss").unwrap().as_arr().unwrap().len(), 2);
        // parse what we print
        let re = Json::parse(&format!("{j:#}")).unwrap();
        assert_eq!(re.get("final_loss").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn full_json_roundtrips_every_step_field() {
        let r = sample_run();
        let text = format!("{:#}", r.to_json_full());
        let re = RunRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(re.name, r.name);
        assert_eq!(re.spec, r.spec);
        assert_eq!(re.converged_at, r.converged_at);
        assert_eq!(re.steps.len(), r.steps.len());
        for (a, b) in r.steps.iter().zip(&re.steps) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss must be bitwise");
            assert_eq!(a.eval_metric, b.eval_metric);
            assert_eq!(a.lr.to_bits(), b.lr.to_bits());
            assert_eq!(a.grad_comm_bytes, b.grad_comm_bytes);
            assert_eq!(a.sync_comm_bytes, b.sync_comm_bytes);
            assert_eq!(a.inverse_updated, b.inverse_updated);
            assert_eq!(
                a.second_order_secs.to_bits(),
                b.second_order_secs.to_bits(),
                "second_order_secs must be bitwise"
            );
        }
        // A messy f64 survives the text round-trip bitwise.
        let mut r2 = sample_run();
        r2.steps[0].loss = std::f64::consts::LN_2 / 7.0;
        let text = format!("{:#}", r2.to_json_full());
        let re2 = RunRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(re2.steps[0].loss.to_bits(), r2.steps[0].loss.to_bits());
        // A record without `steps` is rejected with the field name.
        let e = RunRecord::from_json(&sample_run().to_json()).unwrap_err();
        assert!(e.contains("steps"), "{e}");
    }

    #[test]
    fn pre_observability_records_parse_with_defaults() {
        // Records written before `inverse_updated`/`second_order_secs`
        // existed (old checkpoints, old worker files) must still parse.
        let mut j = sample_run().to_json_full();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(steps)) = o.get_mut("steps") {
                for s in steps {
                    if let Json::Obj(so) = s {
                        so.remove("inverse_updated");
                        so.remove("second_order_secs");
                    }
                }
            }
        }
        let re = RunRecord::from_json(&j).unwrap();
        assert!(!re.steps[0].inverse_updated);
        assert_eq!(re.steps[0].second_order_secs, 0.0);
        assert_eq!(re.steps[0].loss, 2.0);
    }

    #[test]
    fn nonfinite_losses_survive_the_full_json_roundtrip() {
        // A diverged run records the non-finite step that killed it; JSON
        // numbers cannot carry NaN/inf, so they travel as strings.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut r = sample_run();
            r.steps[1].loss = bad;
            r.steps[1].eval_metric = Some(bad);
            r.diverged = true;
            let text = format!("{:#}", r.to_json_full());
            let re = RunRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert!(re.diverged);
            if bad.is_nan() {
                assert!(re.steps[1].loss.is_nan());
                assert!(re.steps[1].eval_metric.unwrap().is_nan());
            } else {
                assert_eq!(re.steps[1].loss, bad);
                assert_eq!(re.steps[1].eval_metric, Some(bad));
            }
        }
        // Legacy `null` losses read back as NaN instead of failing.
        let mut r = sample_run();
        r.steps[0].loss = f64::NAN;
        let legacy = format!("{:#}", r.to_json_full()).replace("\"NaN\"", "null");
        let re = RunRecord::from_json(&Json::parse(&legacy).unwrap()).unwrap();
        assert!(re.steps[0].loss.is_nan());
    }

    #[test]
    fn sweep_progress_lines_share_one_format() {
        let line = sweep_progress_line(3, 9, "mkor:f=10", 4, 0.1, "ok, loss 0.5 after 6 steps");
        assert_eq!(line, "[3/9] mkor:f=10 seed=4 lr=0.1 → ok, loss 0.5 after 6 steps");
    }

    #[test]
    fn steps_to_loss_smooths_over_a_window() {
        let mut r = sample_run();
        r.steps[0].loss = 5.0;
        r.steps[1].loss = 1.0;
        // Window mean at step 1 is 3.0, so target 2.0 is not yet reached...
        assert_eq!(r.steps_to_loss(3.0), Some(1));
        assert_eq!(r.steps_to_loss(0.5), None);
        assert_eq!(r.steps_to_loss(5.0), Some(0));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample_run().to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("step,"));
        assert!(lines[2].contains("0.8"));
    }
}
