//! Live trace following: the reader side of `mkor tail`.
//!
//! A running sim/sweep appends JSONL events to its `--trace` file; the
//! follower re-reads the growth since its last poll, consuming only
//! *complete* lines (through the last newline) so a torn tail — the
//! writer mid-`write` — is simply left for the next poll, the same
//! offset-tailing discipline the multi-process sweep coordinator uses
//! on worker result files ([`crate::sweep::dispatch`]). Unlike the
//! post-mortem [`super::summary::read_trace`], a malformed complete
//! line is *skipped*, not fatal: a live view must keep rendering while
//! a writer misbehaves.
//!
//! [`TailView`] is the aggregation the `mkor tail` screen shows: event
//! counts, the latest step/loss, and the most recent heartbeat payload.

use super::event::{EventKind, TraceEvent};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

/// Incremental reader over a growing trace file.
pub struct TraceFollower {
    path: PathBuf,
    offset: u64,
}

impl TraceFollower {
    pub fn new(path: &Path) -> TraceFollower {
        TraceFollower { path: path.to_path_buf(), offset: 0 }
    }

    /// Bytes of the file consumed so far.
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Decode every complete line appended since the last poll. A
    /// missing file (the writer has not created it yet) and a torn tail
    /// both yield an empty batch, never an error.
    pub fn poll(&mut self) -> Vec<TraceEvent> {
        let Ok(mut f) = std::fs::File::open(&self.path) else {
            return Vec::new();
        };
        if f.seek(SeekFrom::Start(self.offset)).is_err() {
            return Vec::new();
        }
        let mut buf = Vec::new();
        if f.read_to_end(&mut buf).is_err() {
            return Vec::new();
        }
        // Only whole lines are consumed; a torn tail stays unread so the
        // next poll sees it completed.
        let Some(last_nl) = buf.iter().rposition(|&b| b == b'\n') else {
            return Vec::new();
        };
        let text = String::from_utf8_lossy(&buf[..=last_nl]);
        let mut out = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            if let Ok(ev) = TraceEvent::from_jsonl(line) {
                out.push(ev);
            }
        }
        self.offset += last_nl as u64 + 1;
        out
    }
}

/// The aggregated live view `mkor tail` renders in place.
#[derive(Default)]
pub struct TailView {
    counts: BTreeMap<EventKind, usize>,
    first_t: Option<f64>,
    last_t: f64,
    /// Latest `(step, loss)` seen on a `step` event.
    last_step: Option<(f64, f64)>,
    /// Payload of the most recent heartbeat.
    last_heartbeat: Option<BTreeMap<String, Json>>,
}

impl TailView {
    pub fn events(&self) -> usize {
        self.counts.values().sum()
    }

    pub fn absorb(&mut self, ev: &TraceEvent) {
        *self.counts.entry(ev.kind).or_insert(0) += 1;
        self.first_t.get_or_insert(ev.t_secs);
        self.last_t = self.last_t.max(ev.t_secs);
        match ev.kind {
            EventKind::Step => {
                let get = |k: &str| ev.fields.get(k).and_then(Json::as_f64);
                if let (Some(step), Some(loss)) = (get("step"), get("loss")) {
                    self.last_step = Some((step, loss));
                }
            }
            EventKind::Heartbeat => self.last_heartbeat = Some(ev.fields.clone()),
            _ => {}
        }
    }

    /// The multi-line screen (fixed line count per content shape, so
    /// the caller can redraw in place).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let span = (self.last_t - self.first_t.unwrap_or(self.last_t)).max(0.0);
        out.push_str(&format!(
            "trace: {} events over {}\n",
            self.events(),
            crate::bench_utils::fmt_secs(span)
        ));
        match self.last_step {
            Some((step, loss)) => {
                out.push_str(&format!("step {step}: loss {loss:.6}\n"));
            }
            None => out.push_str("step -: no step events yet\n"),
        }
        match &self.last_heartbeat {
            Some(fields) => {
                out.push_str("heartbeat:");
                for (k, v) in fields {
                    out.push_str(&format!(" {k}={v}"));
                }
                out.push('\n');
            }
            None => out.push_str("heartbeat: none yet\n"),
        }
        out.push_str("kinds:");
        for (kind, count) in &self.counts {
            out.push_str(&format!(" {}={count}", kind.as_str()));
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn follower_tolerates_torn_tails_and_live_appends() {
        let dir = std::env::temp_dir().join(format!("mkor-obs-follow-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("live.jsonl");

        // Not created yet: the follower just waits.
        let mut f = TraceFollower::new(&path);
        assert!(f.poll().is_empty());

        // One complete line plus a torn tail: only the complete line is
        // consumed, and the torn bytes stay for later.
        let a = TraceEvent::new(EventKind::Step).num("step", 0.0).num("loss", 1.5);
        let b = TraceEvent::new(EventKind::Heartbeat).num("steps_per_sec", 12.0);
        let b_line = b.to_jsonl();
        let (b_head, b_rest) = b_line.split_at(10);
        let mut w = std::fs::File::create(&path).unwrap();
        write!(w, "{}\n{}", a.to_jsonl(), b_head).unwrap();
        w.flush().unwrap();
        let batch = f.poll();
        assert_eq!(batch, vec![a.clone()]);
        assert!(f.poll().is_empty(), "torn tail must not be consumed");

        // The writer finishes the line and appends another: both arrive.
        let c = TraceEvent::new(EventKind::Step).num("step", 1.0).num("loss", 1.25);
        write!(w, "{}\n{}\n", b_rest, c.to_jsonl()).unwrap();
        w.flush().unwrap();
        let batch = f.poll();
        assert_eq!(batch, vec![b.clone(), c.clone()]);
        assert!(f.poll().is_empty());

        // The view aggregated what the follower saw.
        let mut view = TailView::default();
        for ev in [&a, &b, &c] {
            view.absorb(ev);
        }
        assert_eq!(view.events(), 3);
        let screen = view.render();
        assert!(screen.contains("step 1: loss 1.250000"), "{screen}");
        assert!(screen.contains("steps_per_sec=12"), "{screen}");
        assert!(screen.contains("step=2"), "{screen}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_complete_lines_are_skipped_live() {
        let dir = std::env::temp_dir().join(format!("mkor-obs-follow2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("live.jsonl");
        let ok = TraceEvent::new(EventKind::Eval).num("loss", 0.5);
        std::fs::write(&path, format!("garbage line\n{}\n", ok.to_jsonl())).unwrap();
        let mut f = TraceFollower::new(&path);
        assert_eq!(f.poll(), vec![ok]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
