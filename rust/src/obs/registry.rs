//! The metrics registry: counters, gauges and histograms with a
//! deterministic dump.
//!
//! [`Hist`] is the repo's one quantile-bearing sample accumulator: the
//! perf harness' median-of-k ([`crate::perf::time_median`]), the trace
//! summarizer's per-kind p50/p99 and the live registry all use it, so
//! every reported quantile in the repo is the same linear-interpolated
//! definition ([`crate::util::stats::quantile_sorted`]). It retains exact
//! samples (the populations here are small: k repeats, per-kind event
//! counts) and derives fixed power-of-two bucket counts on demand for
//! dump output.
//!
//! The process-global [`global`] registry is fed by the same
//! instrumentation sites as the trace sink, under the same
//! [`super::sink::enabled`] branch — with tracing off, nothing here is
//! touched. Dumps ([`Registry::render`]/[`Registry::to_json`]) iterate
//! sorted maps, so equal content always produces equal bytes.

use crate::util::json::Json;
use crate::util::stats::quantile_sorted;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Sample-retaining histogram with exact quantiles and fixed log2 buckets.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Hist {
    samples: Vec<f64>,
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Record one observation. Non-finite samples are rejected (they would
    /// poison every quantile) — a caller bug, not data.
    pub fn add(&mut self, x: f64) {
        if x.is_finite() {
            self.samples.push(x);
        }
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.total() / self.samples.len() as f64)
        }
    }

    pub fn min(&self) -> Option<f64> {
        self.samples.iter().copied().reduce(f64::min)
    }

    pub fn max(&self) -> Option<f64> {
        self.samples.iter().copied().reduce(f64::max)
    }

    /// Linear-interpolated quantile, `q` in `[0, 1]`; `None` when empty.
    /// One sample returns that sample at every `q`.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(quantile_sorted(&s, q))
    }

    /// Fixed log2 bucket counts: bucket `i` holds samples in
    /// `[2^(i+lo_exp-1), 2^(i+lo_exp))` with the first/last buckets
    /// catching under/overflow. Bucket edges depend only on the constants
    /// below — never on the data — so dumps are comparable across runs.
    pub fn log2_buckets(&self) -> [u64; Self::BUCKETS] {
        let mut counts = [0u64; Self::BUCKETS];
        for &x in &self.samples {
            counts[Self::bucket_of(x)] += 1;
        }
        counts
    }

    /// Number of fixed buckets in [`Hist::log2_buckets`].
    pub const BUCKETS: usize = 32;
    /// Exponent of the first bucket's upper edge: bucket 0 is `< 2^-24 s`
    /// (~60 ns), bucket 31 is `≥ 2^6 s` (64 s+).
    const LO_EXP: i32 = -24;

    fn bucket_of(x: f64) -> usize {
        if x <= 0.0 {
            return 0;
        }
        let e = x.log2().floor() as i64 - (Self::LO_EXP as i64 - 1);
        e.clamp(0, Self::BUCKETS as i64 - 1) as usize
    }

    /// Fold another histogram's samples in.
    pub fn merge(&mut self, other: &Hist) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Counters, gauges and histograms keyed by name (sorted, so dumps are
/// deterministic for equal content).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `by` to the named counter (creating it at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set the named gauge to its latest value.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record one sample into the named histogram.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().add(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Deterministic text dump: one `name value` line per metric, sorted
    /// within each section.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("counter {k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("gauge {k} {v}\n"));
        }
        for (k, h) in &self.hists {
            let (mean, p50, p99) = match (h.mean(), h.quantile(0.5), h.quantile(0.99)) {
                (Some(m), Some(a), Some(b)) => (m, a, b),
                _ => continue, // empty hist: nothing to report
            };
            out.push_str(&format!(
                "hist {k} count={} total={:.9} mean={:.9} p50={:.9} p99={:.9}\n",
                h.count(),
                h.total(),
                mean,
                p50,
                p99
            ));
        }
        out
    }

    /// Deterministic JSON dump (same content as [`Registry::render`]).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, Json::Num(*v));
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            let mut o = Json::obj();
            o.set("count", Json::Num(h.count() as f64))
                .set("total", Json::Num(h.total()));
            if let (Some(m), Some(p50), Some(p99)) =
                (h.mean(), h.quantile(0.5), h.quantile(0.99))
            {
                o.set("mean", Json::Num(m))
                    .set("p50", Json::Num(p50))
                    .set("p99", Json::Num(p99));
            }
            hists.set(k, o);
        }
        let mut j = Json::obj();
        j.set("counters", counters).set("gauges", gauges).set("hists", hists);
        j
    }
}

static GLOBAL: Mutex<Option<Registry>> = Mutex::new(None);

/// Run `f` against the process-global registry (created on first use).
/// Callers gate on [`super::sink::enabled`] first, so with tracing off
/// the global registry is never even allocated.
pub fn with_global<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    let mut guard = GLOBAL.lock().unwrap();
    f(guard.get_or_insert_with(Registry::new))
}

/// Snapshot the global registry (empty if it was never touched).
pub fn global_snapshot() -> Registry {
    GLOBAL.lock().unwrap().clone().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_empty_is_none() {
        let h = Hist::new();
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.mean(), None);
        assert_eq!(h.min(), None);
        assert_eq!(h.count(), 0);
        assert_eq!(h.total(), 0.0);
    }

    #[test]
    fn quantile_one_sample_is_that_sample() {
        let mut h = Hist::new();
        h.add(3.25);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Some(3.25), "q={q}");
        }
        assert_eq!(h.mean(), Some(3.25));
    }

    #[test]
    fn quantile_all_equal_is_the_value() {
        let mut h = Hist::new();
        for _ in 0..7 {
            h.add(2.0);
        }
        assert_eq!(h.quantile(0.5), Some(2.0));
        assert_eq!(h.quantile(0.99), Some(2.0));
        assert_eq!(h.min(), Some(2.0));
        assert_eq!(h.max(), Some(2.0));
    }

    #[test]
    fn quantile_interpolates_and_ignores_insertion_order() {
        let mut h = Hist::new();
        for x in [10.0, 0.0] {
            h.add(x);
        }
        assert_eq!(h.quantile(0.25), Some(2.5));
        assert_eq!(h.quantile(0.5), Some(5.0));
    }

    #[test]
    fn nonfinite_samples_are_rejected() {
        let mut h = Hist::new();
        h.add(f64::NAN);
        h.add(f64::INFINITY);
        h.add(1.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), Some(1.0));
    }

    #[test]
    fn log2_buckets_are_fixed_and_cover_extremes() {
        let mut h = Hist::new();
        h.add(0.0); // bucket 0
        h.add(1e-9); // far underflow → bucket 0
        h.add(1.5); // 2^0..2^1
        h.add(1e9); // far overflow → last bucket
        let b = h.log2_buckets();
        assert_eq!(b.iter().sum::<u64>(), 4);
        assert_eq!(b[0], 2);
        assert_eq!(b[Hist::BUCKETS - 1], 1);
        assert_eq!(b[Hist::bucket_of(1.5)], 1);
    }

    #[test]
    fn registry_dump_is_deterministic() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        // Same content, different insertion order.
        a.inc("steps", 3);
        a.gauge("pool_occupancy", 0.5);
        a.observe("step_secs", 1.0);
        a.observe("step_secs", 3.0);
        b.observe("step_secs", 1.0);
        b.observe("step_secs", 3.0);
        b.gauge("pool_occupancy", 0.5);
        b.inc("steps", 1);
        b.inc("steps", 2);
        assert_eq!(a.render(), b.render());
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.render().contains("counter steps 3"));
        assert!(a.render().contains("hist step_secs count=2"));
        assert_eq!(a.counter("steps"), 3);
        assert_eq!(a.hist("step_secs").unwrap().quantile(0.5), Some(2.0));
    }

    #[test]
    fn empty_hist_is_skipped_in_render_but_counted_in_json() {
        let mut r = Registry::new();
        r.observe("x", f64::NAN); // rejected → hist exists but empty
        assert!(!r.render().contains("hist x"));
        let j = r.to_json();
        assert_eq!(
            j.get("hists").unwrap().get("x").unwrap().get("count").unwrap().as_usize(),
            Some(0)
        );
    }
}
