//! RAII nested-span profiling: `span_begin`/`span_end` pairs with a
//! thread-local current-span stack, so nesting is automatic.
//!
//! ```text
//! let _step = obs::span::span("step");          // begin("step")
//! {
//!     let _f = obs::span::span("forward");      //   begin("forward") parent=step
//!     // ... leaf events use .maybe_under(obs::span::current())
//! }                                             //   end("forward")
//! ```
//!
//! A guard emits one [`EventKind::SpanBegin`] when created and one
//! [`EventKind::SpanEnd`] when dropped; **both markers share the same
//! `span` id**, which is what makes the pair reconstructible by readers
//! (the Chrome exporter, the span-tree renderer). The parent is captured
//! at begin time — the top of this thread's stack, or an explicit handoff
//! via [`span_under`] for work that runs on a freshly spawned thread
//! (the trainer's per-shard forward/backward closures) — and reused at
//! end time, so a guard that outlives its thread's stack discipline
//! still closes with the right parent.
//!
//! Dropping guards out of creation order is allowed (it happens whenever
//! two guards live in one scope): the stack removes the dropped span
//! wherever it sits, and parent chains stay correct because they were
//! resolved at begin time.
//!
//! Cost discipline matches the rest of the layer: when no sink is
//! installed, [`span`] is one atomic load returning an inert guard — no
//! clock read, no thread-local touch, no allocation.

use crate::obs::event::{next_span, EventKind, TraceEvent};
use crate::obs::sink;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Stable per-thread id for trace consumers that lay spans out on
    /// virtual tracks (the Chrome exporter's `tid`).
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    /// Open spans on this thread, innermost last.
    static STACK: RefCell<Vec<u64>> = RefCell::new(Vec::new());
}

/// This thread's stable trace-track id.
pub fn thread_tid() -> u64 {
    TID.with(|t| *t)
}

/// The innermost open span on this thread, if any.
pub fn current() -> Option<u64> {
    STACK.with(|s| s.borrow().last().copied())
}

struct Open {
    span: u64,
    name: &'static str,
    parent: Option<u64>,
    t0: Instant,
}

/// The RAII guard returned by [`span`] / [`span_under`]. Emits the end
/// marker on drop; inert (`state: None`) when tracing is disabled.
pub struct Span {
    state: Option<Open>,
}

impl Span {
    /// The open span's id — the parent to hand to [`span_under`] when
    /// child work runs on another thread. `None` when tracing is off.
    pub fn id(&self) -> Option<u64> {
        self.state.as_ref().map(|o| o.span)
    }
}

/// Open a span nested under this thread's innermost open span.
pub fn span(name: &'static str) -> Span {
    span_under(name, current())
}

/// Open a span with an explicit parent (cross-thread handoff: the
/// spawning scope captures `guard.id()` and the spawned closure passes
/// it here). `parent = None` opens a root span.
pub fn span_under(name: &'static str, parent: Option<u64>) -> Span {
    if !sink::enabled() {
        return Span { state: None };
    }
    let id = next_span();
    let mut ev = TraceEvent::new(EventKind::SpanBegin)
        .label("name", name)
        .num("tid", thread_tid() as f64);
    ev.span = id;
    ev.parent = parent;
    sink::emit(ev);
    STACK.with(|s| s.borrow_mut().push(id));
    Span { state: Some(Open { span: id, name, parent, t0: Instant::now() }) }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(open) = self.state.take() else { return };
        STACK.with(|s| {
            let mut stack = s.borrow_mut();
            if let Some(i) = stack.iter().rposition(|&id| id == open.span) {
                stack.remove(i);
            }
        });
        let mut ev = TraceEvent::new(EventKind::SpanEnd)
            .label("name", open.name)
            .num("secs", open.t0.elapsed().as_secs_f64())
            .num("tid", thread_tid() as f64);
        ev.span = open.span;
        ev.parent = open.parent;
        sink::emit(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global, so these tests only cover the
    // disabled path and sink-free invariants; the armed begin/end
    // semantics are pinned end to end in `rust/tests/span_nesting.rs`.

    #[test]
    fn disabled_guard_is_inert() {
        assert!(!sink::enabled());
        let g = span("anything");
        assert_eq!(g.id(), None);
        assert_eq!(current(), None);
        drop(g);
        assert_eq!(current(), None);
    }

    #[test]
    fn thread_tids_are_stable_and_unique() {
        let here = thread_tid();
        assert_eq!(here, thread_tid(), "tid is stable within a thread");
        let there = std::thread::spawn(thread_tid).join().unwrap();
        assert_ne!(here, there, "each thread gets its own track");
    }
}
