//! The one leveled front end for user-facing progress output.
//!
//! Before this module, sweep progress lines were bare `println!` calls
//! from concurrent pool threads — two cells finishing together could
//! interleave their bytes mid-line (stdout is line-buffered per *call*,
//! not per line, once several `write` calls are in flight). Every
//! progress/note/warn line now goes through exactly one locked
//! `write_all` of the complete line, so concurrent emitters serialize at
//! line granularity and torn lines cannot happen.
//!
//! Levels reuse [`crate::util::logging`] (`MKOR_LOG=quiet|error|warn|
//! info|debug`): [`progress`]/[`note`] are Info-level stdout lines (what
//! `quiet` suppresses), [`warn`] is a Warn-level stderr line, [`debug`]
//! a Debug-level stderr line. Unlike [`crate::log_info!`] these print the
//! bare line without a timestamp prefix — they are the CLI's primary
//! output, not its diagnostic stream.

use crate::util::logging::{enabled, Level};
use std::io::Write;

/// Info-level progress line on stdout, written atomically (one locked
/// `write_all` for the whole line). `MKOR_LOG=quiet` suppresses it.
pub fn progress(line: &str) {
    if !enabled(Level::Info) {
        return;
    }
    let mut out = std::io::stdout().lock();
    let _ = out.write_all(format!("{line}\n").as_bytes());
}

/// Alias of [`progress`] for one-off informational notes.
pub fn note(line: &str) {
    progress(line);
}

/// Warn-level line on stderr, written atomically. Survives
/// `MKOR_LOG=quiet`.
pub fn warn(line: &str) {
    if !enabled(Level::Warn) {
        return;
    }
    let mut err = std::io::stderr().lock();
    let _ = err.write_all(format!("{line}\n").as_bytes());
}

/// Debug-level line on stderr, written atomically (`MKOR_LOG=debug`).
pub fn debug(line: &str) {
    if !enabled(Level::Debug) {
        return;
    }
    let mut err = std::io::stderr().lock();
    let _ = err.write_all(format!("{line}\n").as_bytes());
}

#[cfg(test)]
mod tests {
    use crate::util::logging::{enabled, init_from_env, set_level, Level};

    #[test]
    fn quiet_maps_to_warn() {
        // init_from_env only acts when MKOR_LOG is set; drive set_level
        // directly the way "quiet" resolves.
        set_level(Level::Warn);
        assert!(!enabled(Level::Info), "quiet suppresses progress");
        assert!(enabled(Level::Warn), "quiet keeps warnings");
        set_level(Level::Info); // restore default for other tests
        init_from_env(); // exercise the env path (no-op without MKOR_LOG)
    }
}
