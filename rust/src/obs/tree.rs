//! Nested time breakdown: what `mkor trace export --span-tree` prints.
//!
//! Folds the `span_end` markers of a trace into a name-path tree
//! (`step → allreduce → gemm`), aggregating count and total wall-clock
//! per path, and hangs timed point events (`gemm`, `allreduce`,
//! `inverse_update`…) off whatever span they were emitted under. The
//! rendering is the text twin of the Chrome export: the same hierarchy,
//! as an indented table with each row's share of its parent.
//!
//! Aggregation is by *name path*, not span id: a 50-step run has 50
//! `step` spans but one `step` row, with `count=50` — the Anil-style
//! breakdown, now nested.

use super::event::{EventKind, TraceEvent};
use crate::bench_utils::{fmt_secs, Table};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Parent-chain depth cap: a cycle in a (corrupt) trace must not hang
/// the renderer.
const MAX_DEPTH: usize = 64;

struct SpanInfo {
    name: String,
    parent: Option<u64>,
}

/// The name path of span `id`, root first. `None` on a broken chain
/// (missing parent or a cycle past [`MAX_DEPTH`]).
fn path_of(spans: &BTreeMap<u64, SpanInfo>, id: u64) -> Option<Vec<String>> {
    let mut path = Vec::new();
    let mut cur = Some(id);
    while let Some(c) = cur {
        let info = spans.get(&c)?;
        path.push(info.name.clone());
        cur = info.parent;
        if path.len() > MAX_DEPTH {
            return None;
        }
    }
    path.reverse();
    Some(path)
}

/// Render the aggregated span tree of one decoded trace.
pub fn render_span_tree(events: &[TraceEvent]) -> String {
    let mut spans: BTreeMap<u64, SpanInfo> = BTreeMap::new();
    for ev in events {
        if ev.kind == EventKind::SpanEnd {
            let name =
                ev.fields.get("name").and_then(Json::as_str).unwrap_or("span").to_string();
            spans.insert(ev.span, SpanInfo { name, parent: ev.parent });
        }
    }
    // BTreeMap over name paths: a parent path sorts before every path it
    // prefixes, so iteration order is exactly depth-first render order.
    let mut agg: BTreeMap<Vec<String>, (usize, f64)> = BTreeMap::new();
    for ev in events {
        let entry = match ev.kind {
            EventKind::SpanEnd => path_of(&spans, ev.span).map(|p| (p, ev.secs().unwrap_or(0.0))),
            EventKind::SpanBegin => None,
            // A timed leaf emitted under a known span hangs off its path.
            _ => match (ev.secs(), ev.parent.and_then(|p| path_of(&spans, p))) {
                (Some(secs), Some(mut path)) => {
                    path.push(ev.kind.as_str().to_string());
                    Some((path, secs))
                }
                _ => None,
            },
        };
        if let Some((path, secs)) = entry {
            let slot = agg.entry(path).or_insert((0, 0.0));
            slot.0 += 1;
            slot.1 += secs;
        }
    }
    if agg.is_empty() {
        return "no spans in trace (run with span instrumentation enabled)\n".to_string();
    }
    let mut t = Table::new(&["span", "count", "total", "mean", "% of parent"]);
    for (path, &(count, total)) in &agg {
        let depth = path.len() - 1;
        let name = format!("{}{}", "  ".repeat(depth), path.last().unwrap());
        let share = if depth == 0 {
            "-".to_string()
        } else {
            match agg.get(&path[..depth]) {
                Some(&(_, parent_total)) if parent_total > 0.0 => {
                    format!("{:.1}%", total / parent_total * 100.0)
                }
                _ => "-".to_string(),
            }
        };
        t.row(&[
            name,
            count.to_string(),
            fmt_secs(total),
            fmt_secs(total / count as f64),
            share,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span_end(span: u64, parent: Option<u64>, name: &str, secs: f64) -> TraceEvent {
        let mut ev = TraceEvent::new(EventKind::SpanEnd).label("name", name).num("secs", secs);
        ev.span = span;
        ev.parent = parent;
        ev
    }

    #[test]
    fn tree_nests_and_shares_add_up() {
        let mut gemm = TraceEvent::new(EventKind::Gemm).num("secs", 0.1);
        gemm.parent = Some(2);
        let events = vec![
            span_end(1, None, "step", 1.0),
            span_end(2, Some(1), "forward", 0.25),
            span_end(3, Some(1), "forward", 0.25),
            gemm,
        ];
        let out = render_span_tree(&events);
        assert!(out.contains("| step"), "{out}");
        assert!(out.contains("|   forward"), "nested indent missing:\n{out}");
        assert!(out.contains("|     gemm"), "leaf indent missing:\n{out}");
        // Two forward spans aggregate into one row at 50% of step.
        assert!(out.contains("| 2"), "{out}");
        assert!(out.contains("50.0%"), "{out}");
        // The gemm leaf is 0.1 of 0.5 forward seconds.
        assert!(out.contains("20.0%"), "{out}");
    }

    #[test]
    fn orphan_leaves_and_empty_traces_are_tolerated() {
        let mut orphan = TraceEvent::new(EventKind::Gemm).num("secs", 0.1);
        orphan.parent = Some(999); // parent never closed in this trace
        assert!(render_span_tree(&[orphan]).contains("no spans"));
        assert!(render_span_tree(&[]).contains("no spans"));
    }
}
