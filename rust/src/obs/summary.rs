//! Trace aggregation: what `mkor trace summarize` prints.
//!
//! Reads a `--trace` JSONL file back through the validating
//! [`TraceEvent::from_jsonl`] decoder and folds it into one [`Hist`] per
//! event kind, rendered as the Anil-style per-phase breakdown table —
//! count / total / mean / p50 / p99 per kind, plus each kind's share of
//! total `step` time (where the inverse-update and all-reduce phases of
//! a run actually spend their wall-clock).
//!
//! Reader tolerance matches the sweep coordinator's JSONL tailing
//! ([`crate::sweep::dispatch`]): a torn *final* line (no trailing
//! newline — the writer died mid-line) is skipped and counted, but a
//! malformed or version-skewed complete line is an error — those mean
//! the file is not a trace this binary understands.

use super::event::{EventKind, TraceEvent};
use super::registry::Hist;
use crate::bench_utils::{fmt_secs, Table};
use std::collections::BTreeMap;
use std::path::Path;

/// A decoded trace file.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    pub events: Vec<TraceEvent>,
    /// True if the file ended in a torn (newline-less, unparseable) line.
    pub torn_tail: bool,
}

/// Read and validate a JSONL trace file.
pub fn read_trace(path: &Path) -> anyhow::Result<TraceLog> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let mut log = TraceLog::default();
    let complete = text.ends_with('\n');
    let lines: Vec<&str> = text.lines().collect();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match TraceEvent::from_jsonl(line) {
            Ok(ev) => log.events.push(ev),
            Err(e) => {
                if i + 1 == lines.len() && !complete {
                    log.torn_tail = true; // writer died mid-line; drop it
                } else {
                    anyhow::bail!("{} line {}: {e}", path.display(), i + 1);
                }
            }
        }
    }
    Ok(log)
}

/// Per-kind aggregates over one trace.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// Occurrences per kind (every event counts, timed or not).
    pub counts: BTreeMap<EventKind, usize>,
    /// Duration samples per kind (only events carrying `secs`).
    pub secs: BTreeMap<EventKind, Hist>,
}

impl TraceSummary {
    pub fn from_events(events: &[TraceEvent]) -> TraceSummary {
        let mut s = TraceSummary::default();
        for ev in events {
            *s.counts.entry(ev.kind).or_insert(0) += 1;
            if let Some(d) = ev.secs() {
                s.secs.entry(ev.kind).or_default().add(d);
            }
        }
        s
    }

    /// Total recorded `step` time — the denominator of the share column.
    pub fn step_total_secs(&self) -> f64 {
        self.secs.get(&EventKind::Step).map_or(0.0, Hist::total)
    }

    /// The per-kind breakdown table. Kinds appear in [`EventKind::ALL`]
    /// order; kinds absent from the trace are omitted; kinds without
    /// durations (lifecycle markers) render `-` in the timing columns.
    pub fn render(&self) -> String {
        let step_total = self.step_total_secs();
        let mut t = Table::new(&["kind", "count", "total", "mean", "p50", "p99", "% of step"]);
        for kind in EventKind::ALL {
            let Some(&count) = self.counts.get(&kind) else {
                continue;
            };
            let row = match self.secs.get(&kind) {
                Some(h) if h.count() > 0 => {
                    let share = if step_total > 0.0 {
                        format!("{:.1}%", h.total() / step_total * 100.0)
                    } else {
                        "-".to_string()
                    };
                    [
                        kind.as_str().to_string(),
                        count.to_string(),
                        fmt_secs(h.total()),
                        fmt_secs(h.mean().unwrap()),
                        fmt_secs(h.quantile(0.5).unwrap()),
                        fmt_secs(h.quantile(0.99).unwrap()),
                        share,
                    ]
                }
                _ => [
                    kind.as_str().to_string(),
                    count.to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ],
            };
            t.row(&row);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, secs: Option<f64>) -> TraceEvent {
        let mut e = TraceEvent::new(kind);
        if let Some(s) = secs {
            e = e.num("secs", s);
        }
        e
    }

    #[test]
    fn summarize_golden_output() {
        let events = vec![
            ev(EventKind::Step, Some(0.1)),
            ev(EventKind::Allreduce, Some(0.02)),
            ev(EventKind::Step, Some(0.1)),
            ev(EventKind::InverseUpdate, Some(0.05)),
            ev(EventKind::Allreduce, Some(0.02)),
            ev(EventKind::WorkerSpawn, None),
        ];
        let s = TraceSummary::from_events(&events);
        let expected = "\
+----------------+-------+-----------+-----------+-----------+-----------+-----------+
| kind           | count | total     | mean      | p50       | p99       | % of step |
+----------------+-------+-----------+-----------+-----------+-----------+-----------+
| step           | 2     | 200.00 ms | 100.00 ms | 100.00 ms | 100.00 ms | 100.0%    |
| inverse_update | 1     | 50.00 ms  | 50.00 ms  | 50.00 ms  | 50.00 ms  | 25.0%     |
| allreduce      | 2     | 40.00 ms  | 20.00 ms  | 20.00 ms  | 20.00 ms  | 20.0%     |
| worker_spawn   | 1     | -         | -         | -         | -         | -         |
+----------------+-------+-----------+-----------+-----------+-----------+-----------+
";
        assert_eq!(s.render(), expected);
    }

    #[test]
    fn share_column_dashes_without_step_events() {
        let s = TraceSummary::from_events(&[ev(EventKind::Gemm, Some(0.01))]);
        assert_eq!(s.step_total_secs(), 0.0);
        let r = s.render();
        assert!(r.contains("| gemm"), "{r}");
        assert!(r.contains("| -"), "{r}");
    }

    #[test]
    fn read_trace_round_trips_and_tolerates_torn_tail() {
        let dir = std::env::temp_dir().join(format!("mkor-obs-summary-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let a = ev(EventKind::Step, Some(0.5));
        let b = ev(EventKind::CellDone, None).num("index", 3.0);
        let mut text = format!("{}\n{}\n", a.to_jsonl(), b.to_jsonl());
        text.push_str("{\"v\":1,\"t\":0.1,\"spa"); // torn tail: writer died
        std::fs::write(&path, &text).unwrap();
        let log = read_trace(&path).unwrap();
        assert!(log.torn_tail);
        assert_eq!(log.events.len(), 2);
        assert_eq!(log.events[0], a);
        assert_eq!(log.events[1], b);

        // A malformed COMPLETE line is an error, not a skip.
        std::fs::write(&path, "not json\n").unwrap();
        assert!(read_trace(&path).is_err());

        // Version skew anywhere is an error.
        let mut skew = a.to_json();
        skew.set("v", crate::util::json::Json::Num(2.0));
        std::fs::write(&path, format!("{skew}\n")).unwrap();
        let err = read_trace(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported trace format version 2"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
