//! The process-global trace sink: where instrumented code sends events.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled must be almost free.** Every instrumented path guards on
//!    [`enabled`] — one relaxed atomic load — before it builds an event or
//!    reads a clock. With no sink installed the hot paths pay one branch.
//! 2. **Telemetry must never perturb numerics.** The sink only observes:
//!    it takes no RNG draws, changes no shared training state, and the
//!    artifact-bytes invariant (trace-on ≡ trace-off) is asserted by
//!    `rust/tests/trace_obs.rs`.
//! 3. **Writers must not stall trainers.** Events are encoded on the
//!    emitting thread, then handed to a background flusher through a
//!    bounded channel ([`CHANNEL_BOUND`] lines) that batches them into a
//!    `BufWriter`. Backpressure (a full channel) blocks the emitter
//!    briefly rather than dropping events — a trace with holes is worse
//!    than a slightly slower traced run.
//!
//! Lifecycle: [`install`] (from `--trace PATH` or `MKOR_TRACE`) →
//! instrumented code calls [`emit`] → [`finish`] joins the flusher and
//! reports the line count. `install` after `install` is an error;
//! `finish` with no sink is a no-op (so CLI teardown is unconditional).

use super::event::TraceEvent;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;
use std::thread::JoinHandle;

/// Bounded channel depth between emitters and the flush thread.
const CHANNEL_BOUND: usize = 8192;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<ActiveSink>> = Mutex::new(None);

struct ActiveSink {
    tx: SyncSender<String>,
    flusher: JoinHandle<std::io::Result<u64>>,
    path: PathBuf,
}

/// What [`finish`] reports about a completed trace.
#[derive(Clone, Debug)]
pub struct TraceReceipt {
    pub path: PathBuf,
    /// Event lines written to the file.
    pub events: u64,
}

/// The one branch every instrumented path takes. True iff a sink is
/// installed and accepting events.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install a JSONL file sink at `path` (parent directories are created).
/// Errors if a sink is already active or the file can't be created.
pub fn install(path: &Path) -> anyhow::Result<()> {
    let mut guard = SINK.lock().unwrap();
    if let Some(active) = guard.as_ref() {
        anyhow::bail!(
            "a trace sink is already active (writing {}); finish it first",
            active.path.display()
        );
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        }
    }
    let file = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", path.display()))?;
    let (tx, rx) = sync_channel::<String>(CHANNEL_BOUND);
    let flusher = std::thread::Builder::new()
        .name("mkor-trace-flush".to_string())
        .spawn(move || flush_loop(rx, file))
        .map_err(|e| anyhow::anyhow!("spawning trace flusher: {e}"))?;
    *guard = Some(ActiveSink { tx, flusher, path: path.to_path_buf() });
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

fn flush_loop(rx: Receiver<String>, file: std::fs::File) -> std::io::Result<u64> {
    let mut w = BufWriter::new(file);
    let mut lines = 0u64;
    // Ends when every sender is dropped (finish() takes the sink).
    for line in rx {
        w.write_all(line.as_bytes())?;
        w.write_all(b"\n")?;
        lines += 1;
    }
    w.flush()?;
    Ok(lines)
}

/// Send one event to the active sink. No-op (one branch) when disabled.
/// Invalid events are a caller bug and are dropped rather than written —
/// the trace file only ever holds lines that re-validate on read.
pub fn emit(ev: TraceEvent) {
    if !enabled() {
        return;
    }
    if ev.validate().is_err() {
        debug_assert!(false, "invalid trace event: {ev:?}");
        return;
    }
    let line = ev.to_jsonl();
    // Clone the sender out of the lock so slow disk I/O (a full channel)
    // never blocks other emitters on the mutex.
    let tx = match SINK.lock().unwrap().as_ref() {
        Some(active) => active.tx.clone(),
        None => return, // racing a finish(); the trace is closing anyway
    };
    let _ = tx.send(line);
}

/// Tear the sink down: stop accepting events, drain the channel, flush
/// the file. Returns what was written, or `None` if no sink was active.
pub fn finish() -> Option<anyhow::Result<TraceReceipt>> {
    let active = SINK.lock().unwrap().take()?;
    ENABLED.store(false, Ordering::Relaxed);
    let ActiveSink { tx, flusher, path } = active;
    drop(tx); // hang up: the flusher drains and exits
    let res = match flusher.join() {
        Ok(Ok(events)) => Ok(TraceReceipt { path, events }),
        Ok(Err(e)) => Err(anyhow::anyhow!("writing {}: {e}", path.display())),
        Err(_) => Err(anyhow::anyhow!("trace flusher panicked")),
    };
    Some(res)
}

/// Install a sink from `MKOR_TRACE` (a JSONL path) if one is named and
/// none is active. CLI `--trace` flags take precedence by installing
/// first. Failures warn rather than abort: tracing is never load-bearing.
pub fn init_from_env() {
    let Ok(path) = std::env::var("MKOR_TRACE") else {
        return;
    };
    if path.is_empty() || enabled() {
        return;
    }
    if let Err(e) = install(Path::new(&path)) {
        crate::log_warn!("MKOR_TRACE: {e:#}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::EventKind;

    // One test owns the whole install→emit→finish lifecycle: the sink is
    // process-global, so splitting this across #[test] fns would race.
    #[test]
    fn lifecycle_writes_valid_jsonl_and_double_install_fails() {
        let dir = std::env::temp_dir().join(format!("mkor-obs-sink-{}", std::process::id()));
        let path = dir.join("t.jsonl");
        assert!(!enabled());
        emit(TraceEvent::new(EventKind::Step)); // disabled: dropped, no panic
        assert!(finish().is_none());

        install(&path).unwrap();
        assert!(enabled());
        assert!(install(&path).is_err(), "second install must fail");
        emit(TraceEvent::new(EventKind::Step).num("secs", 0.5).num("step", 0.0));
        emit(TraceEvent::new(EventKind::Allreduce).num("secs", 0.1).num("bytes", 4096.0));
        // Invalid events are dropped, not written (release builds; under
        // debug_assertions this would fire the assert instead).
        if !cfg!(debug_assertions) {
            let mut bad = TraceEvent::new(EventKind::Step);
            bad.t_secs = f64::NAN;
            emit(bad);
        }
        let receipt = finish().unwrap().unwrap();
        assert!(!enabled());
        assert_eq!(receipt.events, 2);
        assert_eq!(receipt.path, path);

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let ev = TraceEvent::from_jsonl(lines[0]).unwrap();
        assert_eq!(ev.kind, EventKind::Step);
        assert_eq!(ev.secs(), Some(0.5));
        let ev = TraceEvent::from_jsonl(lines[1]).unwrap();
        assert_eq!(ev.kind, EventKind::Allreduce);
        std::fs::remove_dir_all(&dir).ok();
    }
}
