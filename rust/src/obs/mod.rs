//! Structured observability: trace events, the process-global sink, the
//! metrics registry and the leveled log front end.
//!
//! The paper's whole argument is about *where the time goes* — MKOR wins
//! by making the second-order factor update cheap enough to run every
//! `1/f` steps (Table 1), and evaluating that claim needs per-phase
//! wall-clock breakdowns: inverse-update vs. gradient step vs.
//! communication. This module is that substrate:
//!
//! * [`event`] — the versioned [`event::TraceEvent`] JSONL schema
//!   (monotonic timestamp, span ids, a closed kind vocabulary,
//!   validate-before-write, version-skew rejection on read);
//! * [`sink`] — the process-global sink behind `--trace PATH` /
//!   `MKOR_TRACE`: bounded channel into a background flusher, one-branch
//!   no-op when disabled;
//! * [`registry`] — counters/gauges/histograms with deterministic dumps;
//!   [`registry::Hist`] is the repo's single quantile implementation
//!   (the perf harness' median-of-k and `trace summarize`'s p50/p99 both
//!   use it);
//! * [`summary`] — `mkor trace summarize` aggregation: per-kind
//!   count/total/mean/p50/p99 and time-share of `step`;
//! * [`log`] — the leveled, torn-line-free progress front end
//!   (`MKOR_LOG=quiet|info|debug`);
//! * [`span`] — RAII nested-span guards (`span_begin`/`span_end` pairs
//!   over a thread-local current-span stack), making the trainer's
//!   forward/backward/factor/precond/allreduce phases *children* of
//!   their `step` and parenting leaf events (`gemm`, `allreduce`,
//!   `inverse_update`) under whatever phase dispatched them;
//! * [`chrome`] — `mkor trace export --chrome`: Chrome trace-event JSON
//!   (Perfetto/speedscope-loadable B/E pairs);
//! * [`tree`] — `--span-tree`: the nested breakdown as text;
//! * [`follow`] — the `mkor tail` live follower (offset tailing with
//!   torn-tail tolerance) and its aggregated screen;
//! * [`diff`] — `mkor trace diff`: per-kind/per-phase median comparison
//!   of two traces or two perf reports, CI's perf regression gate.
//!
//! Instrumented layers: the trainer (`step`/`allreduce`/`eval`), MKOR
//! and MKOR-H (`inverse_update`/`stabilizer_trigger`/`mkorh_switch`),
//! the parallel linalg engine (`gemm` per dispatch), the ring collective,
//! the checkpoint subsystem (`ckpt_save`/`ckpt_restore`) and both sweep
//! executors (`cell_done`, `worker_spawn`/`worker_dead`/`redispatch`).
//! The trainer and both executors additionally emit periodic `heartbeat`
//! events (steps/sec, loss EMA, state bytes, progress, per-worker
//! last-seen) — the liveness signal `mkor tail` watches.
//!
//! **Invariant — telemetry never perturbs numerics.** Instrumentation
//! only reads clocks and copies already-computed values; it takes no RNG
//! draws and mutates no training state. Deterministic run artifacts
//! (sweep CSV/JSON, loss series) are byte-identical with tracing on vs.
//! off — asserted in `rust/tests/trace_obs.rs`, in the same spirit as the
//! engine's threads-N ≡ threads-1 parity rule.

pub mod chrome;
pub mod diff;
pub mod event;
pub mod follow;
pub mod log;
pub mod registry;
pub mod sink;
pub mod span;
pub mod summary;
pub mod tree;

pub use chrome::chrome_trace_json;
pub use diff::{MetricDiff, TraceDiff};
pub use event::{EventKind, TraceError, TraceEvent, TRACE_FORMAT_VERSION};
pub use follow::{TailView, TraceFollower};
pub use registry::{Hist, Registry};
pub use sink::{emit, enabled, finish, install, TraceReceipt};
pub use summary::{read_trace, TraceLog, TraceSummary};
pub use tree::render_span_tree;
