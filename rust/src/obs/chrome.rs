//! Chrome trace-event export: what `mkor trace export --chrome` writes.
//!
//! Converts a decoded trace into the Trace Event Format that
//! `chrome://tracing`, Perfetto and speedscope all load: a root object
//! with a `traceEvents` array of phase-coded records.
//!
//! * [`EventKind::SpanBegin`] / [`EventKind::SpanEnd`] become duration
//!   pairs (`ph:"B"` / `ph:"E"`), named by the span's `name` field and
//!   laid out on the virtual track (`tid`) the guard recorded — nesting
//!   renders as stacked bars, exactly the paper's "where does the step
//!   go" picture.
//! * Point events carrying `secs` become complete events (`ph:"X"`,
//!   back-dated by their duration so the bar ends at emit time).
//! * Untimed lifecycle markers become instants (`ph:"i"`).
//!
//! The `pid` is the event's `worker` field when it has one (sweep
//! executors tag subprocess lifecycle events), else 0 — one virtual
//! process lane per worker. Every event's full `fields` payload rides
//! along as `args`, so nothing the JSONL had is lost in the viewer.
//!
//! The output is deterministic: objects are key-sorted by the JSON
//! writer and events keep trace order, so a fixed input trace exports to
//! byte-stable JSON (pinned by the golden test below).

use super::event::{EventKind, TraceEvent};
use crate::util::json::Json;

/// Microseconds, the unit Chrome trace timestamps are defined in.
fn usecs(secs: f64) -> f64 {
    secs * 1e6
}

fn chrome_event(ev: &TraceEvent) -> Json {
    let num = |k: &str| ev.fields.get(k).and_then(Json::as_f64);
    let mut o = Json::obj();
    match ev.kind {
        EventKind::SpanBegin | EventKind::SpanEnd => {
            let name = ev.fields.get("name").and_then(Json::as_str).unwrap_or("span");
            let ph = if ev.kind == EventKind::SpanBegin { "B" } else { "E" };
            o.set("ph", Json::Str(ph.to_string()))
                .set("ts", Json::Num(usecs(ev.t_secs)))
                .set("name", Json::Str(name.to_string()))
                .set("cat", Json::Str("span".to_string()));
        }
        _ => {
            o.set("name", Json::Str(ev.kind.as_str().to_string()))
                .set("cat", Json::Str("event".to_string()));
            match ev.secs() {
                // Timed point events are emitted *after* the work: the
                // bar starts `secs` before the stamp (clamped to the
                // epoch) and ends at it.
                Some(secs) => {
                    o.set("ph", Json::Str("X".to_string()))
                        .set("ts", Json::Num(usecs((ev.t_secs - secs).max(0.0))))
                        .set("dur", Json::Num(usecs(secs)));
                }
                None => {
                    o.set("ph", Json::Str("i".to_string()))
                        .set("ts", Json::Num(usecs(ev.t_secs)))
                        .set("s", Json::Str("t".to_string()));
                }
            }
        }
    }
    o.set("pid", Json::Num(num("worker").unwrap_or(0.0)))
        .set("tid", Json::Num(num("tid").unwrap_or(0.0)))
        .set("args", Json::Obj(ev.fields.clone()));
    o
}

/// The full Chrome trace document for one decoded trace.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut root = Json::obj();
    root.set("displayTimeUnit", Json::Str("ms".to_string()))
        .set("traceEvents", Json::Arr(events.iter().map(chrome_event).collect()));
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn ev(
        kind: EventKind,
        t: f64,
        span: u64,
        parent: Option<u64>,
        fields: &[(&str, Json)],
    ) -> TraceEvent {
        let fields: BTreeMap<String, Json> =
            fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        TraceEvent { t_secs: t, span, parent, kind, fields }
    }

    /// The export is byte-stable for a fixed trace. All timestamps are
    /// exact binary fractions so the µs values print as integers.
    #[test]
    fn chrome_export_golden_bytes() {
        let events = vec![
            ev(
                EventKind::SpanBegin,
                0.25,
                1,
                None,
                &[("name", Json::Str("step".into())), ("tid", Json::Num(1.0))],
            ),
            ev(
                EventKind::Gemm,
                0.5,
                2,
                Some(1),
                &[("m", Json::Num(8.0)), ("secs", Json::Num(0.25))],
            ),
            ev(EventKind::WorkerSpawn, 0.5, 3, None, &[("worker", Json::Num(2.0))]),
            ev(
                EventKind::SpanEnd,
                0.75,
                1,
                None,
                &[
                    ("name", Json::Str("step".into())),
                    ("secs", Json::Num(0.5)),
                    ("tid", Json::Num(1.0)),
                ],
            ),
        ];
        let expected = concat!(
            "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[",
            "{\"args\":{\"name\":\"step\",\"tid\":1},\"cat\":\"span\",\"name\":\"step\",",
            "\"ph\":\"B\",\"pid\":0,\"tid\":1,\"ts\":250000},",
            "{\"args\":{\"m\":8,\"secs\":0.25},\"cat\":\"event\",\"dur\":250000,",
            "\"name\":\"gemm\",\"ph\":\"X\",\"pid\":0,\"tid\":0,\"ts\":250000},",
            "{\"args\":{\"worker\":2},\"cat\":\"event\",\"name\":\"worker_spawn\",",
            "\"ph\":\"i\",\"pid\":2,\"s\":\"t\",\"tid\":0,\"ts\":500000},",
            "{\"args\":{\"name\":\"step\",\"secs\":0.5,\"tid\":1},\"cat\":\"span\",",
            "\"name\":\"step\",\"ph\":\"E\",\"pid\":0,\"tid\":1,\"ts\":750000}",
            "]}"
        );
        assert_eq!(chrome_trace_json(&events).to_string(), expected);
    }

    #[test]
    fn timed_events_never_backdate_past_the_epoch() {
        let e = ev(EventKind::Allreduce, 0.001, 1, None, &[("secs", Json::Num(0.5))]);
        let j = chrome_trace_json(&[e]);
        let rec = &j.get("traceEvents").unwrap().as_arr().unwrap()[0];
        assert_eq!(rec.get("ts").unwrap().as_f64(), Some(0.0));
        assert_eq!(rec.get("dur").unwrap().as_f64(), Some(500000.0));
    }

    #[test]
    fn begin_end_counts_balance() {
        let events = vec![
            ev(EventKind::SpanBegin, 0.0, 1, None, &[("name", Json::Str("a".into()))]),
            ev(EventKind::SpanEnd, 1.0, 1, None, &[("name", Json::Str("a".into()))]),
        ];
        let j = chrome_trace_json(&events);
        let ph: Vec<String> = j
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| r.get("ph").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(ph, ["B", "E"]);
    }
}
