//! The versioned trace event: what one JSONL line in a `--trace` file is.
//!
//! Every line is one compact JSON object:
//!
//! ```text
//! {"fields":{"secs":0.0021,"step":40},"kind":"inverse_update","span":17,"t":1.203,"v":1}
//! ```
//!
//! * `v` — [`TRACE_FORMAT_VERSION`]; readers reject a skewed version the
//!   same way [`crate::perf::PerfReport::from_json`] rejects a skewed
//!   `schema_version`, instead of mis-decoding.
//! * `t` — seconds since the process trace clock's epoch (monotonic
//!   [`std::time::Instant`], not wall time — it never goes backwards).
//! * `span` — process-unique event id; `parent` (optional) nests an event
//!   under an enclosing one (a `gemm` under the `step` that dispatched it).
//!   The begin/end markers of one RAII span (`span_begin`/`span_end`,
//!   emitted by [`crate::obs::span`]) share a single `span` id.
//! * `kind` — the closed [`EventKind`] vocabulary; unknown kinds are a
//!   schema violation, not a silent pass-through.
//! * `fields` — kind-specific payload (`secs`, `step`, `bytes`, shapes…)
//!   as a sorted object, so encoded bytes are stable.
//!
//! Events are validated before they are written ([`TraceEvent::validate`])
//! and re-validated as they are read back — a trace that parses is a trace
//! whose numbers can be trusted.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Version stamp carried by every event line (`"v"`).
pub const TRACE_FORMAT_VERSION: u64 = 1;

/// The closed vocabulary of things a trace can record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// One trainer step (duration + loss + step index).
    Step,
    /// A factor-inversion step: the Sherman–Morrison rank-1 updates of
    /// L⁻¹/R⁻¹ ran this step (Equations 5/6; cadence is `1/f`).
    InverseUpdate,
    /// The norm-based stabilizer clipped a factor inverse.
    StabilizerTrigger,
    /// MKOR-H handed off from MKOR to its first-order fallback.
    MkorhSwitch,
    /// One parallel-engine dispatch (GEMM or rowwise op) with shape.
    Gemm,
    /// One ring all-reduce (bytes on the wire + duration).
    Allreduce,
    /// A checkpoint directory was written.
    CkptSave,
    /// Training state was restored from a checkpoint.
    CkptRestore,
    /// A sweep worker subprocess was launched.
    WorkerSpawn,
    /// A sweep worker exited with cells unfinished.
    WorkerDead,
    /// A dead worker's remaining cells were dispatched again.
    Redispatch,
    /// One sweep cell finished (either executor tier).
    CellDone,
    /// A held-out evaluation ran.
    Eval,
    /// An RAII [`crate::obs::span`] guard opened (begin marker; shares
    /// its `span` id with the matching [`EventKind::SpanEnd`]).
    SpanBegin,
    /// The matching guard dropped (end marker; carries `secs`).
    SpanEnd,
    /// Periodic liveness beacon from the trainer or a sweep executor
    /// (steps/sec, loss EMA, progress counters, per-worker last-seen).
    Heartbeat,
}

impl EventKind {
    /// Every kind, in rendering order for summaries.
    pub const ALL: [EventKind; 16] = [
        EventKind::Step,
        EventKind::InverseUpdate,
        EventKind::StabilizerTrigger,
        EventKind::MkorhSwitch,
        EventKind::Gemm,
        EventKind::Allreduce,
        EventKind::CkptSave,
        EventKind::CkptRestore,
        EventKind::WorkerSpawn,
        EventKind::WorkerDead,
        EventKind::Redispatch,
        EventKind::CellDone,
        EventKind::Eval,
        EventKind::SpanBegin,
        EventKind::SpanEnd,
        EventKind::Heartbeat,
    ];

    /// Wire name (the `"kind"` field).
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Step => "step",
            EventKind::InverseUpdate => "inverse_update",
            EventKind::StabilizerTrigger => "stabilizer_trigger",
            EventKind::MkorhSwitch => "mkorh_switch",
            EventKind::Gemm => "gemm",
            EventKind::Allreduce => "allreduce",
            EventKind::CkptSave => "ckpt_save",
            EventKind::CkptRestore => "ckpt_restore",
            EventKind::WorkerSpawn => "worker_spawn",
            EventKind::WorkerDead => "worker_dead",
            EventKind::Redispatch => "redispatch",
            EventKind::CellDone => "cell_done",
            EventKind::Eval => "eval",
            EventKind::SpanBegin => "span_begin",
            EventKind::SpanEnd => "span_end",
            EventKind::Heartbeat => "heartbeat",
        }
    }

    /// Inverse of [`EventKind::as_str`].
    pub fn parse(s: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }
}

/// What can be wrong with an event line.
#[derive(Debug, thiserror::Error)]
pub enum TraceError {
    #[error("unsupported trace format version {found} (expected {expected})")]
    Version { found: u64, expected: u64 },
    #[error("unknown event kind `{0}`")]
    UnknownKind(String),
    #[error("malformed trace event: {0}")]
    Malformed(String),
}

static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);

/// Seconds since the process trace epoch (first call wins the epoch).
/// Monotonic: derived from [`Instant`], never from wall time.
pub fn now_secs() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Allocate a fresh process-unique span id.
pub fn next_span() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// One trace event (see the module docs for the wire layout).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Seconds since the trace epoch.
    pub t_secs: f64,
    /// Process-unique event id.
    pub span: u64,
    /// Enclosing span, if this event is nested under one.
    pub parent: Option<u64>,
    pub kind: EventKind,
    /// Kind-specific payload, key-sorted.
    pub fields: BTreeMap<String, Json>,
}

impl TraceEvent {
    /// Stamp a new event of `kind` with the current trace time and a
    /// fresh span id.
    pub fn new(kind: EventKind) -> TraceEvent {
        TraceEvent {
            t_secs: now_secs(),
            span: next_span(),
            parent: None,
            kind,
            fields: BTreeMap::new(),
        }
    }

    /// Builder: attach a numeric field.
    pub fn num(mut self, key: &str, v: f64) -> TraceEvent {
        self.fields.insert(key.to_string(), Json::Num(v));
        self
    }

    /// Builder: attach a string field.
    pub fn label(mut self, key: &str, v: &str) -> TraceEvent {
        self.fields.insert(key.to_string(), Json::Str(v.to_string()));
        self
    }

    /// Builder: nest under `parent`.
    pub fn under(mut self, parent: u64) -> TraceEvent {
        self.parent = Some(parent);
        self
    }

    /// Builder: nest under `parent` when there is one. The idiom for
    /// point events emitted from instrumented leaves — pass
    /// [`crate::obs::span::current`] and the event lands under whatever
    /// span happens to enclose the call site (or stays a root).
    pub fn maybe_under(self, parent: Option<u64>) -> TraceEvent {
        match parent {
            Some(p) => self.under(p),
            None => self,
        }
    }

    /// `fields["secs"]`, the duration most kinds carry.
    pub fn secs(&self) -> Option<f64> {
        self.fields.get("secs").and_then(Json::as_f64)
    }

    /// Encode as a JSON object (sorted keys → stable bytes).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("v", Json::Num(TRACE_FORMAT_VERSION as f64))
            .set("t", Json::Num(self.t_secs))
            .set("span", Json::Num(self.span as f64))
            .set("kind", Json::Str(self.kind.as_str().to_string()))
            .set("fields", Json::Obj(self.fields.clone()));
        if let Some(p) = self.parent {
            j.set("parent", Json::Num(p as f64));
        }
        j
    }

    /// Encode as one compact JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        self.to_json().to_string()
    }

    /// Decode one event, rejecting version skew and unknown kinds.
    pub fn from_json(j: &Json) -> Result<TraceEvent, TraceError> {
        let v = j
            .get("v")
            .and_then(Json::as_f64)
            .ok_or_else(|| TraceError::Malformed("missing `v`".into()))? as u64;
        if v != TRACE_FORMAT_VERSION {
            return Err(TraceError::Version { found: v, expected: TRACE_FORMAT_VERSION });
        }
        let kind_s = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| TraceError::Malformed("missing `kind`".into()))?;
        let kind =
            EventKind::parse(kind_s).ok_or_else(|| TraceError::UnknownKind(kind_s.to_string()))?;
        let t_secs = j
            .get("t")
            .and_then(Json::as_f64)
            .ok_or_else(|| TraceError::Malformed("missing `t`".into()))?;
        let span = j
            .get("span")
            .and_then(Json::as_f64)
            .ok_or_else(|| TraceError::Malformed("missing `span`".into()))? as u64;
        let parent = j.get("parent").and_then(Json::as_f64).map(|p| p as u64);
        let fields = match j.get("fields") {
            Some(Json::Obj(m)) => m.clone(),
            Some(_) => return Err(TraceError::Malformed("`fields` is not an object".into())),
            None => BTreeMap::new(),
        };
        let ev = TraceEvent { t_secs, span, parent, kind, fields };
        ev.validate()?;
        Ok(ev)
    }

    /// Decode one JSONL line.
    pub fn from_jsonl(line: &str) -> Result<TraceEvent, TraceError> {
        let j = Json::parse(line).map_err(|e| TraceError::Malformed(e.to_string()))?;
        TraceEvent::from_json(&j)
    }

    /// Check invariants shared by writer and reader: finite non-negative
    /// timestamp, finite non-negative duration when one is present.
    pub fn validate(&self) -> Result<(), TraceError> {
        if !self.t_secs.is_finite() || self.t_secs < 0.0 {
            return Err(TraceError::Malformed(format!("bad timestamp {}", self.t_secs)));
        }
        if let Some(s) = self.secs() {
            if !s.is_finite() || s < 0.0 {
                return Err(TraceError::Malformed(format!("bad duration {s}")));
            }
        }
        Ok(())
    }

    /// One human-readable line for `mkor trace cat`.
    pub fn render(&self) -> String {
        let mut out = format!("[{:>10.6}] {:<18}", self.t_secs, self.kind.as_str());
        for (k, v) in &self.fields {
            out.push_str(&format!(" {k}={v}"));
        }
        if let Some(p) = self.parent {
            out.push_str(&format!(" parent={p}"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::parse(k.as_str()), Some(k), "{k:?}");
        }
        assert_eq!(EventKind::parse("nonsense"), None);
    }

    #[test]
    fn jsonl_round_trip_is_lossless() {
        let ev = TraceEvent {
            t_secs: 1.25,
            span: 17,
            parent: Some(3),
            kind: EventKind::Gemm,
            fields: BTreeMap::from([
                ("m".to_string(), Json::Num(64.0)),
                ("op".to_string(), Json::Str("gemm".to_string())),
                ("secs".to_string(), Json::Num(0.002)),
            ]),
        };
        let line = ev.to_jsonl();
        assert!(!line.contains('\n'), "one line per event");
        let back = TraceEvent::from_jsonl(&line).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn version_skew_is_rejected() {
        let mut j = TraceEvent::new(EventKind::Step).to_json();
        j.set("v", Json::Num(99.0));
        let err = TraceEvent::from_json(&j).unwrap_err();
        assert!(
            matches!(err, TraceError::Version { found: 99, expected: 1 }),
            "{err}"
        );
        assert!(err.to_string().contains("unsupported trace format version 99"));
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let mut j = TraceEvent::new(EventKind::Step).to_json();
        j.set("kind", Json::Str("warp_drive".to_string()));
        let err = TraceEvent::from_json(&j).unwrap_err();
        assert!(matches!(err, TraceError::UnknownKind(ref k) if k == "warp_drive"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_numbers() {
        let mut ev = TraceEvent::new(EventKind::Step);
        ev.t_secs = f64::NAN;
        assert!(ev.validate().is_err());
        let ev = TraceEvent::new(EventKind::Step).num("secs", -1.0);
        assert!(ev.validate().is_err());
        assert!(TraceEvent::new(EventKind::Step).num("secs", 0.5).validate().is_ok());
    }

    #[test]
    fn spans_are_unique_and_time_is_monotonic() {
        let a = TraceEvent::new(EventKind::Step);
        let b = TraceEvent::new(EventKind::Step);
        assert_ne!(a.span, b.span);
        assert!(b.t_secs >= a.t_secs);
    }
}
