//! Perf regression gate: what `mkor trace diff BASE NEW` computes.
//!
//! Compares two runs metric by metric and flags regressions past a
//! threshold, so CI can gate on "did this change make the hot paths
//! slower". Two input shapes share one diff type:
//!
//! * **traces** ([`TraceDiff::of_traces`]) — per-kind median duration
//!   (`kind:gemm`, `kind:inverse_update`…) and per-phase median span
//!   time (`phase:forward`, `phase:precond`… from `span_end` markers),
//!   both *lower-is-better*;
//! * **perf reports** ([`TraceDiff::of_reports`]) — the
//!   [`PerfReport`] throughput figures (`BENCH_mkor.json`'s schema):
//!   GEMM GFLOP/s, optimizer steps/sec, ring GB/s, all
//!   *higher-is-better*.
//!
//! Only metrics present in **both** inputs are compared — a kind that
//! appears on one side only is a workload difference, not a regression.
//! Medians (via [`Hist`]) keep the gate robust to the long tail one
//! noisy outlier step would otherwise drag.

use super::event::{EventKind, TraceEvent};
use super::registry::Hist;
use crate::bench_utils::{fmt_secs, Table};
use crate::perf::report::PerfReport;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One compared metric.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    pub name: String,
    pub base: f64,
    pub new: f64,
    /// `(new - base) / base * 100`.
    pub delta_pct: f64,
    /// Throughput metrics regress downward; duration metrics upward.
    pub higher_is_better: bool,
}

impl MetricDiff {
    fn of(name: String, base: f64, new: f64, higher_is_better: bool) -> Option<MetricDiff> {
        if !(base.is_finite() && new.is_finite()) || base <= 0.0 {
            return None; // no meaningful percentage against a zero/bad base
        }
        let delta_pct = (new - base) / base * 100.0;
        Some(MetricDiff { name, base, new, delta_pct, higher_is_better })
    }

    /// Did this metric move the *bad* way by more than `max_pct`?
    pub fn regressed(&self, max_pct: f64) -> bool {
        if self.higher_is_better {
            self.delta_pct < -max_pct
        } else {
            self.delta_pct > max_pct
        }
    }
}

/// The full comparison of two runs.
#[derive(Clone, Debug, Default)]
pub struct TraceDiff {
    pub rows: Vec<MetricDiff>,
}

/// Median duration per event kind and per span phase name.
fn medians(events: &[TraceEvent]) -> (BTreeMap<EventKind, f64>, BTreeMap<String, f64>) {
    let mut kinds: BTreeMap<EventKind, Hist> = BTreeMap::new();
    let mut phases: BTreeMap<String, Hist> = BTreeMap::new();
    for ev in events {
        let Some(secs) = ev.secs() else { continue };
        if ev.kind == EventKind::SpanEnd {
            if let Some(name) = ev.fields.get("name").and_then(Json::as_str) {
                phases.entry(name.to_string()).or_default().add(secs);
            }
        } else {
            kinds.entry(ev.kind).or_default().add(secs);
        }
    }
    let med = |h: &Hist| h.quantile(0.5).unwrap_or(0.0);
    (
        kinds.iter().map(|(&k, h)| (k, med(h))).collect(),
        phases.iter().map(|(n, h)| (n.clone(), med(h))).collect(),
    )
}

impl TraceDiff {
    /// Compare two decoded traces (per-kind and per-phase medians).
    pub fn of_traces(base: &[TraceEvent], new: &[TraceEvent]) -> TraceDiff {
        let (bk, bp) = medians(base);
        let (nk, np) = medians(new);
        let mut rows = Vec::new();
        for (kind, &b) in &bk {
            if let Some(&n) = nk.get(kind) {
                rows.extend(MetricDiff::of(format!("kind:{}", kind.as_str()), b, n, false));
            }
        }
        for (phase, &b) in &bp {
            if let Some(&n) = np.get(phase) {
                rows.extend(MetricDiff::of(format!("phase:{phase}"), b, n, false));
            }
        }
        TraceDiff { rows }
    }

    /// Compare two perf reports (throughput figures, higher-is-better).
    pub fn of_reports(base: &PerfReport, new: &PerfReport) -> TraceDiff {
        let mut b: BTreeMap<String, f64> = BTreeMap::new();
        let mut n: BTreeMap<String, f64> = BTreeMap::new();
        for (report, out) in [(base, &mut b), (new, &mut n)] {
            for g in &report.gemm {
                out.insert(format!("gemm:{}:d={} gflops", g.kind, g.d), g.engine_gflops);
            }
            for o in &report.optimizers {
                out.insert(format!("opt:{} steps/sec", o.name), o.steps_per_sec);
            }
            for r in &report.allreduce {
                out.insert(format!("ring:w={}:n={} fp32 gbps", r.workers, r.elems), r.fp32_gbps);
                out.insert(format!("ring:w={}:n={} bf16 gbps", r.workers, r.elems), r.bf16_gbps);
            }
        }
        let mut rows = Vec::new();
        for (name, &bv) in &b {
            if let Some(&nv) = n.get(name) {
                rows.extend(MetricDiff::of(name.clone(), bv, nv, true));
            }
        }
        TraceDiff { rows }
    }

    /// Every metric that moved the bad way by more than `max_pct`.
    pub fn regressions(&self, max_pct: f64) -> Vec<&MetricDiff> {
        self.rows.iter().filter(|r| r.regressed(max_pct)).collect()
    }

    /// The comparison table.
    pub fn render(&self) -> String {
        if self.rows.is_empty() {
            return "no commensurable metrics (inputs share no kinds/phases)\n".to_string();
        }
        let fmt_val = |row: &MetricDiff, v: f64| {
            if row.higher_is_better {
                format!("{v:.2}")
            } else {
                fmt_secs(v)
            }
        };
        let mut t = Table::new(&["metric", "base", "new", "delta", "direction"]);
        for row in &self.rows {
            t.row(&[
                row.name.clone(),
                fmt_val(row, row.base),
                fmt_val(row, row.new),
                format!("{:+.1}%", row.delta_pct),
                if row.higher_is_better { "higher is better" } else { "lower is better" }
                    .to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timed(kind: EventKind, secs: f64) -> TraceEvent {
        TraceEvent::new(kind).num("secs", secs)
    }

    fn phase(name: &str, secs: f64) -> TraceEvent {
        TraceEvent::new(EventKind::SpanEnd).label("name", name).num("secs", secs)
    }

    fn base_events() -> Vec<TraceEvent> {
        vec![
            timed(EventKind::Step, 0.1),
            timed(EventKind::Step, 0.12),
            timed(EventKind::Gemm, 0.01),
            phase("forward", 0.04),
        ]
    }

    #[test]
    fn self_diff_passes_and_inverted_threshold_fails_everything() {
        let events = base_events();
        let d = TraceDiff::of_traces(&events, &events);
        assert!(!d.rows.is_empty());
        assert!(d.rows.iter().all(|r| r.delta_pct == 0.0));
        assert!(d.regressions(50.0).is_empty(), "identical runs never regress");
        // The CI inversion trick: a negative threshold means "0% worse
        // is already too much", so every compared metric trips.
        assert_eq!(d.regressions(-100.0).len(), d.rows.len());
    }

    #[test]
    fn injected_slowdown_is_caught_per_kind_and_per_phase() {
        let base = base_events();
        let slow: Vec<TraceEvent> = base
            .iter()
            .map(|e| {
                let mut e = e.clone();
                let secs = e.secs().unwrap() * 2.0;
                e.num("secs", secs)
            })
            .collect();
        let d = TraceDiff::of_traces(&base, &slow);
        let bad: Vec<&str> = d.regressions(50.0).iter().map(|r| r.name.as_str()).collect();
        assert!(bad.contains(&"kind:step"), "{bad:?}");
        assert!(bad.contains(&"phase:forward"), "{bad:?}");
        // A 2x *speedup* is not a regression for durations.
        let d = TraceDiff::of_traces(&slow, &base);
        assert!(d.regressions(50.0).is_empty());
        assert!(d.render().contains("kind:gemm"));
    }

    #[test]
    fn disjoint_kinds_produce_no_rows() {
        let a = vec![timed(EventKind::Step, 0.1)];
        let b = vec![timed(EventKind::Gemm, 0.1)];
        let d = TraceDiff::of_traces(&a, &b);
        assert!(d.rows.is_empty());
        assert!(d.render().contains("no commensurable metrics"));
    }

    #[test]
    fn report_diff_is_higher_is_better() {
        let report = |scale: f64| {
            let mut j = Json::obj();
            let mut host = Json::obj();
            host.set("os", Json::Str("linux".into()))
                .set("arch", Json::Str("x86_64".into()))
                .set("threads", Json::Num(2.0))
                .set("hw_threads", Json::Num(4.0));
            let mut timer = Json::obj();
            timer.set("warmup", Json::Num(1.0)).set("repeats", Json::Num(3.0));
            let mut gemm = Json::obj();
            gemm.set("kind", Json::Str("nn".into()))
                .set("d", Json::Num(128.0))
                .set("serial_gflops", Json::Num(4.0))
                .set("engine_gflops", Json::Num(16.0 * scale))
                .set("speedup", Json::Num(4.0 * scale));
            let mut opt = Json::obj();
            opt.set("name", Json::Str("mkor".into()))
                .set("steps_per_sec", Json::Num(100.0 * scale));
            let mut ring = Json::obj();
            ring.set("workers", Json::Num(4.0))
                .set("elems", Json::Num(1024.0))
                .set("fp32_gbps", Json::Num(8.0 * scale))
                .set("bf16_gbps", Json::Num(4.0 * scale));
            j.set("schema_version", Json::Num(1.0))
                .set("quick", Json::Bool(true))
                .set("host", host)
                .set("timer", timer)
                .set("gemm", Json::Arr(vec![gemm]))
                .set("optimizers", Json::Arr(vec![opt]))
                .set("allreduce", Json::Arr(vec![ring]));
            PerfReport::from_json(&j).unwrap()
        };
        let (fast, slow) = (report(1.0), report(0.4));
        // Throughput dropped 60% everywhere: every row regresses at 50%.
        let d = TraceDiff::of_reports(&fast, &slow);
        assert_eq!(d.rows.len(), 4);
        assert_eq!(d.regressions(50.0).len(), 4);
        // The other way around is an improvement, not a regression.
        let d = TraceDiff::of_reports(&slow, &fast);
        assert!(d.regressions(50.0).is_empty());
        assert!(d.render().contains("opt:mkor steps/sec"));
        assert!(d.render().contains("higher is better"));
    }
}
