//! KFAC baseline in its KAISA-style distributed form.
//!
//! Maintains EMA covariances `L = γL + (1−γ)·GGᵀ/b` and
//! `R = γR + (1−γ)·AAᵀ/b` (Equations 3/4), and every `inv_freq` steps
//! explicitly inverts the damped factors `(L + μI)⁻¹`, `(R + μI)⁻¹` — the
//! O(d³) cost (and O(d²)-per-factor communication) that Table 1 charges
//! KFAC with and that motivates MKOR. Between inversions it preconditions
//! with *stale* factors, exactly the trade-off §3.3 analyzes.

use crate::checkpoint::snapshot::{matrices_from, put_matrices};
use crate::checkpoint::{Checkpointable, StateDict, StateError};
use crate::linalg::cholesky::invert_spd;
use crate::linalg::{ops, Matrix};
use crate::model::{Capture, Dense, LayerShape};
use crate::optim::first_order::SgdMomentum;
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;

/// KFAC hyperparameters (KAISA defaults: f=50 for BERT, damping 3e-3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KfacConfig {
    /// Covariance EMA momentum γ.
    pub gamma: f32,
    /// Factor re-inversion period f (stale factors in between).
    pub inv_freq: usize,
    /// Tikhonov damping μ added before inversion.
    pub damping: f32,
    /// Backend momentum.
    pub momentum: f32,
    /// Covariance update period (KAISA computes covariances every step by
    /// default; set >1 to model reduced-frequency variants).
    pub cov_freq: usize,
    /// KAISA-style update scaling (its KL-clip analog): match the
    /// preconditioned update's norm to the raw gradient's.
    pub rescale: bool,
}

impl Default for KfacConfig {
    fn default() -> Self {
        // Damping 0.03 = KAISA's BERT fine-tune setting; the 3e-3 used for
        // CNNs makes the inverse explode on ill-conditioned factors (§8.4).
        KfacConfig {
            gamma: 0.95,
            inv_freq: 50,
            damping: 0.03,
            momentum: 0.9,
            cov_freq: 1,
            rescale: true,
        }
    }
}

struct LayerState {
    l_cov: Matrix,
    r_cov: Matrix,
    l_inv: Matrix,
    r_inv: Matrix,
}

/// The KFAC/KAISA optimizer.
pub struct Kfac {
    cfg: KfacConfig,
    layers: Vec<LayerState>,
    shapes: Vec<LayerShape>,
    backend: SgdMomentum,
    t: usize,
    last_sync_bytes: usize,
    /// Count of inversions that failed PD (fell back to stronger damping).
    pub inversion_failures: usize,
}

impl Kfac {
    pub fn new(shapes: &[LayerShape], cfg: KfacConfig) -> Self {
        let layers = shapes
            .iter()
            .map(|s| LayerState {
                l_cov: Matrix::identity(s.d_out),
                r_cov: Matrix::identity(s.d_in),
                l_inv: Matrix::identity(s.d_out),
                r_inv: Matrix::identity(s.d_in),
            })
            .collect();
        Kfac {
            cfg,
            layers,
            shapes: shapes.to_vec(),
            backend: SgdMomentum::new(shapes, cfg.momentum),
            t: 0,
            last_sync_bytes: 0,
            inversion_failures: 0,
        }
    }

    pub fn is_inversion_step(&self, t: usize) -> bool {
        t % self.cfg.inv_freq == 0
    }

    /// Invert `cov + μI` with escalating damping on failure (the numerical
    /// fragility §8.4 documents: factors are near-singular in practice).
    fn damped_inverse(cov: &Matrix, mut mu: f32, failures: &mut usize) -> Matrix {
        for _ in 0..6 {
            let mut damped = cov.clone();
            for i in 0..damped.rows() {
                damped[(i, i)] += mu;
            }
            match invert_spd(&damped) {
                Ok(inv) => return inv,
                Err(_) => {
                    *failures += 1;
                    mu *= 10.0;
                }
            }
        }
        Matrix::identity(cov.rows()) // total failure: fall back to SGD
    }

    /// Read access for the Figure 8 condition-number experiment.
    pub fn covariances(&self, layer: usize) -> (&Matrix, &Matrix) {
        (&self.layers[layer].l_cov, &self.layers[layer].r_cov)
    }
}

impl Checkpointable for Kfac {
    fn state_dict(&self) -> StateDict {
        // Both the EMA covariances and the (possibly stale) inverses are
        // state: between inversion steps KFAC preconditions with inverses
        // older than the covariances, and a resumed run must do the same.
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t)
            .put_usize("inversion_failures", self.inversion_failures)
            .put_usize("last_sync_bytes", self.last_sync_bytes);
        put_matrices(&mut sd, "l_cov", self.layers.iter().map(|l| &l.l_cov));
        put_matrices(&mut sd, "r_cov", self.layers.iter().map(|l| &l.r_cov));
        put_matrices(&mut sd, "l_inv", self.layers.iter().map(|l| &l.l_inv));
        put_matrices(&mut sd, "r_inv", self.layers.iter().map(|l| &l.r_inv));
        sd.put_dict("backend", self.backend.state_dict());
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(
            &[
                "t",
                "inversion_failures",
                "last_sync_bytes",
                "l_cov",
                "r_cov",
                "l_inv",
                "r_inv",
                "backend",
            ],
            &[],
        )?;
        let l_shapes: Vec<(usize, usize)> =
            self.shapes.iter().map(|s| (s.d_out, s.d_out)).collect();
        let r_shapes: Vec<(usize, usize)> =
            self.shapes.iter().map(|s| (s.d_in, s.d_in)).collect();
        let l_cov = matrices_from(state, "l_cov", &l_shapes)?;
        let r_cov = matrices_from(state, "r_cov", &r_shapes)?;
        let l_inv = matrices_from(state, "l_inv", &l_shapes)?;
        let r_inv = matrices_from(state, "r_inv", &r_shapes)?;
        for ((((layer, lc), rc), li), ri) in
            self.layers.iter_mut().zip(l_cov).zip(r_cov).zip(l_inv).zip(r_inv)
        {
            layer.l_cov = lc;
            layer.r_cov = rc;
            layer.l_inv = li;
            layer.r_inv = ri;
        }
        self.backend.load_state_dict(state.dict("backend")?)?;
        self.t = state.usizev("t")?;
        self.inversion_failures = state.usizev("inversion_failures")?;
        self.last_sync_bytes = state.usizev("last_sync_bytes")?;
        Ok(())
    }
}

impl Optimizer for Kfac {
    fn name(&self) -> &str {
        "kfac"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        assert_eq!(caps.len(), self.layers.len());
        let inv_step = self.is_inversion_step(self.t);
        let cov_step = self.t % self.cfg.cov_freq == 0;
        self.last_sync_bytes = 0;

        let mut deltas = Vec::with_capacity(caps.len());
        for (idx, cap) in caps.iter().enumerate() {
            // ---- factor computation + inversion ------------------------
            let t0 = std::time::Instant::now();
            if cov_step {
                let b = cap.g.cols().max(1);
                let st = &mut self.layers[idx];
                // L ← γL + (1−γ) GGᵀ/b  (O(b·d²))
                let mut ggt = ops::matmul_nt(&cap.g, &cap.g);
                ggt.scale(1.0 / b as f32);
                st.l_cov.blend(self.cfg.gamma, 1.0 - self.cfg.gamma, &ggt);
                let mut aat = ops::matmul_nt(&cap.a, &cap.a);
                aat.scale(1.0 / b as f32);
                st.r_cov.blend(self.cfg.gamma, 1.0 - self.cfg.gamma, &aat);
            }
            if inv_step {
                let st = &mut self.layers[idx];
                st.l_inv =
                    Kfac::damped_inverse(&st.l_cov, self.cfg.damping, &mut self.inversion_failures);
                st.r_inv =
                    Kfac::damped_inverse(&st.r_cov, self.cfg.damping, &mut self.inversion_failures);
                // KAISA synchronizes covariances *and* inverses: 4d² floats
                // (Table 1's O(4d²) communication).
                let s = &self.shapes[idx];
                self.last_sync_bytes +=
                    4 * (s.d_out * s.d_out + s.d_in * s.d_in) / 2 * 4;
            }
            timer.add("factor", t0.elapsed());

            // ---- precondition (stale factors between inversions) -------
            let t0 = std::time::Instant::now();
            let st = &self.layers[idx];
            let gr = ops::matmul(&cap.dw, &st.r_inv);
            let mut delta = ops::matmul(&st.l_inv, &gr);
            if self.cfg.rescale {
                crate::optim::rescale::rescale_to_gradient_norm(&mut delta, &cap.dw);
            }
            timer.add("precond", t0.elapsed());
            deltas.push(delta);
        }

        let t0 = std::time::Instant::now();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        self.backend.apply(layers, &deltas, &dbs, lr);
        timer.add("update", t0.elapsed());
        self.t += 1;
    }

    fn state_bytes(&self) -> usize {
        // 2 covariances + 2 inverses per layer (Table 1's O(4d²)).
        self.shapes
            .iter()
            .map(|s| 2 * (s.d_out * s.d_out + s.d_in * s.d_in) * 4)
            .sum::<usize>()
            + self.backend.state_bytes()
    }

    fn sync_bytes_last_step(&self) -> usize {
        self.last_sync_bytes
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Kfac(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;
    use crate::util::Rng;

    fn toy_capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
        let a = Matrix::randn(shape.d_in, b, 1.0, rng);
        let g = Matrix::randn(shape.d_out, b, 1.0, rng);
        let mut dw = ops::matmul_nt(&g, &a);
        dw.scale(1.0 / b as f32);
        Capture { a, g, dw, db: vec![0.0; shape.d_out] }
    }

    #[test]
    fn covariances_accumulate_toward_batch_covariance() {
        let shapes = [LayerShape::new(6, 4)];
        let mut cfg = KfacConfig::default();
        cfg.gamma = 0.0; // no momentum: covariance equals batch covariance
        cfg.inv_freq = 1;
        let mut opt = Kfac::new(&shapes, cfg);
        let mut rng = Rng::new(1);
        let cap = toy_capture(shapes[0], 16, &mut rng);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let mut timer = PhaseTimer::new();
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer);
        let (l_cov, _) = opt.covariances(0);
        let mut want = ops::matmul_nt(&cap.g, &cap.g);
        want.scale(1.0 / 16.0);
        assert!(l_cov.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn inversion_happens_on_schedule_and_syncs_quadratic_bytes() {
        let shapes = [LayerShape::new(8, 8)];
        let mut cfg = KfacConfig::default();
        cfg.inv_freq = 3;
        let mut opt = Kfac::new(&shapes, cfg);
        let mut rng = Rng::new(2);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let mut timer = PhaseTimer::new();
        let mut sync = Vec::new();
        for _ in 0..4 {
            let cap = toy_capture(shapes[0], 8, &mut rng);
            opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer);
            sync.push(opt.sync_bytes_last_step());
        }
        assert!(sync[0] > 0); // t=0 inversion
        assert_eq!(sync[1], 0);
        assert_eq!(sync[2], 0);
        assert!(sync[3] > 0); // t=3 inversion
        // quadratic in d: 2*(64+64) f32 words (our impl counts 2d² pairs)
        assert_eq!(sync[0], 4 * (64 + 64) / 2 * 4);
    }

    #[test]
    fn damped_inverse_handles_singular_covariance() {
        // Rank-1 covariance is singular; damping must save the inversion.
        let v = vec![1.0f32, 2.0, 3.0];
        let cov = ops::outer(&v, &v);
        let mut failures = 0;
        let inv = Kfac::damped_inverse(&cov, 1e-3, &mut failures);
        assert!(inv.all_finite());
        // (cov + μI)·inv ≈ I
        let mut damped = cov.clone();
        for i in 0..3 {
            damped[(i, i)] += 1e-3;
        }
        let prod = ops::matmul(&damped, &inv);
        assert!(prod.max_abs_diff(&Matrix::identity(3)) < 1e-2);
    }

    #[test]
    fn identity_covariance_preconditioning_is_damped_sgd() {
        // With γ=1 the covariances stay at their identity init, so the
        // t=0 inversion yields (I+μI)⁻¹ = I/(1+μ) and the step (without
        // the KL-clip rescale) is momentum-SGD scaled by 1/(1+μ)².
        let shapes = [LayerShape::new(5, 3)];
        let mut cfg = KfacConfig::default();
        cfg.gamma = 1.0;
        cfg.rescale = false;
        let mu = cfg.damping;
        let mut opt = Kfac::new(&shapes, cfg);
        let mut rng = Rng::new(3);
        let cap = toy_capture(shapes[0], 8, &mut rng);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let w0 = layers[0].w.clone();
        let mut timer = PhaseTimer::new();
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.1, &mut timer);
        let mut want = w0.clone();
        let mut d = cap.dw.clone();
        d.scale(0.1 / ((1.0 + mu) * (1.0 + mu)));
        want.blend(1.0, -1.0, &d);
        assert!(layers[0].w.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn state_bytes_dwarf_mkor_factor_storage() {
        use crate::optim::{Mkor, MkorConfig};
        // Large enough that factor storage dominates the shared momentum
        // backend. Table 1: KFAC 4d² f32 vs MKOR 2d² bf16.
        let shapes = [LayerShape::new(256, 256)];
        let kfac = Kfac::new(&shapes, KfacConfig::default());
        let mkor = Mkor::new(&shapes, MkorConfig::default()); // bf16 state
        assert!(
            kfac.state_bytes() > 2 * mkor.state_bytes(),
            "kfac {} vs mkor {}",
            kfac.state_bytes(),
            mkor.state_bytes()
        );
    }
}
