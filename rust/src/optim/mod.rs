//! The optimizer family: MKOR (the paper's contribution) plus every
//! baseline its evaluation compares against.
//!
//! Construction goes through the typed [`OptimizerSpec`] registry: parse a
//! spec string with the grammar `name[:key=val,...]`, then
//! [`OptimizerSpec::build`] the boxed optimizer. One example string per
//! optimizer (keys are optional — the bare name gives paper defaults, §8.9):
//!
//! | Module       | Optimizer        | Example spec string                         | Factor cost  | Paper role            |
//! |--------------|------------------|---------------------------------------------|--------------|-----------------------|
//! | [`mkor`]     | MKOR (Alg. 1)    | `mkor:f=10,gamma=0.99,backend=lamb,half=bf16` | O(d²)      | contribution          |
//! | [`hybrid`]   | MKOR-H (§3.2)    | `mkor-h:f=10,switch_ratio=0.1,min_steps=50` | O(d²)→O(1)   | contribution          |
//! | [`kfac`]     | KFAC/KAISA       | `kfac:f=50,damping=3e-2,gamma=0.95`         | O(d³)        | 2nd-order SOTA        |
//! | [`sngd`]     | SNGD/HyLo        | `sngd:f=10,damping=0.3`                     | O(b³)        | 2nd-order SOTA        |
//! | [`eva`]      | Eva              | `eva:damping=3e-2,beta=0.95`                | O(d²)        | 2nd-order baseline    |
//! | [`first_order`] | SGD-m         | `sgd:momentum=0.9`                          | —            | 1st-order baseline    |
//! | [`first_order`] | Adam           | `adam:beta1=0.9,beta2=0.999,eps=1e-6`       | —            | 1st-order baseline    |
//! | [`first_order`] | LAMB           | `lamb:wd=0.01`                              | —            | 1st-order baseline    |
//!
//! `kaisa` and `hylo` are accepted aliases for `kfac` / `sngd`. For MKOR,
//! `damping` aliases the stabilizer threshold `epsilon` (MKOR has no
//! Tikhonov damping; the norm-based stabilizer plays that role), and
//! `half` ∈ {`bf16`, `f16`, `none`} picks the rank-1 sync precision.
//! Nested `backend.*` keys configure the line-14 first-order backend:
//! `mkor:backend=adam,backend.beta1=0.95,backend.eps=1e-8,backend.wd=0.01`
//! (and `backend.momentum` for the SGD backend, aliasing `momentum`).
//! See [`spec`] for the full key tables and error behavior.
//!
//! Every optimizer implements [`Optimizer`] against the Rust-native model
//! captures and reports the spec it was built from via
//! [`Optimizer::spec`]; phase timings ("factor" / "precond" / "update")
//! feed the Figure 3/4a breakdowns, and the `state_bytes`/`sync_bytes`
//! accounting feeds Tables 1 and 6.

pub mod eva;
pub mod first_order;
pub mod hybrid;
pub mod kfac;
pub mod mkor;
pub mod rescale;
pub mod schedule;
pub mod sngd;
pub mod spec;
pub mod stabilizer;

use crate::checkpoint::Checkpointable;
use crate::model::{Capture, Dense};
use crate::util::timer::PhaseTimer;

pub use hybrid::MkorH;
pub use mkor::{Mkor, MkorConfig};
pub use spec::{OptimizerSpec, SpecError};

/// Common optimizer interface for the convergence/benchmark harnesses.
///
/// `step` consumes the per-layer [`Capture`]s of one (already all-reduced)
/// batch and updates `layers` in place. Implementations record their wall
/// time into `timer` under the phases `"factor"`, `"precond"`, `"update"`.
///
/// Every optimizer is also [`Checkpointable`]: `state_dict()` captures the
/// factor inverses / moments / counters and `load_state_dict()` restores
/// them bitwise into a freshly-built optimizer of the same spec, which is
/// what makes killed runs resumable (see [`crate::checkpoint`]).
pub trait Optimizer: Checkpointable {
    fn name(&self) -> &str;

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer);

    /// Bytes of optimizer state held per replica (Table 6 accounting).
    fn state_bytes(&self) -> usize;

    /// Bytes of *second-order* data this optimizer had to synchronize
    /// across workers on its most recent step (Table 1 communication
    /// column; gradient all-reduce is common to all and excluded).
    fn sync_bytes_last_step(&self) -> usize {
        0
    }

    /// The step counter (number of `step` calls so far).
    fn steps_done(&self) -> usize;

    /// The full hyperparameter set this optimizer was built with, as a
    /// typed [`OptimizerSpec`] — `spec().canonical()` re-parses to an
    /// identical configuration, which is how run records stay reproducible.
    ///
    /// One exception: `MkorConfig::second_order_layers` (a programmatic
    /// per-layer mask with no grammar key) is not encoded by `canonical()`;
    /// a masked MKOR's recorded spec reproduces the run with every layer
    /// second-order. See the [`spec`] module docs.
    fn spec(&self) -> OptimizerSpec;

    /// Feed the post-step training loss. Default no-op; MKOR-H uses this
    /// to drive its loss-decrease-rate switching rule (§3.2).
    fn observe_loss(&mut self, _loss: f64) {}
}

/// First-order backend choice for MKOR's line 14 / MKOR-H's fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    SgdMomentum,
    Adam,
    Lamb,
}

/// Construct any optimizer in the suite by CLI name with default
/// hyperparameters.
#[deprecated(
    since = "0.2.0",
    note = "use `OptimizerSpec::parse(name)?.build(shapes)` — the spec \
            grammar also accepts hyperparameter overrides"
)]
pub fn by_name(
    name: &str,
    shapes: &[crate::model::LayerShape],
) -> Option<Box<dyn Optimizer + Send>> {
    OptimizerSpec::parse(name).ok().map(|s| s.build(shapes))
}

/// Canonical names accepted by [`OptimizerSpec::parse`] (stable order for
/// reports).
pub const ALL_OPTIMIZERS: &[&str] =
    &["sgd", "adam", "lamb", "kfac", "sngd", "eva", "mkor", "mkor-h"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerShape;

    #[test]
    fn registry_constructs_all() {
        let shapes = [LayerShape::new(8, 4), LayerShape::new(4, 2)];
        for name in ALL_OPTIMIZERS {
            let o = OptimizerSpec::parse(name)
                .unwrap_or_else(|e| panic!("{name}: {e}"))
                .build(&shapes);
            assert_eq!(o.steps_done(), 0);
        }
        assert!(OptimizerSpec::parse("bogus").is_err());
    }

    #[test]
    #[allow(deprecated)]
    fn by_name_shim_still_works() {
        let shapes = [LayerShape::new(8, 4)];
        for name in ALL_OPTIMIZERS {
            assert!(by_name(name, &shapes).is_some(), "{name}");
        }
        // The aliases by_name historically accepted still resolve.
        assert!(by_name("kaisa", &shapes).is_some());
        assert!(by_name("hylo", &shapes).is_some());
        assert!(by_name("bogus", &shapes).is_none());
    }
}
