//! The optimizer family: MKOR (the paper's contribution) plus every
//! baseline its evaluation compares against.
//!
//! | Module       | Optimizer        | Factor cost  | Paper role            |
//! |--------------|------------------|--------------|-----------------------|
//! | [`mkor`]     | MKOR (Alg. 1)    | O(d²)        | contribution          |
//! | [`hybrid`]   | MKOR-H (§3.2)    | O(d²)→O(1)   | contribution          |
//! | [`kfac`]     | KFAC/KAISA       | O(d³)        | 2nd-order SOTA        |
//! | [`sngd`]     | SNGD/HyLo        | O(b³)        | 2nd-order SOTA        |
//! | [`eva`]      | Eva              | O(d²)        | 2nd-order baseline    |
//! | [`first_order`] | SGD-m, Adam, LAMB | —       | 1st-order baselines   |
//!
//! Every optimizer implements [`Optimizer`] against the Rust-native model
//! captures; phase timings ("factor" / "precond" / "update") feed the
//! Figure 3/4a breakdowns, and the `state_bytes`/`sync_bytes` accounting
//! feeds Tables 1 and 6.

pub mod eva;
pub mod first_order;
pub mod hybrid;
pub mod kfac;
pub mod mkor;
pub mod rescale;
pub mod schedule;
pub mod sngd;
pub mod stabilizer;

use crate::model::{Capture, Dense};
use crate::util::timer::PhaseTimer;

pub use hybrid::MkorH;
pub use mkor::{Mkor, MkorConfig};

/// Common optimizer interface for the convergence/benchmark harnesses.
///
/// `step` consumes the per-layer [`Capture`]s of one (already all-reduced)
/// batch and updates `layers` in place. Implementations record their wall
/// time into `timer` under the phases `"factor"`, `"precond"`, `"update"`.
pub trait Optimizer {
    fn name(&self) -> &str;

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer);

    /// Bytes of optimizer state held per replica (Table 6 accounting).
    fn state_bytes(&self) -> usize;

    /// Bytes of *second-order* data this optimizer had to synchronize
    /// across workers on its most recent step (Table 1 communication
    /// column; gradient all-reduce is common to all and excluded).
    fn sync_bytes_last_step(&self) -> usize {
        0
    }

    /// The step counter (number of `step` calls so far).
    fn steps_done(&self) -> usize;

    /// Feed the post-step training loss. Default no-op; MKOR-H uses this
    /// to drive its loss-decrease-rate switching rule (§3.2).
    fn observe_loss(&mut self, _loss: f64) {}
}

/// First-order backend choice for MKOR's line 14 / MKOR-H's fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    SgdMomentum,
    Adam,
    Lamb,
}

/// Construct any optimizer in the suite by CLI name, with per-optimizer
/// defaults matching the paper's setup (§8.9): MKOR f=10, KAISA f=50
/// (BERT) — callers override via the returned concrete types if needed.
pub fn by_name(
    name: &str,
    shapes: &[crate::model::LayerShape],
) -> Option<Box<dyn Optimizer + Send>> {
    let opt: Box<dyn Optimizer + Send> = match name {
        "mkor" => Box::new(Mkor::new(shapes, MkorConfig::default())),
        "mkor-h" => Box::new(MkorH::new(shapes, MkorConfig::default(), hybrid::SwitchConfig::default())),
        "kfac" | "kaisa" => Box::new(kfac::Kfac::new(shapes, kfac::KfacConfig::default())),
        "sngd" | "hylo" => Box::new(sngd::Sngd::new(shapes, sngd::SngdConfig::default())),
        "eva" => Box::new(eva::Eva::new(shapes, eva::EvaConfig::default())),
        "sgd" => Box::new(first_order::SgdMomentum::new(shapes, 0.9)),
        "adam" => Box::new(first_order::Adam::new(shapes, first_order::AdamConfig::default())),
        "lamb" => Box::new(first_order::Lamb::new(shapes, first_order::AdamConfig::default())),
        _ => return None,
    };
    Some(opt)
}

/// Names accepted by [`by_name`] (stable order for reports).
pub const ALL_OPTIMIZERS: &[&str] =
    &["sgd", "adam", "lamb", "kfac", "sngd", "eva", "mkor", "mkor-h"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerShape;

    #[test]
    fn registry_constructs_all() {
        let shapes = [LayerShape::new(8, 4), LayerShape::new(4, 2)];
        for name in ALL_OPTIMIZERS {
            let o = by_name(name, &shapes).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(o.steps_done(), 0);
        }
        assert!(by_name("bogus", &shapes).is_none());
    }
}
