//! MKOR-H (§3.2): hybrid second-/first-order optimizer with a
//! loss-decrease-rate switching rule.
//!
//! Second-order acceleration concentrates in the early phase of training —
//! near convergence the curvature approaches identity and the expensive
//! factor machinery stops paying for itself. MKOR-H monitors the loss
//! decrease *rate* (EMA-smoothed) and permanently switches to the
//! first-order backend when the rate of the recent window falls below
//! `switch_ratio` × the rate observed early on.

use crate::checkpoint::{Checkpointable, StateDict, StateError};
use crate::model::{Capture, Dense, LayerShape};
use crate::obs::{self, EventKind, TraceEvent};
use crate::optim::first_order::SgdMomentum;
use crate::optim::mkor::{Mkor, MkorConfig};
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::stats::Ema;
use crate::util::timer::PhaseTimer;

/// Switching rule parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchConfig {
    /// EMA smoothing of the per-step loss decrease.
    pub beta: f64,
    /// Switch when smoothed rate < switch_ratio × peak smoothed rate.
    pub switch_ratio: f64,
    /// Don't consider switching before this many steps (rate estimates are
    /// noise until the EMA warms up).
    pub min_steps: usize,
}

impl Default for SwitchConfig {
    fn default() -> Self {
        SwitchConfig { beta: 0.95, switch_ratio: 0.1, min_steps: 50 }
    }
}

/// The MKOR-H optimizer. Callers feed the observed training loss via
/// [`MkorH::observe_loss`] after each step (the coordinator does this).
pub struct MkorH {
    mkor: Mkor,
    fallback: SgdMomentum,
    switch_cfg: SwitchConfig,
    rate_ema: Ema,
    peak_rate: f64,
    last_loss: Option<f64>,
    switched_at: Option<usize>,
    t: usize,
}

impl MkorH {
    pub fn new(shapes: &[LayerShape], mkor_cfg: MkorConfig, switch_cfg: SwitchConfig) -> Self {
        let momentum = mkor_cfg.momentum;
        MkorH {
            mkor: Mkor::new(shapes, mkor_cfg),
            fallback: SgdMomentum::new(shapes, momentum),
            switch_cfg,
            rate_ema: Ema::new(switch_cfg.beta),
            peak_rate: 0.0,
            last_loss: None,
            switched_at: None,
            t: 0,
        }
    }

    /// Report the training loss after a step; drives the switching rule.
    pub fn observe_loss(&mut self, loss: f64) {
        if let Some(prev) = self.last_loss {
            let decrease = (prev - loss).max(0.0);
            let rate = self.rate_ema.update(decrease);
            if self.rate_ema.steps() as usize >= self.switch_cfg.min_steps {
                self.peak_rate = self.peak_rate.max(rate);
                if self.switched_at.is_none()
                    && self.peak_rate > 0.0
                    && rate < self.switch_cfg.switch_ratio * self.peak_rate
                {
                    self.switched_at = Some(self.t);
                    if obs::enabled() {
                        obs::emit(
                            TraceEvent::new(EventKind::MkorhSwitch)
                                .num("step", self.t as f64)
                                .num("rate", rate)
                                .num("peak_rate", self.peak_rate)
                                .maybe_under(obs::span::current()),
                        );
                        obs::registry::with_global(|r| {
                            r.gauge("mkorh.switched_at", self.t as f64)
                        });
                    }
                }
            }
        }
        self.last_loss = Some(loss);
    }

    /// Has the hybrid fallen back to first-order yet?
    pub fn switched(&self) -> bool {
        self.switched_at.is_some()
    }

    /// Step index at which the switch happened, if it has.
    pub fn switched_at(&self) -> Option<usize> {
        self.switched_at
    }

    /// Force the switch (tests / manual schedules).
    pub fn force_switch(&mut self) {
        if self.switched_at.is_none() {
            self.switched_at = Some(self.t);
        }
    }
}

impl Checkpointable for MkorH {
    fn state_dict(&self) -> StateDict {
        // The switching rule's EMA / peak-rate / last-loss are as much
        // optimizer state as the factor inverses: dropping them would let a
        // resumed run re-warm the rate estimate and switch at a different
        // step than the uninterrupted run.
        let (ema_value, ema_steps) = self.rate_ema.state();
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t)
            .put_dict("mkor", self.mkor.state_dict())
            .put_dict("fallback", self.fallback.state_dict())
            .put_f64("rate_ema_value", ema_value)
            .put_u64("rate_ema_steps", ema_steps)
            .put_f64("peak_rate", self.peak_rate)
            .put_opt_f64("last_loss", self.last_loss)
            .put_opt_u64("switched_at", self.switched_at.map(|s| s as u64));
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(
            &["t", "mkor", "fallback", "rate_ema_value", "rate_ema_steps", "peak_rate"],
            &["last_loss", "switched_at"],
        )?;
        self.mkor.load_state_dict(state.dict("mkor")?)?;
        self.fallback.load_state_dict(state.dict("fallback")?)?;
        self.rate_ema
            .set_state(state.f64v("rate_ema_value")?, state.u64v("rate_ema_steps")?);
        self.peak_rate = state.f64v("peak_rate")?;
        self.last_loss = state.opt_f64("last_loss")?;
        self.switched_at = state.opt_u64("switched_at")?.map(|s| s as usize);
        self.t = state.usizev("t")?;
        Ok(())
    }
}

impl Optimizer for MkorH {
    fn name(&self) -> &str {
        "mkor-h"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        if self.switched() {
            // First-order phase: momentum SGD on raw gradients — the cheap
            // late-training regime MKOR-H buys its speedup from.
            let t0 = std::time::Instant::now();
            let deltas: Vec<_> = caps.iter().map(|c| c.dw.clone()).collect();
            let dbs: Vec<_> = caps.iter().map(|c| c.db.clone()).collect();
            self.fallback.apply(layers, &deltas, &dbs, lr);
            timer.add("update", t0.elapsed());
        } else {
            self.mkor.step(layers, caps, lr, timer);
        }
        self.t += 1;
    }

    fn state_bytes(&self) -> usize {
        self.mkor.state_bytes() + self.fallback.state_bytes()
    }

    fn sync_bytes_last_step(&self) -> usize {
        if self.switched() {
            0
        } else {
            self.mkor.sync_bytes_last_step()
        }
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::MkorH {
            mkor: self.mkor.config().clone(),
            switch: self.switch_cfg,
        }
    }

    fn observe_loss(&mut self, loss: f64) {
        MkorH::observe_loss(self, loss);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ops, Matrix};
    use crate::model::Activation;
    use crate::util::Rng;

    fn toy_capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
        let a = Matrix::randn(shape.d_in, b, 1.0, rng);
        let g = Matrix::randn(shape.d_out, b, 1.0, rng);
        let mut dw = ops::matmul_nt(&g, &a);
        dw.scale(1.0 / b as f32);
        Capture { a, g, dw, db: vec![0.0; shape.d_out] }
    }

    #[test]
    fn switches_when_loss_flattens() {
        let shapes = [LayerShape::new(4, 4)];
        let cfg = SwitchConfig { beta: 0.9, switch_ratio: 0.2, min_steps: 10 };
        let mut h = MkorH::new(&shapes, MkorConfig::default(), cfg);
        // Fast decrease for 60 steps, then a plateau.
        let mut loss = 10.0;
        for t in 0..200 {
            h.t = t;
            h.observe_loss(loss);
            loss -= if t < 60 { 0.1 } else { 0.0001 };
        }
        assert!(h.switched());
        let at = h.switched_at().unwrap();
        assert!(at >= 60 && at < 150, "switched at {at}");
    }

    #[test]
    fn does_not_switch_while_improving() {
        let shapes = [LayerShape::new(4, 4)];
        let mut h = MkorH::new(&shapes, MkorConfig::default(), SwitchConfig::default());
        let mut loss = 10.0;
        for t in 0..300 {
            h.t = t;
            h.observe_loss(loss);
            loss *= 0.995; // steady geometric improvement
        }
        assert!(!h.switched());
    }

    #[test]
    fn after_switch_steps_are_first_order() {
        let shapes = [LayerShape::new(5, 3)];
        let mut rng = Rng::new(1);
        let mut h = MkorH::new(&shapes, MkorConfig::default(), SwitchConfig::default());
        h.force_switch();
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let cap = toy_capture(shapes[0], 8, &mut rng);
        let w0 = layers[0].w.clone();
        let mut timer = PhaseTimer::new();
        h.step(&mut layers, std::slice::from_ref(&cap), 0.1, &mut timer);
        // No factor/precond phases, no second-order sync.
        assert_eq!(timer.count("factor"), 0);
        assert_eq!(timer.count("precond"), 0);
        assert_eq!(h.sync_bytes_last_step(), 0);
        // And the step equals momentum-SGD on the raw gradient.
        let mut want = w0;
        let mut d = cap.dw.clone();
        d.scale(0.1);
        want.blend(1.0, -1.0, &d);
        assert!(layers[0].w.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn switch_state_survives_a_roundtrip() {
        // Warm the rate EMA mid-decline, snapshot, restore into a fresh
        // hybrid, and feed both the same plateau: they must switch at the
        // same step.
        let shapes = [LayerShape::new(4, 4)];
        let cfg = SwitchConfig { beta: 0.9, switch_ratio: 0.2, min_steps: 10 };
        let mut a = MkorH::new(&shapes, MkorConfig::default(), cfg);
        let mut loss = 10.0;
        for t in 0..40 {
            a.t = t;
            a.observe_loss(loss);
            loss -= 0.1;
        }
        let sd = a.state_dict();
        let mut b = MkorH::new(&shapes, MkorConfig::default(), cfg);
        b.load_state_dict(&sd).unwrap();
        assert_eq!(b.state_dict(), sd);
        b.t = a.t;
        for t in 40..200 {
            a.t = t;
            b.t = t;
            a.observe_loss(loss);
            b.observe_loss(loss);
            loss -= if t < 60 { 0.1 } else { 0.0001 };
        }
        assert_eq!(a.switched_at(), b.switched_at());
        assert!(a.switched());
        // switched_at survives the round-trip once set.
        let sd2 = a.state_dict();
        let mut c = MkorH::new(&shapes, MkorConfig::default(), cfg);
        c.load_state_dict(&sd2).unwrap();
        assert_eq!(c.switched_at(), a.switched_at());
    }

    #[test]
    fn switch_beta_reaches_the_rate_ema() {
        // Regression: `switch_beta` used to parse through the spec grammar
        // but `MkorH::new` hardcoded `Ema::new(0.95)`, so the knob silently
        // did nothing. Two betas on the same decline-then-plateau loss
        // series must now produce *different* switch steps (the slower EMA
        // takes longer to decay below the ratio threshold).
        let shapes = [LayerShape::new(4, 4)];
        let run = |beta: f64| {
            let cfg = SwitchConfig { beta, switch_ratio: 0.1, min_steps: 10 };
            let mut h = MkorH::new(&shapes, MkorConfig::default(), cfg);
            let mut loss = 10.0;
            for t in 0..400 {
                h.t = t;
                h.observe_loss(loss);
                loss -= if t < 60 { 0.1 } else { 0.0 };
            }
            h.switched_at()
        };
        let fast = run(0.8).expect("beta=0.8 never switched");
        let slow = run(0.99).expect("beta=0.99 never switched");
        assert!(
            fast < slow,
            "switch step must move with beta: beta=0.8 at {fast}, beta=0.99 at {slow}"
        );
        // And the spec-grammar route carries the beta into construction:
        // the built optimizer re-reports it via its canonical spec.
        let spec = OptimizerSpec::parse("mkor-h:switch_beta=0.8,min_steps=10").unwrap();
        let built = spec.build(&shapes);
        assert!(
            built.spec().canonical().contains("switch_beta=0.8"),
            "{}",
            built.spec().canonical()
        );
    }

    #[test]
    fn checkpoint_roundtrip_preserves_configured_beta() {
        // Beta is configuration, not state: the round-trip restores the EMA
        // value/steps while the freshly-built hybrid supplies the beta. A
        // resumed non-default-beta run must keep switching like the
        // uninterrupted one (and unlike the default-beta run).
        let shapes = [LayerShape::new(4, 4)];
        let cfg = SwitchConfig { beta: 0.8, switch_ratio: 0.1, min_steps: 10 };
        let mut a = MkorH::new(&shapes, MkorConfig::default(), cfg);
        let mut loss = 10.0;
        for t in 0..40 {
            a.t = t;
            a.observe_loss(loss);
            loss -= 0.1;
        }
        let sd = a.state_dict();
        let mut b = MkorH::new(&shapes, MkorConfig::default(), cfg);
        b.load_state_dict(&sd).unwrap();
        assert_eq!(b.switch_cfg.beta, 0.8);
        assert_eq!(b.spec(), a.spec());
        let mut loss_b = loss;
        for t in 40..400 {
            a.t = t;
            b.t = t;
            a.observe_loss(loss);
            b.observe_loss(loss_b);
            let d = if t < 60 { 0.1 } else { 0.0 };
            loss -= d;
            loss_b -= d;
        }
        assert!(a.switched());
        assert_eq!(a.switched_at(), b.switched_at());
    }

    #[test]
    fn before_switch_behaves_like_mkor() {
        let shapes = [LayerShape::new(5, 3)];
        let mut rng = Rng::new(2);
        let mut h = MkorH::new(&shapes, MkorConfig::default(), SwitchConfig::default());
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let cap = toy_capture(shapes[0], 8, &mut rng);
        let mut timer = PhaseTimer::new();
        h.step(&mut layers, std::slice::from_ref(&cap), 0.1, &mut timer);
        assert!(timer.count("factor") > 0); // t=0 is a factor step
        assert!(timer.count("precond") > 0);
        assert!(h.sync_bytes_last_step() > 0);
    }
}
