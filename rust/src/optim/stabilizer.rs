//! Norm-based stabilizer (§3.3, Algorithm 1 lines 5–6, Equations 7/8).
//!
//! Second-order methods explode when the factor inverses grow without
//! bound: the preconditioned update is a product with those inverses, so an
//! unbounded ‖J⁻¹‖ amplifies gradients arbitrarily. MKOR watches the
//! infinity norm of each factor inverse and, when it crosses a threshold,
//! blends the inverse toward the identity — leaning the layer toward SGD
//! (Lemma 3.3 shows the blended preconditioner still decreases the
//! linearized loss for any ζ ∈ [0,1]).

use crate::linalg::Matrix;

/// Stabilizer configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StabilizerConfig {
    /// Threshold ε on ‖J⁻¹‖∞ above which blending triggers.
    pub epsilon: f64,
    /// Blend retention ζ: `J⁻¹ ← ζ J⁻¹ + (1−ζ) I`.
    pub zeta: f32,
}

impl Default for StabilizerConfig {
    fn default() -> Self {
        // ε is in factor-inverse-norm units; the factors start at identity
        // (norm 1), so 100 tolerates two orders of magnitude of growth
        // before intervening. ζ=0.5 halves the distance to identity per
        // trigger — a handful of triggers suffices to stop an explosion
        // without collapsing to SGD (the paper warns small ζ "converts
        // MKOR to SGD").
        StabilizerConfig { epsilon: 100.0, zeta: 0.5 }
    }
}

/// Outcome of one stabilizer check.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StabilizerReport {
    pub triggered: bool,
    pub norm_before: f64,
}

/// Apply lines 5–6 of Algorithm 1 to one factor inverse.
pub fn stabilize(inv: &mut Matrix, cfg: &StabilizerConfig) -> StabilizerReport {
    let norm = inv.inf_norm();
    // Non-finite entries are the worst-case explosion: reset hard to
    // identity (norm check alone would propagate NaN through the blend —
    // and NaN row sums don't surface through max-folds, so check finiteness
    // of the entries, not just of the norm).
    if !norm.is_finite() || !inv.all_finite() {
        let n = inv.rows();
        *inv = Matrix::identity(n);
        return StabilizerReport { triggered: true, norm_before: norm };
    }
    if norm > cfg.epsilon {
        inv.blend_identity(cfg.zeta);
        StabilizerReport { triggered: true, norm_before: norm }
    } else {
        StabilizerReport { triggered: false, norm_before: norm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::is_positive_definite;
    use crate::util::Rng;

    #[test]
    fn below_threshold_is_untouched() {
        let mut m = Matrix::identity(4);
        let before = m.clone();
        let r = stabilize(&mut m, &StabilizerConfig::default());
        assert!(!r.triggered);
        assert_eq!(m, before);
    }

    #[test]
    fn above_threshold_blends_toward_identity() {
        let cfg = StabilizerConfig { epsilon: 10.0, zeta: 0.5 };
        let mut m = Matrix::diag(&[40.0, 40.0]);
        let r = stabilize(&mut m, &cfg);
        assert!(r.triggered);
        assert!((r.norm_before - 40.0).abs() < 1e-9);
        assert!((m[(0, 0)] - 20.5).abs() < 1e-6); // 0.5*40 + 0.5*1
    }

    #[test]
    fn repeated_triggers_converge_to_bounded_norm() {
        let cfg = StabilizerConfig { epsilon: 2.0, zeta: 0.5 };
        let mut m = Matrix::diag(&[1000.0; 3]);
        for _ in 0..40 {
            stabilize(&mut m, &cfg);
        }
        assert!(m.inf_norm() <= 2.0 * (1.0 + 1e-6), "norm={}", m.inf_norm());
    }

    #[test]
    fn nan_is_reset_to_identity() {
        let mut m = Matrix::diag(&[1.0, f32::NAN]);
        let r = stabilize(&mut m, &StabilizerConfig::default());
        assert!(r.triggered);
        assert_eq!(m, Matrix::identity(2));
    }

    #[test]
    fn blending_preserves_positive_definiteness() {
        // Lemma 3.3's premise: ζJ⁻¹+(1−ζ)I stays PD when J⁻¹ is PD.
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let mut m = Matrix::rand_spd(8, 0.01, &mut rng);
            m.scale(500.0); // push above threshold
            stabilize(&mut m, &StabilizerConfig::default());
            assert!(is_positive_definite(&m));
        }
    }
}
