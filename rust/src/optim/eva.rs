//! Eva baseline (Zhang, Shi & Li 2023): vectorized second-order
//! approximation.
//!
//! Eva stores only the Kronecker *vectors* ā, ḡ (batch means) and
//! preconditions with the closed-form SMW inverse of the damped rank-1
//! factors:
//!
//! ```text
//! (v vᵀ + μI)⁻¹ = (1/μ)(I − v vᵀ / (μ + vᵀv))
//! ```
//!
//! applied on both sides of the gradient — O(d²) work with O(2d) state
//! (Table 1). Two contrasts with MKOR that the paper calls out (§1): Eva
//! needs the damping factor μ (an extra approximation-error knob), and
//! because it stores vectors rather than factor inverses, it cannot carry
//! momentum in the second-order statistics — each step's preconditioner
//! sees only the current batch (optionally smoothed over the vectors, not
//! the factors).

use crate::checkpoint::snapshot::{put_vectors, vectors_from};
use crate::checkpoint::{Checkpointable, StateDict, StateError};
use crate::linalg::{ops, Matrix};
use crate::model::{Capture, Dense, LayerShape};
use crate::optim::first_order::SgdMomentum;
use crate::optim::rescale::rescale_to_gradient_norm;
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;

/// Eva hyperparameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvaConfig {
    /// SMW damping μ.
    pub damping: f32,
    /// EMA smoothing of the Kronecker vectors (Eva's β; this smooths the
    /// *vectors*, not the factors — see module docs).
    pub beta: f32,
    pub momentum: f32,
    /// Refresh period for the vectors (Eva updates every step by default).
    pub update_freq: usize,
}

impl Default for EvaConfig {
    fn default() -> Self {
        EvaConfig { damping: 0.03, beta: 0.95, momentum: 0.9, update_freq: 1 }
    }
}

struct LayerState {
    a_vec: Vec<f32>,
    g_vec: Vec<f32>,
    initialized: bool,
}

/// The Eva optimizer.
pub struct Eva {
    cfg: EvaConfig,
    layers: Vec<LayerState>,
    shapes: Vec<LayerShape>,
    backend: SgdMomentum,
    t: usize,
    last_sync_bytes: usize,
}

impl Eva {
    pub fn new(shapes: &[LayerShape], cfg: EvaConfig) -> Self {
        Eva {
            cfg,
            layers: shapes
                .iter()
                .map(|s| LayerState {
                    a_vec: vec![0.0; s.d_in],
                    g_vec: vec![0.0; s.d_out],
                    initialized: false,
                })
                .collect(),
            shapes: shapes.to_vec(),
            backend: SgdMomentum::new(shapes, cfg.momentum),
            t: 0,
            last_sync_bytes: 0,
        }
    }

    /// Apply `(vvᵀ + μI)⁻¹` to the rows/cols of `m` via the closed form.
    /// `side = true` applies from the left (v has d_out entries), else from
    /// the right. O(d_out·d_in).
    fn apply_smw(m: &Matrix, v: &[f32], mu: f32, left: bool) -> Matrix {
        let denom = mu as f64 + ops::dot(v, v);
        let mut out = m.clone();
        if left {
            // out = (1/μ)(m − v (vᵀ m)/denom)
            let vt_m = ops::matvec_t(m, v); // wait: need vᵀM over rows
            // matvec_t computes Mᵀ v with M (rows×cols): gives cols-dim = correct vᵀM.
            for r in 0..out.rows() {
                let vr = v[r] as f64;
                let row = out.row_mut(r);
                for (c, val) in row.iter_mut().enumerate() {
                    *val = ((*val as f64 - vr * vt_m[c] as f64 / denom) / mu as f64) as f32;
                }
            }
        } else {
            // out = (1/μ)(m − (m v) vᵀ/denom)
            let mv = ops::matvec(m, v);
            for r in 0..out.rows() {
                let mvr = mv[r] as f64;
                let row = out.row_mut(r);
                for (c, val) in row.iter_mut().enumerate() {
                    *val = ((*val as f64 - mvr * v[c] as f64 / denom) / mu as f64) as f32;
                }
            }
        }
        out
    }
}

impl Checkpointable for Eva {
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t)
            .put_usize("last_sync_bytes", self.last_sync_bytes);
        put_vectors(&mut sd, "a_vec", self.layers.iter().map(|l| &l.a_vec));
        put_vectors(&mut sd, "g_vec", self.layers.iter().map(|l| &l.g_vec));
        let mut init = StateDict::new();
        for (i, layer) in self.layers.iter().enumerate() {
            init.put_u64(&i.to_string(), layer.initialized as u64);
        }
        sd.put_dict("initialized", init);
        sd.put_dict("backend", self.backend.state_dict());
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(
            &["t", "last_sync_bytes", "a_vec", "g_vec", "initialized", "backend"],
            &[],
        )?;
        let a_lens: Vec<usize> = self.shapes.iter().map(|s| s.d_in).collect();
        let g_lens: Vec<usize> = self.shapes.iter().map(|s| s.d_out).collect();
        let a_vec = vectors_from(state, "a_vec", &a_lens)?;
        let g_vec = vectors_from(state, "g_vec", &g_lens)?;
        let init = state.dict("initialized")?;
        let expected: Vec<String> = (0..self.layers.len()).map(|i| i.to_string()).collect();
        init.check_keys_exact(&expected)?;
        for (i, ((layer, a), g)) in
            self.layers.iter_mut().zip(a_vec).zip(g_vec).enumerate()
        {
            layer.a_vec = a;
            layer.g_vec = g;
            layer.initialized = init.u64v(&i.to_string())? != 0;
        }
        self.backend.load_state_dict(state.dict("backend")?)?;
        self.t = state.usizev("t")?;
        self.last_sync_bytes = state.usizev("last_sync_bytes")?;
        Ok(())
    }
}

impl Optimizer for Eva {
    fn name(&self) -> &str {
        "eva"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        self.last_sync_bytes = 0;
        let mut deltas = Vec::with_capacity(caps.len());
        for (idx, cap) in caps.iter().enumerate() {
            // ---- vector update (factor computation) --------------------
            if self.t % self.cfg.update_freq == 0 {
                let t0 = std::time::Instant::now();
                let a = ops::col_mean(&cap.a);
                let g = ops::col_mean(&cap.g);
                let st = &mut self.layers[idx];
                if st.initialized {
                    let b = self.cfg.beta;
                    for (sv, &nv) in st.a_vec.iter_mut().zip(&a) {
                        *sv = b * *sv + (1.0 - b) * nv;
                    }
                    for (sv, &nv) in st.g_vec.iter_mut().zip(&g) {
                        *sv = b * *sv + (1.0 - b) * nv;
                    }
                } else {
                    st.a_vec = a;
                    st.g_vec = g;
                    st.initialized = true;
                }
                // Sync: 2d fp32 vector elements (Table 1's O(2d)).
                let s = &self.shapes[idx];
                self.last_sync_bytes += (s.d_in + s.d_out) * 4;
                timer.add("factor", t0.elapsed());
            }

            // ---- precondition ------------------------------------------
            let t0 = std::time::Instant::now();
            let st = &self.layers[idx];
            let mu = self.cfg.damping;
            let left = Eva::apply_smw(&cap.dw, &st.g_vec, mu, true);
            let mut delta = Eva::apply_smw(&left, &st.a_vec, mu, false);
            // Eva normalizes update scale via KL-clip; we use the same
            // norm-matching rescale for comparability across optimizers.
            rescale_to_gradient_norm(&mut delta, &cap.dw);
            timer.add("precond", t0.elapsed());
            deltas.push(delta);
        }

        let t0 = std::time::Instant::now();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        self.backend.apply(layers, &deltas, &dbs, lr);
        timer.add("update", t0.elapsed());
        self.t += 1;
    }

    fn state_bytes(&self) -> usize {
        // O(2d): two vectors per layer.
        self.shapes
            .iter()
            .map(|s| (s.d_in + s.d_out) * 4)
            .sum::<usize>()
            + self.backend.state_bytes()
    }

    fn sync_bytes_last_step(&self) -> usize {
        self.last_sync_bytes
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Eva(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::inverse::invert;
    use crate::model::Activation;
    use crate::util::Rng;

    #[test]
    fn smw_closed_form_matches_dense_inverse() {
        let mut rng = Rng::new(1);
        let n = 6;
        let v: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mu = 0.4f32;
        // Dense (vvᵀ + μI)⁻¹ M
        let mut vvmu = ops::outer(&v, &v);
        for i in 0..n {
            vvmu[(i, i)] += mu;
        }
        let dense_inv = invert(&vvmu).unwrap();
        let m = Matrix::randn(n, 4, 1.0, &mut rng);
        let want = ops::matmul(&dense_inv, &m);
        let got = Eva::apply_smw(&m, &v, mu, true);
        assert!(got.max_abs_diff(&want) < 1e-3);

        // Right application: M (vvᵀ + μI)⁻¹
        let m2 = Matrix::randn(4, n, 1.0, &mut rng);
        let want2 = ops::matmul(&m2, &dense_inv);
        let got2 = Eva::apply_smw(&m2, &v, mu, false);
        assert!(got2.max_abs_diff(&want2) < 1e-3);
    }

    #[test]
    fn state_is_linear_in_d() {
        let shapes = [LayerShape::new(100, 100)];
        let eva = Eva::new(&shapes, EvaConfig::default());
        // 2d vectors (800 bytes) + backend momentum (d² f32).
        assert_eq!(eva.state_bytes(), 200 * 4 + (100 * 100 + 100) * 4);
    }

    #[test]
    fn reduces_quadratic_loss() {
        let mut rng = Rng::new(2);
        let shapes = [LayerShape::new(6, 4)];
        let x = Matrix::randn(6, 16, 1.0, &mut rng);
        let w_true = Matrix::randn(4, 6, 1.0, &mut rng);
        let y = ops::matmul(&w_true, &x);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        layers[0].w = Matrix::zeros(4, 6);
        let mut opt = Eva::new(&shapes, EvaConfig::default());
        let mut timer = PhaseTimer::new();
        let mut loss = f64::INFINITY;
        for _ in 0..150 {
            let pred = ops::matmul(&layers[0].w, &x);
            let mut err = pred.clone();
            err.blend(1.0, -1.0, &y);
            loss = err.fro_norm().powi(2) / 16.0;
            let mut g = err;
            g.scale(2.0 / 16.0);
            let dw = ops::matmul_nt(&g, &x);
            let cap = Capture { a: x.clone(), g, dw, db: vec![0.0; 4] };
            opt.step(&mut layers, std::slice::from_ref(&cap), 0.05, &mut timer);
        }
        assert!(loss < 0.1, "loss={loss}");
    }

    #[test]
    fn sync_is_linear_and_fp32() {
        let shapes = [LayerShape::new(64, 64)];
        let mut opt = Eva::new(&shapes, EvaConfig::default());
        let mut rng = Rng::new(3);
        let a = Matrix::randn(64, 4, 1.0, &mut rng);
        let g = Matrix::randn(64, 4, 1.0, &mut rng);
        let mut dw = ops::matmul_nt(&g, &a);
        dw.scale(0.25);
        let cap = Capture { a, g, dw, db: vec![0.0; 64] };
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let mut timer = PhaseTimer::new();
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer);
        assert_eq!(opt.sync_bytes_last_step(), 128 * 4);
    }
}
