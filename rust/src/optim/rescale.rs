//! Gradient rescaling (Algorithm 1 line 10, Figure 1-d).
//!
//! Preconditioning changes the norm of the update, which interferes with
//! learning-rate schedules tuned for raw gradients. MKOR rescales the
//! preconditioned update so its Frobenius norm matches the raw gradient's.

use crate::linalg::Matrix;

/// Scale `delta` in place so `‖delta‖_F == ‖grad‖_F`. Returns the applied
/// scale factor (1.0 when either norm is ~0, leaving `delta` unchanged).
pub fn rescale_to_gradient_norm(delta: &mut Matrix, grad: &Matrix) -> f32 {
    let gn = grad.fro_norm();
    let dn = delta.fro_norm();
    if !(gn.is_finite() && dn.is_finite()) || dn < 1e-30 || gn < 1e-30 {
        return 1.0;
    }
    let s = (gn / dn) as f32;
    delta.scale(s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn norm_matches_after_rescale() {
        let mut rng = Rng::new(1);
        let grad = Matrix::randn(6, 9, 2.0, &mut rng);
        let mut delta = Matrix::randn(6, 9, 0.01, &mut rng);
        let s = rescale_to_gradient_norm(&mut delta, &grad);
        assert!(s > 1.0);
        assert!((delta.fro_norm() - grad.fro_norm()).abs() / grad.fro_norm() < 1e-5);
    }

    #[test]
    fn direction_is_preserved() {
        let grad = Matrix::from_rows(&[&[2.0, 0.0]]);
        let mut delta = Matrix::from_rows(&[&[0.0, 0.5]]);
        rescale_to_gradient_norm(&mut delta, &grad);
        assert_eq!(delta[(0, 0)], 0.0);
        assert!((delta[(0, 1)] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn zero_delta_is_left_alone() {
        let grad = Matrix::from_rows(&[&[1.0]]);
        let mut delta = Matrix::from_rows(&[&[0.0]]);
        let s = rescale_to_gradient_norm(&mut delta, &grad);
        assert_eq!(s, 1.0);
        assert_eq!(delta[(0, 0)], 0.0);
    }
}
