//! Typed, serializable optimizer specifications — the one construction API
//! for the whole suite.
//!
//! An [`OptimizerSpec`] carries an optimizer's *full* hyperparameter set as
//! plain data. It parses from the CLI grammar
//!
//! ```text
//! name[:key=val,...]
//! ```
//!
//! (e.g. `mkor:f=10,damping=3e-2,backend=lamb`), prints back to a canonical
//! string via [`OptimizerSpec::canonical`] (only non-default keys, fixed key
//! order, so `parse(canonical(spec)) == spec`), serializes to JSON via
//! [`OptimizerSpec::to_json`] so run records capture the exact configuration
//! that produced every figure/table, and builds the boxed optimizer with
//! [`OptimizerSpec::build`].
//!
//! Every [`Optimizer`] also reports the spec it was built from via
//! [`Optimizer::spec`], closing the loop: a run record's spec string can be
//! re-parsed to reproduce the run.
//!
//! The per-optimizer key tables (canonical key first, aliases after) live in
//! the `KEYS_*` constants below and are printed verbatim in [`SpecError`]
//! messages; the module-level table in [`crate::optim`] documents one
//! example string per optimizer.
//!
//! One deliberate gap: `MkorConfig::second_order_layers` (a per-layer bool
//! mask) is programmatic-only — it has no grammar key, and `canonical()`
//! does not encode it. Specs built from strings always treat every layer as
//! second-order.

use crate::linalg::half::HalfKind;
use crate::model::LayerShape;
use crate::optim::eva::{Eva, EvaConfig};
use crate::optim::first_order::{Adam, AdamConfig, Lamb, SgdMomentum};
use crate::optim::hybrid::{MkorH, SwitchConfig};
use crate::optim::kfac::{Kfac, KfacConfig};
use crate::optim::mkor::{Mkor, MkorConfig};
use crate::optim::sngd::{Sngd, SngdConfig};
use crate::optim::{Backend, Optimizer, ALL_OPTIMIZERS};
use crate::util::json::Json;
use std::fmt;

/// Why an optimizer spec string failed to parse.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// The optimizer name itself is unknown.
    UnknownOptimizer { name: String },
    /// A `key=val` pair named a key the optimizer doesn't have.
    UnknownKey {
        optimizer: &'static str,
        key: String,
        valid: &'static [&'static str],
    },
    /// A key's value failed to parse as the expected type.
    BadValue {
        key: String,
        value: String,
        expected: &'static str,
    },
    /// A comma-separated part was not of the form `key=val`.
    Malformed { part: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::UnknownOptimizer { name } => write!(
                f,
                "unknown optimizer `{name}`; valid optimizers: {}",
                ALL_OPTIMIZERS.join(", ")
            ),
            SpecError::UnknownKey { optimizer, key, valid } => write!(
                f,
                "unknown key `{key}` for optimizer `{optimizer}`; valid keys: {}",
                valid.join(", ")
            ),
            SpecError::BadValue { key, value, expected } => write!(
                f,
                "bad value `{value}` for key `{key}`: expected {expected}"
            ),
            SpecError::Malformed { part } => write!(
                f,
                "malformed spec part `{part}`: expected `key=val` (grammar: name[:key=val,...])"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// Valid keys per optimizer (canonical key first, aliases after).
pub const KEYS_SGD: &[&str] = &["momentum", "m"];
pub const KEYS_ADAM: &[&str] = &["beta1", "beta2", "eps", "wd", "weight_decay"];
pub const KEYS_KFAC: &[&str] =
    &["f", "inv_freq", "gamma", "damping", "momentum", "cov_freq", "rescale"];
pub const KEYS_SNGD: &[&str] = &["f", "inv_freq", "damping", "momentum"];
pub const KEYS_EVA: &[&str] = &["damping", "beta", "momentum", "f", "update_freq"];
pub const KEYS_MKOR: &[&str] = &[
    "f", "inv_freq", "gamma", "backend", "momentum", "half", "epsilon", "damping", "zeta",
    "backend.beta1", "backend.beta2", "backend.eps", "backend.wd", "backend.weight_decay",
    "backend.momentum",
];
pub const KEYS_MKOR_H: &[&str] = &[
    "f", "inv_freq", "gamma", "backend", "momentum", "half", "epsilon", "damping", "zeta",
    "backend.beta1", "backend.beta2", "backend.eps", "backend.wd", "backend.weight_decay",
    "backend.momentum", "switch_ratio", "switch_beta", "min_steps",
];

/// A fully-specified optimizer configuration: the typed construction API.
///
/// Obtain one with [`OptimizerSpec::parse`] (CLI strings) or by constructing
/// a variant directly; turn it into a live optimizer with
/// [`OptimizerSpec::build`].
#[derive(Clone, Debug, PartialEq)]
pub enum OptimizerSpec {
    /// SGD with heavy-ball momentum.
    Sgd { momentum: f32 },
    /// Adam with the paper's BERT hyperparameters as defaults.
    Adam(AdamConfig),
    /// LAMB (Adam direction + per-layer trust ratio).
    Lamb(AdamConfig),
    /// KFAC in its KAISA-style distributed form.
    Kfac(KfacConfig),
    /// SNGD/HyLo batch-side SMW preconditioning.
    Sngd(SngdConfig),
    /// Eva rank-1 closed-form SMW.
    Eva(EvaConfig),
    /// MKOR (Algorithm 1).
    Mkor(MkorConfig),
    /// MKOR-H: MKOR + loss-rate switch to the first-order backend.
    MkorH { mkor: MkorConfig, switch: SwitchConfig },
}

/// SGD's default momentum — the one spot it lives so parse/canonical/
/// Default can never disagree (the other optimizers compare against their
/// `Config::default()`s).
pub const SGD_DEFAULT_MOMENTUM: f32 = 0.9;

impl Default for OptimizerSpec {
    fn default() -> Self {
        OptimizerSpec::Sgd { momentum: SGD_DEFAULT_MOMENTUM }
    }
}

fn f32_val(key: &str, val: &str) -> Result<f32, SpecError> {
    val.parse::<f32>().map_err(|_| SpecError::BadValue {
        key: key.to_string(),
        value: val.to_string(),
        expected: "a float",
    })
}

fn f64_val(key: &str, val: &str) -> Result<f64, SpecError> {
    val.parse::<f64>().map_err(|_| SpecError::BadValue {
        key: key.to_string(),
        value: val.to_string(),
        expected: "a float",
    })
}

fn usize_val(key: &str, val: &str) -> Result<usize, SpecError> {
    match val.parse::<usize>() {
        Ok(n) if n > 0 => Ok(n),
        _ => Err(SpecError::BadValue {
            key: key.to_string(),
            value: val.to_string(),
            expected: "a positive integer",
        }),
    }
}

fn bool_val(key: &str, val: &str) -> Result<bool, SpecError> {
    match val {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => Err(SpecError::BadValue {
            key: key.to_string(),
            value: val.to_string(),
            expected: "a boolean (true/false/1/0/yes/no/on/off)",
        }),
    }
}

fn backend_val(key: &str, val: &str) -> Result<Backend, SpecError> {
    match val {
        "sgd" => Ok(Backend::SgdMomentum),
        "adam" => Ok(Backend::Adam),
        "lamb" => Ok(Backend::Lamb),
        _ => Err(SpecError::BadValue {
            key: key.to_string(),
            value: val.to_string(),
            expected: "one of sgd, adam, lamb",
        }),
    }
}

fn half_val(key: &str, val: &str) -> Result<Option<HalfKind>, SpecError> {
    match val {
        "none" | "fp32" => Ok(None),
        "bf16" => Ok(Some(HalfKind::Bf16)),
        "f16" | "fp16" => Ok(Some(HalfKind::F16)),
        _ => Err(SpecError::BadValue {
            key: key.to_string(),
            value: val.to_string(),
            expected: "one of bf16, f16, none",
        }),
    }
}

fn backend_str(b: Backend) -> &'static str {
    match b {
        Backend::SgdMomentum => "sgd",
        Backend::Adam => "adam",
        Backend::Lamb => "lamb",
    }
}

fn half_str(h: Option<HalfKind>) -> &'static str {
    match h {
        None => "none",
        Some(HalfKind::Bf16) => "bf16",
        Some(HalfKind::F16) => "f16",
    }
}

/// Apply one `key=val` pair to an `AdamConfig` (shared by adam / lamb).
fn apply_adam_key(
    c: &mut AdamConfig,
    optimizer: &'static str,
    key: &str,
    val: &str,
) -> Result<(), SpecError> {
    match key {
        "beta1" => c.beta1 = f32_val(key, val)?,
        "beta2" => c.beta2 = f32_val(key, val)?,
        "eps" => c.eps = f32_val(key, val)?,
        "wd" | "weight_decay" => c.weight_decay = f32_val(key, val)?,
        _ => {
            return Err(SpecError::UnknownKey {
                optimizer,
                key: key.to_string(),
                valid: KEYS_ADAM,
            });
        }
    }
    Ok(())
}

/// Apply one `key=val` pair to an `MkorConfig` (shared by mkor / mkor-h).
/// Returns `Ok(false)` when the key isn't an MKOR key so mkor-h can try its
/// switch-rule keys next.
fn apply_mkor_key(cfg: &mut MkorConfig, key: &str, val: &str) -> Result<bool, SpecError> {
    match key {
        "f" | "inv_freq" => cfg.inv_freq = usize_val(key, val)?,
        "gamma" => cfg.gamma = f32_val(key, val)?,
        "backend" => cfg.backend = backend_val(key, val)?,
        "momentum" => cfg.momentum = f32_val(key, val)?,
        "half" => cfg.half_sync = half_val(key, val)?,
        // MKOR has no Tikhonov damping — the norm-based stabilizer threshold
        // ε plays that regularization role, so `damping` aliases it.
        "epsilon" | "damping" => cfg.stabilizer.epsilon = f64_val(key, val)?,
        "zeta" => cfg.stabilizer.zeta = f32_val(key, val)?,
        // Nested keys configure the line-14 first-order backend.
        "backend.beta1" => cfg.backend_cfg.beta1 = f32_val(key, val)?,
        "backend.beta2" => cfg.backend_cfg.beta2 = f32_val(key, val)?,
        "backend.eps" => cfg.backend_cfg.eps = f32_val(key, val)?,
        "backend.wd" | "backend.weight_decay" => {
            cfg.backend_cfg.weight_decay = f32_val(key, val)?
        }
        "backend.momentum" => cfg.momentum = f32_val(key, val)?,
        _ => return Ok(false),
    }
    Ok(true)
}

/// Push `key=val` onto the canonical pair list.
fn kv(pairs: &mut Vec<String>, key: &str, val: impl fmt::Display) {
    pairs.push(format!("{key}={val}"));
}

/// Canonical pairs for an `MkorConfig` (non-default fields, fixed order).
fn mkor_pairs(c: &MkorConfig, pairs: &mut Vec<String>) {
    let d = MkorConfig::default();
    if c.inv_freq != d.inv_freq {
        kv(pairs, "f", c.inv_freq);
    }
    if c.gamma != d.gamma {
        kv(pairs, "gamma", c.gamma);
    }
    if c.backend != d.backend {
        kv(pairs, "backend", backend_str(c.backend));
    }
    if c.momentum != d.momentum {
        kv(pairs, "momentum", c.momentum);
    }
    if c.half_sync != d.half_sync {
        kv(pairs, "half", half_str(c.half_sync));
    }
    if c.stabilizer.epsilon != d.stabilizer.epsilon {
        kv(pairs, "epsilon", c.stabilizer.epsilon);
    }
    if c.stabilizer.zeta != d.stabilizer.zeta {
        kv(pairs, "zeta", c.stabilizer.zeta);
    }
    if c.backend_cfg.beta1 != d.backend_cfg.beta1 {
        kv(pairs, "backend.beta1", c.backend_cfg.beta1);
    }
    if c.backend_cfg.beta2 != d.backend_cfg.beta2 {
        kv(pairs, "backend.beta2", c.backend_cfg.beta2);
    }
    if c.backend_cfg.eps != d.backend_cfg.eps {
        kv(pairs, "backend.eps", c.backend_cfg.eps);
    }
    if c.backend_cfg.weight_decay != d.backend_cfg.weight_decay {
        kv(pairs, "backend.wd", c.backend_cfg.weight_decay);
    }
}

/// JSON object for an `MkorConfig` (all fields).
fn mkor_json(c: &MkorConfig) -> Json {
    let mut p = Json::obj();
    p.set("inv_freq", Json::Num(c.inv_freq as f64))
        .set("gamma", Json::Num(c.gamma as f64))
        .set("backend", Json::Str(backend_str(c.backend).into()))
        .set("momentum", Json::Num(c.momentum as f64))
        .set("half_sync", Json::Str(half_str(c.half_sync).into()))
        .set("stabilizer_epsilon", Json::Num(c.stabilizer.epsilon))
        .set("stabilizer_zeta", Json::Num(c.stabilizer.zeta as f64))
        .set("backend_beta1", Json::Num(c.backend_cfg.beta1 as f64))
        .set("backend_beta2", Json::Num(c.backend_cfg.beta2 as f64))
        .set("backend_eps", Json::Num(c.backend_cfg.eps as f64))
        .set("backend_wd", Json::Num(c.backend_cfg.weight_decay as f64));
    p
}

impl OptimizerSpec {
    /// Parse `name[:key=val,...]`. The bare name yields the paper-default
    /// configuration (§8.9); `kaisa` and `hylo` are accepted aliases for
    /// `kfac` and `sngd`.
    pub fn parse(s: &str) -> Result<OptimizerSpec, SpecError> {
        let (name, rest) = match s.split_once(':') {
            Some((n, r)) => (n.trim(), r.trim()),
            None => (s.trim(), ""),
        };
        let mut spec = match name {
            "sgd" => OptimizerSpec::Sgd { momentum: SGD_DEFAULT_MOMENTUM },
            "adam" => OptimizerSpec::Adam(AdamConfig::default()),
            "lamb" => OptimizerSpec::Lamb(AdamConfig::default()),
            "kfac" | "kaisa" => OptimizerSpec::Kfac(KfacConfig::default()),
            "sngd" | "hylo" => OptimizerSpec::Sngd(SngdConfig::default()),
            "eva" => OptimizerSpec::Eva(EvaConfig::default()),
            "mkor" => OptimizerSpec::Mkor(MkorConfig::default()),
            "mkor-h" => OptimizerSpec::MkorH {
                mkor: MkorConfig::default(),
                switch: SwitchConfig::default(),
            },
            _ => {
                return Err(SpecError::UnknownOptimizer { name: name.to_string() });
            }
        };
        for part in rest.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, val)) = part.split_once('=') else {
                return Err(SpecError::Malformed { part: part.to_string() });
            };
            let (key, val) = (key.trim(), val.trim());
            spec.apply_key(key, val)?;
        }
        Ok(spec)
    }

    /// Apply one `key=val` override to this spec.
    fn apply_key(&mut self, key: &str, val: &str) -> Result<(), SpecError> {
        let unknown = |optimizer, valid| SpecError::UnknownKey {
            optimizer,
            key: key.to_string(),
            valid,
        };
        match self {
            OptimizerSpec::Sgd { momentum } => match key {
                "momentum" | "m" => *momentum = f32_val(key, val)?,
                _ => return Err(unknown("sgd", KEYS_SGD)),
            },
            OptimizerSpec::Adam(c) => apply_adam_key(c, "adam", key, val)?,
            OptimizerSpec::Lamb(c) => apply_adam_key(c, "lamb", key, val)?,
            OptimizerSpec::Kfac(c) => match key {
                "f" | "inv_freq" => c.inv_freq = usize_val(key, val)?,
                "gamma" => c.gamma = f32_val(key, val)?,
                "damping" => c.damping = f32_val(key, val)?,
                "momentum" => c.momentum = f32_val(key, val)?,
                "cov_freq" => c.cov_freq = usize_val(key, val)?,
                "rescale" => c.rescale = bool_val(key, val)?,
                _ => return Err(unknown("kfac", KEYS_KFAC)),
            },
            OptimizerSpec::Sngd(c) => match key {
                "f" | "inv_freq" => c.inv_freq = usize_val(key, val)?,
                "damping" => c.damping = f32_val(key, val)?,
                "momentum" => c.momentum = f32_val(key, val)?,
                _ => return Err(unknown("sngd", KEYS_SNGD)),
            },
            OptimizerSpec::Eva(c) => match key {
                "damping" => c.damping = f32_val(key, val)?,
                "beta" => c.beta = f32_val(key, val)?,
                "momentum" => c.momentum = f32_val(key, val)?,
                "f" | "update_freq" => c.update_freq = usize_val(key, val)?,
                _ => return Err(unknown("eva", KEYS_EVA)),
            },
            OptimizerSpec::Mkor(c) => {
                if !apply_mkor_key(c, key, val)? {
                    return Err(unknown("mkor", KEYS_MKOR));
                }
            }
            OptimizerSpec::MkorH { mkor, switch } => {
                if !apply_mkor_key(mkor, key, val)? {
                    match key {
                        "switch_ratio" => switch.switch_ratio = f64_val(key, val)?,
                        "switch_beta" => switch.beta = f64_val(key, val)?,
                        "min_steps" => switch.min_steps = usize_val(key, val)?,
                        _ => return Err(unknown("mkor-h", KEYS_MKOR_H)),
                    }
                }
            }
        }
        Ok(())
    }

    /// The canonical optimizer name (first column of `ALL_OPTIMIZERS`).
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerSpec::Sgd { .. } => "sgd",
            OptimizerSpec::Adam(_) => "adam",
            OptimizerSpec::Lamb(_) => "lamb",
            OptimizerSpec::Kfac(_) => "kfac",
            OptimizerSpec::Sngd(_) => "sngd",
            OptimizerSpec::Eva(_) => "eva",
            OptimizerSpec::Mkor(_) => "mkor",
            OptimizerSpec::MkorH { .. } => "mkor-h",
        }
    }

    /// Canonical string form: `name` alone when every hyperparameter is at
    /// its default, otherwise `name:key=val,...` with non-default keys in a
    /// fixed order. Guaranteed to round-trip:
    /// `parse(canonical(s)) == s` for any grammar-expressible spec.
    pub fn canonical(&self) -> String {
        let mut pairs: Vec<String> = Vec::new();
        match self {
            OptimizerSpec::Sgd { momentum } => {
                if *momentum != SGD_DEFAULT_MOMENTUM {
                    kv(&mut pairs, "momentum", momentum);
                }
            }
            OptimizerSpec::Adam(c) | OptimizerSpec::Lamb(c) => {
                let d = AdamConfig::default();
                if c.beta1 != d.beta1 {
                    kv(&mut pairs, "beta1", c.beta1);
                }
                if c.beta2 != d.beta2 {
                    kv(&mut pairs, "beta2", c.beta2);
                }
                if c.eps != d.eps {
                    kv(&mut pairs, "eps", c.eps);
                }
                if c.weight_decay != d.weight_decay {
                    kv(&mut pairs, "wd", c.weight_decay);
                }
            }
            OptimizerSpec::Kfac(c) => {
                let d = KfacConfig::default();
                if c.inv_freq != d.inv_freq {
                    kv(&mut pairs, "f", c.inv_freq);
                }
                if c.gamma != d.gamma {
                    kv(&mut pairs, "gamma", c.gamma);
                }
                if c.damping != d.damping {
                    kv(&mut pairs, "damping", c.damping);
                }
                if c.momentum != d.momentum {
                    kv(&mut pairs, "momentum", c.momentum);
                }
                if c.cov_freq != d.cov_freq {
                    kv(&mut pairs, "cov_freq", c.cov_freq);
                }
                if c.rescale != d.rescale {
                    kv(&mut pairs, "rescale", c.rescale);
                }
            }
            OptimizerSpec::Sngd(c) => {
                let d = SngdConfig::default();
                if c.inv_freq != d.inv_freq {
                    kv(&mut pairs, "f", c.inv_freq);
                }
                if c.damping != d.damping {
                    kv(&mut pairs, "damping", c.damping);
                }
                if c.momentum != d.momentum {
                    kv(&mut pairs, "momentum", c.momentum);
                }
            }
            OptimizerSpec::Eva(c) => {
                let d = EvaConfig::default();
                if c.damping != d.damping {
                    kv(&mut pairs, "damping", c.damping);
                }
                if c.beta != d.beta {
                    kv(&mut pairs, "beta", c.beta);
                }
                if c.momentum != d.momentum {
                    kv(&mut pairs, "momentum", c.momentum);
                }
                if c.update_freq != d.update_freq {
                    kv(&mut pairs, "f", c.update_freq);
                }
            }
            OptimizerSpec::Mkor(c) => mkor_pairs(c, &mut pairs),
            OptimizerSpec::MkorH { mkor, switch } => {
                mkor_pairs(mkor, &mut pairs);
                let d = SwitchConfig::default();
                if switch.switch_ratio != d.switch_ratio {
                    kv(&mut pairs, "switch_ratio", switch.switch_ratio);
                }
                if switch.beta != d.beta {
                    kv(&mut pairs, "switch_beta", switch.beta);
                }
                if switch.min_steps != d.min_steps {
                    kv(&mut pairs, "min_steps", switch.min_steps);
                }
            }
        }
        if pairs.is_empty() {
            self.name().to_string()
        } else {
            format!("{}:{}", self.name(), pairs.join(","))
        }
    }

    /// JSON form: `{"name": ..., "spec": <canonical string>, "params":
    /// {<every hyperparameter>}}` — written into `RunRecord` dumps so every
    /// figure/table records the exact configuration that produced it.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name().into()))
            .set("spec", Json::Str(self.canonical()));
        let mut p = Json::obj();
        match self {
            OptimizerSpec::Sgd { momentum } => {
                p.set("momentum", Json::Num(*momentum as f64));
            }
            OptimizerSpec::Adam(c) | OptimizerSpec::Lamb(c) => {
                p.set("beta1", Json::Num(c.beta1 as f64))
                    .set("beta2", Json::Num(c.beta2 as f64))
                    .set("eps", Json::Num(c.eps as f64))
                    .set("weight_decay", Json::Num(c.weight_decay as f64));
            }
            OptimizerSpec::Kfac(c) => {
                p.set("inv_freq", Json::Num(c.inv_freq as f64))
                    .set("gamma", Json::Num(c.gamma as f64))
                    .set("damping", Json::Num(c.damping as f64))
                    .set("momentum", Json::Num(c.momentum as f64))
                    .set("cov_freq", Json::Num(c.cov_freq as f64))
                    .set("rescale", Json::Bool(c.rescale));
            }
            OptimizerSpec::Sngd(c) => {
                p.set("inv_freq", Json::Num(c.inv_freq as f64))
                    .set("damping", Json::Num(c.damping as f64))
                    .set("momentum", Json::Num(c.momentum as f64));
            }
            OptimizerSpec::Eva(c) => {
                p.set("damping", Json::Num(c.damping as f64))
                    .set("beta", Json::Num(c.beta as f64))
                    .set("momentum", Json::Num(c.momentum as f64))
                    .set("update_freq", Json::Num(c.update_freq as f64));
            }
            OptimizerSpec::Mkor(c) => {
                p = mkor_json(c);
            }
            OptimizerSpec::MkorH { mkor, switch } => {
                p = mkor_json(mkor);
                p.set("switch_ratio", Json::Num(switch.switch_ratio))
                    .set("switch_beta", Json::Num(switch.beta))
                    .set("min_steps", Json::Num(switch.min_steps as f64));
            }
        }
        o.set("params", p);
        o
    }

    /// Build the boxed optimizer this spec describes.
    pub fn build(&self, shapes: &[LayerShape]) -> Box<dyn Optimizer + Send> {
        match self {
            OptimizerSpec::Sgd { momentum } => Box::new(SgdMomentum::new(shapes, *momentum)),
            OptimizerSpec::Adam(c) => Box::new(Adam::new(shapes, *c)),
            OptimizerSpec::Lamb(c) => Box::new(Lamb::new(shapes, *c)),
            OptimizerSpec::Kfac(c) => Box::new(Kfac::new(shapes, *c)),
            OptimizerSpec::Sngd(c) => Box::new(Sngd::new(shapes, *c)),
            OptimizerSpec::Eva(c) => Box::new(Eva::new(shapes, *c)),
            OptimizerSpec::Mkor(c) => Box::new(Mkor::new(shapes, c.clone())),
            OptimizerSpec::MkorH { mkor, switch } => {
                Box::new(MkorH::new(shapes, mkor.clone(), *switch))
            }
        }
    }

    /// Override the second-order refresh period (MKOR/MKOR-H factor period,
    /// KFAC inversion period, SNGD kernel period, Eva vector period).
    /// No-op for first-order optimizers — the knob they don't have.
    pub fn with_inv_freq(mut self, f: usize) -> Self {
        match &mut self {
            OptimizerSpec::Mkor(c) => c.inv_freq = f,
            OptimizerSpec::MkorH { mkor, .. } => mkor.inv_freq = f,
            OptimizerSpec::Kfac(c) => c.inv_freq = f,
            OptimizerSpec::Sngd(c) => c.inv_freq = f,
            OptimizerSpec::Eva(c) => c.update_freq = f,
            _ => {}
        }
        self
    }

    /// Override MKOR's factor-recurrence momentum γ (Equations 5/6).
    /// Applies to MKOR and MKOR-H only — other optimizers' EMA momenta are
    /// distinct knobs with their own grammar keys.
    pub fn with_gamma(mut self, gamma: f32) -> Self {
        match &mut self {
            OptimizerSpec::Mkor(c) => c.gamma = gamma,
            OptimizerSpec::MkorH { mkor, .. } => mkor.gamma = gamma,
            _ => {}
        }
        self
    }
}

impl fmt::Display for OptimizerSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical())
    }
}

impl std::str::FromStr for OptimizerSpec {
    type Err = SpecError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        OptimizerSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_names_parse_to_defaults() {
        for name in ALL_OPTIMIZERS {
            let spec = OptimizerSpec::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.name(), *name);
            assert_eq!(spec.canonical(), *name, "defaults must print bare");
        }
        assert_eq!(OptimizerSpec::parse("kaisa").unwrap().name(), "kfac");
        assert_eq!(OptimizerSpec::parse("hylo").unwrap().name(), "sngd");
    }

    #[test]
    fn keyed_parse_applies_overrides() {
        let spec = OptimizerSpec::parse("mkor:f=25,gamma=0.95,backend=lamb,half=none").unwrap();
        let OptimizerSpec::Mkor(c) = &spec else { panic!("wrong variant") };
        assert_eq!(c.inv_freq, 25);
        assert_eq!(c.gamma, 0.95);
        assert_eq!(c.backend, Backend::Lamb);
        assert_eq!(c.half_sync, None);

        let spec = OptimizerSpec::parse("kfac:f=5,damping=3e-2,rescale=false").unwrap();
        let OptimizerSpec::Kfac(c) = &spec else { panic!("wrong variant") };
        assert_eq!(c.inv_freq, 5);
        assert!((c.damping - 0.03).abs() < 1e-9);
        assert!(!c.rescale);
    }

    #[test]
    fn mkor_damping_aliases_stabilizer_epsilon() {
        let spec = OptimizerSpec::parse("mkor:damping=50").unwrap();
        let OptimizerSpec::Mkor(c) = &spec else { panic!() };
        assert_eq!(c.stabilizer.epsilon, 50.0);
    }

    #[test]
    fn nested_backend_keys_configure_the_backend() {
        let s = "mkor:backend=adam,backend.beta1=0.95,backend.eps=1e-8,backend.wd=0.01";
        let spec = OptimizerSpec::parse(s).unwrap();
        let OptimizerSpec::Mkor(c) = &spec else { panic!("wrong variant") };
        assert_eq!(c.backend, Backend::Adam);
        assert_eq!(c.backend_cfg.beta1, 0.95);
        assert_eq!(c.backend_cfg.eps, 1e-8);
        assert_eq!(c.backend_cfg.weight_decay, 0.01);
        // Canonical prints the nested keys and round-trips.
        let canon = spec.canonical();
        assert!(canon.contains("backend.beta1=0.95"), "{canon}");
        assert_eq!(OptimizerSpec::parse(&canon).unwrap(), spec);
        // `backend.momentum` aliases the SGD backend's momentum key.
        let spec = OptimizerSpec::parse("mkor:backend.momentum=0.8").unwrap();
        let OptimizerSpec::Mkor(c) = &spec else { panic!() };
        assert_eq!(c.momentum, 0.8);
        assert_eq!(spec.canonical(), "mkor:momentum=0.8");
        // mkor-h accepts them too, alongside its switch keys.
        let spec =
            OptimizerSpec::parse("mkor-h:backend=lamb,backend.beta2=0.98,switch_ratio=0.2")
                .unwrap();
        assert_eq!(OptimizerSpec::parse(&spec.canonical()).unwrap(), spec);
        // Unknown nested keys list the valid ones.
        let e = OptimizerSpec::parse("mkor:backend.nope=1").unwrap_err();
        assert!(e.to_string().contains("backend.beta1"), "{e}");
    }

    #[test]
    fn roundtrip_nondefault_specs_for_every_optimizer() {
        // parse(canonical(spec)) == spec with non-default hyperparameters.
        let inputs = [
            "sgd:momentum=0.75",
            "adam:beta1=0.8,beta2=0.99,eps=1e-8,wd=0.01",
            "lamb:beta1=0.85,wd=0.1",
            "kfac:f=7,gamma=0.9,damping=0.003,momentum=0.8,cov_freq=2,rescale=false",
            "sngd:f=3,damping=0.5,momentum=0.95",
            "eva:damping=0.01,beta=0.9,momentum=0.85,f=4",
            "mkor:f=25,gamma=0.9,backend=adam,momentum=0.8,half=f16,epsilon=50,zeta=0.25",
            "mkor-h:f=15,backend=lamb,switch_ratio=0.2,switch_beta=0.9,min_steps=20",
        ];
        for s in inputs {
            let spec = OptimizerSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
            let canon = spec.canonical();
            let re = OptimizerSpec::parse(&canon)
                .unwrap_or_else(|e| panic!("reparse `{canon}`: {e}"));
            assert_eq!(re, spec, "round-trip failed for `{s}` via `{canon}`");
        }
    }

    #[test]
    fn roundtrip_pseudorandom_sweep() {
        // Proptest-style: a seeded LCG drives value choices for every
        // optimizer; each sampled spec must round-trip through canonical().
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        for _ in 0..50 {
            let f = 1 + next() % 100;
            let gamma = 0.5 + (next() % 49) as f32 / 100.0;
            let damping = (1 + next() % 99) as f32 / 100.0;
            let momentum = (next() % 100) as f32 / 100.0;
            let inputs = [
                format!("sgd:momentum={momentum}"),
                format!("adam:beta1={gamma},wd={damping}"),
                format!("lamb:beta2={gamma},eps={damping}"),
                format!("kfac:f={f},gamma={gamma},damping={damping}"),
                format!("sngd:f={f},damping={damping},momentum={momentum}"),
                format!("eva:f={f},damping={damping},beta={gamma}"),
                format!("mkor:f={f},gamma={gamma},zeta={damping}"),
                format!("mkor-h:f={f},gamma={gamma},switch_ratio={damping}"),
            ];
            for s in &inputs {
                let spec = OptimizerSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
                let re = OptimizerSpec::parse(&spec.canonical()).unwrap();
                assert_eq!(re, spec, "round-trip failed for `{s}`");
            }
        }
    }

    #[test]
    fn errors_are_actionable() {
        let e = OptimizerSpec::parse("bogus").unwrap_err();
        let msg = e.to_string();
        for name in ALL_OPTIMIZERS {
            assert!(msg.contains(name), "`{msg}` should list `{name}`");
        }

        let e = OptimizerSpec::parse("mkor:nope=1").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("nope"));
        for key in ["f", "gamma", "backend", "half", "zeta"] {
            assert!(msg.contains(key), "`{msg}` should list key `{key}`");
        }

        let e = OptimizerSpec::parse("mkor:f=abc").unwrap_err();
        assert!(e.to_string().contains("abc"));

        let e = OptimizerSpec::parse("mkor:f").unwrap_err();
        assert!(e.to_string().contains("key=val"));
    }

    #[test]
    fn build_honors_inv_freq_override_via_is_factor_step() {
        // `mkor:f=25` must actually factor every 25 steps (concrete-type
        // check; the trait-level cadence check lives in tests/spec_roundtrip).
        let spec = OptimizerSpec::parse("mkor:f=25").unwrap();
        let OptimizerSpec::Mkor(cfg) = &spec else { panic!() };
        let shapes = [LayerShape::new(4, 4)];
        let opt = Mkor::new(&shapes, cfg.clone());
        assert!(opt.is_factor_step(0));
        assert!(!opt.is_factor_step(24));
        assert!(opt.is_factor_step(25));
        assert!(!opt.is_factor_step(26));
        assert!(opt.is_factor_step(50));
    }

    #[test]
    fn built_optimizers_report_their_spec() {
        let shapes = [LayerShape::new(6, 4), LayerShape::new(4, 2)];
        for s in [
            "sgd", "adam", "lamb", "kfac:f=5", "sngd:damping=0.5", "eva",
            "mkor:f=25,backend=lamb", "mkor-h:switch_ratio=0.3",
        ] {
            let spec = OptimizerSpec::parse(s).unwrap();
            let opt = spec.build(&shapes);
            assert_eq!(opt.spec(), spec, "spec() introspection for `{s}`");
            assert_eq!(opt.steps_done(), 0);
        }
    }

    #[test]
    fn json_carries_canonical_spec_and_params() {
        let spec = OptimizerSpec::parse("mkor:f=25,backend=lamb").unwrap();
        let j = spec.to_json();
        assert_eq!(j.require_str("name").unwrap(), "mkor");
        assert_eq!(j.require_str("spec").unwrap(), "mkor:f=25,backend=lamb");
        let params = j.get("params").unwrap();
        assert_eq!(params.get("inv_freq").unwrap().as_usize(), Some(25));
        assert_eq!(params.get("backend").unwrap().as_str(), Some("lamb"));
        // What we print re-parses to the same spec.
        let re = OptimizerSpec::parse(j.require_str("spec").unwrap()).unwrap();
        assert_eq!(re, spec);
    }

    #[test]
    fn override_helpers_match_grammar_semantics() {
        let s = OptimizerSpec::parse("mkor").unwrap().with_inv_freq(25).with_gamma(0.9);
        assert_eq!(s, OptimizerSpec::parse("mkor:f=25,gamma=0.9").unwrap());
        // with_gamma is MKOR-only; kfac's EMA gamma is untouched.
        let k = OptimizerSpec::parse("kfac").unwrap().with_gamma(0.5);
        assert_eq!(k, OptimizerSpec::parse("kfac").unwrap());
        // with_inv_freq is a no-op for first-order optimizers.
        let a = OptimizerSpec::parse("adam").unwrap().with_inv_freq(3);
        assert_eq!(a, OptimizerSpec::parse("adam").unwrap());
    }
}
