//! Learning-rate schedules, including the paper's knee-point scheduler
//! (§8.13): decay the LR when the EMA of the improvement rate drops below
//! β × the total improvement accumulated under the current LR.

use crate::checkpoint::{StateDict, StateError};
use crate::util::stats::Ema;

/// A learning-rate schedule driven by step count and (optionally) observed
/// loss/metric values.
pub trait LrSchedule {
    /// The LR to use for step `t` (0-based).
    fn lr(&self, t: usize) -> f32;
    /// Feed an observation (training loss or eval metric) after step `t`.
    fn observe(&mut self, _t: usize, _value: f64) {}

    /// Checkpointable schedule state. Stateless schedules (constant, step
    /// decay, warmup — everything driven purely by `t`) return an empty
    /// dict; stateful ones ([`KneePoint`]) override both methods so a
    /// resumed run's LR trajectory continues bitwise.
    fn state_dict(&self) -> StateDict {
        StateDict::new()
    }

    /// Restore state captured by [`LrSchedule::state_dict`]. The stateless
    /// default rejects non-empty dicts: restoring a stateful schedule's
    /// checkpoint into a stateless schedule is a configuration mismatch.
    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(&[], &[])
    }
}

/// Constant LR.
pub struct Constant(pub f32);

impl LrSchedule for Constant {
    fn lr(&self, _t: usize) -> f32 {
        self.0
    }
}

/// Piecewise decay at fixed steps: lr × factor at each milestone (the §8.9
/// ResNet schedule: decay by 2 at epochs 25,35,40,…).
pub struct StepDecay {
    pub base: f32,
    pub factor: f32,
    pub milestones: Vec<usize>,
}

impl LrSchedule for StepDecay {
    fn lr(&self, t: usize) -> f32 {
        let hits = self.milestones.iter().filter(|&&m| t >= m).count() as i32;
        self.base * self.factor.powi(hits)
    }
}

/// Linear warmup then polynomial (power-1) decay — the LAMB/BERT schedule.
pub struct WarmupLinear {
    pub base: f32,
    pub warmup: usize,
    pub total: usize,
}

impl LrSchedule for WarmupLinear {
    fn lr(&self, t: usize) -> f32 {
        if t < self.warmup {
            self.base * (t + 1) as f32 / self.warmup as f32
        } else if t >= self.total {
            0.0
        } else {
            self.base * (self.total - t) as f32 / (self.total - self.warmup) as f32
        }
    }
}

/// Knee-point scheduler (§8.13).
///
/// Tracks an EMA of the per-step improvement (loss decrease). A knee-point
/// is declared when that smoothed rate falls below `beta` × the *average*
/// rate since the current LR was adopted; the LR is then multiplied by
/// `decay` (with a cooldown so one knee can't trigger repeatedly).
pub struct KneePoint {
    base: f32,
    decay: f32,
    beta: f64,
    cooldown: usize,
    min_lr: f32,
    // state
    current: f32,
    rate_ema: Ema,
    since_change: usize,
    improvement_since_change: f64,
    last_value: Option<f64>,
    /// Steps at which knees were detected (observability/tests).
    pub knees: Vec<usize>,
}

impl KneePoint {
    pub fn new(base: f32, decay: f32, beta: f64, cooldown: usize, min_lr: f32) -> Self {
        KneePoint {
            base,
            decay,
            beta,
            cooldown,
            min_lr,
            current: base,
            rate_ema: Ema::new(0.9),
            since_change: 0,
            improvement_since_change: 0.0,
            last_value: None,
            knees: Vec::new(),
        }
    }
}

impl LrSchedule for KneePoint {
    fn lr(&self, _t: usize) -> f32 {
        self.current
    }

    fn observe(&mut self, t: usize, value: f64) {
        if let Some(prev) = self.last_value {
            let dec = (prev - value).max(0.0);
            self.improvement_since_change += dec;
            let rate = self.rate_ema.update(dec);
            self.since_change += 1;
            if self.since_change >= self.cooldown {
                let avg_rate =
                    self.improvement_since_change / self.since_change.max(1) as f64;
                if avg_rate > 0.0 && rate < self.beta * avg_rate {
                    // Knee: decay and reset the window.
                    self.current = (self.current * self.decay).max(self.min_lr);
                    self.knees.push(t);
                    self.since_change = 0;
                    self.improvement_since_change = 0.0;
                    self.rate_ema = Ema::new(0.9);
                }
            }
        }
        self.last_value = Some(value);
    }

    fn state_dict(&self) -> StateDict {
        let (ema_value, ema_steps) = self.rate_ema.state();
        let mut sd = StateDict::new();
        sd.put_f64("current", self.current as f64)
            .put_f64("rate_ema_value", ema_value)
            .put_u64("rate_ema_steps", ema_steps)
            .put_usize("since_change", self.since_change)
            .put_f64("improvement_since_change", self.improvement_since_change)
            .put_opt_f64("last_value", self.last_value);
        // Step indices stay exact as u64 entries (f32 tensors would round
        // beyond 2^24 steps).
        let mut knees = StateDict::new();
        for (i, &k) in self.knees.iter().enumerate() {
            knees.put_usize(&i.to_string(), k);
        }
        sd.put_dict("knees", knees);
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(
            &[
                "current",
                "rate_ema_value",
                "rate_ema_steps",
                "since_change",
                "improvement_since_change",
                "knees",
            ],
            &["last_value"],
        )?;
        self.current = state.f64v("current")? as f32;
        self.rate_ema
            .set_state(state.f64v("rate_ema_value")?, state.u64v("rate_ema_steps")?);
        self.since_change = state.usizev("since_change")?;
        self.improvement_since_change = state.f64v("improvement_since_change")?;
        self.last_value = state.opt_f64("last_value")?;
        let knees = state.dict("knees")?;
        let mut steps = Vec::with_capacity(knees.len());
        for i in 0..knees.len() {
            steps.push(knees.usizev(&i.to_string())?);
        }
        self.knees = steps;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Constant(0.1);
        assert_eq!(s.lr(0), 0.1);
        assert_eq!(s.lr(10_000), 0.1);
    }

    #[test]
    fn step_decay_applies_milestones() {
        let s = StepDecay { base: 1.0, factor: 0.5, milestones: vec![10, 20] };
        assert_eq!(s.lr(5), 1.0);
        assert_eq!(s.lr(10), 0.5);
        assert_eq!(s.lr(25), 0.25);
    }

    #[test]
    fn warmup_linear_shape() {
        let s = WarmupLinear { base: 1.0, warmup: 10, total: 110 };
        assert!(s.lr(0) < s.lr(5));
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
        assert!(s.lr(60) < 1.0);
        assert_eq!(s.lr(110), 0.0);
    }

    #[test]
    fn knee_point_decays_on_plateau() {
        let mut s = KneePoint::new(1.0, 0.5, 0.3, 10, 1e-4);
        let mut loss = 10.0;
        for t in 0..60 {
            s.observe(t, loss);
            loss -= 0.1; // steady improvement: no knee
        }
        assert!(s.knees.is_empty(), "knees={:?}", s.knees);
        for t in 60..120 {
            s.observe(t, loss);
            loss -= 0.0001; // plateau: knee expected
        }
        assert!(!s.knees.is_empty());
        assert!(s.lr(120) <= 0.5);
    }

    #[test]
    fn knee_point_state_roundtrip_continues_bitwise() {
        // Drive one scheduler to a mid-plateau state, snapshot it, restore
        // into a fresh instance, and check both produce identical LR
        // trajectories from there on.
        let mut a = KneePoint::new(1.0, 0.5, 0.3, 10, 1e-4);
        let mut loss = 10.0;
        for t in 0..80 {
            a.observe(t, loss);
            loss -= if t < 60 { 0.1 } else { 0.0001 };
        }
        let sd = a.state_dict();
        let mut b = KneePoint::new(1.0, 0.5, 0.3, 10, 1e-4);
        b.load_state_dict(&sd).unwrap();
        assert_eq!(b.state_dict(), sd);
        for t in 80..200 {
            a.observe(t, loss);
            b.observe(t, loss);
            loss -= 0.0001;
            assert_eq!(a.lr(t).to_bits(), b.lr(t).to_bits(), "t={t}");
        }
        assert_eq!(a.knees, b.knees);
        // Restoring knee state into a stateless schedule is rejected.
        let mut c = Constant(0.1);
        assert!(c.load_state_dict(&sd).is_err());
        assert!(c.load_state_dict(&StateDict::new()).is_ok());
    }

    #[test]
    fn knee_point_respects_min_lr() {
        let mut s = KneePoint::new(0.1, 0.1, 0.9, 2, 1e-3);
        for t in 0..500 {
            s.observe(t, 1.0); // perpetual plateau
        }
        assert!(s.lr(500) >= 1e-3);
    }
}
