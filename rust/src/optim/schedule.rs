//! Learning-rate schedules, including the paper's knee-point scheduler
//! (§8.13): decay the LR when the EMA of the improvement rate drops below
//! β × the total improvement accumulated under the current LR.

use crate::util::stats::Ema;

/// A learning-rate schedule driven by step count and (optionally) observed
/// loss/metric values.
pub trait LrSchedule {
    /// The LR to use for step `t` (0-based).
    fn lr(&self, t: usize) -> f32;
    /// Feed an observation (training loss or eval metric) after step `t`.
    fn observe(&mut self, _t: usize, _value: f64) {}
}

/// Constant LR.
pub struct Constant(pub f32);

impl LrSchedule for Constant {
    fn lr(&self, _t: usize) -> f32 {
        self.0
    }
}

/// Piecewise decay at fixed steps: lr × factor at each milestone (the §8.9
/// ResNet schedule: decay by 2 at epochs 25,35,40,…).
pub struct StepDecay {
    pub base: f32,
    pub factor: f32,
    pub milestones: Vec<usize>,
}

impl LrSchedule for StepDecay {
    fn lr(&self, t: usize) -> f32 {
        let hits = self.milestones.iter().filter(|&&m| t >= m).count() as i32;
        self.base * self.factor.powi(hits)
    }
}

/// Linear warmup then polynomial (power-1) decay — the LAMB/BERT schedule.
pub struct WarmupLinear {
    pub base: f32,
    pub warmup: usize,
    pub total: usize,
}

impl LrSchedule for WarmupLinear {
    fn lr(&self, t: usize) -> f32 {
        if t < self.warmup {
            self.base * (t + 1) as f32 / self.warmup as f32
        } else if t >= self.total {
            0.0
        } else {
            self.base * (self.total - t) as f32 / (self.total - self.warmup) as f32
        }
    }
}

/// Knee-point scheduler (§8.13).
///
/// Tracks an EMA of the per-step improvement (loss decrease). A knee-point
/// is declared when that smoothed rate falls below `beta` × the *average*
/// rate since the current LR was adopted; the LR is then multiplied by
/// `decay` (with a cooldown so one knee can't trigger repeatedly).
pub struct KneePoint {
    base: f32,
    decay: f32,
    beta: f64,
    cooldown: usize,
    min_lr: f32,
    // state
    current: f32,
    rate_ema: Ema,
    since_change: usize,
    improvement_since_change: f64,
    last_value: Option<f64>,
    /// Steps at which knees were detected (observability/tests).
    pub knees: Vec<usize>,
}

impl KneePoint {
    pub fn new(base: f32, decay: f32, beta: f64, cooldown: usize, min_lr: f32) -> Self {
        KneePoint {
            base,
            decay,
            beta,
            cooldown,
            min_lr,
            current: base,
            rate_ema: Ema::new(0.9),
            since_change: 0,
            improvement_since_change: 0.0,
            last_value: None,
            knees: Vec::new(),
        }
    }
}

impl LrSchedule for KneePoint {
    fn lr(&self, _t: usize) -> f32 {
        self.current
    }

    fn observe(&mut self, t: usize, value: f64) {
        if let Some(prev) = self.last_value {
            let dec = (prev - value).max(0.0);
            self.improvement_since_change += dec;
            let rate = self.rate_ema.update(dec);
            self.since_change += 1;
            if self.since_change >= self.cooldown {
                let avg_rate =
                    self.improvement_since_change / self.since_change.max(1) as f64;
                if avg_rate > 0.0 && rate < self.beta * avg_rate {
                    // Knee: decay and reset the window.
                    self.current = (self.current * self.decay).max(self.min_lr);
                    self.knees.push(t);
                    self.since_change = 0;
                    self.improvement_since_change = 0.0;
                    self.rate_ema = Ema::new(0.9);
                }
            }
        }
        self.last_value = Some(value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Constant(0.1);
        assert_eq!(s.lr(0), 0.1);
        assert_eq!(s.lr(10_000), 0.1);
    }

    #[test]
    fn step_decay_applies_milestones() {
        let s = StepDecay { base: 1.0, factor: 0.5, milestones: vec![10, 20] };
        assert_eq!(s.lr(5), 1.0);
        assert_eq!(s.lr(10), 0.5);
        assert_eq!(s.lr(25), 0.25);
    }

    #[test]
    fn warmup_linear_shape() {
        let s = WarmupLinear { base: 1.0, warmup: 10, total: 110 };
        assert!(s.lr(0) < s.lr(5));
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
        assert!(s.lr(60) < 1.0);
        assert_eq!(s.lr(110), 0.0);
    }

    #[test]
    fn knee_point_decays_on_plateau() {
        let mut s = KneePoint::new(1.0, 0.5, 0.3, 10, 1e-4);
        let mut loss = 10.0;
        for t in 0..60 {
            s.observe(t, loss);
            loss -= 0.1; // steady improvement: no knee
        }
        assert!(s.knees.is_empty(), "knees={:?}", s.knees);
        for t in 60..120 {
            s.observe(t, loss);
            loss -= 0.0001; // plateau: knee expected
        }
        assert!(!s.knees.is_empty());
        assert!(s.lr(120) <= 0.5);
    }

    #[test]
    fn knee_point_respects_min_lr() {
        let mut s = KneePoint::new(0.1, 0.1, 0.9, 2, 1e-3);
        for t in 0..500 {
            s.observe(t, 1.0); // perpetual plateau
        }
        assert!(s.lr(500) >= 1e-3);
    }
}
