//! First-order baselines: SGD with momentum, Adam, and LAMB.
//!
//! LAMB (You et al., 2019) is the paper's first-order baseline for BERT
//! (Tables 2/3); SGD-momentum is the ResNet baseline (§8.1). Each exposes
//! both the [`Optimizer`] interface (stand-alone baseline) and an
//! `apply`-style entry point so MKOR/MKOR-H can use it as the line-14
//! backend on *preconditioned* deltas.

use crate::checkpoint::snapshot::{matrices_from, put_matrices, put_vectors, vectors_from};
use crate::checkpoint::{Checkpointable, StateDict, StateError};
use crate::linalg::Matrix;
use crate::model::{Capture, Dense, LayerShape};
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;

/// SGD with heavy-ball momentum: `v ← m·v + Δ; W ← W − lr·v`.
pub struct SgdMomentum {
    momentum: f32,
    vel_w: Vec<Matrix>,
    vel_b: Vec<Vec<f32>>,
    t: usize,
}

impl SgdMomentum {
    pub fn new(shapes: &[LayerShape], momentum: f32) -> Self {
        SgdMomentum {
            momentum,
            vel_w: shapes.iter().map(|s| Matrix::zeros(s.d_out, s.d_in)).collect(),
            vel_b: shapes.iter().map(|s| vec![0.0; s.d_out]).collect(),
            t: 0,
        }
    }

    /// Apply deltas (gradients or preconditioned gradients) with momentum.
    pub fn apply(&mut self, layers: &mut [Dense], deltas: &[Matrix], dbs: &[Vec<f32>], lr: f32) {
        for i in 0..layers.len() {
            let v = &mut self.vel_w[i];
            for (vv, &d) in v.data_mut().iter_mut().zip(deltas[i].data()) {
                *vv = self.momentum * *vv + d;
            }
            for (w, &vv) in layers[i].w.data_mut().iter_mut().zip(v.data()) {
                *w -= lr * vv;
            }
            let vb = &mut self.vel_b[i];
            for ((bv, vv), &d) in layers[i].bias.iter_mut().zip(vb.iter_mut()).zip(&dbs[i]) {
                *vv = self.momentum * *vv + d;
                *bv -= lr * *vv;
            }
        }
        self.t += 1;
    }

    pub fn state_bytes(&self) -> usize {
        self.vel_w.iter().map(|m| m.len() * 4).sum::<usize>()
            + self.vel_b.iter().map(|v| v.len() * 4).sum::<usize>()
    }
}

impl Checkpointable for SgdMomentum {
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t);
        put_matrices(&mut sd, "vel_w", self.vel_w.iter());
        put_vectors(&mut sd, "vel_b", self.vel_b.iter());
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(&["t", "vel_w", "vel_b"], &[])?;
        let shapes: Vec<(usize, usize)> =
            self.vel_w.iter().map(|m| (m.rows(), m.cols())).collect();
        let lens: Vec<usize> = self.vel_b.iter().map(Vec::len).collect();
        self.vel_w = matrices_from(state, "vel_w", &shapes)?;
        self.vel_b = vectors_from(state, "vel_b", &lens)?;
        self.t = state.usizev("t")?;
        Ok(())
    }
}

impl Optimizer for SgdMomentum {
    fn name(&self) -> &str {
        "sgd"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        let t0 = std::time::Instant::now();
        let deltas: Vec<Matrix> = caps.iter().map(|c| c.dw.clone()).collect();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        self.apply(layers, &deltas, &dbs, lr);
        timer.add("update", t0.elapsed());
    }

    fn state_bytes(&self) -> usize {
        SgdMomentum::state_bytes(self)
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Sgd { momentum: self.momentum }
    }
}

/// Adam/LAMB moment hyperparameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-6, weight_decay: 0.0 }
    }
}

/// Per-layer Adam state.
struct Moments {
    m_w: Matrix,
    v_w: Matrix,
    m_b: Vec<f32>,
    v_b: Vec<f32>,
}

/// Adam (Kingma & Ba).
pub struct Adam {
    cfg: AdamConfig,
    state: Vec<Moments>,
    t: usize,
}

impl Adam {
    pub fn new(shapes: &[LayerShape], cfg: AdamConfig) -> Self {
        Adam {
            cfg,
            state: shapes
                .iter()
                .map(|s| Moments {
                    m_w: Matrix::zeros(s.d_out, s.d_in),
                    v_w: Matrix::zeros(s.d_out, s.d_in),
                    m_b: vec![0.0; s.d_out],
                    v_b: vec![0.0; s.d_out],
                })
                .collect(),
            t: 0,
        }
    }

    /// Compute the bias-corrected Adam direction for one layer's delta.
    fn adam_direction(&mut self, i: usize, delta: &Matrix, db: &[f32]) -> (Matrix, Vec<f32>) {
        let AdamConfig { beta1, beta2, eps, .. } = self.cfg;
        let t = (self.t + 1) as i32;
        let bc1 = 1.0 - beta1.powi(t);
        let bc2 = 1.0 - beta2.powi(t);
        let st = &mut self.state[i];
        let mut dir = Matrix::zeros(delta.rows(), delta.cols());
        for (((dv, m), v), &g) in dir
            .data_mut()
            .iter_mut()
            .zip(st.m_w.data_mut())
            .zip(st.v_w.data_mut())
            .zip(delta.data())
        {
            *m = beta1 * *m + (1.0 - beta1) * g;
            *v = beta2 * *v + (1.0 - beta2) * g * g;
            *dv = (*m / bc1) / ((*v / bc2).sqrt() + eps);
        }
        let mut dirb = vec![0.0f32; db.len()];
        for (((dv, m), v), &g) in dirb
            .iter_mut()
            .zip(st.m_b.iter_mut())
            .zip(st.v_b.iter_mut())
            .zip(db)
        {
            *m = beta1 * *m + (1.0 - beta1) * g;
            *v = beta2 * *v + (1.0 - beta2) * g * g;
            *dv = (*m / bc1) / ((*v / bc2).sqrt() + eps);
        }
        (dir, dirb)
    }

    pub fn apply(&mut self, layers: &mut [Dense], deltas: &[Matrix], dbs: &[Vec<f32>], lr: f32) {
        let wd = self.cfg.weight_decay;
        for i in 0..layers.len() {
            let (mut dir, dirb) = self.adam_direction(i, &deltas[i], &dbs[i]);
            if wd > 0.0 {
                for (d, &w) in dir.data_mut().iter_mut().zip(layers[i].w.data()) {
                    *d += wd * w;
                }
            }
            for (w, &d) in layers[i].w.data_mut().iter_mut().zip(dir.data()) {
                *w -= lr * d;
            }
            for (b, &d) in layers[i].bias.iter_mut().zip(&dirb) {
                *b -= lr * d;
            }
        }
        self.t += 1;
    }

    pub fn state_bytes(&self) -> usize {
        self.state
            .iter()
            .map(|s| (s.m_w.len() + s.v_w.len() + s.m_b.len() + s.v_b.len()) * 4)
            .sum()
    }
}

impl Checkpointable for Adam {
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t);
        put_matrices(&mut sd, "m_w", self.state.iter().map(|s| &s.m_w));
        put_matrices(&mut sd, "v_w", self.state.iter().map(|s| &s.v_w));
        put_vectors(&mut sd, "m_b", self.state.iter().map(|s| &s.m_b));
        put_vectors(&mut sd, "v_b", self.state.iter().map(|s| &s.v_b));
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(&["t", "m_w", "v_w", "m_b", "v_b"], &[])?;
        let shapes: Vec<(usize, usize)> =
            self.state.iter().map(|s| (s.m_w.rows(), s.m_w.cols())).collect();
        let lens: Vec<usize> = self.state.iter().map(|s| s.m_b.len()).collect();
        let m_w = matrices_from(state, "m_w", &shapes)?;
        let v_w = matrices_from(state, "v_w", &shapes)?;
        let m_b = vectors_from(state, "m_b", &lens)?;
        let v_b = vectors_from(state, "v_b", &lens)?;
        for ((((st, m), v), mb), vb) in
            self.state.iter_mut().zip(m_w).zip(v_w).zip(m_b).zip(v_b)
        {
            st.m_w = m;
            st.v_w = v;
            st.m_b = mb;
            st.v_b = vb;
        }
        self.t = state.usizev("t")?;
        Ok(())
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &str {
        "adam"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        let t0 = std::time::Instant::now();
        let deltas: Vec<Matrix> = caps.iter().map(|c| c.dw.clone()).collect();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        self.apply(layers, &deltas, &dbs, lr);
        timer.add("update", t0.elapsed());
    }

    fn state_bytes(&self) -> usize {
        Adam::state_bytes(self)
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Adam(self.cfg)
    }
}

/// LAMB: Adam direction with a per-layer trust ratio `‖W‖/‖dir‖`.
pub struct Lamb {
    inner: Adam,
    t: usize,
}

impl Lamb {
    pub fn new(shapes: &[LayerShape], cfg: AdamConfig) -> Self {
        Lamb { inner: Adam::new(shapes, cfg), t: 0 }
    }

    pub fn apply(&mut self, layers: &mut [Dense], deltas: &[Matrix], dbs: &[Vec<f32>], lr: f32) {
        let wd = self.inner.cfg.weight_decay;
        for i in 0..layers.len() {
            let (mut dir, dirb) = self.inner.adam_direction(i, &deltas[i], &dbs[i]);
            if wd > 0.0 {
                for (d, &w) in dir.data_mut().iter_mut().zip(layers[i].w.data()) {
                    *d += wd * w;
                }
            }
            // Trust ratio, clipped to [0, 10] like NVIDIA's Fused LAMB.
            let wnorm = layers[i].w.fro_norm();
            let dnorm = dir.fro_norm();
            let ratio = if wnorm > 0.0 && dnorm > 0.0 {
                ((wnorm / dnorm) as f32).min(10.0)
            } else {
                1.0
            };
            for (w, &d) in layers[i].w.data_mut().iter_mut().zip(dir.data()) {
                *w -= lr * ratio * d;
            }
            for (b, &d) in layers[i].bias.iter_mut().zip(&dirb) {
                *b -= lr * d;
            }
        }
        self.inner.t += 1;
        self.t += 1;
    }

    pub fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }
}

impl Checkpointable for Lamb {
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t).put_dict("inner", self.inner.state_dict());
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(&["t", "inner"], &[])?;
        self.inner.load_state_dict(state.dict("inner")?)?;
        self.t = state.usizev("t")?;
        Ok(())
    }
}

impl Optimizer for Lamb {
    fn name(&self) -> &str {
        "lamb"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        let t0 = std::time::Instant::now();
        let deltas: Vec<Matrix> = caps.iter().map(|c| c.dw.clone()).collect();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        self.apply(layers, &deltas, &dbs, lr);
        timer.add("update", t0.elapsed());
    }

    fn state_bytes(&self) -> usize {
        Lamb::state_bytes(self)
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Lamb(self.inner.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::model::Activation;
    use crate::util::Rng;

    fn quadratic_losses(opt_name: &str, steps: usize, lr: f32) -> f64 {
        // min ‖Wx − y‖² from zero init.
        let mut rng = Rng::new(31);
        let shapes = [LayerShape::new(6, 4)];
        let x = Matrix::randn(6, 32, 1.0, &mut rng);
        let w_true = Matrix::randn(4, 6, 1.0, &mut rng);
        let y = ops::matmul(&w_true, &x);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        layers[0].w = Matrix::zeros(4, 6);
        let mut opt = OptimizerSpec::parse(opt_name).unwrap().build(&shapes);
        let mut timer = PhaseTimer::new();
        let mut loss = f64::INFINITY;
        for _ in 0..steps {
            let pred = ops::matmul(&layers[0].w, &x);
            let mut err = pred.clone();
            err.blend(1.0, -1.0, &y);
            loss = err.fro_norm().powi(2) / 32.0;
            let mut g = err;
            g.scale(2.0 / 32.0);
            let dw = ops::matmul_nt(&g, &x);
            let cap = Capture { a: x.clone(), g, dw, db: vec![0.0; 4] };
            opt.step(&mut layers, std::slice::from_ref(&cap), lr, &mut timer);
        }
        loss
    }

    #[test]
    fn sgd_momentum_reduces_quadratic_loss() {
        assert!(quadratic_losses("sgd", 100, 0.05) < 0.05);
    }

    #[test]
    fn adam_reduces_quadratic_loss() {
        assert!(quadratic_losses("adam", 200, 0.05) < 0.05);
    }

    #[test]
    fn lamb_reduces_quadratic_loss() {
        // LAMB's trust ratio throttles steps while ‖W‖ is small (zero
        // init), so it needs more steps than Adam on this toy problem; the
        // contract is a large decrease, not a race.
        let final_loss = quadratic_losses("lamb", 400, 0.05);
        let init_loss = quadratic_losses("lamb", 1, 0.0);
        assert!(
            final_loss < 0.1 * init_loss,
            "final {final_loss} vs init {init_loss}"
        );
    }

    #[test]
    fn momentum_accumulates() {
        let shapes = [LayerShape::new(1, 1)];
        let mut rng = Rng::new(1);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        layers[0].w[(0, 0)] = 0.0;
        let mut sgd = SgdMomentum::new(&shapes, 0.5);
        let delta = vec![Matrix::from_rows(&[&[1.0f32]])];
        let dbs = vec![vec![0.0f32]];
        sgd.apply(&mut layers, &delta, &dbs, 1.0);
        assert!((layers[0].w[(0, 0)] + 1.0).abs() < 1e-6); // -1
        sgd.apply(&mut layers, &delta, &dbs, 1.0);
        // velocity = 0.5*1 + 1 = 1.5 → w = -2.5
        assert!((layers[0].w[(0, 0)] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_sign_like() {
        let shapes = [LayerShape::new(2, 1)];
        let mut rng = Rng::new(2);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        layers[0].w = Matrix::zeros(1, 2);
        let mut adam = Adam::new(&shapes, AdamConfig::default());
        let delta = vec![Matrix::from_rows(&[&[10.0f32, -0.001]])];
        let dbs = vec![vec![0.0f32]];
        adam.apply(&mut layers, &delta, &dbs, 0.1);
        // Both coordinates move ≈ lr in magnitude regardless of scale.
        assert!((layers[0].w[(0, 0)] + 0.1).abs() < 0.02);
        assert!((layers[0].w[(0, 1)] - 0.1).abs() < 0.02);
    }

    #[test]
    fn lamb_trust_ratio_bounds_step() {
        let shapes = [LayerShape::new(1, 1)];
        let mut rng = Rng::new(3);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        layers[0].w[(0, 0)] = 1e-3; // tiny weight norm → tiny trust ratio
        let mut lamb = Lamb::new(&shapes, AdamConfig::default());
        let delta = vec![Matrix::from_rows(&[&[100.0f32]])];
        let dbs = vec![vec![0.0f32]];
        lamb.apply(&mut layers, &delta, &dbs, 0.1);
        // Step is ≤ lr·ratio·1 ≈ lr·(1e-3/1) — tiny, unlike Adam's 0.1.
        assert!(layers[0].w[(0, 0)].abs() < 1e-2);
    }

    #[test]
    fn moment_state_roundtrip_is_bitwise() {
        // Warm the moments up, snapshot, restore into a fresh optimizer,
        // and check the next update is bit-identical — the invariant the
        // checkpoint subsystem's resume equivalence rests on.
        let shapes = [LayerShape::new(3, 2)];
        let mut rng = Rng::new(7);
        let delta = vec![Matrix::randn(2, 3, 1.0, &mut rng)];
        let dbs = vec![vec![0.3f32, -0.2]];
        let mut warm = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];

        let mut a = Adam::new(&shapes, AdamConfig::default());
        for _ in 0..3 {
            a.apply(&mut warm, &delta, &dbs, 0.05);
        }
        let sd = a.state_dict();
        let mut b = Adam::new(&shapes, AdamConfig::default());
        b.load_state_dict(&sd).unwrap();
        assert_eq!(b.state_dict(), sd);
        // One post-restore step from identical weights matches exactly.
        let mut la = warm.clone();
        let mut lb = warm.clone();
        a.apply(&mut la, &delta, &dbs, 0.05);
        b.apply(&mut lb, &delta, &dbs, 0.05);
        assert_eq!(la[0].w.data(), lb[0].w.data());
        assert_eq!(la[0].bias, lb[0].bias);
        // Shape mismatches are rejected.
        let mut wrong = Adam::new(&[LayerShape::new(4, 2)], AdamConfig::default());
        assert!(wrong.load_state_dict(&sd).is_err());
        // SGD and LAMB round-trip too.
        let mut s = SgdMomentum::new(&shapes, 0.9);
        s.apply(&mut warm, &delta, &dbs, 0.1);
        let ssd = s.state_dict();
        let mut s2 = SgdMomentum::new(&shapes, 0.9);
        s2.load_state_dict(&ssd).unwrap();
        assert_eq!(s2.state_dict(), ssd);
        let mut l = Lamb::new(&shapes, AdamConfig::default());
        l.apply(&mut warm, &delta, &dbs, 0.1);
        let lsd = l.state_dict();
        let mut l2 = Lamb::new(&shapes, AdamConfig::default());
        l2.load_state_dict(&lsd).unwrap();
        assert_eq!(l2.state_dict(), lsd);
    }

    #[test]
    fn state_bytes_scale_with_params() {
        let shapes = [LayerShape::new(10, 10)];
        let sgd = SgdMomentum::new(&shapes, 0.9);
        let adam = Adam::new(&shapes, AdamConfig::default());
        // Adam keeps 2 moments vs SGD's 1.
        assert_eq!(adam.state_bytes(), 2 * sgd.state_bytes());
    }
}
