//! MKOR — Algorithm 1 of the paper, exactly.
//!
//! Per second-order layer `m`, MKOR maintains the *inverses* of the left and
//! right Kronecker factors directly (initialized to identity, so training
//! starts as a first-order method — §8.7) and updates them with the
//! Sherman–Morrison-based rank-1 recurrence:
//!
//! ```text
//! L_t⁻¹ = γ L̂⁻¹ + (1−γ) / (γ² (1 + γ(1−γ) gᵀ L̂⁻¹ g)) · (L̂⁻¹g)(L̂⁻¹g)ᵀ   (Eq. 5)
//! R_t⁻¹ = γ R̂⁻¹ + (1−γ) / (γ² (1 + γ(1−γ) aᵀ R̂⁻¹ a)) · (R̂⁻¹a)(R̂⁻¹a)ᵀ   (Eq. 6)
//! ```
//!
//! where `g`/`a` are the batch means of the input gradients/activations
//! (the rank-1 covariance approximations, lines 2–3) and `L̂⁻¹`/`R̂⁻¹` are
//! the stabilized factors (lines 5–6). Note the recurrence *adds* a PSD
//! rank-1 term to a scaled PD matrix, which is why Lemma 3.1's
//! positive-definiteness proof is unconditional — there is no subtraction
//! and no division by a quantity that can vanish. Cost: one matvec + one
//! rank-1 update = O(d²), vs O(d³) for explicit inversion.
//!
//! Gradients are then preconditioned `ΔW = L⁻¹ ∇W R⁻¹` (line 9) and rescaled
//! to the raw gradient norm (line 10) before the first-order backend applies
//! them (line 14).

use crate::checkpoint::snapshot::{matrices_from, put_matrices};
use crate::checkpoint::{Checkpointable, StateDict, StateError};
use crate::linalg::half::{self, HalfKind};
use crate::linalg::{ops, Matrix};
use crate::model::{Capture, Dense, LayerShape};
use crate::obs::{self, EventKind, TraceEvent};
use crate::optim::first_order::{Adam, AdamConfig, Lamb, SgdMomentum};
use crate::optim::rescale::rescale_to_gradient_norm;
use crate::optim::stabilizer::{stabilize, StabilizerConfig};
use crate::optim::{Backend, Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;

/// MKOR hyperparameters (paper defaults: γ close to 1, f = 10, bf16 sync).
#[derive(Clone, Debug, PartialEq)]
pub struct MkorConfig {
    /// Momentum γ of the factor recurrence (Equations 5/6).
    pub gamma: f32,
    /// Factor-update period f ("inversion frequency" is 1/f). The paper
    /// uses f=10 where KAISA needs 50–200 (§8.9).
    pub inv_freq: usize,
    /// Norm-based stabilizer (ε, ζ).
    pub stabilizer: StabilizerConfig,
    /// Synchronize rank-1 vectors in half precision (Table 1's ÷2).
    pub half_sync: Option<HalfKind>,
    /// First-order backend for line 14.
    pub backend: Backend,
    /// Backend momentum (SGD backend only; `backend.momentum` in the
    /// grammar aliases this key).
    pub momentum: f32,
    /// Adam/LAMB backend hyperparameters (`backend.beta1`, `backend.beta2`,
    /// `backend.eps`, `backend.wd` in the grammar); ignored by the SGD
    /// backend, which only has `momentum`.
    pub backend_cfg: AdamConfig,
    /// Layers to treat second-order; `None` = all.
    pub second_order_layers: Option<Vec<bool>>,
}

impl Default for MkorConfig {
    fn default() -> Self {
        MkorConfig {
            gamma: 0.99,
            inv_freq: 10,
            stabilizer: StabilizerConfig::default(),
            half_sync: Some(HalfKind::Bf16),
            backend: Backend::SgdMomentum,
            momentum: 0.9,
            backend_cfg: AdamConfig::default(),
            second_order_layers: None,
        }
    }
}

/// Per-layer factor state.
struct LayerState {
    l_inv: Matrix,
    r_inv: Matrix,
    /// Scratch for `J⁻¹v` matvecs (no allocation in the hot loop).
    scratch_out: Vec<f32>,
    scratch_in: Vec<f32>,
    /// Scratch for the two-matmul preconditioning.
    scratch_gr: Matrix,
    scratch_delta: Matrix,
}

enum BackendState {
    Sgd(SgdMomentum),
    Adam(Adam),
    Lamb(Lamb),
}

/// The MKOR optimizer over a fixed layer-shape list.
pub struct Mkor {
    cfg: MkorConfig,
    layers: Vec<LayerState>,
    shapes: Vec<LayerShape>,
    backend: BackendState,
    t: usize,
    last_sync_bytes: usize,
    /// Stabilizer trigger count (observability / tests).
    pub stabilizer_triggers: usize,
}

impl Mkor {
    pub fn new(shapes: &[LayerShape], cfg: MkorConfig) -> Self {
        let layers = shapes
            .iter()
            .map(|s| LayerState {
                l_inv: Matrix::identity(s.d_out),
                r_inv: Matrix::identity(s.d_in),
                scratch_out: vec![0.0; s.d_out],
                scratch_in: vec![0.0; s.d_in],
                scratch_gr: Matrix::zeros(s.d_out, s.d_in),
                scratch_delta: Matrix::zeros(s.d_out, s.d_in),
            })
            .collect();
        let backend = match cfg.backend {
            Backend::SgdMomentum => BackendState::Sgd(SgdMomentum::new(shapes, cfg.momentum)),
            Backend::Adam => BackendState::Adam(Adam::new(shapes, cfg.backend_cfg)),
            Backend::Lamb => BackendState::Lamb(Lamb::new(shapes, cfg.backend_cfg)),
        };
        Mkor {
            cfg,
            layers,
            shapes: shapes.to_vec(),
            backend,
            t: 0,
            last_sync_bytes: 0,
            stabilizer_triggers: 0,
        }
    }

    /// Is this a factor-update step? (line 1 gating + inversion frequency.)
    pub fn is_factor_step(&self, t: usize) -> bool {
        t % self.cfg.inv_freq == 0
    }

    fn second_order(&self, layer: usize) -> bool {
        self.cfg
            .second_order_layers
            .as_ref()
            .map(|v| v[layer])
            .unwrap_or(true)
    }

    /// The Eq. 5/6 recurrence applied to one factor inverse, given the
    /// (already synchronized) rank-1 vector `v`. Public so the XLA
    /// cross-check test can drive it directly against the Pallas kernel.
    pub fn sm_update(inv: &mut Matrix, v: &[f32], gamma: f32, scratch: &mut [f32]) {
        debug_assert_eq!(inv.rows(), v.len());
        // u = J⁻¹ v  (O(d²))
        ops::matvec_into(inv, v, scratch);
        // s = vᵀ u
        let s = ops::dot(v, scratch);
        let g = gamma as f64;
        let denom = g * g * (1.0 + g * (1.0 - g) * s);
        let coef = ((1.0 - g) / denom) as f32;
        // J⁻¹ ← γ J⁻¹ + coef · u uᵀ   (O(d²), fused single pass)
        ops::scaled_rank1_update(inv, gamma, coef, scratch);
    }

    /// Batch-mean rank-1 vectors for a capture (lines 2–3), optionally
    /// round-tripped through half precision to model the quantized
    /// all-reduce the real system performs.
    fn rank1_vectors(&self, cap: &Capture) -> (Vec<f32>, Vec<f32>) {
        let mut a = ops::col_mean(&cap.a);
        let mut g = ops::col_mean(&cap.g);
        if let Some(kind) = self.cfg.half_sync {
            a = half::roundtrip(&a, kind);
            g = half::roundtrip(&g, kind);
        }
        (a, g)
    }

    /// Read-only view of a layer's factor inverses (tests, Fig. 8 analog).
    pub fn factors(&self, layer: usize) -> (&Matrix, &Matrix) {
        (&self.layers[layer].l_inv, &self.layers[layer].r_inv)
    }

    pub fn config(&self) -> &MkorConfig {
        &self.cfg
    }
}

impl Checkpointable for Mkor {
    fn state_dict(&self) -> StateDict {
        // The factor inverses ARE the optimizer (they accumulate every
        // rank-1 update since step 0); scratch buffers are per-step
        // outputs and carry no state.
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t)
            .put_usize("stabilizer_triggers", self.stabilizer_triggers)
            .put_usize("last_sync_bytes", self.last_sync_bytes);
        put_matrices(&mut sd, "l_inv", self.layers.iter().map(|l| &l.l_inv));
        put_matrices(&mut sd, "r_inv", self.layers.iter().map(|l| &l.r_inv));
        let backend = match &self.backend {
            BackendState::Sgd(b) => b.state_dict(),
            BackendState::Adam(b) => b.state_dict(),
            BackendState::Lamb(b) => b.state_dict(),
        };
        sd.put_dict("backend", backend);
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(
            &["t", "stabilizer_triggers", "last_sync_bytes", "l_inv", "r_inv", "backend"],
            &[],
        )?;
        let l_shapes: Vec<(usize, usize)> =
            self.shapes.iter().map(|s| (s.d_out, s.d_out)).collect();
        let r_shapes: Vec<(usize, usize)> =
            self.shapes.iter().map(|s| (s.d_in, s.d_in)).collect();
        let l_inv = matrices_from(state, "l_inv", &l_shapes)?;
        let r_inv = matrices_from(state, "r_inv", &r_shapes)?;
        for ((layer, l), r) in self.layers.iter_mut().zip(l_inv).zip(r_inv) {
            layer.l_inv = l;
            layer.r_inv = r;
        }
        let backend = state.dict("backend")?;
        match &mut self.backend {
            BackendState::Sgd(b) => b.load_state_dict(backend)?,
            BackendState::Adam(b) => b.load_state_dict(backend)?,
            BackendState::Lamb(b) => b.load_state_dict(backend)?,
        }
        self.t = state.usizev("t")?;
        self.stabilizer_triggers = state.usizev("stabilizer_triggers")?;
        self.last_sync_bytes = state.usizev("last_sync_bytes")?;
        Ok(())
    }
}

impl Optimizer for Mkor {
    fn name(&self) -> &str {
        "mkor"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        assert_eq!(layers.len(), self.layers.len());
        assert_eq!(caps.len(), self.layers.len());
        let factor_step = self.is_factor_step(self.t);
        self.last_sync_bytes = 0;

        let mut deltas: Vec<Matrix> = Vec::with_capacity(caps.len());
        for (idx, cap) in caps.iter().enumerate() {
            let second_order = self.second_order(idx);
            // ---- factor update (lines 2–8) -----------------------------
            if second_order && factor_step {
                let _factor_span = obs::span::span("factor");
                let t0 = std::time::Instant::now();
                let (a, g) = self.rank1_vectors(cap);
                let st = &mut self.layers[idx];
                // Sync accounting: 2d elements, 2 or 4 bytes each.
                let elem = if self.cfg.half_sync.is_some() { 2 } else { 4 };
                self.last_sync_bytes += (a.len() + g.len()) * elem;
                // Lines 5–6: norm-based stabilizer.
                let r1 = stabilize(&mut st.l_inv, &self.cfg.stabilizer);
                let r2 = stabilize(&mut st.r_inv, &self.cfg.stabilizer);
                self.stabilizer_triggers += r1.triggered as usize + r2.triggered as usize;
                // Lines 7–8: SM-based factor inversion.
                Mkor::sm_update(&mut st.l_inv, &g, self.cfg.gamma, &mut st.scratch_out);
                Mkor::sm_update(&mut st.r_inv, &a, self.cfg.gamma, &mut st.scratch_in);
                // One elapsed sample feeds the phase timer, the trace event
                // and the histogram, so the three always agree on the same
                // update (they used to sample the clock independently).
                let factor_elapsed = t0.elapsed();
                timer.add("factor", factor_elapsed);
                if obs::enabled() {
                    if r1.triggered || r2.triggered {
                        obs::emit(
                            TraceEvent::new(EventKind::StabilizerTrigger)
                                .num("step", self.t as f64)
                                .num("layer", idx as f64)
                                .num("left", u8::from(r1.triggered) as f64)
                                .num("right", u8::from(r2.triggered) as f64)
                                .maybe_under(obs::span::current()),
                        );
                    }
                    obs::emit(
                        TraceEvent::new(EventKind::InverseUpdate)
                            .num("step", self.t as f64)
                            .num("layer", idx as f64)
                            .num("secs", factor_elapsed.as_secs_f64())
                            .maybe_under(obs::span::current()),
                    );
                    obs::registry::with_global(|r| {
                        r.inc("mkor.inverse_updates", 1);
                        let trig = u64::from(r1.triggered) + u64::from(r2.triggered);
                        if trig > 0 {
                            r.inc("mkor.stabilizer_triggers", trig);
                        }
                        r.observe("mkor.factor_secs", factor_elapsed.as_secs_f64());
                    });
                }
            }
            // ---- precondition + rescale (lines 9–10) -------------------
            let st = &mut self.layers[idx];
            let delta = if second_order {
                let _precond_span = obs::span::span("precond");
                let t0 = std::time::Instant::now();
                ops::matmul_into(&cap.dw, &st.r_inv, &mut st.scratch_gr);
                ops::matmul_into(&st.l_inv, &st.scratch_gr, &mut st.scratch_delta);
                let mut delta = st.scratch_delta.clone();
                rescale_to_gradient_norm(&mut delta, &cap.dw);
                timer.add("precond", t0.elapsed());
                delta
            } else {
                cap.dw.clone() // line 12
            };
            deltas.push(delta);
        }

        // ---- line 14: backend weight update ----------------------------
        let _update_span = obs::span::span("update");
        let t0 = std::time::Instant::now();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        match &mut self.backend {
            BackendState::Sgd(b) => b.apply(layers, &deltas, &dbs, lr),
            BackendState::Adam(b) => b.apply(layers, &deltas, &dbs, lr),
            BackendState::Lamb(b) => b.apply(layers, &deltas, &dbs, lr),
        }
        timer.add("update", t0.elapsed());
        self.t += 1;
    }

    fn state_bytes(&self) -> usize {
        // Factor inverses are held as f32 `Matrix` regardless of the wire
        // format — `half_sync` quantizes only the 2d rank-1 vectors that
        // cross the network, never L⁻¹/R⁻¹ themselves — so the inverses
        // always count at 4 bytes. (Table 1's modeled ÷2 applies to the
        // paper's half-precision *storage* variant of Lemma 3.2; this
        // implementation keeps resident factors in f32 for the bitwise
        // checkpoint/restore guarantees, and the ÷2 shows up only in
        // `sync_bytes_last_step`.)
        let vec_elem = if self.cfg.half_sync.is_some() { 2 } else { 4 };
        let bytes: usize = self
            .shapes
            .iter()
            .map(|s| {
                (s.d_out * s.d_out + s.d_in * s.d_in) * 4
                    + (s.d_out + s.d_in) * vec_elem
            })
            .sum();
        let backend = match &self.backend {
            BackendState::Sgd(b) => b.state_bytes(),
            BackendState::Adam(b) => b.state_bytes(),
            BackendState::Lamb(b) => b.state_bytes(),
        };
        bytes + backend
    }

    fn sync_bytes_last_step(&self) -> usize {
        self.last_sync_bytes
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Mkor(self.cfg.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::is_positive_definite;
    use crate::util::Rng;

    fn toy_capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
        let a = Matrix::randn(shape.d_in, b, 1.0, rng);
        let g = Matrix::randn(shape.d_out, b, 1.0, rng);
        let mut dw = ops::matmul_nt(&g, &a);
        dw.scale(1.0 / b as f32);
        let db = vec![0.0; shape.d_out];
        Capture { a, g, dw, db }
    }

    #[test]
    fn sm_update_matches_dense_recurrence() {
        // Eq. 5 computed via the O(d²) path must equal the same formula
        // evaluated with dense matrix products.
        let mut rng = Rng::new(3);
        let n = 10;
        let mut inv = Matrix::rand_spd(n, 0.5, &mut rng);
        let dense = inv.clone();
        let v: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let gamma = 0.95f32;

        let mut scratch = vec![0.0; n];
        Mkor::sm_update(&mut inv, &v, gamma, &mut scratch);

        // Dense evaluation.
        let u = ops::matvec(&dense, &v);
        let s = ops::dot(&v, &u);
        let g = gamma as f64;
        let coef = ((1.0 - g) / (g * g * (1.0 + g * (1.0 - g) * s))) as f32;
        let mut want = dense.clone();
        want.scale(gamma);
        let mut uu = ops::outer(&u, &u);
        uu.scale(coef);
        want.blend(1.0, 1.0, &uu);

        assert!(inv.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn lemma_3_1_factors_stay_positive_definite() {
        // Property test (seeded sweep): from random PD starts, arbitrary
        // rank-1 vectors and γ ∈ (0.9, 1), the recurrence preserves PD at
        // every step. (Mathematically PD holds for any γ ∈ (0,1); in f32
        // the recurrence's unbounded growth along repeated directions —
        // the very thing the norm-based stabilizer exists to bound —
        // eventually overflows, so we run the stabilized loop exactly as
        // Algorithm 1 lines 5–8 do.)
        use crate::optim::stabilizer::{stabilize, StabilizerConfig};
        let mut rng = Rng::new(7);
        let cfg = StabilizerConfig::default();
        for case in 0..25 {
            let n = 4 + (case % 8);
            let mut inv = Matrix::rand_spd(n, 0.2, &mut rng);
            let gamma = 0.9 + 0.09 * rng.next_f32();
            let mut scratch = vec![0.0; n];
            for step in 0..50 {
                stabilize(&mut inv, &cfg);
                let v: Vec<f32> = (0..n).map(|_| rng.gaussian_f32() * 2.0).collect();
                Mkor::sm_update(&mut inv, &v, gamma, &mut scratch);
                assert!(inv.all_finite(), "case {case} step {step} overflowed");
                assert!(
                    is_positive_definite(&inv),
                    "case {case} step {step} lost PD"
                );
            }
        }
    }

    #[test]
    fn unstabilized_recurrence_grows_without_bound() {
        // Documents the behaviour that motivates lines 5–6 of Algorithm 1:
        // Eq. 5 *adds* a PSD rank-1 term every update, so with repeated
        // data directions the inverse factor grows monotonically and, left
        // unstabilized, explodes. The norm-based stabilizer is therefore a
        // required component, not an optional safeguard.
        let n = 6;
        let v: Vec<f32> = vec![1.0; n];
        let gamma = 0.9f32;
        let mut inv = Matrix::identity(n);
        let mut scratch = vec![0.0; n];
        let mut prev_gain = 0.0f64;
        let mut grew = 0;
        for step in 0..60 {
            Mkor::sm_update(&mut inv, &v, gamma, &mut scratch);
            if !inv.all_finite() {
                // Explosion observed — exactly the failure mode documented.
                assert!(step > 5, "overflowed suspiciously early");
                return;
            }
            let gain = ops::dot(&v, &ops::matvec(&inv, &v));
            if gain > prev_gain {
                grew += 1;
            }
            prev_gain = gain;
        }
        // If it survives 60 steps, growth along v must have been monotone.
        assert!(grew >= 55, "gain grew only {grew}/60 steps");
        assert!(prev_gain > ops::dot(&v, &v));
    }

    #[test]
    fn identity_start_means_first_step_is_sgd_direction() {
        // Factors start at I, so before any factor update the
        // preconditioned gradient equals the raw gradient (§8.7).
        let shapes = [LayerShape::new(5, 4)];
        let mut cfg = MkorConfig::default();
        cfg.inv_freq = 1000; // no factor update on step 0? (t=0 IS an update step)
        cfg.half_sync = None;
        let mut rng = Rng::new(11);
        let mut opt = Mkor::new(&shapes, cfg);
        // Factor update at t=0 changes factors but only slightly (γ=0.99);
        // check the preconditioned direction stays ≈ gradient direction.
        let mut layers = vec![Dense::init(shapes[0], crate::model::Activation::Linear, &mut rng)];
        let w0 = layers[0].w.clone();
        let cap = toy_capture(shapes[0], 8, &mut rng);
        let mut timer = PhaseTimer::new();
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.1, &mut timer);
        // Update should be ≈ lr * dw (momentum buffer = dw on first step).
        let mut diff = w0.clone();
        diff.blend(1.0, -1.0, &layers[0].w); // w0 - w1 = lr * delta
        let mut expect = cap.dw.clone();
        expect.scale(0.1);
        // direction cosine > 0.99
        let cos = ops::dot(diff.data(), expect.data())
            / (diff.fro_norm() * expect.fro_norm());
        assert!(cos > 0.99, "cos={cos}");
    }

    #[test]
    fn factor_updates_respect_inversion_frequency() {
        let shapes = [LayerShape::new(4, 4)];
        let mut cfg = MkorConfig::default();
        cfg.inv_freq = 5;
        let mut opt = Mkor::new(&shapes, cfg);
        assert!(opt.is_factor_step(0));
        assert!(!opt.is_factor_step(1));
        assert!(!opt.is_factor_step(4));
        assert!(opt.is_factor_step(5));
        // sync bytes only on factor steps
        let mut rng = Rng::new(13);
        let mut layers = vec![Dense::init(shapes[0], crate::model::Activation::Linear, &mut rng)];
        let cap = toy_capture(shapes[0], 4, &mut rng);
        let mut timer = PhaseTimer::new();
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer); // t=0 factor step
        assert!(opt.sync_bytes_last_step() > 0);
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer); // t=1 not
        assert_eq!(opt.sync_bytes_last_step(), 0);
    }

    #[test]
    fn sync_bytes_are_linear_in_d_and_halved_by_bf16() {
        let shapes = [LayerShape::new(64, 64)];
        let mut rng = Rng::new(14);
        let cap = toy_capture(shapes[0], 4, &mut rng);
        let mut timer = PhaseTimer::new();

        let mut full = MkorConfig::default();
        full.half_sync = None;
        let mut o1 = Mkor::new(&shapes, full);
        let mut l1 = vec![Dense::init(shapes[0], crate::model::Activation::Linear, &mut rng)];
        o1.step(&mut l1, std::slice::from_ref(&cap), 0.01, &mut timer);
        assert_eq!(o1.sync_bytes_last_step(), (64 + 64) * 4);

        let mut o2 = Mkor::new(&shapes, MkorConfig::default()); // bf16
        o2.step(&mut l1, std::slice::from_ref(&cap), 0.01, &mut timer);
        assert_eq!(o2.sync_bytes_last_step(), (64 + 64) * 2);
    }

    #[test]
    fn state_bytes_counts_f32_inverses_and_half_wire_vectors() {
        // The factor inverses live in f32 no matter what the wire format
        // is; only the 2d rank-1 vectors shrink under half_sync. A bf16
        // config must therefore differ from fp32 by exactly 2·(d_out+d_in)
        // bytes per layer — not by half the factor storage.
        let shapes = [LayerShape::new(8, 6), LayerShape::new(6, 4)];
        let factor_bytes: usize = shapes
            .iter()
            .map(|s| (s.d_out * s.d_out + s.d_in * s.d_in) * 4)
            .sum();
        let vec_elems: usize = shapes.iter().map(|s| s.d_out + s.d_in).sum();

        let mut full = MkorConfig::default();
        full.half_sync = None;
        let o_full = Mkor::new(&shapes, full);
        let o_half = Mkor::new(&shapes, MkorConfig::default()); // bf16
        let backend = match &o_full.backend {
            BackendState::Sgd(b) => b.state_bytes(),
            _ => unreachable!("default backend is SGD"),
        };
        assert_eq!(o_full.state_bytes(), factor_bytes + vec_elems * 4 + backend);
        assert_eq!(o_half.state_bytes(), factor_bytes + vec_elems * 2 + backend);
        assert_eq!(o_full.state_bytes() - o_half.state_bytes(), vec_elems * 2);
    }

    #[test]
    fn converges_on_skewed_quadratic() {
        // Minimize ‖W X − Y‖² where X has a skewed spectrum. This is a
        // convergence *contract* test (loss drops well below init and the
        // factors stay healthy); the MKOR-vs-SGD rate comparisons are the
        // Figure 2/6 benches, not unit tests.
        let mut rng = Rng::new(15);
        let (dout, din, b) = (6, 8, 64);
        let shapes = [LayerShape::new(din, dout)];
        // Skewed inputs.
        let mut x = Matrix::randn(din, b, 1.0, &mut rng);
        for i in 0..din {
            let s = 1.0 / (1 << i.min(6)) as f32;
            for j in 0..b {
                x[(i, j)] *= s;
            }
        }
        let w_true = Matrix::randn(dout, din, 1.0, &mut rng);
        let y = ops::matmul(&w_true, &x);

        let run = |use_mkor: bool, rng: &mut Rng| -> (f64, f64) {
            let mut layers =
                vec![Dense::init(shapes[0], crate::model::Activation::Linear, rng)];
            layers[0].w = Matrix::zeros(dout, din);
            let mut cfg = MkorConfig::default();
            cfg.inv_freq = 1;
            cfg.gamma = 0.9;
            cfg.half_sync = None;
            cfg.momentum = 0.0;
            let mut mkor = Mkor::new(&shapes, cfg);
            let mut timer = PhaseTimer::new();
            let mut loss = 0.0;
            let mut first_loss = 0.0;
            for step in 0..80 {
                let pred = ops::matmul(&layers[0].w, &x);
                let mut err = pred.clone();
                err.blend(1.0, -1.0, &y);
                loss = err.fro_norm().powi(2) / (b as f64);
                if step == 0 {
                    first_loss = loss;
                }
                let mut g = err.clone();
                g.scale(2.0 / b as f32);
                let mut dw = ops::matmul_nt(&g, &x);
                dw.scale(1.0); // already averaged via g
                let cap = Capture {
                    a: x.clone(),
                    g: g.clone(),
                    dw,
                    db: vec![0.0; dout],
                };
                if use_mkor {
                    mkor.step(&mut layers, std::slice::from_ref(&cap), 0.05, &mut timer);
                } else {
                    // plain SGD on the raw gradient:
                    for (w, &dv) in layers[0].w.data_mut().iter_mut().zip(cap.dw.data()) {
                        *w -= 0.05 * dv;
                    }
                }
            }
            (first_loss, loss)
        };
        let (init, final_mkor) = run(true, &mut rng);
        assert!(
            final_mkor < 0.2 * init,
            "mkor final {final_mkor} vs init {init}: insufficient decrease"
        );
        assert!(final_mkor.is_finite());
    }

    #[test]
    fn factor_state_roundtrip_resumes_bitwise() {
        // 10 straight steps vs 5 + snapshot + restore-into-fresh + 5 must
        // produce identical factors, backend moments and weights — the
        // checkpoint subsystem's acceptance property at the unit level.
        let shapes = [LayerShape::new(5, 4), LayerShape::new(4, 3)];
        let mut cfg = MkorConfig::default();
        cfg.inv_freq = 3; // cross several factor updates in 10 steps
        let mut rng = Rng::new(21);
        let caps: Vec<Vec<Capture>> = (0..10)
            .map(|_| {
                shapes
                    .iter()
                    .map(|&s| toy_capture(s, 6, &mut rng))
                    .collect()
            })
            .collect();
        let mut init_rng = Rng::new(22);
        let layers0: Vec<Dense> = shapes
            .iter()
            .map(|&s| Dense::init(s, crate::model::Activation::Linear, &mut init_rng))
            .collect();
        let mut timer = PhaseTimer::new();

        // Straight run.
        let mut straight = Mkor::new(&shapes, cfg.clone());
        let mut lw = layers0.clone();
        for cap in &caps {
            straight.step(&mut lw, cap, 0.05, &mut timer);
        }

        // Interrupted run: 5 steps, snapshot, fresh optimizer, 5 more.
        let mut first = Mkor::new(&shapes, cfg.clone());
        let mut lr_ = layers0.clone();
        for cap in &caps[..5] {
            first.step(&mut lr_, cap, 0.05, &mut timer);
        }
        let sd = first.state_dict();
        let mut resumed = Mkor::new(&shapes, cfg.clone());
        resumed.load_state_dict(&sd).unwrap();
        assert_eq!(resumed.state_dict(), sd);
        for cap in &caps[5..] {
            resumed.step(&mut lr_, cap, 0.05, &mut timer);
        }

        for (a, b) in lw.iter().zip(&lr_) {
            assert_eq!(a.w.data(), b.w.data());
            assert_eq!(a.bias, b.bias);
        }
        assert_eq!(straight.state_dict(), resumed.state_dict());
        // A wrong-shaped optimizer refuses the state.
        let mut wrong = Mkor::new(&[LayerShape::new(5, 4)], cfg);
        assert!(wrong.load_state_dict(&sd).is_err());
    }

    #[test]
    fn backend_cfg_reaches_the_adam_backend() {
        // Same capture, Adam backend with default eps vs eps=10: the huge
        // eps shrinks the Adam step, so the resulting weights must differ.
        let shapes = [LayerShape::new(6, 4)];
        let mut rng = Rng::new(11);
        let cap = toy_capture(shapes[0], 8, &mut rng);
        let mut run = |eps: f32| {
            let mut cfg = MkorConfig { backend: Backend::Adam, ..Default::default() };
            cfg.backend_cfg.eps = eps;
            let mut opt = Mkor::new(&shapes, cfg);
            let mut rng = Rng::new(12);
            let act = crate::model::Activation::Linear;
            let mut layers = vec![Dense::init(shapes[0], act, &mut rng)];
            let mut timer = PhaseTimer::new();
            opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer);
            layers[0].w.clone()
        };
        let w_default = run(AdamConfig::default().eps);
        let w_blunt = run(10.0);
        assert!(w_default.max_abs_diff(&w_blunt) > 1e-4);
    }
}
