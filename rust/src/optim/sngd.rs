//! SNGD baseline (HyLo-style): Sherman–Morrison–Woodbury NGD.
//!
//! Preconditions with `(F + μI)⁻¹∇ = (∇ − U(K + μI)⁻¹Uᵀ∇)/μ` where
//! `K = AᵀA ⊙ GᵀG ∈ R^{b×b}` (Equation 13). The kernel inversion is O(b³)
//! and the stored `A`,`G` are O(bd) — the batch-size scaling that breaks
//! down for transformers, where b is batch×sequence-length (§1). Like HyLo
//! we refresh the kernel every `inv_freq` steps and reuse the *stored*
//! A/G/K⁻¹ (stale-kernel preconditioning) in between, which is where the
//! O(2bd + b²) memory overhead of Table 1 comes from.

use crate::checkpoint::{Checkpointable, StateDict, StateError};
use crate::linalg::inverse::invert;
use crate::linalg::{ops, Matrix};
use crate::model::{Capture, Dense, LayerShape};
use crate::optim::first_order::SgdMomentum;
use crate::optim::{Optimizer, OptimizerSpec};
use crate::util::timer::PhaseTimer;

/// SNGD hyperparameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SngdConfig {
    /// Kernel refresh period.
    pub inv_freq: usize,
    /// SMW damping μ.
    pub damping: f32,
    pub momentum: f32,
}

impl Default for SngdConfig {
    fn default() -> Self {
        SngdConfig { inv_freq: 10, damping: 0.3, momentum: 0.9 }
    }
}

struct LayerState {
    /// Stored activations/gradients from the last kernel refresh (d×b).
    a: Option<Matrix>,
    g: Option<Matrix>,
    /// (K + μI)⁻¹ from the last refresh (b×b).
    kinv: Option<Matrix>,
}

/// The SNGD/HyLo optimizer.
pub struct Sngd {
    cfg: SngdConfig,
    layers: Vec<LayerState>,
    shapes: Vec<LayerShape>,
    backend: SgdMomentum,
    t: usize,
    last_sync_bytes: usize,
    /// Kernel inversions that failed (singular even with damping).
    pub inversion_failures: usize,
}

impl Sngd {
    pub fn new(shapes: &[LayerShape], cfg: SngdConfig) -> Self {
        Sngd {
            cfg,
            layers: shapes.iter().map(|_| LayerState { a: None, g: None, kinv: None }).collect(),
            shapes: shapes.to_vec(),
            backend: SgdMomentum::new(shapes, cfg.momentum),
            t: 0,
            last_sync_bytes: 0,
            inversion_failures: 0,
        }
    }

    pub fn is_kernel_step(&self, t: usize) -> bool {
        t % self.cfg.inv_freq == 0
    }

    /// `K = AᵀA ⊙ GᵀG` (b×b Hadamard of Gram matrices).
    fn kernel(a: &Matrix, g: &Matrix) -> Matrix {
        let ata = ops::matmul_tn(a, a);
        let gtg = ops::matmul_tn(g, g);
        let b = ata.rows();
        let mut k = Matrix::zeros(b, b);
        for (kv, (&x, &y)) in k
            .data_mut()
            .iter_mut()
            .zip(ata.data().iter().zip(gtg.data()))
        {
            *kv = x * y;
        }
        k
    }
}

impl Checkpointable for Sngd {
    fn state_dict(&self) -> StateDict {
        // The stored A/G/K⁻¹ come from the last kernel refresh and get
        // reused (stale) until the next one — a resumed run must reuse
        // exactly the same stored batch, not refresh early.
        let mut sd = StateDict::new();
        sd.put_usize("t", self.t)
            .put_usize("inversion_failures", self.inversion_failures)
            .put_usize("last_sync_bytes", self.last_sync_bytes);
        let mut layers = StateDict::new();
        for (i, st) in self.layers.iter().enumerate() {
            let mut d = StateDict::new();
            if let (Some(a), Some(g), Some(kinv)) = (&st.a, &st.g, &st.kinv) {
                d.put_matrix("a", a).put_matrix("g", g).put_matrix("kinv", kinv);
            }
            layers.put_dict(&i.to_string(), d);
        }
        sd.put_dict("layers", layers);
        sd.put_dict("backend", self.backend.state_dict());
        sd
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<(), StateError> {
        state.check_keys(
            &["t", "inversion_failures", "last_sync_bytes", "layers", "backend"],
            &[],
        )?;
        let layers = state.dict("layers")?;
        let expected: Vec<String> = (0..self.layers.len()).map(|i| i.to_string()).collect();
        layers.check_keys_exact(&expected)?;
        for (i, (st, shape)) in self.layers.iter_mut().zip(&self.shapes).enumerate() {
            let d = layers.dict(&i.to_string())?;
            d.check_keys(&[], &["a", "g", "kinv"])?;
            if d.is_empty() {
                st.a = None;
                st.g = None;
                st.kinv = None;
                continue;
            }
            // All-or-nothing: the kernel is only ever stored as a triple.
            let a = d.tensor("a")?;
            let g = d.tensor("g")?;
            let kinv = d.tensor("kinv")?;
            let b = a.cols;
            // The batch side b is data-dependent; the model-side dims and
            // internal consistency are still checkable.
            if a.rows != shape.d_in {
                return Err(StateError::ShapeMismatch {
                    key: format!("layers.{i}.a"),
                    expected_rows: shape.d_in,
                    expected_cols: b,
                    found_rows: a.rows,
                    found_cols: a.cols,
                });
            }
            if g.rows != shape.d_out || g.cols != b {
                return Err(StateError::ShapeMismatch {
                    key: format!("layers.{i}.g"),
                    expected_rows: shape.d_out,
                    expected_cols: b,
                    found_rows: g.rows,
                    found_cols: g.cols,
                });
            }
            if kinv.rows != b || kinv.cols != b {
                return Err(StateError::ShapeMismatch {
                    key: format!("layers.{i}.kinv"),
                    expected_rows: b,
                    expected_cols: b,
                    found_rows: kinv.rows,
                    found_cols: kinv.cols,
                });
            }
            st.a = Some(a.to_matrix());
            st.g = Some(g.to_matrix());
            st.kinv = Some(kinv.to_matrix());
        }
        self.backend.load_state_dict(state.dict("backend")?)?;
        self.t = state.usizev("t")?;
        self.inversion_failures = state.usizev("inversion_failures")?;
        self.last_sync_bytes = state.usizev("last_sync_bytes")?;
        Ok(())
    }
}

impl Optimizer for Sngd {
    fn name(&self) -> &str {
        "sngd"
    }

    fn step(&mut self, layers: &mut [Dense], caps: &[Capture], lr: f32, timer: &mut PhaseTimer) {
        let kernel_step = self.is_kernel_step(self.t);
        self.last_sync_bytes = 0;
        let mu = self.cfg.damping;

        let mut deltas = Vec::with_capacity(caps.len());
        for (idx, cap) in caps.iter().enumerate() {
            // ---- kernel refresh (factor computation) -------------------
            if kernel_step {
                let t0 = std::time::Instant::now();
                let mut k = Sngd::kernel(&cap.a, &cap.g);
                let b = k.rows();
                for i in 0..b {
                    k[(i, i)] += mu;
                }
                match invert(&k) {
                    Ok(kinv) => {
                        let st = &mut self.layers[idx];
                        st.a = Some(cap.a.clone());
                        st.g = Some(cap.g.clone());
                        st.kinv = Some(kinv);
                        // Sync: activations+gradients (2bd) + kernel (b²)
                        // per Table 1.
                        let s = &self.shapes[idx];
                        self.last_sync_bytes += (2 * b * (s.d_in + s.d_out) / 2 + b * b) * 4;
                    }
                    Err(_) => {
                        // KID-style failure mode (§3.3: "for batch sizes
                        // larger than d ... the method fails").
                        self.inversion_failures += 1;
                    }
                }
                timer.add("factor", t0.elapsed());
            }

            // ---- precondition with (possibly stale) kernel -------------
            let t0 = std::time::Instant::now();
            let st = &self.layers[idx];
            let delta = match (&st.a, &st.g, &st.kinv) {
                (Some(a), Some(g), Some(kinv)) => {
                    // v_i = g_iᵀ ∇ a_i  via M = ∇·A (d_out×b), v = colsum(G ⊙ M)
                    let m = ops::matmul(&cap.dw, a);
                    let b = a.cols();
                    let mut v = vec![0.0f32; b];
                    for (i, vi) in v.iter_mut().enumerate() {
                        let gi = g.col(i);
                        let mut acc = 0.0f64;
                        for r in 0..g.rows() {
                            acc += gi[r] as f64 * m[(r, i)] as f64;
                        }
                        *vi = acc as f32;
                    }
                    // w = K⁻¹ v
                    let w = ops::matvec(kinv, &v);
                    // correction = G·diag(w)·Aᵀ = (G*w) Aᵀ
                    let mut gw = g.clone();
                    for i in 0..b {
                        let wi = w[i];
                        for r in 0..gw.rows() {
                            gw[(r, i)] *= wi;
                        }
                    }
                    let corr = ops::matmul_nt(&gw, a);
                    let mut delta = cap.dw.clone();
                    delta.blend(1.0, -1.0, &corr);
                    delta.scale(1.0 / mu);
                    delta
                }
                _ => cap.dw.clone(), // kernel never built: SGD fallback
            };
            timer.add("precond", t0.elapsed());
            deltas.push(delta);
        }

        let t0 = std::time::Instant::now();
        let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
        self.backend.apply(layers, &deltas, &dbs, lr);
        timer.add("update", t0.elapsed());
        self.t += 1;
    }

    fn state_bytes(&self) -> usize {
        // Stored A, G (2bd) + kernel (b²) per layer, counted at the actual
        // stored sizes (0 before first refresh).
        self.layers
            .iter()
            .map(|st| {
                st.a.as_ref().map_or(0, |m| m.len() * 4)
                    + st.g.as_ref().map_or(0, |m| m.len() * 4)
                    + st.kinv.as_ref().map_or(0, |m| m.len() * 4)
            })
            .sum::<usize>()
            + self.backend.state_bytes()
    }

    fn sync_bytes_last_step(&self) -> usize {
        self.last_sync_bytes
    }

    fn steps_done(&self) -> usize {
        self.t
    }

    fn spec(&self) -> OptimizerSpec {
        OptimizerSpec::Sngd(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;
    use crate::util::Rng;

    fn toy_capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
        let a = Matrix::randn(shape.d_in, b, 1.0, rng);
        let g = Matrix::randn(shape.d_out, b, 1.0, rng);
        let mut dw = ops::matmul_nt(&g, &a);
        dw.scale(1.0 / b as f32);
        Capture { a, g, dw, db: vec![0.0; shape.d_out] }
    }

    #[test]
    fn kernel_is_hadamard_of_grams() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(5, 3, 1.0, &mut rng);
        let g = Matrix::randn(4, 3, 1.0, &mut rng);
        let k = Sngd::kernel(&a, &g);
        assert_eq!(k.rows(), 3);
        for i in 0..3 {
            for j in 0..3 {
                let ai = a.col(i);
                let aj = a.col(j);
                let gi = g.col(i);
                let gj = g.col(j);
                let want = ops::dot(&ai, &aj) * ops::dot(&gi, &gj);
                assert!((k[(i, j)] as f64 - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn smw_identity_matches_direct_fim_inverse() {
        // For a single layer, F = (1/b)Σ u_i u_iᵀ with u_i = vec(g_i a_iᵀ).
        // Check (F + μI)⁻¹∇ via SMW == direct inversion on a tiny problem.
        let mut rng = Rng::new(2);
        let (dout, din, b) = (3usize, 2, 4);
        let a = Matrix::randn(din, b, 1.0, &mut rng);
        let g = Matrix::randn(dout, b, 1.0, &mut rng);
        let mu = 0.5f32;
        let d2 = dout * din;

        // Build U (d²×b) with u_i = vec(g_i a_iᵀ) (row-major dout×din).
        let mut u = Matrix::zeros(d2, b);
        for i in 0..b {
            for r in 0..dout {
                for c in 0..din {
                    u[(r * din + c, i)] = g[(r, i)] * a[(c, i)];
                }
            }
        }
        // F + μI — note the paper's Eq. 13 uses unnormalized Σ u uᵀ.
        let f = ops::matmul_nt(&u, &u);
        let mut fmu = f.clone();
        for i in 0..d2 {
            fmu[(i, i)] += mu;
        }
        let finv = invert(&fmu).unwrap();
        let grad: Vec<f32> = (0..d2).map(|_| rng.gaussian_f32()).collect();
        let want = ops::matvec(&finv, &grad);

        // SMW path (as the optimizer computes it, with unnormalized kernel).
        let mut k = Sngd::kernel(&a, &g);
        for i in 0..b {
            k[(i, i)] += mu;
        }
        let kinv = invert(&k).unwrap();
        let utg = ops::matvec_t(&u, &grad);
        let w = ops::matvec(&kinv, &utg);
        let uw = ops::matvec(&u, &w);
        let got: Vec<f32> = grad
            .iter()
            .zip(&uw)
            .map(|(&gv, &uv)| (gv - uv) / mu)
            .collect();

        for i in 0..d2 {
            assert!((got[i] - want[i]).abs() < 1e-3, "i={i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn stale_kernel_reused_between_refreshes() {
        let shapes = [LayerShape::new(6, 4)];
        let mut cfg = SngdConfig::default();
        cfg.inv_freq = 4;
        let mut opt = Sngd::new(&shapes, cfg);
        let mut rng = Rng::new(3);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        let mut timer = PhaseTimer::new();
        for t in 0..5 {
            let cap = toy_capture(shapes[0], 8, &mut rng);
            opt.step(&mut layers, std::slice::from_ref(&cap), 0.01, &mut timer);
            if t == 0 || t == 4 {
                assert!(opt.sync_bytes_last_step() > 0, "t={t}");
            } else {
                assert_eq!(opt.sync_bytes_last_step(), 0, "t={t}");
            }
        }
        // Memory overhead now includes stored A (b·d_in), G (b·d_out) and
        // K⁻¹ (b²) — the "2bd + b²" of Table 1 with d_in=d_out=d.
        let want = (8 * (6 + 4) + 8 * 8) * 4 + opt.backend.state_bytes();
        assert_eq!(opt.state_bytes(), want);
    }

    #[test]
    fn reduces_quadratic_loss() {
        let mut rng = Rng::new(4);
        let shapes = [LayerShape::new(6, 4)];
        let x = Matrix::randn(6, 16, 1.0, &mut rng);
        let w_true = Matrix::randn(4, 6, 1.0, &mut rng);
        let y = ops::matmul(&w_true, &x);
        let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
        layers[0].w = Matrix::zeros(4, 6);
        let mut opt = Sngd::new(&shapes, SngdConfig::default());
        let mut timer = PhaseTimer::new();
        let mut loss = f64::INFINITY;
        for _ in 0..120 {
            let pred = ops::matmul(&layers[0].w, &x);
            let mut err = pred.clone();
            err.blend(1.0, -1.0, &y);
            loss = err.fro_norm().powi(2) / 16.0;
            let mut g = err;
            g.scale(2.0 / 16.0);
            let dw = ops::matmul_nt(&g, &x);
            let cap = Capture { a: x.clone(), g, dw, db: vec![0.0; 4] };
            opt.step(&mut layers, std::slice::from_ref(&cap), 0.05, &mut timer);
        }
        assert!(loss < 0.1, "loss={loss}");
    }
}
