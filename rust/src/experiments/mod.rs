//! Shared experiment runners used by the `cargo bench` targets that
//! regenerate the paper's tables and figures (DESIGN.md §4).
//!
//! Each runner is a thin composition of the substrates: a workload
//! generator ([`crate::data`]), the data-parallel [`crate::coordinator`],
//! one of the [`crate::optim`] optimizers, and (for wall-clock numbers at
//! paper scale) the calibrated [`crate::costmodel`].

pub mod convergence;
pub mod spectra;

pub use convergence::{run_convergence, ConvergenceResult, TaskKind};
