//! Proxy-convergence runner: train a proxy model under any optimizer and
//! record loss/metric trajectories — the measurement behind Tables 2/3/5
//! and Figures 2/4b/6/11/12.

use crate::checkpoint::Checkpoint;
use crate::coordinator::{RunRecord, Target, TrainerBuilder};
use crate::data::classification::{Dataset, TaskConfig};
use crate::data::images::{ImageConfig, ImageGen};
use crate::data::text::{CausalLmBatchGen, MlmBatchGen, TextConfig};
use crate::model::{Activation, Mlp, Model, Transformer, TransformerConfig};
use crate::optim::OptimizerSpec;
use crate::util::Rng;

/// The proxy workloads.
#[derive(Clone, Debug)]
pub enum TaskKind {
    /// Masked-token prediction from bag-of-context features — the
    /// BERT-pre-training / SQuAD / IMDB stand-in (vocab classes).
    TextClass { feat_dim: usize, vocab: usize },
    /// Template-image classification — ResNet/AlexNet stand-in.
    Images,
    /// Denoising autoencoder — the paper's own Figure 4 workload.
    Autoencoder,
    /// A materialized Gaussian-mixture task (GLUE proxies).
    Glue(TaskConfig),
    /// Next-token prediction with the causal-transformer proxy
    /// ([`Transformer`]) on the Markov–Zipf corpus — the workload where
    /// MKOR-H's switching rule matters (§3.2: transformer pre-training).
    /// Sequence positions fold into the batch, so each step's captures
    /// carry `batch·seq_len` sample columns.
    CharLm { vocab: usize, seq_len: usize },
}

/// Result of one run.
#[derive(Clone, Debug, Default)]
pub struct ConvergenceResult {
    pub optimizer: String,
    /// Training loss per step.
    pub losses: Vec<f64>,
    /// (step, eval metric) pairs; metric = accuracy or −eval-loss.
    pub evals: Vec<(usize, f64)>,
    pub diverged: bool,
    /// Mean wall seconds per step (local, proxy scale).
    pub step_secs: f64,
    /// Optimizer-phase seconds totals: (factor, precond, update).
    pub phase_secs: (f64, f64, f64),
    /// Total second-order sync bytes.
    pub sync_bytes: usize,
}

impl ConvergenceResult {
    /// First step at which train loss ≤ target (mean-smoothed over a
    /// trailing window of 5; one shared definition in `util::stats`).
    pub fn steps_to_loss(&self, target: f64) -> Option<usize> {
        crate::util::stats::first_at_or_below(&self.losses, target, 5)
    }

    /// First eval step at which the metric ≥ target.
    pub fn steps_to_metric(&self, target: f64) -> Option<usize> {
        self.evals.iter().find(|(_, m)| *m >= target).map(|(s, _)| *s)
    }

    pub fn final_metric(&self) -> Option<f64> {
        self.evals.last().map(|(_, m)| *m)
    }

    pub fn final_loss(&self) -> f64 {
        self.losses.last().copied().unwrap_or(f64::NAN)
    }
}

/// Options for [`run_convergence`].
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub lr: f32,
    pub steps: usize,
    pub workers: usize,
    pub batch: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// Override the optimizer's factor-update period (second-order only).
    pub inv_freq: Option<usize>,
    /// Override MKOR's factor momentum γ (proxy runs are short, so a
    /// smaller γ than the paper's long-run value lets the factors adapt
    /// within the budget).
    pub gamma: Option<f32>,
    /// Hidden widths of the proxy model (MLP tasks only; the `charlm`
    /// transformer's dimensions come from [`TransformerConfig::proxy`]).
    pub hidden: Vec<usize>,
    /// Convergence target recorded into the run record (accuracy for
    /// labeled tasks, loss for dense) — checked at each eval.
    pub target_metric: Option<f64>,
    /// Write a checkpoint into `checkpoint_dir` every n completed steps
    /// (0 = never).
    pub checkpoint_every: usize,
    /// Checkpoint directory: the periodic write target, and — with
    /// `resume` — the restore source.
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Resume from `checkpoint_dir` when it holds a manifest. The data
    /// stream is replayed deterministically up to the checkpoint step, so
    /// the resumed run's loss series and final weights are identical to an
    /// uninterrupted run with the same options.
    pub resume: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            lr: 0.1,
            steps: 300,
            workers: 2,
            batch: 64,
            seed: 0,
            eval_every: 10,
            inv_freq: None,
            gamma: Some(0.9),
            hidden: vec![128, 64],
            target_metric: None,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
        }
    }
}

/// Does the raw spec string explicitly set one of `keys`?
///
/// Used to give spec-string keys precedence over the `RunOpts` harness
/// overrides — `RunOpts::default()` carries `gamma: Some(0.9)`, which must
/// not silently clobber an explicit `mkor:gamma=0.99`.
fn spec_sets_key(s: &str, keys: &[&str]) -> bool {
    match s.split_once(':') {
        Some((_, rest)) => rest.split(',').any(|part| {
            part.split_once('=')
                .map(|(k, _)| keys.contains(&k.trim()))
                .unwrap_or(false)
        }),
        None => false,
    }
}

/// Resolve the run's optimizer spec: parse the (possibly keyed) spec
/// string, then layer the harness overrides on top. A key written in the
/// spec string always wins over the corresponding `RunOpts` override.
///
/// `inv_freq` overrides the refresh period of the second-order methods
/// (MKOR/MKOR-H factor period, KFAC inversion period, SNGD kernel period,
/// Eva vector period — Eva previously ignored this override) and `gamma`
/// overrides MKOR's factor momentum only, as `RunOpts` documents.
fn resolve_spec(name: &str, inv_freq: Option<usize>, gamma: Option<f32>) -> OptimizerSpec {
    let mut spec =
        OptimizerSpec::parse(name).unwrap_or_else(|e| panic!("optimizer spec: {e}"));
    if let Some(f) = inv_freq {
        if !spec_sets_key(name, &["f", "inv_freq", "update_freq"]) {
            spec = spec.with_inv_freq(f);
        }
    }
    if let Some(g) = gamma {
        if !spec_sets_key(name, &["gamma"]) {
            spec = spec.with_gamma(g);
        }
    }
    spec
}

/// Train a proxy model and record its trajectory.
///
/// `opt_name` is an optimizer spec string — a bare name (`"mkor"`) or the
/// full `name[:key=val,...]` grammar (`"mkor:f=25,backend=lamb"`); the
/// `RunOpts` `inv_freq`/`gamma` overrides are applied on top. Panics on an
/// invalid spec (harness code; the CLI path reports errors instead).
pub fn run_convergence(task: &TaskKind, opt_name: &str, opts: &RunOpts) -> ConvergenceResult {
    let spec = resolve_spec(opt_name, opts.inv_freq, opts.gamma);
    let (record, phase_secs, step_secs) = run_core(task, &spec, opt_name, opts);
    let mut losses = record.loss_series();
    if record.diverged {
        // The trainer records the diverged step too; the trajectory result
        // reports only the completed steps (Table 5's "D" cell semantics).
        losses.pop();
    }
    ConvergenceResult {
        optimizer: opt_name.to_string(),
        losses,
        evals: record
            .steps
            .iter()
            .filter_map(|s| s.eval_metric.map(|m| (s.step, m)))
            .collect(),
        diverged: record.diverged,
        step_secs,
        phase_secs,
        sync_bytes: record.steps.iter().map(|s| s.sync_comm_bytes).sum(),
    }
}

/// Train a proxy model from a fully-typed spec and return the complete
/// [`RunRecord`] — the sweep engine's per-cell entry point.
///
/// Unlike [`run_convergence`], the `RunOpts` `inv_freq`/`gamma` overrides
/// are *not* layered on: the spec alone describes the optimizer, so the
/// record's canonical spec string reproduces the run exactly.
pub fn run_record(
    task: &TaskKind,
    spec: &OptimizerSpec,
    run_name: &str,
    opts: &RunOpts,
) -> RunRecord {
    run_core(task, spec, run_name, opts).0
}

/// Shared core: build the workload + trainer, run the step/eval loop, and
/// return the record plus (factor, precond, update) phase seconds and the
/// mean wall seconds per completed step.
fn run_core(
    task: &TaskKind,
    spec: &OptimizerSpec,
    run_name: &str,
    opts: &RunOpts,
) -> (RunRecord, (f64, f64, f64), f64) {
    let mut rng = Rng::new(opts.seed);

    // Workload-specific batch source + eval source + model dims.
    enum Src {
        Text(MlmBatchGen, usize),
        Img(ImageGen),
        Auto(ImageGen),
        Glue(Dataset, u64, Vec<crate::data::Batch>),
        CharLm(CausalLmBatchGen),
    }
    let (mut src, dims): (Src, Vec<usize>) = match task {
        TaskKind::TextClass { feat_dim, vocab } => {
            let gen = MlmBatchGen::new(
                TextConfig { vocab: *vocab, seed: opts.seed, ..Default::default() },
                64,
                0.15,
                opts.seed ^ 0x7E,
            );
            let mut dims = vec![*feat_dim];
            dims.extend(&opts.hidden);
            dims.push(*vocab);
            (Src::Text(gen, *feat_dim), dims)
        }
        TaskKind::Images => {
            let gen = ImageGen::new(ImageConfig::default(), opts.seed);
            let mut dims = vec![gen.dim()];
            dims.extend(&opts.hidden);
            dims.push(gen.classes());
            (Src::Img(gen), dims)
        }
        TaskKind::Autoencoder => {
            let gen = ImageGen::new(ImageConfig::default(), opts.seed);
            let d = gen.dim();
            let mut dims = vec![d];
            dims.extend(&opts.hidden);
            dims.push(d);
            (Src::Auto(gen), dims)
        }
        TaskKind::Glue(cfg) => {
            let ds = Dataset::generate(cfg.clone());
            let mut dims = vec![cfg.dim];
            dims.extend(&opts.hidden);
            dims.push(cfg.classes);
            (Src::Glue(ds, 0, Vec::new()), dims)
        }
        TaskKind::CharLm { vocab, seq_len } => {
            let gen = CausalLmBatchGen::new(
                TextConfig { vocab: *vocab, seed: opts.seed, ..Default::default() },
                *seq_len,
                opts.seed ^ 0x7E,
            );
            (Src::CharLm(gen), Vec::new())
        }
    };

    // Pick the substrate: the charlm task trains the causal transformer,
    // everything else an MLP shaped by `dims`.
    let model: Box<dyn Model> = match task {
        TaskKind::CharLm { vocab, seq_len } => {
            Box::new(Transformer::new(TransformerConfig::proxy(*vocab, *seq_len), &mut rng))
        }
        _ => {
            let act = match task {
                TaskKind::Autoencoder => Activation::Tanh,
                TaskKind::TextClass { .. } => Activation::Gelu,
                _ => Activation::Relu,
            };
            Box::new(Mlp::new(&dims, act, &mut rng))
        }
    };
    let mut builder = TrainerBuilder::new_boxed(model)
        .optimizer(spec.clone())
        .constant_lr(opts.lr)
        .workers(opts.workers)
        .run_name(run_name)
        // Always label the run with its task: the checkpoint manifest and
        // the per-step trace events both carry it.
        .checkpoint_task(crate::sweep::grid::task_label(task));
    if let Some(target) = opts.target_metric {
        builder = builder.target_metric(target);
    }
    if let Some(dir) = &opts.checkpoint_dir {
        builder = builder
            .checkpoint_dir(dir.clone())
            .checkpoint_every(opts.checkpoint_every);
        if opts.resume && Checkpoint::exists(dir) {
            builder = builder.resume_from(dir.clone());
        }
    }
    let mut trainer = builder.build();
    // Resume: the trainer restored `start` completed steps; the loop below
    // replays the data stream deterministically (same seed, same draws)
    // and skips training on the first `start` batches, so batch `start`
    // onward sees exactly what the uninterrupted run saw.
    let start = trainer.steps_done();

    let mut next = |src: &mut Src, b: usize| -> (crate::linalg::Matrix, Target) {
        match src {
            Src::Text(gen, feat) => {
                let batch = gen.next_dense(b, *feat, 6);
                (batch.x, Target::Labels(batch.labels))
            }
            Src::Img(gen) => {
                let batch = gen.next_batch(b);
                (batch.x, Target::Labels(batch.labels))
            }
            Src::Auto(gen) => {
                let batch = gen.next_autoencoder_batch(b);
                (batch.x, Target::Dense(batch.y))
            }
            Src::Glue(ds, epoch, queue) => {
                if queue.is_empty() {
                    *queue = ds.epoch_batches(b, *epoch);
                    *epoch += 1;
                }
                let batch = queue.pop().unwrap();
                (batch.x, Target::Labels(batch.labels))
            }
            Src::CharLm(gen) => {
                let batch = gen.next_batch(b);
                (batch.x, Target::Labels(batch.labels))
            }
        }
    };

    // Held-out eval batch (fresh draw / test split). The charlm eval draw
    // is smaller — 64 sequences unroll to 64·seq_len eval columns.
    let eval = match &mut src {
        Src::Glue(ds, _, _) => {
            let t = ds.test_batch();
            Some((t.x, Target::Labels(t.labels)))
        }
        s @ Src::CharLm(_) => {
            let (x, t) = next(s, 64);
            Some((x, t))
        }
        s => {
            let (x, t) = next(s, 256);
            Some((x, t))
        }
    };

    let mut ok_steps = 0usize;
    let t0 = std::time::Instant::now();
    for step in 0..opts.steps {
        let (x, target) = next(&mut src, opts.batch);
        if step < start {
            continue; // replayed batch — trained before the checkpoint
        }
        match trainer.step(&x, &target) {
            Some(_) => ok_steps += 1,
            None => break,
        }
        if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
            if let Some((ex, et)) = &eval {
                trainer.evaluate(ex, et);
            }
        }
        // After the eval, so a boundary checkpoint carries this step's
        // eval metric in its record.
        trainer.checkpoint_tick();
    }
    let step_secs = t0.elapsed().as_secs_f64() / ok_steps.max(1) as f64;
    let phase_secs = (
        trainer.phases.total_secs("factor"),
        trainer.phases.total_secs("precond"),
        trainer.phases.total_secs("update"),
    );
    (trainer.finish(), phase_secs, step_secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_task_trains_under_mkor_and_sgd() {
        let task = TaskKind::TextClass { feat_dim: 96, vocab: 64 };
        let opts = RunOpts { steps: 60, hidden: vec![64], ..Default::default() };
        for name in ["sgd", "mkor"] {
            let r = run_convergence(&task, name, &opts);
            assert!(!r.diverged, "{name}");
            assert_eq!(r.losses.len(), 60);
            assert!(r.final_loss() < r.losses[0], "{name}: no improvement");
        }
    }

    #[test]
    fn charlm_task_trains_the_transformer() {
        // The issue's acceptance workload: the causal-transformer proxy
        // under MKOR and under MKOR-H with a non-default switch_beta.
        let task = TaskKind::CharLm { vocab: 48, seq_len: 16 };
        let opts = RunOpts {
            steps: 30,
            batch: 16,
            lr: 0.05,
            workers: 2,
            hidden: Vec::new(),
            ..Default::default()
        };
        for name in ["mkor:f=10", "mkor-h:min_steps=5,switch_beta=0.8"] {
            let r = run_convergence(&task, name, &opts);
            assert!(!r.diverged, "{name}");
            assert_eq!(r.losses.len(), 30, "{name}");
            assert!(r.final_loss() < r.losses[0], "{name}: no improvement");
        }
    }

    #[test]
    fn autoencoder_reduces_mse() {
        let r = run_convergence(
            &TaskKind::Autoencoder,
            "mkor",
            &RunOpts { steps: 50, lr: 0.05, hidden: vec![64, 16, 64], ..Default::default() },
        );
        assert!(!r.diverged);
        assert!(r.final_loss() < 0.8 * r.losses[0]);
        // MKOR synced rank-1 vectors on its factor steps.
        assert!(r.sync_bytes > 0);
    }

    #[test]
    fn steps_to_loss_and_metric() {
        let r = ConvergenceResult {
            losses: vec![3.0, 2.0, 1.0, 0.5, 0.4],
            evals: vec![(9, 0.5), (19, 0.9)],
            ..Default::default()
        };
        assert_eq!(r.steps_to_metric(0.85), Some(19));
        assert!(r.steps_to_loss(1.5).is_some());
        assert_eq!(r.steps_to_loss(0.01), None);
    }

    #[test]
    fn divergence_detected_with_huge_lr() {
        let r = run_convergence(
            &TaskKind::Images,
            "sgd",
            &RunOpts { steps: 100, lr: 1e6, hidden: vec![32], ..Default::default() },
        );
        assert!(r.diverged);
    }

    #[test]
    fn run_record_returns_the_full_record() {
        let spec = OptimizerSpec::parse("mkor:f=5,gamma=0.9").unwrap();
        let opts = RunOpts {
            steps: 30,
            hidden: vec![32],
            eval_every: 5,
            target_metric: Some(0.5),
            ..Default::default()
        };
        let rec = run_record(&TaskKind::Images, &spec, "cell-0", &opts);
        assert_eq!(rec.name, "cell-0");
        assert_eq!(rec.spec, "mkor:f=5,gamma=0.9");
        assert_eq!(rec.steps.len(), 30);
        assert!(rec.steps.iter().any(|s| s.eval_metric.is_some()));
        // The RunOpts overrides are NOT layered onto run_record specs.
        let re = OptimizerSpec::parse(&rec.spec).unwrap();
        assert_eq!(re, spec);
        // Convergence tracking against the target is wired through.
        if let Some(at) = rec.converged_at {
            assert!(at < 30);
        }
    }

    #[test]
    fn run_record_resumes_bitwise_from_a_checkpoint() {
        // 20 straight steps vs 10 + checkpoint + resume-to-20 ("fresh
        // process": everything is rebuilt from the options + checkpoint).
        let dir =
            std::env::temp_dir().join(format!("mkor-conv-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = OptimizerSpec::parse("mkor:f=5").unwrap();
        let base = RunOpts { steps: 20, hidden: vec![32], eval_every: 5, ..Default::default() };
        let straight = run_record(&TaskKind::Images, &spec, "r", &base);

        let mut first = base.clone();
        first.steps = 10;
        first.checkpoint_every = 10;
        first.checkpoint_dir = Some(dir.clone());
        let partial = run_record(&TaskKind::Images, &spec, "r", &first);
        assert_eq!(partial.steps.len(), 10);

        let mut rest = base.clone();
        rest.checkpoint_dir = Some(dir.clone());
        rest.resume = true;
        let resumed = run_record(&TaskKind::Images, &spec, "r", &rest);

        assert_eq!(straight.steps.len(), resumed.steps.len());
        for (i, (a, b)) in straight.steps.iter().zip(&resumed.steps).enumerate() {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss differs at step {i}");
            assert_eq!(a.eval_metric, b.eval_metric, "eval differs at step {i}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spec_string_keys_win_over_runopts_overrides() {
        // An explicit key in the string survives a conflicting harness
        // override (RunOpts::default() carries gamma: Some(0.9))...
        let s = resolve_spec("mkor:gamma=0.97", Some(5), Some(0.9));
        assert_eq!(s, OptimizerSpec::parse("mkor:f=5,gamma=0.97").unwrap());
        // ...while keys the string leaves unset still take the override.
        let s = resolve_spec("mkor", Some(5), Some(0.9));
        assert_eq!(s, OptimizerSpec::parse("mkor:f=5,gamma=0.9").unwrap());
    }

    #[test]
    fn spec_strings_are_accepted_as_optimizer_names() {
        // The same sweep the RunOpts override drives, as one-line specs.
        let task = TaskKind::Images;
        let base = RunOpts { steps: 40, hidden: vec![32], ..Default::default() };
        let r1 = run_convergence(&task, "mkor:f=1", &base);
        let r40 = run_convergence(&task, "mkor:f=40", &base);
        assert!(!r1.diverged && !r40.diverged);
        assert!(r1.sync_bytes > 10 * r40.sync_bytes.max(1));
    }

    #[test]
    fn inv_freq_override_changes_sync_cadence() {
        let task = TaskKind::Images;
        let base = RunOpts { steps: 40, hidden: vec![32], ..Default::default() };
        let mut o1 = base.clone();
        o1.inv_freq = Some(1);
        let mut o40 = base.clone();
        o40.inv_freq = Some(40);
        let r1 = run_convergence(&task, "mkor", &o1);
        let r40 = run_convergence(&task, "mkor", &o40);
        assert!(r1.sync_bytes > 10 * r40.sync_bytes.max(1));
    }
}
