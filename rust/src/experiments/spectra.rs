//! Covariance-spectrum collection during proxy training — the measurement
//! behind Figures 5, 8 and 10 (rank-1 approximation error and KFAC factor
//! condition numbers).

use crate::coordinator::{Target, TrainerBuilder};
use crate::data::images::{ImageConfig, ImageGen};
use crate::linalg::eigen::{condition_number, jacobi_eigen};
use crate::linalg::lowrank::{covariance, mean_rank1_error, optimal_rank1_error};
use crate::model::{Activation, Mlp, Model};
use crate::optim::OptimizerSpec;
use crate::util::Rng;

/// One sampled covariance observation.
#[derive(Clone, Debug)]
pub struct SpectrumSample {
    pub step: usize,
    pub layer: usize,
    /// Which side: activations (`"a"`, right factor) or input gradients
    /// (`"g"`, left factor).
    pub side: &'static str,
    /// Relative error of the optimal rank-1 approximation (power iter).
    pub optimal_rank1_err: f64,
    /// Relative error of MKOR's mean-vector rank-1 approximation.
    pub mean_rank1_err: f64,
    /// λmax, λmin and condition number of the covariance (Jacobi).
    pub lambda_max: f64,
    pub lambda_min: f64,
    pub cond: f64,
}

/// Train an image classifier briefly and sample covariance spectra of the
/// per-layer activation/gradient batches every `sample_every` steps.
pub fn collect_spectra(
    steps: usize,
    sample_every: usize,
    hidden: &[usize],
    seed: u64,
) -> Vec<SpectrumSample> {
    let mut gen = ImageGen::new(ImageConfig::default(), seed);
    let mut rng = Rng::new(seed);
    let mut dims = vec![gen.dim()];
    dims.extend(hidden);
    dims.push(gen.classes());
    let model = Mlp::new(&dims, Activation::Relu, &mut rng);
    let mut trainer = TrainerBuilder::new(model)
        .optimizer(OptimizerSpec::parse("sgd").unwrap())
        .constant_lr(0.1)
        .workers(1)
        .run_name("spectra")
        .build();

    // We need the captures, which the Trainer consumes internally — so run
    // the model manually alongside for sampling (same weights: sample
    // BEFORE the step so both see identical parameters).
    let mut samples = Vec::new();
    for step in 0..steps {
        let b = gen.next_batch(64);
        if step % sample_every == 0 {
            // Forward/backward on a clone for capture sampling.
            let mut probe = trainer.leader().clone_model();
            let out = probe.forward(&b.x);
            let (_, dl) = crate::model::softmax_xent(&out, &b.labels);
            let caps = probe.backward(&dl);
            for (layer, cap) in caps.iter().enumerate() {
                for (side, mat) in [("a", &cap.a), ("g", &cap.g)] {
                    // Cap the dim for the O(d³) Jacobi calls.
                    if mat.rows() > 300 {
                        continue;
                    }
                    let c = covariance(mat);
                    let eig = jacobi_eigen(&c, 1e-9, 40);
                    samples.push(SpectrumSample {
                        step,
                        layer,
                        side,
                        optimal_rank1_err: optimal_rank1_error(&c, 100, seed ^ step as u64),
                        mean_rank1_err: mean_rank1_error(mat),
                        lambda_max: eig.values[0],
                        lambda_min: *eig.values.last().unwrap(),
                        cond: condition_number(&eig.values),
                    });
                }
            }
        }
        if trainer.step(&b.x, &Target::Labels(b.labels.clone())).is_none() {
            break;
        }
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectra_collection_produces_samples() {
        let s = collect_spectra(6, 3, &[48, 24], 1);
        assert!(!s.is_empty());
        for x in &s {
            assert!(x.optimal_rank1_err >= -1e-9 && x.optimal_rank1_err <= 1.0 + 1e-9);
            // Optimal rank-1 can't be worse than the mean-based one.
            assert!(x.optimal_rank1_err <= x.mean_rank1_err + 1e-6);
            assert!(x.lambda_max >= x.lambda_min);
            assert!(x.cond >= 1.0 || x.cond.is_infinite());
        }
        // Both sides sampled.
        assert!(s.iter().any(|x| x.side == "a"));
        assert!(s.iter().any(|x| x.side == "g"));
    }

    #[test]
    fn covariances_are_low_rank_in_practice() {
        // The paper's Figure 5 claim on our proxy: batch 64 < some dims and
        // over-parameterization keep rank-1 error well below 1.
        let s = collect_spectra(4, 4, &[48], 2);
        let mean_err: f64 =
            s.iter().map(|x| x.optimal_rank1_err).sum::<f64>() / s.len() as f64;
        assert!(mean_err < 0.9, "mean optimal rank-1 error {mean_err}");
    }
}
