//! Layer-dimension specs of the paper's real models.
//!
//! The cost model (Table 1, Figure 3, Figure 9) and the memory accounting
//! (Table 6) price optimizer steps at *paper scale*, which requires the true
//! per-layer dimensions of BERT-Large-Uncased, BERT-Base, ResNet-50 and
//! AlexNet — not the proxy models'. KFAC treats a conv layer with `c_in`
//! input channels, `c_out` filters and k×k kernels as a linear layer of
//! shape `(c_in·k²) → c_out` (patch extraction), which is how the conv specs
//! below are expressed.

use crate::model::LayerShape;

/// A named model spec: the learnable layers KFAC-family optimizers
/// precondition, plus the effective per-GPU batch size in *samples at the
/// layer input* (for transformers this is batch×seq-len — the b that SNGD's
/// O(b³) scales with, §1).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<LayerShape>,
    /// Effective per-device batch dimension seen by the factor math.
    pub effective_batch: usize,
}

impl ModelSpec {
    pub fn params(&self) -> usize {
        self.layers.iter().map(LayerShape::params).sum()
    }

    /// Largest layer dimension `d = max(d_in, d_out)` over the model — the
    /// `d` of Table 1.
    pub fn max_dim(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.d_in.max(l.d_out))
            .max()
            .unwrap_or(0)
    }
}

/// BERT-Large-Uncased: 24 transformer blocks, hidden 1024, FFN 4096,
/// embeddings + pooler + MLM head. Effective batch = 8 sequences × 512
/// tokens (phase-2 pre-training shape used by KAISA).
pub fn bert_large() -> ModelSpec {
    let h = 1024;
    let ffn = 4096;
    let vocab = 30522;
    let mut layers = Vec::new();
    // Embedding projection treated as a (vocab → h) linear for cost purposes.
    layers.push(LayerShape::new(vocab, h));
    for _ in 0..24 {
        // Q, K, V, attention-output projections.
        for _ in 0..4 {
            layers.push(LayerShape::new(h, h));
        }
        // FFN up / down.
        layers.push(LayerShape::new(h, ffn));
        layers.push(LayerShape::new(ffn, h));
    }
    // Pooler + MLM head transform; the MLM decoder is weight-tied to the
    // embedding and therefore not counted again (matches HF param counts).
    layers.push(LayerShape::new(h, h));
    layers.push(LayerShape::new(h, h));
    ModelSpec { name: "BERT-Large-Uncased".into(), layers, effective_batch: 8 * 512 }
}

/// BERT-Base-Cased: 12 blocks, hidden 768, FFN 3072.
pub fn bert_base() -> ModelSpec {
    let h = 768;
    let ffn = 3072;
    let vocab = 28996;
    let mut layers = Vec::new();
    layers.push(LayerShape::new(vocab, h));
    for _ in 0..12 {
        for _ in 0..4 {
            layers.push(LayerShape::new(h, h));
        }
        layers.push(LayerShape::new(h, ffn));
        layers.push(LayerShape::new(ffn, h));
    }
    // Tied MLM decoder not re-counted (see bert_large).
    layers.push(LayerShape::new(h, h));
    layers.push(LayerShape::new(h, h));
    ModelSpec { name: "BERT-Base-Cased".into(), layers, effective_batch: 8 * 384 }
}

/// ResNet-50 conv/fc layers in KFAC's (c_in·k², c_out) linear view.
/// Effective batch = 32 images × mean spatial positions (~196 at stride-16
/// resolution); 32·196 ≈ 6272, but KFAC implementations subsample spatial
/// positions; KAISA's effective per-GPU batch for factor math is ~32·49.
pub fn resnet50() -> ModelSpec {
    let mut layers = Vec::new();
    let mut push_conv = |cin: usize, k: usize, cout: usize, n: usize| {
        for _ in 0..n {
            layers.push(LayerShape::new(cin * k * k, cout));
        }
    };
    // Stem.
    push_conv(3, 7, 64, 1);
    // Stage conv blocks (bottlenecks): (1x1 reduce, 3x3, 1x1 expand) × blocks.
    // Stage 1: 3 blocks, width 64→256.
    push_conv(64, 1, 64, 1);
    push_conv(64, 3, 64, 3);
    push_conv(64, 1, 256, 3);
    push_conv(256, 1, 64, 2);
    push_conv(64, 1, 256, 1); // downsample shortcut
    // Stage 2: 4 blocks, width 128→512.
    push_conv(256, 1, 128, 4);
    push_conv(128, 3, 128, 4);
    push_conv(128, 1, 512, 4);
    push_conv(256, 1, 512, 1);
    // Stage 3: 6 blocks, width 256→1024.
    push_conv(512, 1, 256, 6);
    push_conv(256, 3, 256, 6);
    push_conv(256, 1, 1024, 6);
    push_conv(512, 1, 1024, 1);
    // Stage 4: 3 blocks, width 512→2048.
    push_conv(1024, 1, 512, 3);
    push_conv(512, 3, 512, 3);
    push_conv(512, 1, 2048, 3);
    push_conv(1024, 1, 2048, 1);
    // Classifier.
    layers.push(LayerShape::new(2048, 1000));
    ModelSpec { name: "ResNet-50".into(), layers, effective_batch: 32 * 49 }
}

/// AlexNet, CIFAR-100 variant used in §8.12 (paper: 20.3M params): 5 conv
/// + 3 fc; the 32×32 input leaves a 2×2 spatial map before the classifier,
/// which is what brings the fc1 below the 37.7M of ImageNet AlexNet.
pub fn alexnet() -> ModelSpec {
    let mut layers = Vec::new();
    layers.push(LayerShape::new(3 * 11 * 11, 64));
    layers.push(LayerShape::new(64 * 5 * 5, 192));
    layers.push(LayerShape::new(192 * 3 * 3, 384));
    layers.push(LayerShape::new(384 * 3 * 3, 256));
    layers.push(LayerShape::new(256 * 3 * 3, 256));
    layers.push(LayerShape::new(256 * 2 * 2, 4096));
    layers.push(LayerShape::new(4096, 4096));
    layers.push(LayerShape::new(4096, 100));
    ModelSpec { name: "AlexNet".into(), layers, effective_batch: 128 }
}

/// A GPT-2-small-scale causal LM, expressed through the SAME
/// [`TransformerConfig::layer_shapes`](crate::model::TransformerConfig)
/// the live [`Transformer`](crate::model::Transformer) proxy builds from —
/// the cost model prices exactly the layer structure the Rust-native
/// substrate trains (fused QKV per block, tied unembedding not re-counted).
/// Effective batch = 8 sequences × 1024 tokens.
pub fn causal_lm() -> ModelSpec {
    let cfg = crate::model::TransformerConfig {
        vocab: 50257,
        d_model: 768,
        n_heads: 12,
        n_blocks: 12,
        d_ff: 3072,
        seq_len: 1024,
    };
    ModelSpec {
        name: "Causal-LM-small".into(),
        layers: cfg.layer_shapes(),
        effective_batch: 8 * 1024,
    }
}

/// The autoencoder of the Figure 4 experiment (CIFAR-100-shaped).
pub fn autoencoder_spec() -> ModelSpec {
    let dims = [3072usize, 1024, 256, 64, 256, 1024, 3072];
    let layers = dims
        .windows(2)
        .map(|w| LayerShape::new(w[0], w[1]))
        .collect();
    ModelSpec { name: "Autoencoder".into(), layers, effective_batch: 128 }
}

/// All specs keyed by CLI-friendly names.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "bert-large" => Some(bert_large()),
        "bert-base" => Some(bert_base()),
        "resnet50" => Some(resnet50()),
        "alexnet" => Some(alexnet()),
        "autoencoder" => Some(autoencoder_spec()),
        "causal-lm" => Some(causal_lm()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_large_param_count_in_range() {
        // Paper Table 7: 335.1M parameters. Our layer view (no layernorm /
        // position embeddings, decoder counted once) should land within ~15%.
        let p = bert_large().params() as f64 / 1e6;
        assert!(p > 310.0 && p < 360.0, "params={p}M");
    }

    #[test]
    fn bert_base_param_count_in_range() {
        let p = bert_base().params() as f64 / 1e6;
        assert!(p > 95.0 && p < 120.0, "params={p}M"); // paper: 108.9M
    }

    #[test]
    fn resnet50_param_count_in_range() {
        let p = resnet50().params() as f64 / 1e6;
        assert!(p > 20.0 && p < 30.0, "params={p}M"); // paper: 25.5M
    }

    #[test]
    fn alexnet_param_count_in_range() {
        let p = alexnet().params() as f64 / 1e6;
        assert!(p > 15.0 && p < 26.0, "params={p}M"); // paper: 20.3M
    }

    #[test]
    fn causal_lm_param_count_in_range() {
        // GPT-2-small scale: ~124M (embed 38.6M + 12 × 7.1M blocks; tied
        // unembedding counted once, as in the live Transformer).
        let spec = causal_lm();
        let p = spec.params() as f64 / 1e6;
        assert!(p > 115.0 && p < 135.0, "params={p}M");
        // Fused QKV appears as ONE (768 → 2304) layer per block.
        assert!(spec.layers.iter().any(|l| l.d_in == 768 && l.d_out == 3 * 768));
        assert_eq!(spec.layers.len(), 1 + 4 * 12);
    }

    #[test]
    fn transformer_dims_dominate_resnet_dims() {
        // The paper's core scaling argument: d in transformers >> d in CNNs.
        assert!(bert_large().max_dim() > resnet50().max_dim());
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("bert-large").is_some());
        assert!(by_name("nope").is_none());
    }
}
