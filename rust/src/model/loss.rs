//! Loss functions (column-sample layout): softmax cross-entropy, MSE,
//! accuracy. All return `(loss, dL/dlogits)` with the gradient already
//! averaged over the batch, matching the `∇_W L` convention of Algorithm 1.

use crate::linalg::Matrix;

/// Softmax cross-entropy over logits `C×b` with integer labels.
pub fn softmax_xent(logits: &Matrix, labels: &[usize]) -> (f64, Matrix) {
    let (c, b) = (logits.rows(), logits.cols());
    assert_eq!(labels.len(), b);
    let mut dl = Matrix::zeros(c, b);
    let mut loss = 0.0f64;
    for col in 0..b {
        // Stable log-sum-exp per column.
        let mut maxv = f32::NEG_INFINITY;
        for i in 0..c {
            maxv = maxv.max(logits[(i, col)]);
        }
        let mut z = 0.0f64;
        for i in 0..c {
            z += ((logits[(i, col)] - maxv) as f64).exp();
        }
        let logz = z.ln() + maxv as f64;
        let y = labels[col];
        assert!(y < c, "label {y} out of range {c}");
        loss += logz - logits[(y, col)] as f64;
        for i in 0..c {
            let p = ((logits[(i, col)] as f64) - logz).exp();
            dl[(i, col)] = (p as f32 - if i == y { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    (loss / b as f64, dl)
}

/// Mean-squared error `mean((pred-target)^2)` (mean over all entries).
pub fn mse_loss(pred: &Matrix, target: &Matrix) -> (f64, Matrix) {
    assert_eq!(pred.rows(), target.rows());
    assert_eq!(pred.cols(), target.cols());
    let n = pred.len() as f64;
    let mut dl = Matrix::zeros(pred.rows(), pred.cols());
    let mut loss = 0.0f64;
    for ((d, &p), &t) in dl.data_mut().iter_mut().zip(pred.data()).zip(target.data()) {
        let e = p - t;
        loss += (e as f64) * (e as f64);
        *d = 2.0 * e / n as f32;
    }
    (loss / n, dl)
}

/// Top-1 accuracy of logits against labels.
pub fn accuracy(logits: &Matrix, labels: &[usize]) -> f64 {
    let (c, b) = (logits.rows(), logits.cols());
    assert_eq!(labels.len(), b);
    let mut correct = 0usize;
    for col in 0..b {
        let mut best = (f32::NEG_INFINITY, 0usize);
        for i in 0..c {
            if logits[(i, col)] > best.0 {
                best = (logits[(i, col)], i);
            }
        }
        if best.1 == labels[col] {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xent_of_perfect_prediction_is_small() {
        let mut logits = Matrix::zeros(3, 2);
        logits[(0, 0)] = 20.0;
        logits[(2, 1)] = 20.0;
        let (loss, _) = softmax_xent(&logits, &[0, 2]);
        assert!(loss < 1e-6);
    }

    #[test]
    fn xent_uniform_is_log_c() {
        let logits = Matrix::zeros(4, 3);
        let (loss, dl) = softmax_xent(&logits, &[0, 1, 2]);
        assert!((loss - (4.0f64).ln()).abs() < 1e-9);
        // Gradient columns sum to zero (softmax minus one-hot).
        for col in 0..3 {
            let s: f32 = (0..4).map(|i| dl[(i, col)]).sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn xent_gradient_finite_difference() {
        let mut logits = Matrix::from_rows(&[&[0.3, -0.2], &[0.1, 0.5], &[-0.4, 0.2]]);
        let labels = [2usize, 0];
        let (_, dl) = softmax_xent(&logits, &labels);
        let eps = 1e-3f32;
        for i in 0..3 {
            for j in 0..2 {
                let orig = logits[(i, j)];
                logits[(i, j)] = orig + eps;
                let (lp, _) = softmax_xent(&logits, &labels);
                logits[(i, j)] = orig - eps;
                let (lm, _) = softmax_xent(&logits, &labels);
                logits[(i, j)] = orig;
                let num = (lp - lm) / (2.0 * eps as f64);
                assert!((num - dl[(i, j)] as f64).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn mse_basics() {
        let p = Matrix::from_rows(&[&[1.0, 2.0]]);
        let t = Matrix::from_rows(&[&[0.0, 0.0]]);
        let (loss, dl) = mse_loss(&p, &t);
        assert!((loss - 2.5).abs() < 1e-9); // (1+4)/2
        assert!((dl[(0, 0)] - 1.0).abs() < 1e-6); // 2*1/2
        assert!((dl[(0, 1)] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        assert!((accuracy(&logits, &[0, 1]) - 1.0).abs() < 1e-12);
        assert!((accuracy(&logits, &[1, 1]) - 0.5).abs() < 1e-12);
    }
}
