//! Rust-native neural network with per-layer activation/gradient capture.
//!
//! The convergence experiments (Figures 2/4/6/11/12, Tables 2/3/5) need to
//! train real models under eight different optimizers, and KFAC-family
//! optimizers need, per layer `m`, the batch of input activations
//! `A_t^{m-1} ∈ R^{d_in×b}` and pre-activation input gradients
//! `G_t^m ∈ R^{d_out×b}` — exactly the quantities Algorithm 1 consumes. The
//! [`Mlp`] here is a column-sample (d×b) fully-connected network whose
//! backward pass returns those captures for every layer.
//!
//! The ~100M-parameter transformer path lives in JAX (L2) and is executed
//! from Rust via `runtime`; this module is the substrate for the many
//! smaller optimizer-comparison experiments where the paper itself uses an
//! autoencoder / AlexNet-scale models (§4 "Inversion Frequency", §8.12).

pub mod loss;
pub mod mlp;
pub mod specs;

pub use loss::{accuracy, mse_loss, softmax_xent};
pub use mlp::{Activation, Capture, Dense, Mlp};

/// Shape of one learnable layer (used by optimizers to allocate state and
/// by the cost model to price steps at paper scale).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerShape {
    pub d_in: usize,
    pub d_out: usize,
}

impl LayerShape {
    pub fn new(d_in: usize, d_out: usize) -> Self {
        LayerShape { d_in, d_out }
    }

    /// Parameter count (weights only; biases are first-order everywhere).
    pub fn params(&self) -> usize {
        self.d_in * self.d_out
    }
}
