//! Rust-native neural networks with per-layer activation/gradient capture.
//!
//! The convergence experiments (Figures 2/4/6/11/12, Tables 2/3/5) need to
//! train real models under eight different optimizers, and KFAC-family
//! optimizers need, per layer `m`, the batch of input activations
//! `A_t^{m-1} ∈ R^{d_in×b}` and pre-activation input gradients
//! `G_t^m ∈ R^{d_out×b}` — exactly the quantities Algorithm 1 consumes.
//! Two substrates implement that contract behind the [`Model`] trait:
//!
//! * [`Mlp`] — a column-sample (d×b) fully-connected network, the proxy
//!   for the paper's autoencoder / AlexNet-scale experiments (§4
//!   "Inversion Frequency", §8.12);
//! * [`Transformer`] — a small causal transformer ([`transformer`]) whose
//!   attention/MLP projections are plain [`Dense`] layers, so every
//!   optimizer in the registry preconditions it unchanged. Sequence
//!   positions fold into the batch dimension
//!   ([`Model::cols_per_sample`]), which is the `b·s` effective-batch
//!   regime the paper's complexity argument is about.
//!
//! The ~100M-parameter transformer path additionally lives in JAX (L2) and
//! is executed from Rust via `runtime`; the [`Transformer`] here is the
//! Rust-native proxy that exercises the same layer structure at
//! experiment scale.

pub mod loss;
pub mod mlp;
pub mod specs;
pub mod transformer;

pub use loss::{accuracy, mse_loss, softmax_xent};
pub use mlp::{Activation, Capture, Dense, Mlp};
pub use transformer::{Transformer, TransformerConfig};

use crate::checkpoint::Checkpointable;
use crate::linalg::Matrix;

/// Shape of one learnable layer (used by optimizers to allocate state and
/// by the cost model to price steps at paper scale).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerShape {
    pub d_in: usize,
    pub d_out: usize,
}

impl LayerShape {
    pub fn new(d_in: usize, d_out: usize) -> Self {
        LayerShape { d_in, d_out }
    }

    /// Parameter count (weights only; biases are first-order everywhere).
    pub fn params(&self) -> usize {
        self.d_in * self.d_out
    }
}

/// A trainable network the [`Trainer`](crate::coordinator::Trainer) can
/// drive: forward/backward with per-layer KFAC-style [`Capture`]s, plus a
/// flat [`Dense`] parameter list the optimizers step directly.
///
/// Object-safe on purpose — the trainer holds `Box<dyn Model>` replicas so
/// one step loop serves every substrate. The contract mirrors [`Mlp`]:
///
/// * `forward` caches whatever `backward` needs; `infer` never touches
///   training state;
/// * `backward` consumes the loss gradient at the network *output* (the
///   1/batch averaging already folded in by [`loss`]'s functions) and
///   returns one capture per entry of `layers()`, in the same order;
/// * `cols_per_sample` declares how many output columns one input column
///   produces — 1 for the MLP, `seq_len` for the transformer, whose
///   sequence positions unroll into the batch dimension. Targets and
///   capture widths scale by this factor.
pub trait Model: Checkpointable + Send {
    /// Training forward pass (caches intermediates for [`Model::backward`]).
    fn forward(&mut self, x: &Matrix) -> Matrix;

    /// Inference-only forward (no caching, doesn't disturb training state).
    fn infer(&self, x: &Matrix) -> Matrix;

    /// Backward from `dL/dy` at the network output; returns per-layer
    /// captures in `layers()` order.
    fn backward(&mut self, dldy: &Matrix) -> Vec<Capture>;

    /// The learnable layers, in capture order.
    fn layers(&self) -> &[Dense];

    /// Mutable view for the optimizer's parameter update.
    fn layers_mut(&mut self) -> &mut [Dense];

    /// Clone into a fresh boxed replica (data-parallel workers).
    fn clone_model(&self) -> Box<dyn Model>;

    /// Output columns produced per input column (see trait docs).
    fn cols_per_sample(&self) -> usize {
        1
    }

    /// Per-layer shapes, as optimizers allocate state from them.
    fn shapes(&self) -> Vec<LayerShape> {
        self.layers().iter().map(Dense::shape).collect()
    }

    fn num_params(&self) -> usize {
        self.layers().iter().map(|l| l.w.len() + l.bias.len()).sum()
    }

    /// True if any parameter is non-finite (divergence detector used by
    /// the Table 5 learning-rate sweep).
    fn diverged(&self) -> bool {
        self.layers()
            .iter()
            .any(|l| !l.w.all_finite() || l.bias.iter().any(|b| !b.is_finite()))
    }
}

impl Model for Mlp {
    fn forward(&mut self, x: &Matrix) -> Matrix {
        Mlp::forward(self, x)
    }

    fn infer(&self, x: &Matrix) -> Matrix {
        Mlp::infer(self, x)
    }

    fn backward(&mut self, dldy: &Matrix) -> Vec<Capture> {
        Mlp::backward(self, dldy)
    }

    fn layers(&self) -> &[Dense] {
        &self.layers
    }

    fn layers_mut(&mut self) -> &mut [Dense] {
        &mut self.layers
    }

    fn clone_model(&self) -> Box<dyn Model> {
        Box::new(self.clone())
    }
}
