//! Fully-connected network (column-sample layout) with KFAC-style captures.

use crate::linalg::{ops, Matrix};
use crate::model::LayerShape;
use crate::util::Rng;

/// Pointwise nonlinearity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Relu,
    Tanh,
    /// tanh-approximated GELU (what BERT uses).
    Gelu,
}

impl Activation {
    #[inline]
    pub(crate) fn apply(self, z: f32) -> f32 {
        match self {
            Activation::Linear => z,
            Activation::Relu => z.max(0.0),
            Activation::Tanh => z.tanh(),
            Activation::Gelu => {
                let c = 0.7978845608f32; // sqrt(2/pi)
                0.5 * z * (1.0 + (c * (z + 0.044715 * z * z * z)).tanh())
            }
        }
    }

    /// Derivative evaluated at pre-activation `z`.
    #[inline]
    pub(crate) fn grad(self, z: f32) -> f32 {
        match self {
            Activation::Linear => 1.0,
            Activation::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => {
                let t = z.tanh();
                1.0 - t * t
            }
            Activation::Gelu => {
                let c = 0.7978845608f32;
                let u = c * (z + 0.044715 * z * z * z);
                let t = u.tanh();
                let sech2 = 1.0 - t * t;
                0.5 * (1.0 + t) + 0.5 * z * sech2 * c * (1.0 + 3.0 * 0.044715 * z * z)
            }
        }
    }
}

/// One dense layer `y = act(W a + bias)`, weights `d_out×d_in`.
#[derive(Clone, Debug)]
pub struct Dense {
    pub w: Matrix,
    pub bias: Vec<f32>,
    pub act: Activation,
}

impl Dense {
    /// He-style initialization (scaled for the activation).
    pub fn init(shape: LayerShape, act: Activation, rng: &mut Rng) -> Self {
        let gain = match act {
            Activation::Relu | Activation::Gelu => 2.0f32,
            _ => 1.0,
        };
        let sigma = (gain / shape.d_in as f32).sqrt();
        Dense {
            w: Matrix::randn(shape.d_out, shape.d_in, sigma, rng),
            bias: vec![0.0; shape.d_out],
            act,
        }
    }

    pub fn shape(&self) -> LayerShape {
        LayerShape::new(self.w.cols(), self.w.rows())
    }
}

/// What the backward pass records for one layer — the inputs to every
/// second-order optimizer in this repo (names follow Algorithm 1):
#[derive(Clone, Debug)]
pub struct Capture {
    /// `A_t^{m-1}`: input activations, d_in×b.
    pub a: Matrix,
    /// `G_t^m`: loss gradient wrt the layer's pre-activation output, d_out×b.
    pub g: Matrix,
    /// `∇_{W^m} L = G Aᵀ`, d_out×d_in. The 1/b batch averaging is already
    /// inside `G` (folded in by the loss gradient), so this is the
    /// batch-mean gradient.
    pub dw: Matrix,
    /// Bias gradient (row sums of G; batch-mean for the same reason).
    pub db: Vec<f32>,
}

/// A sequential dense network.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Dense>,
    /// Per-layer (input, pre-activation) caches from the last forward.
    cache: Vec<(Matrix, Matrix)>,
}

impl Mlp {
    /// Build from a dims spec `[in, h1, ..., out]` with `act` on all hidden
    /// layers and a linear head.
    pub fn new(dims: &[usize], act: Activation, rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2);
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for i in 0..dims.len() - 1 {
            let a = if i + 2 == dims.len() { Activation::Linear } else { act };
            layers.push(Dense::init(LayerShape::new(dims[i], dims[i + 1]), a, rng));
        }
        Mlp { layers, cache: Vec::new() }
    }

    pub fn shapes(&self) -> Vec<LayerShape> {
        self.layers.iter().map(Dense::shape).collect()
    }

    pub fn num_params(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.w.len() + l.bias.len())
            .sum()
    }

    /// Forward pass; caches per-layer inputs and pre-activations for
    /// [`Mlp::backward`]. `x` is d_in×b.
    pub fn forward(&mut self, x: &Matrix) -> Matrix {
        self.cache.clear();
        let mut a = x.clone();
        for layer in &self.layers {
            let mut z = ops::matmul(&layer.w, &a);
            for i in 0..z.rows() {
                let bi = layer.bias[i];
                for v in z.row_mut(i) {
                    *v += bi;
                }
            }
            let mut out = z.clone();
            for v in out.data_mut() {
                *v = layer.act.apply(*v);
            }
            self.cache.push((a, z));
            a = out;
        }
        a
    }

    /// Inference-only forward (no caching, doesn't disturb training state).
    pub fn infer(&self, x: &Matrix) -> Matrix {
        let mut a = x.clone();
        for layer in &self.layers {
            let mut z = ops::matmul(&layer.w, &a);
            for i in 0..z.rows() {
                let bi = layer.bias[i];
                for v in z.row_mut(i) {
                    *v += bi;
                }
            }
            for v in z.data_mut() {
                *v = layer.act.apply(*v);
            }
            a = z;
        }
        a
    }

    /// Backward from `dL/dy` of the network output (d_out×b). Returns the
    /// per-layer captures, outermost layer last (same order as `layers`).
    ///
    /// `dldy` is expected to already include the 1/b batch averaging, as
    /// produced by [`crate::model::loss`]'s functions — so `dw = G Aᵀ` here
    /// is the batch-mean weight gradient without further scaling.
    pub fn backward(&mut self, dldy: &Matrix) -> Vec<Capture> {
        assert_eq!(self.cache.len(), self.layers.len(), "forward() before backward()");
        let mut grads: Vec<Option<Capture>> = (0..self.layers.len()).map(|_| None).collect();
        let mut up = dldy.clone(); // dL/d(layer output)
        for (idx, layer) in self.layers.iter().enumerate().rev() {
            let (a, z) = &self.cache[idx];
            // g = dL/dz = up ⊙ act'(z)
            let mut g = up.clone();
            for (gv, &zv) in g.data_mut().iter_mut().zip(z.data()) {
                *gv *= layer.act.grad(zv);
            }
            // dW = G Aᵀ (1/b already folded into dldy by the loss).
            let dw = ops::matmul_nt(&g, a);
            let db: Vec<f32> = (0..g.rows())
                .map(|i| g.row(i).iter().sum::<f32>())
                .collect();
            // dL/d(input) = Wᵀ g
            if idx > 0 {
                up = ops::matmul_tn(&layer.w, &g);
            }
            grads[idx] = Some(Capture { a: a.clone(), g, dw, db });
        }
        grads.into_iter().map(Option::unwrap).collect()
    }

    /// Apply per-layer weight deltas: `W -= lr * delta`, `bias -= lr * db`.
    pub fn apply_update(&mut self, deltas: &[Matrix], dbs: &[Vec<f32>], lr: f32) {
        assert_eq!(deltas.len(), self.layers.len());
        for ((layer, dw), db) in self.layers.iter_mut().zip(deltas).zip(dbs) {
            assert_eq!(layer.w.rows(), dw.rows());
            assert_eq!(layer.w.cols(), dw.cols());
            for (w, &d) in layer.w.data_mut().iter_mut().zip(dw.data()) {
                *w -= lr * d;
            }
            for (bv, &d) in layer.bias.iter_mut().zip(db) {
                *bv -= lr * d;
            }
        }
    }

    /// True if any parameter is non-finite (divergence detector used by the
    /// Table 5 learning-rate sweep).
    pub fn diverged(&self) -> bool {
        self.layers
            .iter()
            .any(|l| !l.w.all_finite() || l.bias.iter().any(|b| !b.is_finite()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loss::{mse_loss, softmax_xent};

    fn finite_diff_check(act: Activation) {
        // Numerical gradient check on a tiny network.
        let mut rng = Rng::new(42);
        let mut net = Mlp::new(&[3, 4, 2], act, &mut rng);
        let x = Matrix::randn(3, 5, 1.0, &mut rng);
        let labels = vec![0usize, 1, 0, 1, 1];

        let logits = net.forward(&x);
        let (_, dlogits) = softmax_xent(&logits, &labels);
        let caps = net.backward(&dlogits);

        let eps = 1e-3f32;
        for (li, layer) in net.layers.clone().iter().enumerate() {
            for &(i, j) in &[(0usize, 0usize), (1, 2), (layer.w.rows() - 1, layer.w.cols() - 1)] {
                let orig = net.layers[li].w[(i, j)];
                net.layers[li].w[(i, j)] = orig + eps;
                let (lp, _) = softmax_xent(&net.infer(&x), &labels);
                net.layers[li].w[(i, j)] = orig - eps;
                let (lm, _) = softmax_xent(&net.infer(&x), &labels);
                net.layers[li].w[(i, j)] = orig;
                let num = (lp - lm) / (2.0 * eps as f64);
                let ana = caps[li].dw[(i, j)] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                    "{act:?} layer {li} ({i},{j}): numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences_tanh() {
        finite_diff_check(Activation::Tanh);
    }

    #[test]
    fn gradients_match_finite_differences_relu() {
        finite_diff_check(Activation::Relu);
    }

    #[test]
    fn gradients_match_finite_differences_gelu() {
        finite_diff_check(Activation::Gelu);
    }

    #[test]
    fn bias_gradient_matches_finite_differences() {
        let mut rng = Rng::new(43);
        let mut net = Mlp::new(&[3, 4, 2], Activation::Tanh, &mut rng);
        let x = Matrix::randn(3, 6, 1.0, &mut rng);
        let y = Matrix::randn(2, 6, 1.0, &mut rng);
        let out = net.forward(&x);
        let (_, dldy) = mse_loss(&out, &y);
        let caps = net.backward(&dldy);
        let eps = 1e-3f32;
        let orig = net.layers[0].bias[1];
        net.layers[0].bias[1] = orig + eps;
        let (lp, _) = mse_loss(&net.infer(&x), &y);
        net.layers[0].bias[1] = orig - eps;
        let (lm, _) = mse_loss(&net.infer(&x), &y);
        net.layers[0].bias[1] = orig;
        let num = (lp - lm) / (2.0 * eps as f64);
        assert!((num - caps[0].db[1] as f64).abs() < 1e-2);
    }

    #[test]
    fn capture_shapes() {
        let mut rng = Rng::new(44);
        let mut net = Mlp::new(&[5, 7, 3], Activation::Relu, &mut rng);
        let x = Matrix::randn(5, 4, 1.0, &mut rng);
        let out = net.forward(&x);
        assert_eq!(out.rows(), 3);
        assert_eq!(out.cols(), 4);
        let caps = net.backward(&Matrix::zeros(3, 4));
        assert_eq!(caps.len(), 2);
        assert_eq!(caps[0].a.rows(), 5);
        assert_eq!(caps[0].g.rows(), 7);
        assert_eq!(caps[1].a.rows(), 7);
        assert_eq!(caps[1].g.rows(), 3);
        assert_eq!(caps[0].dw.rows(), 7);
        assert_eq!(caps[0].dw.cols(), 5);
    }

    #[test]
    fn sgd_on_captures_learns_xor() {
        // End-to-end sanity: raw gradient descent on the captures solves XOR.
        let mut rng = Rng::new(45);
        let mut net = Mlp::new(&[2, 16, 2], Activation::Tanh, &mut rng);
        let x = Matrix::from_rows(&[&[0.0, 0.0, 1.0, 1.0], &[0.0, 1.0, 0.0, 1.0]]);
        let labels = vec![0usize, 1, 1, 0];
        let mut last = f64::INFINITY;
        for _ in 0..400 {
            let logits = net.forward(&x);
            let (loss, dlogits) = softmax_xent(&logits, &labels);
            let caps = net.backward(&dlogits);
            let deltas: Vec<Matrix> = caps.iter().map(|c| c.dw.clone()).collect();
            let dbs: Vec<Vec<f32>> = caps.iter().map(|c| c.db.clone()).collect();
            net.apply_update(&deltas, &dbs, 0.5);
            last = loss;
        }
        assert!(last < 0.05, "XOR loss {last}");
    }

    #[test]
    fn num_params_counts_weights_and_biases() {
        let mut rng = Rng::new(46);
        let net = Mlp::new(&[3, 5, 2], Activation::Relu, &mut rng);
        assert_eq!(net.num_params(), 3 * 5 + 5 + 5 * 2 + 2);
    }
}
