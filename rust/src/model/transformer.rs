//! A small causal transformer in the repo's from-scratch style, built so
//! every optimizer in the registry preconditions it unchanged.
//!
//! Design choices, all in service of the KFAC-family capture contract:
//!
//! * **Every learnable projection is a [`Dense`]** in one flat list —
//!   `[embed, (qkv, proj, fc1, fc2) × n_blocks]` — so the trainer's
//!   all-reduce, the optimizers and the checkpoint machinery see exactly
//!   the layer structure they already handle for [`Mlp`](super::Mlp).
//! * **Q/K/V are fused into one `d_model → 3·d_model` projection**, i.e.
//!   the three weight-shared heads of one token position share a single
//!   Kronecker factor pair — Eschenhagen et al.'s "expand" setting for
//!   weight-sharing layers (PAPERS.md). MKOR's `l_inv` for that layer is
//!   `3d×3d`, its `r_inv` is `d×d`.
//! * **Sequence positions fold into the batch dimension**: a `seq_len×b`
//!   token batch unrolls to `n = b·s` activation columns (column `j·s+t`
//!   is sample `j`, position `t`), so `col_mean` rank-1 vectors average
//!   over `b·s` samples — the effective-batch regime the paper's
//!   complexity argument (§1) is about.
//! * **Tied unembedding**: logits are `W_embᵀ·h`, and the embedding's
//!   capture `dw` sums both uses (embedding-side `G·A₀ᵀ` plus
//!   unembedding-side `h·dlogitsᵀ`). The factor statistics `(a, g)` come
//!   from the embedding-side use only, where `a` is the one-hot token
//!   matrix — the mean-activation view of the input distribution.
//! * **No LayerNorm**: the optimizers under study precondition linear
//!   layers; normalization layers are first-order everywhere in the paper
//!   and would add parameters outside the capture contract. Stability at
//!   proxy depth (≤ a few blocks) comes from He-scaled init + residuals.
//! * Positional information is a parameter-free sinusoidal table added to
//!   the embedding output.
//!
//! Attention is exact causal softmax attention, per sample and head:
//! `S = QᵀK/√hd` (lower-triangular), `P = softmax_rows(S)`,
//! `O = V·Pᵀ`; the backward pass propagates through the softmax Jacobian
//! (`dS_i = P_i ⊙ (dP_i − (dP_i·P_i))`) with masked entries contributing
//! nothing because their probabilities are exactly zero.

use crate::linalg::{ops, Matrix};
use crate::model::{Activation, Capture, Dense, LayerShape};
use crate::util::Rng;

/// Transformer dimensions. `n_heads` must divide `d_model`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    /// MLP hidden width (the `fc1` output / `fc2` input dimension).
    pub d_ff: usize,
    /// Fixed sequence length of every batch.
    pub seq_len: usize,
}

impl TransformerConfig {
    /// The proxy scale the `charlm` task trains: small enough for CI,
    /// deep enough (2 blocks, 4 heads) to exercise every projection kind.
    pub fn proxy(vocab: usize, seq_len: usize) -> Self {
        TransformerConfig { vocab, d_model: 32, n_heads: 4, n_blocks: 2, d_ff: 64, seq_len }
    }

    /// The flat learnable-layer list, in capture order:
    /// `[embed, (qkv, proj, fc1, fc2) × n_blocks]`. Shared between the
    /// live model and the paper-scale cost specs
    /// ([`specs::causal_lm`](super::specs::causal_lm)).
    pub fn layer_shapes(&self) -> Vec<LayerShape> {
        let d = self.d_model;
        let mut out = vec![LayerShape::new(self.vocab, d)];
        for _ in 0..self.n_blocks {
            out.push(LayerShape::new(d, 3 * d)); // fused Q/K/V (expand setting)
            out.push(LayerShape::new(d, d)); // attention output projection
            out.push(LayerShape::new(d, self.d_ff));
            out.push(LayerShape::new(self.d_ff, d));
        }
        out
    }
}

/// Per-block forward caches (everything the backward pass reads).
#[derive(Clone, Debug)]
struct BlockCache {
    /// Block input = the qkv layer's `A`, d_model×n.
    h_in: Matrix,
    /// Fused q/k/v pre-activations (the qkv layer's linear output), 3d×n.
    qkv: Matrix,
    /// Concatenated head outputs = the proj layer's `A`, d_model×n.
    attn_in: Matrix,
    /// Post-attention residual stream = fc1's `A`, d_model×n.
    h_mid: Matrix,
    /// fc1 pre-activation (for the GELU derivative), d_ff×n.
    z1: Matrix,
    /// GELU(z1) = fc2's `A`, d_ff×n.
    u: Matrix,
    /// Causal softmax rows, one s×s matrix per (sample, head), sample-major.
    probs: Vec<Matrix>,
}

#[derive(Clone, Debug)]
struct FwdCache {
    /// One-hot token matrix (the embedding layer's `A`), vocab×n.
    a0: Matrix,
    blocks: Vec<BlockCache>,
    /// Final hidden state (the tied unembedding's input), d_model×n.
    h_final: Matrix,
}

/// The causal transformer. See the module docs for the design contract.
#[derive(Clone, Debug)]
pub struct Transformer {
    pub cfg: TransformerConfig,
    /// Flat layer list: `[embed, (qkv, proj, fc1, fc2) × n_blocks]`.
    pub layers: Vec<Dense>,
    /// Sinusoidal positional table, d_model×seq_len (parameter-free).
    pos: Matrix,
    cache: Option<FwdCache>,
}

/// `W·a + bias` (no activation; callers apply GELU where needed).
fn affine(layer: &Dense, a: &Matrix) -> Matrix {
    let mut z = ops::matmul(&layer.w, a);
    for i in 0..z.rows() {
        let bi = layer.bias[i];
        for v in z.row_mut(i) {
            *v += bi;
        }
    }
    z
}

fn row_sums(g: &Matrix) -> Vec<f32> {
    (0..g.rows()).map(|i| g.row(i).iter().sum::<f32>()).collect()
}

impl Transformer {
    pub fn new(cfg: TransformerConfig, rng: &mut Rng) -> Self {
        assert!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0, "n_heads must divide d_model");
        assert!(cfg.seq_len > 0 && cfg.vocab > 0 && cfg.n_blocks > 0);
        let d = cfg.d_model;
        let mut layers = Vec::with_capacity(1 + 4 * cfg.n_blocks);
        layers.push(Dense::init(LayerShape::new(cfg.vocab, d), Activation::Linear, rng));
        for _ in 0..cfg.n_blocks {
            layers.push(Dense::init(LayerShape::new(d, 3 * d), Activation::Linear, rng));
            layers.push(Dense::init(LayerShape::new(d, d), Activation::Linear, rng));
            layers.push(Dense::init(LayerShape::new(d, cfg.d_ff), Activation::Gelu, rng));
            layers.push(Dense::init(LayerShape::new(cfg.d_ff, d), Activation::Linear, rng));
        }
        let mut pos = Matrix::zeros(d, cfg.seq_len);
        for t in 0..cfg.seq_len {
            for i in 0..d {
                let freq = 10000f32.powf(-((i / 2) as f32 * 2.0) / d as f32);
                let angle = t as f32 * freq;
                pos[(i, t)] = if i % 2 == 0 { angle.sin() } else { angle.cos() };
            }
        }
        Transformer { cfg, layers, pos, cache: None }
    }

    /// Training forward. `x` is a `seq_len×b` matrix of token ids; the
    /// output is `vocab×(b·seq_len)` logits with column `j·s+t` holding
    /// sample `j`'s next-token prediction at position `t`.
    pub fn forward(&mut self, x: &Matrix) -> Matrix {
        let (out, cache) = self.run(x, true);
        self.cache = cache;
        out
    }

    /// Inference-only forward (no caching).
    pub fn infer(&self, x: &Matrix) -> Matrix {
        self.run(x, false).0
    }

    fn run(&self, x: &Matrix, keep: bool) -> (Matrix, Option<FwdCache>) {
        let s = self.cfg.seq_len;
        assert_eq!(x.rows(), s, "x is seq_len×batch token ids");
        let b = x.cols();
        let n = b * s;
        let d = self.cfg.d_model;
        let embed = &self.layers[0];
        // One-hot unroll: column j·s+t is sample j, position t. The
        // embedding output is computed by gather (bitwise what the one-hot
        // matmul produces, at O(d·n) instead of O(vocab·d·n)); A₀ itself
        // is still materialized because it IS the embedding's factor input.
        let mut a0 = Matrix::zeros(self.cfg.vocab, n);
        let mut h = Matrix::zeros(d, n);
        for j in 0..b {
            for t in 0..s {
                let tok = x[(t, j)] as usize;
                assert!(tok < self.cfg.vocab, "token id {tok} out of vocab {}", self.cfg.vocab);
                let col = j * s + t;
                a0[(tok, col)] = 1.0;
                for r in 0..d {
                    h[(r, col)] = embed.w[(r, tok)] + embed.bias[r] + self.pos[(r, t)];
                }
            }
        }
        let mut blocks = Vec::with_capacity(if keep { self.cfg.n_blocks } else { 0 });
        for blk in 0..self.cfg.n_blocks {
            let base = 1 + 4 * blk;
            let qkv = affine(&self.layers[base], &h);
            let (attn_in, probs) = self.attention(&qkv, b);
            let proj_out = affine(&self.layers[base + 1], &attn_in);
            let mut h_mid = h.clone();
            for (hv, &p) in h_mid.data_mut().iter_mut().zip(proj_out.data()) {
                *hv += p;
            }
            let z1 = affine(&self.layers[base + 2], &h_mid);
            let mut u = z1.clone();
            for v in u.data_mut() {
                *v = Activation::Gelu.apply(*v);
            }
            let z2 = affine(&self.layers[base + 3], &u);
            let mut h_out = h_mid.clone();
            for (hv, &p) in h_out.data_mut().iter_mut().zip(z2.data()) {
                *hv += p;
            }
            if keep {
                blocks.push(BlockCache { h_in: h, qkv, attn_in, h_mid, z1, u, probs });
            }
            h = h_out;
        }
        // Tied unembedding: logits = W_embᵀ·h (no output bias).
        let logits = ops::matmul_tn(&self.layers[0].w, &h);
        let cache = keep.then(|| FwdCache { a0, blocks, h_final: h });
        (logits, cache)
    }

    /// Causal multi-head attention over the fused `qkv` (3d×n). Returns
    /// the concatenated head outputs (d×n) and the softmax rows per
    /// (sample, head) for the backward pass.
    fn attention(&self, qkv: &Matrix, b: usize) -> (Matrix, Vec<Matrix>) {
        let s = self.cfg.seq_len;
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let hd = d / nh;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = Matrix::zeros(d, b * s);
        let mut probs = Vec::with_capacity(b * nh);
        for j in 0..b {
            let c0 = j * s;
            for head in 0..nh {
                let (qr, kr, vr) = (head * hd, d + head * hd, 2 * d + head * hd);
                let mut p = Matrix::zeros(s, s);
                let mut scores = vec![0f32; s];
                for i in 0..s {
                    // Keys t ≤ i only (causal); stable softmax per row.
                    let mut maxv = f32::NEG_INFINITY;
                    for (t, sc) in scores.iter_mut().enumerate().take(i + 1) {
                        let mut dot = 0f32;
                        for r in 0..hd {
                            dot += qkv[(qr + r, c0 + i)] * qkv[(kr + r, c0 + t)];
                        }
                        *sc = dot * scale;
                        maxv = maxv.max(*sc);
                    }
                    let mut z = 0f32;
                    for sc in scores.iter_mut().take(i + 1) {
                        *sc = (*sc - maxv).exp();
                        z += *sc;
                    }
                    for t in 0..=i {
                        p[(i, t)] = scores[t] / z;
                    }
                }
                // o[:,i] = Σ_{t≤i} p[i][t]·v[:,t]
                for i in 0..s {
                    for r in 0..hd {
                        let mut acc = 0f32;
                        for t in 0..=i {
                            acc += p[(i, t)] * qkv[(vr + r, c0 + t)];
                        }
                        out[(head * hd + r, c0 + i)] = acc;
                    }
                }
                probs.push(p);
            }
        }
        (out, probs)
    }

    /// Gradient through the attention mix: `dout` (d×n) → gradient wrt the
    /// fused qkv pre-activations (3d×n).
    fn attention_backward(
        &self,
        qkv: &Matrix,
        probs: &[Matrix],
        dout: &Matrix,
        b: usize,
    ) -> Matrix {
        let s = self.cfg.seq_len;
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let hd = d / nh;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut g = Matrix::zeros(3 * d, b * s);
        for j in 0..b {
            let c0 = j * s;
            for head in 0..nh {
                let p = &probs[j * nh + head];
                let (qr, kr, vr) = (head * hd, d + head * hd, 2 * d + head * hd);
                let or = head * hd;
                // dV[:,t] = Σ_{i≥t} p[i][t]·dO[:,i]
                for t in 0..s {
                    for r in 0..hd {
                        let mut acc = 0f32;
                        for i in t..s {
                            acc += p[(i, t)] * dout[(or + r, c0 + i)];
                        }
                        g[(vr + r, c0 + t)] = acc;
                    }
                }
                // dP[i][t] = dO[:,i]·V[:,t]; softmax rows:
                // dS_i = P_i ⊙ (dP_i − (dP_i·P_i)).
                let mut ds = Matrix::zeros(s, s);
                let mut dp = vec![0f32; s];
                for i in 0..s {
                    let mut inner = 0f32;
                    for (t, dpt) in dp.iter_mut().enumerate().take(i + 1) {
                        let mut acc = 0f32;
                        for r in 0..hd {
                            acc += dout[(or + r, c0 + i)] * qkv[(vr + r, c0 + t)];
                        }
                        *dpt = acc;
                        inner += p[(i, t)] * acc;
                    }
                    for t in 0..=i {
                        ds[(i, t)] = p[(i, t)] * (dp[t] - inner);
                    }
                }
                // dQ[:,i] = scale·Σ_{t≤i} dS[i][t]·K[:,t]
                for i in 0..s {
                    for r in 0..hd {
                        let mut acc = 0f32;
                        for t in 0..=i {
                            acc += ds[(i, t)] * qkv[(kr + r, c0 + t)];
                        }
                        g[(qr + r, c0 + i)] = acc * scale;
                    }
                }
                // dK[:,t] = scale·Σ_{i≥t} dS[i][t]·Q[:,i]
                for t in 0..s {
                    for r in 0..hd {
                        let mut acc = 0f32;
                        for i in t..s {
                            acc += ds[(i, t)] * qkv[(qr + r, c0 + i)];
                        }
                        g[(kr + r, c0 + t)] = acc * scale;
                    }
                }
            }
        }
        g
    }

    /// Backward from `dL/dlogits` (vocab×n, the 1/n batch averaging
    /// already folded in by the loss). Returns one capture per layer in
    /// `layers` order; see the module docs for the tied-embedding and
    /// shared-QKV capture conventions.
    pub fn backward(&mut self, dlogits: &Matrix) -> Vec<Capture> {
        let cache = self.cache.as_ref().expect("forward() before backward()");
        let b = dlogits.cols() / self.cfg.seq_len;
        // Tied unembedding (logits = W_embᵀ·h_final): this use contributes
        // h_final·dlogitsᵀ to the embedding's dw and routes the gradient
        // into the stream as W_emb·dlogits.
        let dw_tied = ops::matmul_nt(&cache.h_final, dlogits);
        let mut dh = ops::matmul(&self.layers[0].w, dlogits);

        let mut caps: Vec<Option<Capture>> = (0..self.layers.len()).map(|_| None).collect();
        for blk in (0..self.cfg.n_blocks).rev() {
            let base = 1 + 4 * blk;
            let bc = &cache.blocks[blk];
            // MLP sub-block: h_out = h_mid + fc2(gelu(fc1(h_mid))).
            let g2 = dh.clone();
            let dw2 = ops::matmul_nt(&g2, &bc.u);
            let db2 = row_sums(&g2);
            let mut g1 = ops::matmul_tn(&self.layers[base + 3].w, &g2);
            for (gv, &zv) in g1.data_mut().iter_mut().zip(bc.z1.data()) {
                *gv *= Activation::Gelu.grad(zv);
            }
            let dw1 = ops::matmul_nt(&g1, &bc.h_mid);
            let db1 = row_sums(&g1);
            let mut dh_mid = ops::matmul_tn(&self.layers[base + 2].w, &g1);
            for (a, &bv) in dh_mid.data_mut().iter_mut().zip(dh.data()) {
                *a += bv; // residual skip
            }
            // Attention sub-block: h_mid = h_in + proj(attn(qkv(h_in))).
            let g_proj = dh_mid.clone();
            let dw_proj = ops::matmul_nt(&g_proj, &bc.attn_in);
            let db_proj = row_sums(&g_proj);
            let d_attn_in = ops::matmul_tn(&self.layers[base + 1].w, &g_proj);
            let g_qkv = self.attention_backward(&bc.qkv, &bc.probs, &d_attn_in, b);
            let dw_qkv = ops::matmul_nt(&g_qkv, &bc.h_in);
            let db_qkv = row_sums(&g_qkv);
            let mut dh_in = ops::matmul_tn(&self.layers[base].w, &g_qkv);
            for (a, &bv) in dh_in.data_mut().iter_mut().zip(dh_mid.data()) {
                *a += bv; // residual skip
            }
            caps[base] = Some(Capture { a: bc.h_in.clone(), g: g_qkv, dw: dw_qkv, db: db_qkv });
            caps[base + 1] =
                Some(Capture { a: bc.attn_in.clone(), g: g_proj, dw: dw_proj, db: db_proj });
            caps[base + 2] = Some(Capture { a: bc.h_mid.clone(), g: g1, dw: dw1, db: db1 });
            caps[base + 3] = Some(Capture { a: bc.u.clone(), g: g2, dw: dw2, db: db2 });
            dh = dh_in;
        }
        // Embedding: z = W·a₀ + bias, h₀ = z + pos (identity gradient).
        // dw sums both uses of the tied weight; the factor inputs (a, g)
        // stay embedding-side (one-hot a₀ against the stream gradient).
        let g0 = dh;
        let mut dw0 = ops::matmul_nt(&g0, &cache.a0);
        for (w, &t) in dw0.data_mut().iter_mut().zip(dw_tied.data()) {
            *w += t;
        }
        let db0 = row_sums(&g0);
        caps[0] = Some(Capture { a: cache.a0.clone(), g: g0, dw: dw0, db: db0 });
        caps.into_iter().map(Option::unwrap).collect()
    }
}

impl crate::model::Model for Transformer {
    fn forward(&mut self, x: &Matrix) -> Matrix {
        Transformer::forward(self, x)
    }

    fn infer(&self, x: &Matrix) -> Matrix {
        Transformer::infer(self, x)
    }

    fn backward(&mut self, dldy: &Matrix) -> Vec<Capture> {
        Transformer::backward(self, dldy)
    }

    fn layers(&self) -> &[Dense] {
        &self.layers
    }

    fn layers_mut(&mut self) -> &mut [Dense] {
        &mut self.layers
    }

    fn clone_model(&self) -> Box<dyn crate::model::Model> {
        Box::new(self.clone())
    }

    /// Sequence positions fold into the batch dimension: one input column
    /// (one sequence) produces `seq_len` output columns.
    fn cols_per_sample(&self) -> usize {
        self.cfg.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::softmax_xent;
    use crate::model::Model;
    use crate::optim::{OptimizerSpec, ALL_OPTIMIZERS};
    use crate::util::timer::PhaseTimer;

    fn tiny_cfg() -> TransformerConfig {
        TransformerConfig { vocab: 11, d_model: 8, n_heads: 2, n_blocks: 2, d_ff: 12, seq_len: 5 }
    }

    fn token_batch(cfg: &TransformerConfig, b: usize, rng: &mut Rng) -> (Matrix, Vec<usize>) {
        let mut x = Matrix::zeros(cfg.seq_len, b);
        let mut labels = Vec::with_capacity(b * cfg.seq_len);
        for j in 0..b {
            for t in 0..cfg.seq_len {
                x[(t, j)] = rng.next_below(cfg.vocab as u64) as f32;
                labels.push(rng.next_below(cfg.vocab as u64) as usize);
            }
        }
        (x, labels)
    }

    #[test]
    fn layer_list_matches_the_shape_spec() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let net = Transformer::new(cfg, &mut rng);
        assert_eq!(net.shapes(), cfg.layer_shapes());
        assert_eq!(net.layers.len(), 1 + 4 * cfg.n_blocks);
    }

    #[test]
    fn sequence_folds_into_batch() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(2);
        let mut net = Transformer::new(cfg, &mut rng);
        let (x, labels) = token_batch(&cfg, 3, &mut rng);
        let out = net.forward(&x);
        // vocab×(b·s) logits — 3 sequences unroll to 15 activation columns.
        assert_eq!((out.rows(), out.cols()), (cfg.vocab, 3 * cfg.seq_len));
        assert_eq!(net.cols_per_sample(), cfg.seq_len);
        let (_, dl) = softmax_xent(&out, &labels);
        let caps = net.backward(&dl);
        assert_eq!(caps.len(), net.layers.len());
        for (c, l) in caps.iter().zip(&net.layers) {
            // Every capture sees the full b·s unrolled batch — what
            // col_mean's rank-1 vectors average over.
            assert_eq!(c.a.cols(), 3 * cfg.seq_len);
            assert_eq!(c.g.cols(), 3 * cfg.seq_len);
            assert_eq!((c.dw.rows(), c.dw.cols()), (l.w.rows(), l.w.cols()));
            assert_eq!(c.db.len(), l.bias.len());
        }
    }

    #[test]
    fn shared_qkv_projection_shares_one_factor_pair() {
        // The fused QKV layer is ONE Dense (Eschenhagen et al. "expand"):
        // one d×d input factor and one 3d×3d output factor for all three
        // of Q, K, V — not three separate pairs.
        let cfg = tiny_cfg();
        let d = cfg.d_model;
        let mut rng = Rng::new(3);
        let mut net = Transformer::new(cfg, &mut rng);
        let (x, labels) = token_batch(&cfg, 4, &mut rng);
        let out = net.forward(&x);
        let (_, dl) = softmax_xent(&out, &labels);
        let caps = net.backward(&dl);
        assert_eq!(caps[1].a.rows(), d, "qkv factor input is the shared stream");
        assert_eq!(caps[1].g.rows(), 3 * d, "qkv output gradient is the fused 3d block");

        let mut opt = crate::optim::mkor::Mkor::new(&net.shapes(), Default::default());
        let mut timer = PhaseTimer::new();
        opt.step(&mut net.layers, &caps, 0.1, &mut timer);
        let (l_inv, r_inv) = opt.factors(1);
        assert_eq!((l_inv.rows(), l_inv.cols()), (3 * d, 3 * d));
        assert_eq!((r_inv.rows(), r_inv.cols()), (d, d));
    }

    #[test]
    fn causal_masking_blocks_future_positions() {
        // Changing a token can only move logits at its own and LATER
        // positions — earlier columns of the same sample stay bitwise
        // identical, other samples are untouched.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(4);
        let net = Transformer::new(cfg, &mut rng);
        let (x, _) = token_batch(&cfg, 2, &mut rng);
        let base = net.infer(&x);
        let mut x2 = x.clone();
        let flip_t = 3;
        x2[(flip_t, 0)] = (x[(flip_t, 0)] as usize as u64 + 1) as f32 % cfg.vocab as f32;
        let out = net.infer(&x2);
        for j in 0..2 {
            for t in 0..cfg.seq_len {
                let col = j * cfg.seq_len + t;
                let same = (0..cfg.vocab).all(|r| base[(r, col)].to_bits() == out[(r, col)].to_bits());
                if j == 1 || t < flip_t {
                    assert!(same, "sample {j} pos {t} must not see the future edit");
                } else if t == flip_t {
                    assert!(!same, "the edited position itself must move");
                }
            }
        }
    }

    #[test]
    fn infer_matches_forward_and_leaves_training_state_alone() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(5);
        let mut net = Transformer::new(cfg, &mut rng);
        let (x, labels) = token_batch(&cfg, 2, &mut rng);
        let out = net.forward(&x);
        let quiet = net.infer(&x);
        assert_eq!(out.data(), quiet.data());
        // infer didn't clobber the forward cache — backward still works.
        let (_, dl) = softmax_xent(&out, &labels);
        let caps = net.backward(&dl);
        assert_eq!(caps.len(), net.layers.len());
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Numerical check through every projection kind: the tied
        // embedding (both uses summed), fused QKV + attention softmax,
        // output projection, both MLP layers, and the residual paths.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(42);
        let mut net = Transformer::new(cfg, &mut rng);
        let (x, labels) = token_batch(&cfg, 3, &mut rng);
        let logits = net.forward(&x);
        let (_, dlogits) = softmax_xent(&logits, &labels);
        let caps = net.backward(&dlogits);

        let eps = 1e-3f32;
        for li in 0..net.layers.len() {
            let (rows, cols) = (net.layers[li].w.rows(), net.layers[li].w.cols());
            for &(i, j) in &[(0usize, 0usize), (1, 2), (rows - 1, cols - 1)] {
                let orig = net.layers[li].w[(i, j)];
                net.layers[li].w[(i, j)] = orig + eps;
                let (lp, _) = softmax_xent(&net.infer(&x), &labels);
                net.layers[li].w[(i, j)] = orig - eps;
                let (lm, _) = softmax_xent(&net.infer(&x), &labels);
                net.layers[li].w[(i, j)] = orig;
                let num = (lp - lm) / (2.0 * eps as f64);
                let ana = caps[li].dw[(i, j)] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                    "layer {li} ({i},{j}): numeric {num} vs analytic {ana}"
                );
            }
            // One bias entry per layer.
            let orig = net.layers[li].bias[0];
            net.layers[li].bias[0] = orig + eps;
            let (lp, _) = softmax_xent(&net.infer(&x), &labels);
            net.layers[li].bias[0] = orig - eps;
            let (lm, _) = softmax_xent(&net.infer(&x), &labels);
            net.layers[li].bias[0] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = caps[li].db[0] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                "layer {li} bias: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn every_registry_optimizer_steps_the_transformer() {
        // The whole point of the Dense-capture contract: all eight
        // optimizers precondition the transformer with zero special cases.
        let cfg = tiny_cfg();
        for name in ALL_OPTIMIZERS {
            let mut rng = Rng::new(7);
            let mut net = Transformer::new(cfg, &mut rng);
            let (x, labels) = token_batch(&cfg, 2, &mut rng);
            let mut opt = OptimizerSpec::parse(name).unwrap().build(&net.shapes());
            let mut timer = PhaseTimer::new();
            for _ in 0..3 {
                let out = net.forward(&x);
                let (loss, dl) = softmax_xent(&out, &labels);
                assert!(loss.is_finite(), "{name}");
                let caps = net.backward(&dl);
                opt.step(&mut net.layers, &caps, 0.05, &mut timer);
                opt.observe_loss(loss);
            }
            assert!(!net.diverged(), "{name} produced non-finite weights");
        }
    }
}
