//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets use [`bench_fn`] for timing (warmup + adaptive
//! repeats + median/MAD) and the table printers for the paper-style
//! output. Results additionally land as JSON/CSV under `results/`.

use crate::util::stats;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median_secs.max(1e-12)
    }
}

/// Time `f`, returning median over enough repeats to fill ~`budget_secs`.
/// The closure's result is black-boxed so the work isn't elided.
pub fn bench_fn<T>(name: &str, budget_secs: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + estimate.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_secs / est).ceil() as usize).clamp(3, 1000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    let s = stats::summarize(&samples);
    BenchResult {
        name: name.to_string(),
        median_secs: s.median,
        mean_secs: s.mean,
        std_secs: s.std,
        iters,
    }
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows_len(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for wd in w {
                s.push_str(&"-".repeat(wd + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = sep(&widths);
        out.push_str(&fmt_row(&self.headers));
        out.push_str(&sep(&widths));
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out.push_str(&sep(&widths));
        out
    }

    /// CSV form (for results/ dumps).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn save_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else if s < 3600.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_measures_something() {
        let r = bench_fn("spin", 0.02, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.median_secs > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Optimizer", "Speedup"]);
        t.row(&["MKOR".into(), "2.57x".into()]);
        t.row(&["LAMB".into(), "1.00x".into()]);
        let s = t.render();
        assert!(s.contains("| MKOR"));
        assert!(s.lines().all(|l| l.len() == s.lines().next().unwrap().len()));
        let csv = t.to_csv();
        assert!(csv.starts_with("Optimizer,Speedup\n"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a"]);
        t.row(&["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(0.5), "500.00 ms");
        assert_eq!(fmt_bytes(2048.0), "2.0 KiB");
        assert!(fmt_secs(7200.0).contains('h'));
    }
}
