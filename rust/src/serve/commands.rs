//! CLI front-ends for the serving stack: `mkor serve`, `mkor submit`,
//! `mkor jobs`, `mkor observe` and the artifact generator `mkor
//! artifacts`. `main.rs` only dispatches here.

use crate::cli::Args;
use crate::obs;
use crate::runtime::sim;
use crate::serve::client::Client;
use crate::serve::daemon::{self, ServeOptions};
use crate::serve::protocol::JobSpec;
use crate::util::json::Json;
use std::io::{IsTerminal, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

const DEFAULT_ADDR: &str = "127.0.0.1:7070";

/// `mkor serve --addr HOST:PORT --dir D [--capacity N] [--runners N]
/// [--job-workers N]`: run the training-as-a-service daemon until
/// SIGTERM/SIGINT or a `shutdown` op.
pub fn cmd_serve(args: &Args) -> i32 {
    let dir = PathBuf::from(args.get_or("dir", "serve-data"));
    let mut opts = ServeOptions::new(args.get_or("addr", DEFAULT_ADDR), dir.clone());
    opts.capacity = args.usize_or("capacity", 64);
    opts.runners = args.usize_or("runners", 1);
    // The daemon always runs with a trace sink so subscriptions have a
    // live feed: the session-wide `--trace PATH` if one was installed,
    // else its own `<dir>/trace.jsonl`.
    opts.trace_path = if obs::enabled() {
        args.get("trace")
            .map(str::to_string)
            .or_else(|| std::env::var("MKOR_TRACE").ok())
            .map(PathBuf::from)
    } else {
        let path = dir.join("trace.jsonl");
        if let Err(e) = std::fs::create_dir_all(&dir) {
            eprintln!("error: creating {}: {e}", dir.display());
            return 1;
        }
        match obs::install(&path) {
            Ok(()) => Some(path),
            Err(e) => {
                obs::log::warn(&format!("serve: no trace sink ({e:#}); streams carry states only"));
                None
            }
        }
    };
    match daemon::serve(&opts) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: serve: {e:#}");
            1
        }
    }
}

/// Build a [`JobSpec`] from `submit`'s CLI flags (defaults mirror
/// `mkor sweep`).
fn spec_from_args(args: &Args) -> Result<JobSpec, String> {
    let specs = args.get("specs").ok_or_else(|| {
        "usage: mkor submit --addr HOST:PORT --specs \"kfac:f={5,10};lamb\" \
         [--task glue] [--steps N] [--lr LR] [--cell-workers W] [--batch B] \
         [--seed S] [--eval-every N] [--hidden 96,48] [--job-workers N] \
         [--wait [--out sweep.csv] [--json sweep.json]]"
            .to_string()
    })?;
    let mut spec = JobSpec::new(specs, args.get_or("task", "glue"));
    spec.steps = args.usize_or("steps", spec.steps);
    spec.lr = args.f32_or("lr", spec.lr);
    spec.cell_workers = args.usize_or("cell-workers", spec.cell_workers);
    spec.batch = args.usize_or("batch", spec.batch);
    spec.seed = args.u64_or("seed", spec.seed);
    spec.eval_every = args.usize_or("eval-every", spec.eval_every);
    spec.job_workers = args.usize_or("job-workers", spec.job_workers);
    if let Some(h) = args.get("hidden") {
        spec.hidden = h
            .split(',')
            .map(|w| w.trim().parse::<usize>().map_err(|_| ()))
            .collect::<Result<Vec<_>, ()>>()
            .map_err(|()| format!("bad --hidden `{h}`: expected widths like `96,48`"))?;
    }
    Ok(spec)
}

/// `mkor submit --addr A --specs "..." [...] [--wait]`: enqueue one sweep
/// job; with `--wait`, poll to completion and optionally save the
/// artifacts locally (byte-identical to a direct `mkor sweep` run).
pub fn cmd_submit(args: &Args) -> i32 {
    let spec = match spec_from_args(args) {
        Ok(spec) => spec,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let mut client = match Client::connect_retry(addr, Duration::from_secs(5)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let job = match client.submit(&spec) {
        Ok(job) => job,
        Err(e) => {
            eprintln!("error: submit: {e:#}");
            return 1;
        }
    };
    println!("submitted {job}");
    if !args.flag("wait") {
        return 0;
    }
    let timeout = Duration::from_secs_f64(args.f64_or("timeout-secs", 3600.0));
    let view = match client.wait(&job, timeout) {
        Ok(view) => view,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!("{job}: {}", view.state);
    if view.state != "done" {
        if let Some(d) = &view.detail {
            eprintln!("{d}");
        }
        return 1;
    }
    if args.get("out").is_some() || args.get("json").is_some() {
        let (csv, json) = match client.result(&job) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: result: {e:#}");
                return 1;
            }
        };
        for (flag, payload) in [("out", csv), ("json", json)] {
            if let Some(path) = args.get(flag) {
                if let Err(e) = std::fs::write(path, payload) {
                    eprintln!("error: saving {path}: {e}");
                    return 1;
                }
                println!("wrote {path}");
            }
        }
    }
    0
}

/// `mkor jobs --addr A [--cancel JOB]`: list the daemon's jobs or cancel
/// a queued one.
pub fn cmd_jobs(args: &Args) -> i32 {
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let mut client = match Client::connect_retry(addr, Duration::from_secs(5)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    if let Some(job) = args.get("cancel") {
        return match client.cancel(job) {
            Ok(()) => {
                println!("cancelled {job}");
                0
            }
            Err(e) => {
                eprintln!("error: cancel: {e:#}");
                1
            }
        };
    }
    let jobs = match client.jobs() {
        Ok(jobs) => jobs,
        Err(e) => {
            eprintln!("error: jobs: {e:#}");
            return 1;
        }
    };
    if jobs.is_empty() {
        println!("no jobs");
        return 0;
    }
    let mut t = crate::bench_utils::Table::new(&["job", "state", "task", "steps", "specs"]);
    for j in &jobs {
        let state = match &j.detail {
            Some(d) => format!("{} ({d})", j.state),
            None => j.state.clone(),
        };
        t.row(&[j.id.clone(), state, j.task.clone(), j.steps.to_string(), j.specs.clone()]);
    }
    print!("{}", t.render());
    0
}

/// `mkor observe JOB --addr A`: subscribe to a job and follow its live
/// feed — the same aggregated view as `mkor tail` on a terminal, one
/// rendered event line per trace event under a pipe.
pub fn cmd_observe(args: &Args) -> i32 {
    let Some(job) = args.positional.get(1) else {
        eprintln!("usage: mkor observe JOB --addr HOST:PORT");
        return 2;
    };
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let mut client = match Client::connect_retry(addr, Duration::from_secs(5)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    if let Err(e) = client.subscribe(job) {
        eprintln!("error: subscribe: {e:#}");
        return 1;
    }
    let ansi = std::io::stdout().is_terminal();
    let mut view = obs::TailView::default();
    let mut drawn_lines = 0usize;
    loop {
        let line = match client.read_json_line() {
            Ok(Some(line)) => line,
            Ok(None) => {
                eprintln!("error: daemon closed the stream");
                return 1;
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        };
        match line.get("stream").and_then(Json::as_str) {
            Some("state") => {
                let state = line.get("state").and_then(Json::as_str).unwrap_or("?");
                let detail = line.get("detail").and_then(Json::as_str);
                println!("{job}: {state}{}", detail.map(|d| format!(" ({d})")).unwrap_or_default());
                match state {
                    "done" => return 0,
                    "failed" | "cancelled" => return 1,
                    _ => {}
                }
            }
            Some("event") => {
                let Some(ev) = line.get("event") else { continue };
                match obs::TraceEvent::from_json(ev) {
                    Ok(ev) => {
                        if ansi {
                            view.absorb(&ev);
                            let screen = view.render();
                            let mut out = std::io::stdout().lock();
                            if drawn_lines > 0 {
                                let _ = write!(out, "\x1b[{drawn_lines}A\x1b[J");
                            }
                            let _ = out.write_all(screen.as_bytes());
                            let _ = out.flush();
                            drawn_lines = screen.lines().count();
                        } else {
                            println!("{}", ev.render());
                        }
                    }
                    Err(e) => obs::log::warn(&format!("observe: bad event: {e}")),
                }
            }
            _ => obs::log::warn(&format!("observe: unexpected line: {line}")),
        }
    }
}

/// `mkor artifacts [--out artifacts] [--preset tiny|small]`: generate the
/// sim-backend preset bundles that `mkor train` and the artifact-driven
/// tests load. Writing them is cheap and deterministic; CI runs this
/// before the test suite so `e2e_smoke`/`xla_cross_check` never skip.
pub fn cmd_artifacts(args: &Args) -> i32 {
    let out = PathBuf::from(args.get_or("out", "artifacts"));
    let presets: Vec<&str> = match args.get("preset") {
        Some(p) => vec![p],
        None => sim::PRESETS.to_vec(),
    };
    for preset in presets {
        match sim::write_preset(&out, preset) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => {
                eprintln!("error: generating `{preset}`: {e:#}");
                return 1;
            }
        }
    }
    0
}

/// Shared by tests: the default artifacts directory relative to the repo
/// root (cargo runs tests with the package root as cwd).
pub fn default_artifacts_dir() -> &'static Path {
    Path::new("artifacts")
}
