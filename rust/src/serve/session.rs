//! One connection, one thread: read framed request lines, answer each in
//! order, stream subscriptions inline.
//!
//! The handler is written so that no client behavior can take the daemon
//! down or desync the stream: every line gets exactly one response (typed
//! error included), oversized lines are drained to the next newline, and
//! a dead socket ends only this session. A silent client is disconnected
//! after [`IDLE_LIMIT`] (reads poll every [`READ_POLL`], so sessions also
//! notice daemon shutdown instead of blocking forever), and a client
//! pausing mid-line keeps its partial bytes across timeouts — no desync.
//! Pipelined requests are answered strictly in arrival order.

use crate::serve::daemon::{job_dir, plan_job, Ctx};
use crate::serve::protocol::{
    parse_request, read_line_capped_idle, stream_state_line, ErrorCode, ProtoError, ReadLine,
    Request, Response, MAX_LINE_BYTES,
};
use crate::serve::queue::JobState;
use crate::serve::signal;
use anyhow::{Context as _, Result};
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Socket read timeout: how often an idle read wakes to re-check the
/// stop flag and the idle budget.
const READ_POLL: Duration = Duration::from_secs(1);

/// A session that sends nothing for this long is closed — a silent
/// client must not pin a daemon thread forever. The clock resets on
/// every received line, so any active client is unaffected.
const IDLE_LIMIT: Duration = Duration::from_secs(10 * 60);

pub fn handle_conn(stream: TcpStream, ctx: &Ctx) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL)).context("setting session read timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning session socket")?);
    let mut writer = stream;
    loop {
        let idle_since = Instant::now();
        let keep_waiting = || !signal::stop_requested() && idle_since.elapsed() < IDLE_LIMIT;
        match read_line_capped_idle(&mut reader, keep_waiting).context("reading request line")? {
            ReadLine::Eof => return Ok(()),
            // Daemon shutting down, or the client went silent past the
            // idle budget: end this session cleanly.
            ReadLine::Idle => return Ok(()),
            ReadLine::Oversized { discarded } => {
                let e = ProtoError::new(
                    ErrorCode::Oversized,
                    format!(
                        "request line of {discarded} bytes exceeds the {MAX_LINE_BYTES}-byte cap"
                    ),
                );
                write_line(&mut writer, &Response::Error(e).to_line())?;
            }
            ReadLine::Line(bytes) => {
                if bytes.iter().all(u8::is_ascii_whitespace) {
                    continue; // blank keep-alive lines are not an error
                }
                match parse_request(&bytes) {
                    Err(e) => write_line(&mut writer, &Response::Error(e).to_line())?,
                    Ok(Request::Subscribe { job }) => {
                        // Streams write multiple lines; handled apart from
                        // the one-line request/response ops.
                        run_subscription(&mut writer, ctx, &job)?;
                    }
                    Ok(req) => {
                        let resp = answer(req, ctx);
                        write_line(&mut writer, &resp.to_line())?;
                    }
                }
            }
        }
    }
}

fn write_line(w: &mut TcpStream, line: &str) -> Result<()> {
    w.write_all(line.as_bytes())
        .and_then(|()| w.write_all(b"\n"))
        .context("writing response")
}

/// Answer every non-streaming op. Infallible by construction: failures
/// become `Response::Error`.
fn answer(req: Request, ctx: &Ctx) -> Response {
    match req {
        Request::Ping => Response::Pong { server: format!("mkor {}", crate::VERSION) },
        Request::Jobs => {
            Response::Jobs { jobs: ctx.queue.list().iter().map(|j| j.view()).collect() }
        }
        Request::Status { job } => match ctx.queue.get(&job) {
            Some(rec) => Response::Status { job: rec.view() },
            None => Response::Error(ProtoError::unknown_job(&job)),
        },
        Request::Cancel { job } => match ctx.queue.cancel(&job) {
            Ok(rec) => {
                ctx.subs.broadcast_state(&rec);
                Response::Cancelled { job: rec.id }
            }
            Err(e) => Response::Error(e),
        },
        Request::Submit { spec } => {
            // Validate end-to-end *before* enqueueing: a spec that cannot
            // plan (unknown task, bad grid) must never occupy the queue or
            // the journal.
            if let Err(e) = plan_job(&spec) {
                return Response::Error(ProtoError::bad_request(format!("{e:#}")));
            }
            match ctx.queue.submit(spec) {
                Ok(rec) => Response::Submitted { job: rec.id },
                Err(e) => Response::Error(e),
            }
        }
        Request::Result { job } => result_payload(ctx, &job),
        Request::Shutdown => {
            signal::request_stop();
            ctx.queue.shutdown();
            Response::ShuttingDown
        }
        // Handled by the caller before `answer`.
        Request::Subscribe { job } => Response::Error(ProtoError::bad_request(format!(
            "internal: subscribe for `{job}` reached answer()"
        ))),
    }
}

fn result_payload(ctx: &Ctx, job: &str) -> Response {
    let Some(rec) = ctx.queue.get(job) else {
        return Response::Error(ProtoError::unknown_job(job));
    };
    if rec.state != JobState::Done {
        let detail = rec.detail.as_deref().map(|d| format!(": {d}")).unwrap_or_default();
        return Response::Error(ProtoError::new(
            ErrorCode::NotDone,
            format!("job `{job}` is {}{detail}; results exist only for done jobs", rec.state.as_str()),
        ));
    }
    let dir = job_dir(&ctx.dir, job);
    let read = |name: &str| {
        std::fs::read_to_string(dir.join(name))
            .map_err(|e| format!("reading {}: {e}", dir.join(name).display()))
    };
    match (read("sweep.csv"), read("sweep.json")) {
        (Ok(csv), Ok(json)) => Response::ResultPayload { job: job.to_string(), csv, json },
        (Err(e), _) | (_, Err(e)) => Response::Error(ProtoError::bad_request(format!(
            "artifacts missing for done job `{job}` ({e})"
        ))),
    }
}

/// Stream a job's live state + trace feed until it reaches a terminal
/// state, then return to request/response mode on the same connection.
///
/// A subscriber killed mid-stream surfaces here as a write error; the
/// subscription is unregistered and only this session ends. The terminal
/// `state` line is detected either from the broadcast itself or — to
/// close the race where a job finishes between `subscribe` and register —
/// by polling the queue on receive timeouts.
fn run_subscription(writer: &mut TcpStream, ctx: &Ctx, job: &str) -> Result<()> {
    let Some(rec) = ctx.queue.get(job) else {
        return write_line(writer, &Response::Error(ProtoError::unknown_job(job)).to_line());
    };
    write_line(writer, &Response::Subscribed { job: job.to_string() }.to_line())?;
    // Opening state frame; for terminal jobs it is also the final one.
    write_line(
        writer,
        &stream_state_line(&rec.id, rec.state.as_str(), rec.detail.as_deref()),
    )?;
    if rec.state.terminal() {
        return Ok(());
    }
    let (sid, rx) = ctx.subs.subscribe(job);
    let streamed = (|| -> Result<()> {
        loop {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(line) => {
                    write_line(writer, &line)?;
                    if is_terminal_state_line(&line) {
                        return Ok(());
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if let Some(now) = ctx.queue.get(job) {
                        if now.state.terminal() {
                            write_line(
                                writer,
                                &stream_state_line(
                                    &now.id,
                                    now.state.as_str(),
                                    now.detail.as_deref(),
                                ),
                            )?;
                            return Ok(());
                        }
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    // Daemon-side teardown; report where the job stands.
                    if let Some(now) = ctx.queue.get(job) {
                        write_line(
                            writer,
                            &stream_state_line(&now.id, now.state.as_str(), now.detail.as_deref()),
                        )?;
                    }
                    return Ok(());
                }
            }
        }
    })();
    ctx.subs.unsubscribe(sid);
    streamed
}

fn is_terminal_state_line(line: &str) -> bool {
    crate::util::json::Json::parse(line).ok().is_some_and(|v| {
        v.get("stream").and_then(crate::util::json::Json::as_str) == Some("state")
            && v.get("state")
                .and_then(crate::util::json::Json::as_str)
                .and_then(JobState::parse)
                .is_some_and(|s| s.terminal())
    })
}
