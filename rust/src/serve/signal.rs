//! Process stop flag for the daemon: SIGTERM/SIGINT (and the `shutdown`
//! op) set one [`AtomicBool`] that the accept and runner loops poll.
//!
//! The handler is installed through the raw libc `signal` symbol — the
//! crate has no libc dependency, and the handler body is a single atomic
//! store, which is async-signal-safe. On non-unix targets installation is
//! a no-op and only the `shutdown` op can stop the daemon.

use std::sync::atomic::{AtomicBool, Ordering};

static STOP: AtomicBool = AtomicBool::new(false);

/// True once a stop was requested by signal or by the `shutdown` op.
pub fn stop_requested() -> bool {
    STOP.load(Ordering::SeqCst)
}

/// Programmatic stop (the `shutdown` op): same effect as SIGTERM.
pub fn request_stop() {
    STOP.store(true, Ordering::SeqCst)
}

/// Reset the flag — test-only, for in-process daemon harnesses that start
/// more than one serve loop per process.
pub fn reset_for_tests() {
    STOP.store(false, Ordering::SeqCst)
}

#[cfg(unix)]
pub fn install_stop_handler() {
    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let handler = on_signal as extern "C" fn(i32);
    unsafe {
        signal(SIGINT, handler as usize);
        signal(SIGTERM, handler as usize);
    }
}

#[cfg(not(unix))]
pub fn install_stop_handler() {}
