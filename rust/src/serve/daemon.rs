//! The `mkor serve` daemon: accept loop, runner threads and the trace
//! pump that feeds live subscriptions.
//!
//! Thread layout:
//!
//! * **accept loop** (caller thread) — non-blocking `accept` + 25 ms poll,
//!   one `session::handle_conn` thread per connection;
//! * **runners** (`--runners N`, default 1) — claim queued jobs in FIFO
//!   order and run them through the same `run_sweep_mp` fan-out the
//!   `mkor sweep --workers` CLI uses, always with `recover = true` so a
//!   job interrupted by a daemon crash resumes from its scratch files;
//! * **trace pump** — follows the daemon's own `--trace` sink with
//!   [`obs::TraceFollower`] and relays each event to subscribers of the
//!   currently running job. The sink is daemon-wide, so events can only
//!   be attributed to a job when exactly one is running: with
//!   `--runners > 1` the pump skips ambiguous windows (and `serve` warns
//!   at startup) rather than interleave one job's events into another
//!   job's stream.
//!
//! Shutdown (SIGTERM, SIGINT or the `shutdown` op) stops accepting
//! connections, submits and claims, lets the in-flight job finish — its
//! transitions keep journaling — and exits 0 with a flushed journal.
//! Queued jobs are not drained: they stay journaled and run on the next
//! start.

use crate::experiments::convergence::RunOpts;
use crate::obs;
use crate::serve::protocol::{stream_state_line, JobSpec};
use crate::serve::queue::{JobQueue, JobRecord};
use crate::serve::{session, signal};
use crate::sweep::{run_sweep_mp, task_by_name, MpOptions, SweepGrid, SweepOptions};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Test hook: hold each claimed job in `running` for this many
/// milliseconds before executing it, giving tests a deterministic window
/// to observe `running`/`queue_full` states. Unset in normal operation.
pub const RUN_DELAY_ENV: &str = "MKOR_SERVE_RUN_DELAY_MS";

#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 picks a free port (printed on stdout).
    pub addr: String,
    /// Daemon state directory: journal, per-job artifacts, default trace.
    pub dir: PathBuf,
    /// Max *queued* jobs before `submit` answers `queue_full`.
    pub capacity: usize,
    /// Concurrent runner threads.
    pub runners: usize,
    /// Trace file the pump follows for subscription streams (the daemon's
    /// own obs sink); `None` disables streaming of trace events. The sink
    /// is shared daemon-wide, so live event streaming is only attributable
    /// with `runners == 1`; with more runners the pump drops events while
    /// several jobs run concurrently.
    pub trace_path: Option<PathBuf>,
}

impl ServeOptions {
    pub fn new(addr: impl Into<String>, dir: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions {
            addr: addr.into(),
            dir: dir.into(),
            capacity: 64,
            runners: 1,
            trace_path: None,
        }
    }
}

/// One live subscription: stream lines queue onto an unbounded channel
/// drained by the subscriber's session thread.
struct Sub {
    id: u64,
    job: String,
    tx: mpsc::Sender<String>,
}

/// Registry of live subscriptions, shared by runners (state transitions),
/// the trace pump (events) and sessions (register/unregister).
#[derive(Default)]
pub struct Subscribers {
    inner: Mutex<(u64, Vec<Sub>)>,
}

impl Subscribers {
    pub fn subscribe(&self, job: &str) -> (u64, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        let mut inner = self.inner.lock().unwrap();
        inner.0 += 1;
        let id = inner.0;
        inner.1.push(Sub { id, job: job.to_string(), tx });
        (id, rx)
    }

    pub fn unsubscribe(&self, id: u64) {
        self.inner.lock().unwrap().1.retain(|s| s.id != id);
    }

    /// Send one line to every subscriber of `job`, dropping subscribers
    /// whose session is gone (a killed client never blocks the sender:
    /// the channel is unbounded and send-errors just unregister).
    pub fn send_to(&self, job: &str, line: &str) {
        self.inner
            .lock()
            .unwrap()
            .1
            .retain(|s| s.job != job || s.tx.send(line.to_string()).is_ok());
    }

    pub fn broadcast_state(&self, job: &JobRecord) {
        self.send_to(
            &job.id,
            &stream_state_line(&job.id, job.state.as_str(), job.detail.as_deref()),
        );
    }
}

/// State shared by every daemon thread.
pub struct Ctx {
    pub queue: JobQueue,
    pub subs: Subscribers,
    pub dir: PathBuf,
}

/// Run the daemon until a stop is requested; returns the process exit
/// code (0 on a clean shutdown).
pub fn serve(opts: &ServeOptions) -> Result<i32> {
    std::fs::create_dir_all(&opts.dir)
        .with_context(|| format!("creating serve dir {}", opts.dir.display()))?;
    let queue = JobQueue::open(&opts.dir, opts.capacity.max(1))?;
    let ctx = Arc::new(Ctx { queue, subs: Subscribers::default(), dir: opts.dir.clone() });
    signal::install_stop_handler();

    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    let local = listener.local_addr().context("reading bound address")?;
    // The one contractual stdout line: scripts and tests parse the port
    // from it (`--addr 127.0.0.1:0` binds an ephemeral port).
    println!("mkor serve: listening on {local}");
    std::io::stdout().flush().ok();
    listener.set_nonblocking(true).context("setting the listener non-blocking")?;
    obs::log::note(&format!(
        "serve: dir {}, capacity {}, {} runner(s), protocol v{}",
        opts.dir.display(),
        opts.capacity.max(1),
        opts.runners.max(1),
        crate::serve::protocol::PROTOCOL_VERSION,
    ));

    let mut runners = Vec::new();
    for i in 0..opts.runners.max(1) {
        let ctx = ctx.clone();
        runners.push(
            std::thread::Builder::new()
                .name(format!("mkor-serve-runner-{i}"))
                .spawn(move || runner_loop(&ctx))
                .context("spawning runner thread")?,
        );
    }
    if let Some(trace) = &opts.trace_path {
        if opts.runners.max(1) > 1 {
            obs::log::warn(&format!(
                "serve: trace streaming attributes events to the single running job; \
                 with --runners {} events are dropped whenever several jobs run \
                 concurrently (use --runners 1 for complete live feeds)",
                opts.runners
            ));
        }
        let ctx = ctx.clone();
        let trace = trace.clone();
        std::thread::Builder::new()
            .name("mkor-serve-pump".into())
            .spawn(move || pump_loop(&ctx, &trace))
            .context("spawning trace pump thread")?;
    }

    while !signal::stop_requested() {
        match listener.accept() {
            Ok((stream, peer)) => {
                let ctx = ctx.clone();
                let name = format!("mkor-serve-conn-{peer}");
                let spawned = std::thread::Builder::new().name(name).spawn(move || {
                    // A session error is one client's problem (dropped
                    // socket, bad pipe) — never the daemon's.
                    if let Err(e) = session::handle_conn(stream, &ctx) {
                        obs::log::note(&format!("serve: session {peer}: {e:#}"));
                    }
                });
                if let Err(e) = spawned {
                    obs::log::warn(&format!("serve: spawning session thread: {e}"));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => obs::log::warn(&format!("serve: accept failed: {e}")),
        }
    }

    // Clean shutdown: no new jobs, wake idle runners, wait out the
    // in-flight job so its terminal transition reaches the journal.
    obs::log::note("serve: stop requested; draining runners");
    ctx.queue.shutdown();
    for handle in runners {
        let _ = handle.join();
    }
    obs::log::note("serve: shut down cleanly");
    Ok(0)
}

fn runner_loop(ctx: &Ctx) {
    loop {
        if signal::stop_requested() {
            // Make sure claim waiters (including this one) fall through.
            ctx.queue.shutdown();
        }
        let Some(job) = ctx.queue.claim_next(Duration::from_millis(100)) else {
            if signal::stop_requested() {
                return;
            }
            continue;
        };
        ctx.subs.broadcast_state(&job);
        if let Some(ms) =
            std::env::var(RUN_DELAY_ENV).ok().and_then(|v| v.parse::<u64>().ok())
        {
            std::thread::sleep(Duration::from_millis(ms));
        }
        obs::log::progress(&format!(
            "serve: {} running `{}` on {} ({} steps)",
            job.id, job.spec.specs, job.spec.task, job.spec.steps
        ));
        let outcome = run_job(ctx, &job);
        if let Err(msg) = &outcome {
            obs::log::warn(&format!("serve: {} failed: {msg}"));
        }
        match ctx.queue.finish(&job.id, outcome) {
            Ok(done) => ctx.subs.broadcast_state(&done),
            Err(e) => obs::log::warn(&format!("serve: recording outcome: {e:#}")),
        }
    }
}

/// Where a job's merged artifacts live: `<dir>/jobs/<id>/sweep.{csv,json}`.
pub fn job_dir(dir: &std::path::Path, id: &str) -> PathBuf {
    dir.join("jobs").join(id)
}

/// Execute one job through the subprocess sweep dispatcher. Artifacts are
/// saved deterministic, so they are byte-identical to
/// `mkor sweep --jobs 1 --deterministic` with the same parameters.
fn run_job(ctx: &Ctx, job: &JobRecord) -> std::result::Result<(), String> {
    let spec = &job.spec;
    let (grid, opts) = plan_job(spec).map_err(|e| format!("{e:#}"))?;
    let dir = job_dir(&ctx.dir, &job.id);
    std::fs::create_dir_all(&dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
    let mut mp = MpOptions::new(dir.join("workers"), spec.job_workers.max(1));
    // Always recover: on a fresh job the scratch scan is a no-op; after a
    // daemon crash it reuses every cell the workers already finished.
    mp.recover = true;
    let report = run_sweep_mp(&grid, &opts, &mp, None).map_err(|e| format!("{e:#}"))?;
    report
        .save_csv_with(&dir.join("sweep.csv"), true)
        .and_then(|()| report.save_json_with(&dir.join("sweep.json"), true))
        .map_err(|e| format!("saving artifacts: {e:#}"))?;
    let (ok, diverged, panicked) = report.counts();
    obs::log::progress(&format!(
        "serve: {} finished: {ok} ok, {diverged} diverged, {panicked} panicked",
        job.id
    ));
    if panicked > 0 {
        return Err(format!("{panicked} of {} cells panicked", report.cells.len()));
    }
    Ok(())
}

/// Expand a [`JobSpec`] into the grid + options `mkor sweep` would build
/// from the same flags. Shared by the submit-time validator (sessions
/// reject a spec that cannot plan) and the runner (which plans again to
/// execute), so nothing unrunnable ever enters the queue.
pub fn plan_job(spec: &JobSpec) -> Result<(SweepGrid, SweepOptions)> {
    let task = task_by_name(&spec.task).map_err(|e| anyhow::anyhow!("{e}"))?;
    let grid = SweepGrid::parse(&spec.specs, &task, spec.seed)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut run = RunOpts {
        lr: spec.lr,
        steps: spec.steps,
        workers: spec.cell_workers,
        batch: spec.batch,
        seed: spec.seed,
        eval_every: spec.eval_every,
        ..Default::default()
    };
    if !spec.hidden.is_empty() {
        run.hidden = spec.hidden.clone();
    }
    Ok((grid, SweepOptions { jobs: 1, run, verbose: false }))
}

/// Follow the daemon's own trace sink and fan events out to subscribers
/// of whatever job is running. Events between jobs (daemon housekeeping)
/// have no audience and are skipped — and so are events while *several*
/// jobs run concurrently (`--runners > 1`): the shared sink cannot say
/// which job emitted them, and misattributing one job's sweep into
/// another job's stream is worse than a gap.
fn pump_loop(ctx: &Ctx, trace: &std::path::Path) {
    let mut follower = obs::TraceFollower::new(trace);
    loop {
        let events = follower.poll();
        if !events.is_empty() {
            let running = ctx.queue.running_jobs();
            if let [job] = running.as_slice() {
                for ev in &events {
                    ctx.subs.send_to(job, &crate::serve::protocol::stream_event_line(job, ev));
                }
            }
        }
        if signal::stop_requested() {
            return;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}
