//! The `mkor serve` wire protocol: versioned line-JSON over TCP.
//!
//! Every request and response is exactly one JSON object per `\n`-terminated
//! line, and every line carries `"v": 1` ([`PROTOCOL_VERSION`]). Requests
//! select an operation with `"op"`; responses answer with `"ok": true` plus
//! op-specific fields, or `"ok": false` plus a typed error:
//!
//! ```text
//! -> {"v":1,"op":"submit","spec":{"specs":"lamb","task":"glue","steps":4}}
//! <- {"v":1,"ok":true,"op":"submit","job":"j1"}
//! -> {"v":1,"op":"status","job":"j9"}
//! <- {"v":1,"ok":false,"error":{"code":"unknown_job","message":"no job `j9`"}}
//! ```
//!
//! The parser is strict and total: any byte sequence a client can send maps
//! to either a [`Request`] or a [`ProtoError`] with an [`ErrorCode`] and an
//! actionable message — the daemon never disconnects, panics or desyncs on
//! bad input. Lines longer than [`MAX_LINE_BYTES`] are discarded to the next
//! newline by [`read_line_capped`] (keeping the stream framed) and answered
//! with `oversized`. Blank lines are ignored, as in most line protocols.
//!
//! Subscription streams reuse the same framing with `"stream"` instead of
//! `"ok"`: `{"v":1,"stream":"event","job":..,"event":{..}}` lines relay the
//! live trace feed and a final `{"v":1,"stream":"state",..}` line reports
//! the terminal state (see `session`).

use crate::obs::TraceEvent;
use crate::util::json::Json;
use std::io::{self, BufRead};

/// Wire schema version; bumped on any incompatible change. Both sides send
/// it on every line and reject a mismatch with `version_skew`.
pub const PROTOCOL_VERSION: u64 = 1;

/// Hard cap on one request line. Anything longer is drained and rejected
/// with an `oversized` error; the connection stays framed and usable.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Typed error classes. The code is machine-readable (tests match on it);
/// the accompanying message is for humans and always names what to fix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Not UTF-8, not JSON, not an object, or missing a required envelope
    /// field (`op`).
    Malformed,
    /// Line exceeded [`MAX_LINE_BYTES`].
    Oversized,
    /// Missing or mismatched `"v"`.
    VersionSkew,
    /// Well-formed envelope, but `op` names no known operation.
    UnknownOp,
    /// Known op with missing/invalid arguments (bad spec, bad types).
    BadRequest,
    /// `job` names no job the daemon has ever seen.
    UnknownJob,
    /// Submit refused: the queue already holds `capacity` queued jobs.
    QueueFull,
    /// Cancel refused: the job is running or already terminal.
    NotCancellable,
    /// Result requested before the job reached `done`.
    NotDone,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::Malformed => "malformed",
            ErrorCode::Oversized => "oversized",
            ErrorCode::VersionSkew => "version_skew",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownJob => "unknown_job",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::NotCancellable => "not_cancellable",
            ErrorCode::NotDone => "not_done",
        }
    }
}

/// A rejected line: the typed code plus an actionable message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProtoError {
    pub code: ErrorCode,
    pub message: String,
}

impl ProtoError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ProtoError {
        ProtoError { code, message: message.into() }
    }

    pub fn malformed(message: impl Into<String>) -> ProtoError {
        ProtoError::new(ErrorCode::Malformed, message)
    }

    pub fn bad_request(message: impl Into<String>) -> ProtoError {
        ProtoError::new(ErrorCode::BadRequest, message)
    }

    pub fn unknown_job(id: &str) -> ProtoError {
        ProtoError::new(ErrorCode::UnknownJob, format!("no job `{id}` (see op `jobs`)"))
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

/// Everything needed to run one sweep job — the daemon-side mirror of the
/// `mkor sweep` CLI flags, so a job's artifacts are byte-identical to a
/// direct `mkor sweep --jobs 1 --deterministic` run with the same values.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Sweep grid string (`"kfac:f={5,10},damping=0.01;lamb"` …).
    pub specs: String,
    /// Task name as accepted by `task_by_name`.
    pub task: String,
    pub steps: usize,
    pub lr: f32,
    /// Simulated data-parallel workers inside each cell.
    pub cell_workers: usize,
    pub batch: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// MLP hidden widths; empty selects the task default.
    pub hidden: Vec<usize>,
    /// Crash-isolated worker subprocesses fanned out while the job runs.
    pub job_workers: usize,
}

impl JobSpec {
    /// Defaults mirror the `mkor sweep` CLI (except `job_workers`, which
    /// defaults to a single subprocess per job).
    pub fn new(specs: impl Into<String>, task: impl Into<String>) -> JobSpec {
        JobSpec {
            specs: specs.into(),
            task: task.into(),
            steps: 300,
            lr: 0.1,
            cell_workers: 2,
            batch: 64,
            seed: 0,
            eval_every: 10,
            hidden: Vec::new(),
            job_workers: 1,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("specs", Json::Str(self.specs.clone()))
            .set("task", Json::Str(self.task.clone()))
            .set("steps", Json::Num(self.steps as f64))
            .set("lr", Json::Num(self.lr as f64))
            .set("cell_workers", Json::Num(self.cell_workers as f64))
            .set("batch", Json::Num(self.batch as f64))
            // A string, not a number: JSON numbers travel as f64, which
            // silently rounds seeds above 2^53 and would break the
            // byte-identical determinism contract for such seeds.
            .set("seed", Json::Str(self.seed.to_string()))
            .set("eval_every", Json::Num(self.eval_every as f64))
            .set("job_workers", Json::Num(self.job_workers as f64));
        if !self.hidden.is_empty() {
            o.set("hidden", Json::from_usizes(&self.hidden));
        }
        o
    }

    /// Decode and validate. `specs` and `task` are required; every other
    /// field is optional with CLI defaults, but present fields must have
    /// the right type and sane values.
    pub fn from_json(v: &Json) -> Result<JobSpec, ProtoError> {
        let obj = match v {
            Json::Obj(_) => v,
            _ => return Err(ProtoError::bad_request("`spec` must be a JSON object")),
        };
        let req_str = |key: &str| -> Result<String, ProtoError> {
            obj.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| ProtoError::bad_request(format!("`spec.{key}` (string) is required")))
        };
        let opt_usize = |key: &str, default: usize| -> Result<usize, ProtoError> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v.as_usize().ok_or_else(|| {
                    ProtoError::bad_request(format!("`spec.{key}` must be a non-negative integer"))
                }),
            }
        };
        let mut spec = JobSpec::new(req_str("specs")?, req_str("task")?);
        spec.steps = opt_usize("steps", spec.steps)?;
        spec.cell_workers = opt_usize("cell_workers", spec.cell_workers)?;
        spec.batch = opt_usize("batch", spec.batch)?;
        spec.seed = match obj.get("seed") {
            None => spec.seed,
            // Canonical form: a decimal string, exact for the full u64
            // range (see `to_json`).
            Some(Json::Str(s)) => s.parse::<u64>().map_err(|_| {
                ProtoError::bad_request("`spec.seed` must be a u64 (decimal string or integer)")
            })?,
            // Numeric form, for hand-written clients and v1 journals:
            // exact only below 2^53, so larger values are rejected rather
            // than silently rounded.
            Some(v) => v
                .as_f64()
                .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= (1u64 << 53) as f64)
                .map(|x| x as u64)
                .ok_or_else(|| {
                    ProtoError::bad_request(
                        "`spec.seed` must be a non-negative integer; values above 2^53 \
                         must be sent as a decimal string to avoid float rounding",
                    )
                })?,
        };
        spec.eval_every = opt_usize("eval_every", spec.eval_every)?;
        spec.job_workers = opt_usize("job_workers", spec.job_workers)?;
        if let Some(v) = obj.get("lr") {
            spec.lr = v
                .as_f64()
                .filter(|x| x.is_finite())
                .ok_or_else(|| ProtoError::bad_request("`spec.lr` must be a finite number"))?
                as f32;
        }
        if let Some(v) = obj.get("hidden") {
            let arr = v
                .as_arr()
                .ok_or_else(|| ProtoError::bad_request("`spec.hidden` must be an array"))?;
            spec.hidden = arr
                .iter()
                .map(|w| w.as_usize().filter(|&w| w > 0))
                .collect::<Option<Vec<usize>>>()
                .ok_or_else(|| {
                    ProtoError::bad_request("`spec.hidden` must hold positive integer widths")
                })?;
        }
        if spec.steps == 0 {
            return Err(ProtoError::bad_request("`spec.steps` must be at least 1"));
        }
        if spec.batch == 0 || spec.cell_workers == 0 || spec.job_workers == 0 {
            return Err(ProtoError::bad_request(
                "`spec.batch`, `spec.cell_workers` and `spec.job_workers` must be at least 1",
            ));
        }
        Ok(spec)
    }
}

/// One parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Submit { spec: JobSpec },
    Jobs,
    Status { job: String },
    Cancel { job: String },
    Result { job: String },
    Subscribe { job: String },
    Shutdown,
}

/// The operation names, for error messages and docs.
pub const OPS: &[&str] =
    &["ping", "submit", "jobs", "status", "cancel", "result", "subscribe", "shutdown"];

/// Parse one raw line (sans `\n`) into a [`Request`]. Every failure mode
/// maps to a typed [`ProtoError`]; this function never panics on untrusted
/// bytes.
pub fn parse_request(raw: &[u8]) -> Result<Request, ProtoError> {
    let text = std::str::from_utf8(raw)
        .map_err(|_| ProtoError::malformed("request line is not valid UTF-8"))?;
    let v = Json::parse(text).map_err(|e| ProtoError::malformed(format!("bad JSON: {e}")))?;
    if !matches!(v, Json::Obj(_)) {
        return Err(ProtoError::malformed("request must be a JSON object"));
    }
    match v.get("v").and_then(Json::as_usize) {
        Some(got) if got as u64 == PROTOCOL_VERSION => {}
        Some(got) => {
            return Err(ProtoError::new(
                ErrorCode::VersionSkew,
                format!("protocol version {got} not supported; this daemon speaks v{PROTOCOL_VERSION}"),
            ))
        }
        None => {
            return Err(ProtoError::new(
                ErrorCode::VersionSkew,
                format!("missing `v`: every request must carry \"v\":{PROTOCOL_VERSION}"),
            ))
        }
    }
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| ProtoError::malformed("missing `op` (string)"))?;
    let job_arg = || -> Result<String, ProtoError> {
        v.get("job")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| ProtoError::bad_request(format!("op `{op}` requires `job` (string)")))
    };
    match op {
        "ping" => Ok(Request::Ping),
        "jobs" => Ok(Request::Jobs),
        "shutdown" => Ok(Request::Shutdown),
        "status" => Ok(Request::Status { job: job_arg()? }),
        "cancel" => Ok(Request::Cancel { job: job_arg()? }),
        "result" => Ok(Request::Result { job: job_arg()? }),
        "subscribe" => Ok(Request::Subscribe { job: job_arg()? }),
        "submit" => {
            let spec = v
                .get("spec")
                .ok_or_else(|| ProtoError::bad_request("op `submit` requires `spec` (object)"))?;
            Ok(Request::Submit { spec: JobSpec::from_json(spec)? })
        }
        _ => Err(ProtoError::new(
            ErrorCode::UnknownOp,
            format!("unknown op `{op}`; known ops: {}", OPS.join(", ")),
        )),
    }
}

impl Request {
    /// Encode back to one wire line (used by the client front-end).
    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        o.set("v", Json::Num(PROTOCOL_VERSION as f64));
        match self {
            Request::Ping => o.set("op", Json::Str("ping".into())),
            Request::Jobs => o.set("op", Json::Str("jobs".into())),
            Request::Shutdown => o.set("op", Json::Str("shutdown".into())),
            Request::Submit { spec } => {
                o.set("op", Json::Str("submit".into())).set("spec", spec.to_json())
            }
            Request::Status { job } => {
                o.set("op", Json::Str("status".into())).set("job", Json::Str(job.clone()))
            }
            Request::Cancel { job } => {
                o.set("op", Json::Str("cancel".into())).set("job", Json::Str(job.clone()))
            }
            Request::Result { job } => {
                o.set("op", Json::Str("result".into())).set("job", Json::Str(job.clone()))
            }
            Request::Subscribe { job } => {
                o.set("op", Json::Str("subscribe".into())).set("job", Json::Str(job.clone()))
            }
        };
        format!("{o}")
    }
}

/// A queue-level job summary, as shipped to clients by `jobs`/`status`.
#[derive(Clone, Debug, PartialEq)]
pub struct JobView {
    pub id: String,
    /// `queued|running|done|failed|cancelled`.
    pub state: String,
    pub specs: String,
    pub task: String,
    pub steps: usize,
    /// Failure message, for `failed` jobs.
    pub detail: Option<String>,
}

impl JobView {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Str(self.id.clone()))
            .set("state", Json::Str(self.state.clone()))
            .set("specs", Json::Str(self.specs.clone()))
            .set("task", Json::Str(self.task.clone()))
            .set("steps", Json::Num(self.steps as f64));
        if let Some(d) = &self.detail {
            o.set("detail", Json::Str(d.clone()));
        }
        o
    }

    pub fn from_json(v: &Json) -> anyhow::Result<JobView> {
        Ok(JobView {
            id: v.require_str("id")?.to_string(),
            state: v.require_str("state")?.to_string(),
            specs: v.require_str("specs")?.to_string(),
            task: v.require_str("task")?.to_string(),
            steps: v.require_usize("steps")?,
            detail: v.get("detail").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// One response line. Every variant encodes with `"v"` and `"ok"`, plus
/// `"op"` echoing what it answers, so pipelined clients can sanity-check
/// ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Pong { server: String },
    Submitted { job: String },
    Jobs { jobs: Vec<JobView> },
    Status { job: JobView },
    Cancelled { job: String },
    ResultPayload { job: String, csv: String, json: String },
    Subscribed { job: String },
    ShuttingDown,
    Error(ProtoError),
}

impl Response {
    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        o.set("v", Json::Num(PROTOCOL_VERSION as f64));
        match self {
            Response::Error(e) => {
                let mut err = Json::obj();
                err.set("code", Json::Str(e.code.as_str().into()))
                    .set("message", Json::Str(e.message.clone()));
                o.set("ok", Json::Bool(false)).set("error", err);
            }
            Response::Pong { server } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("ping".into()))
                    .set("server", Json::Str(server.clone()));
            }
            Response::Submitted { job } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("submit".into()))
                    .set("job", Json::Str(job.clone()));
            }
            Response::Jobs { jobs } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("jobs".into()))
                    .set("jobs", Json::Arr(jobs.iter().map(JobView::to_json).collect()));
            }
            Response::Status { job } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("status".into()))
                    .set("job", job.to_json());
            }
            Response::Cancelled { job } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("cancel".into()))
                    .set("job", Json::Str(job.clone()));
            }
            Response::ResultPayload { job, csv, json } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("result".into()))
                    .set("job", Json::Str(job.clone()))
                    .set("csv", Json::Str(csv.clone()))
                    .set("json", Json::Str(json.clone()));
            }
            Response::Subscribed { job } => {
                o.set("ok", Json::Bool(true))
                    .set("op", Json::Str("subscribe".into()))
                    .set("job", Json::Str(job.clone()));
            }
            Response::ShuttingDown => {
                o.set("ok", Json::Bool(true)).set("op", Json::Str("shutdown".into()));
            }
        }
        format!("{o}")
    }
}

/// One `{"stream":"event",...}` line relaying a trace event to a
/// subscriber.
pub fn stream_event_line(job: &str, event: &TraceEvent) -> String {
    let mut o = Json::obj();
    o.set("v", Json::Num(PROTOCOL_VERSION as f64))
        .set("stream", Json::Str("event".into()))
        .set("job", Json::Str(job.into()))
        .set("event", event.to_json());
    format!("{o}")
}

/// One `{"stream":"state",...}` line reporting a job state transition; a
/// terminal state ends the subscription.
pub fn stream_state_line(job: &str, state: &str, detail: Option<&str>) -> String {
    let mut o = Json::obj();
    o.set("v", Json::Num(PROTOCOL_VERSION as f64))
        .set("stream", Json::Str("state".into()))
        .set("job", Json::Str(job.into()))
        .set("state", Json::Str(state.into()));
    if let Some(d) = detail {
        o.set("detail", Json::Str(d.into()));
    }
    format!("{o}")
}

/// Outcome of one framed read.
#[derive(Debug, PartialEq, Eq)]
pub enum ReadLine {
    /// One complete line (without the terminator, `\r\n` tolerated).
    Line(Vec<u8>),
    /// The line exceeded [`MAX_LINE_BYTES`]; `discarded` bytes were drained
    /// up to (not including) the next `\n`, so the stream stays framed.
    Oversized { discarded: usize },
    Eof,
    /// `keep_waiting` said to stop during a read timeout (only from
    /// [`read_line_capped_idle`] on sockets with a read timeout set).
    Idle,
}

/// Read one `\n`-terminated line without ever buffering more than
/// [`MAX_LINE_BYTES`] — the reason `BufRead::read_line` is not used: a
/// hostile client could otherwise grow the buffer without bound.
pub fn read_line_capped<R: BufRead>(r: &mut R) -> io::Result<ReadLine> {
    read_line_capped_idle(r, || true)
}

/// [`read_line_capped`] for sockets with a read timeout: each time the
/// underlying read times out (`WouldBlock`/`TimedOut`), `keep_waiting` is
/// consulted — `true` resumes the read with any partial line intact (no
/// desync for a client pausing mid-line), `false` returns
/// [`ReadLine::Idle`] so the session can close instead of pinning its
/// thread forever.
pub fn read_line_capped_idle<R: BufRead>(
    r: &mut R,
    mut keep_waiting: impl FnMut() -> bool,
) -> io::Result<ReadLine> {
    let mut buf: Vec<u8> = Vec::new();
    let mut discarding = false;
    let mut discarded = 0usize;
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if keep_waiting() {
                    continue;
                }
                return Ok(ReadLine::Idle);
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            // EOF. A truncated trailing line (no terminator) still parses —
            // clients that close after their last request stay valid.
            return Ok(match (discarding, buf.is_empty()) {
                (true, _) => ReadLine::Oversized { discarded },
                (false, true) => ReadLine::Eof,
                (false, false) => ReadLine::Line(strip_cr(buf)),
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if discarding {
                    discarded += pos;
                } else {
                    buf.extend_from_slice(&chunk[..pos]);
                }
                r.consume(pos + 1);
                if discarding || buf.len() > MAX_LINE_BYTES {
                    return Ok(ReadLine::Oversized { discarded: discarded.max(buf.len()) });
                }
                return Ok(ReadLine::Line(strip_cr(buf)));
            }
            None => {
                let n = chunk.len();
                if discarding {
                    discarded += n;
                } else {
                    buf.extend_from_slice(chunk);
                    if buf.len() > MAX_LINE_BYTES {
                        discarding = true;
                        discarded = buf.len();
                        buf = Vec::new();
                    }
                }
                r.consume(n);
            }
        }
    }
}

fn strip_cr(mut buf: Vec<u8>) -> Vec<u8> {
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn err_code(raw: &str) -> ErrorCode {
        parse_request(raw.as_bytes()).unwrap_err().code
    }

    #[test]
    fn requests_round_trip_through_the_wire_form() {
        let mut spec = JobSpec::new("kfac:f={5,10}", "images");
        spec.steps = 4;
        spec.hidden = vec![16];
        spec.seed = 3;
        let reqs = [
            Request::Ping,
            Request::Jobs,
            Request::Shutdown,
            Request::Submit { spec },
            Request::Status { job: "j1".into() },
            Request::Cancel { job: "j2".into() },
            Request::Result { job: "j3".into() },
            Request::Subscribe { job: "j4".into() },
        ];
        for req in reqs {
            let line = req.to_line();
            assert!(!line.contains('\n'), "wire form must be one line: {line}");
            assert_eq!(parse_request(line.as_bytes()).unwrap(), req, "{line}");
        }
    }

    #[test]
    fn every_rejection_class_maps_to_its_typed_code() {
        assert_eq!(err_code("not json at all"), ErrorCode::Malformed);
        assert_eq!(err_code("[1,2,3]"), ErrorCode::Malformed);
        assert_eq!(err_code("{\"v\":1"), ErrorCode::Malformed);
        assert_eq!(err_code("{}"), ErrorCode::VersionSkew);
        assert_eq!(err_code("{\"v\":99,\"op\":\"ping\"}"), ErrorCode::VersionSkew);
        assert_eq!(err_code("{\"v\":\"one\",\"op\":\"ping\"}"), ErrorCode::VersionSkew);
        assert_eq!(err_code("{\"v\":1}"), ErrorCode::Malformed);
        assert_eq!(err_code("{\"v\":1,\"op\":\"frobnicate\"}"), ErrorCode::UnknownOp);
        assert_eq!(err_code("{\"v\":1,\"op\":\"status\"}"), ErrorCode::BadRequest);
        assert_eq!(err_code("{\"v\":1,\"op\":\"submit\"}"), ErrorCode::BadRequest);
        assert_eq!(err_code("{\"v\":1,\"op\":\"submit\",\"spec\":{}}"), ErrorCode::BadRequest);
        assert_eq!(
            err_code("{\"v\":1,\"op\":\"submit\",\"spec\":{\"specs\":\"lamb\",\"task\":\"glue\",\"steps\":-4}}"),
            ErrorCode::BadRequest
        );
        assert_eq!(
            parse_request(&[0x80, 0xff, b'{', b'}']).unwrap_err().code,
            ErrorCode::Malformed
        );
        // Messages must be actionable, not bare codes.
        let e = parse_request(b"{\"v\":1,\"op\":\"frobnicate\"}").unwrap_err();
        assert!(e.message.contains("ping"), "unknown_op should list ops: {}", e.message);
    }

    #[test]
    fn job_spec_defaults_and_validation() {
        let v = Json::parse("{\"specs\":\"lamb\",\"task\":\"glue\"}").unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec, JobSpec::new("lamb", "glue"));
        let decoded = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(decoded, spec);
        for bad in [
            "{\"task\":\"glue\"}",
            "{\"specs\":\"lamb\"}",
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"hidden\":[0]}",
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"lr\":\"fast\"}",
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"batch\":0}",
        ] {
            let v = Json::parse(bad).unwrap();
            assert_eq!(JobSpec::from_json(&v).unwrap_err().code, ErrorCode::BadRequest, "{bad}");
        }
    }

    #[test]
    fn seeds_survive_the_wire_exactly_for_the_full_u64_range() {
        // Encoded as a decimal string: no f64 rounding above 2^53.
        let mut spec = JobSpec::new("lamb", "glue");
        spec.seed = u64::MAX;
        assert_eq!(JobSpec::from_json(&spec.to_json()).unwrap().seed, u64::MAX);
        // Legacy numeric form (v1 journals, hand-written clients) still
        // decodes while exact...
        let v = Json::parse("{\"specs\":\"lamb\",\"task\":\"glue\",\"seed\":7}").unwrap();
        assert_eq!(JobSpec::from_json(&v).unwrap().seed, 7);
        // ...but seeds a float would round are refused, never truncated.
        for bad in [
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"seed\":18446744073709551615}",
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"seed\":-1}",
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"seed\":1.5}",
            "{\"specs\":\"lamb\",\"task\":\"glue\",\"seed\":\"abc\"}",
        ] {
            let v = Json::parse(bad).unwrap();
            assert_eq!(JobSpec::from_json(&v).unwrap_err().code, ErrorCode::BadRequest, "{bad}");
        }
    }

    #[test]
    fn responses_are_single_parseable_lines() {
        let view = JobView {
            id: "j1".into(),
            state: "done".into(),
            specs: "lamb".into(),
            task: "glue".into(),
            steps: 4,
            detail: None,
        };
        let responses = [
            Response::Pong { server: "mkor 0.2.0".into() },
            Response::Submitted { job: "j1".into() },
            Response::Jobs { jobs: vec![view.clone()] },
            Response::Status { job: view },
            Response::Cancelled { job: "j1".into() },
            Response::ResultPayload {
                job: "j1".into(),
                csv: "a,b\n1,2\n".into(),
                json: "{\n}".into(),
            },
            Response::Subscribed { job: "j1".into() },
            Response::ShuttingDown,
            Response::Error(ProtoError::unknown_job("j9")),
        ];
        for resp in responses {
            let line = resp.to_line();
            assert!(!line.contains('\n'), "embedded newline leaked: {line}");
            let v = Json::parse(&line).unwrap();
            assert_eq!(v.require_usize("v").unwrap() as u64, PROTOCOL_VERSION);
            let ok = v.get("ok").and_then(Json::as_bool).unwrap();
            assert_eq!(ok, !matches!(resp, Response::Error(_)));
            if let Response::ResultPayload { csv, .. } = &resp {
                // Payload bytes survive the line framing exactly.
                assert_eq!(v.get("csv").and_then(Json::as_str).unwrap(), csv);
            }
        }
    }

    #[test]
    fn capped_reader_keeps_the_stream_framed() {
        let huge = "x".repeat(MAX_LINE_BYTES + 100);
        let input = format!("{huge}\n{{\"v\":1,\"op\":\"ping\"}}\nshort");
        let mut r = Cursor::new(input.into_bytes());
        match read_line_capped(&mut r).unwrap() {
            ReadLine::Oversized { discarded } => assert!(discarded > MAX_LINE_BYTES),
            other => panic!("expected Oversized, got {other:?}"),
        }
        // The next line is intact: no desync after discarding.
        match read_line_capped(&mut r).unwrap() {
            ReadLine::Line(bytes) => {
                assert_eq!(parse_request(&bytes).unwrap(), Request::Ping);
            }
            other => panic!("expected Line, got {other:?}"),
        }
        // Unterminated trailing line still arrives, then EOF.
        assert_eq!(read_line_capped(&mut r).unwrap(), ReadLine::Line(b"short".to_vec()));
        assert_eq!(read_line_capped(&mut r).unwrap(), ReadLine::Eof);
    }

    /// A scripted reader: `None` entries yield one `WouldBlock` (a socket
    /// read timeout), `Some(bytes)` yield data, exhaustion yields EOF.
    struct Scripted {
        parts: std::collections::VecDeque<Option<Vec<u8>>>,
    }

    impl std::io::Read for Scripted {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            match self.parts.pop_front() {
                None => Ok(0),
                Some(None) => Err(io::ErrorKind::WouldBlock.into()),
                Some(Some(bytes)) => {
                    out[..bytes.len()].copy_from_slice(&bytes);
                    Ok(bytes.len())
                }
            }
        }
    }

    #[test]
    fn idle_reader_preserves_partial_lines_across_timeouts() {
        // A client pausing mid-line must not desync the stream: the
        // partial prefix survives the timeout and the line completes.
        let parts = vec![Some(b"{\"v\":1,\"op\":\"pi".to_vec()), None, Some(b"ng\"}\n".to_vec())];
        let mut r = std::io::BufReader::new(Scripted { parts: parts.into() });
        let mut waits = 0;
        let line = read_line_capped_idle(&mut r, || {
            waits += 1;
            true
        })
        .unwrap();
        assert_eq!(waits, 1);
        match line {
            ReadLine::Line(bytes) => assert_eq!(parse_request(&bytes).unwrap(), Request::Ping),
            other => panic!("expected Line, got {other:?}"),
        }

        // `keep_waiting() == false` (stop requested / idle budget spent)
        // surfaces as Idle instead of blocking forever.
        let mut r = std::io::BufReader::new(Scripted { parts: vec![None].into() });
        assert_eq!(read_line_capped_idle(&mut r, || false).unwrap(), ReadLine::Idle);
    }
}
