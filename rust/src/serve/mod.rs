//! Training-as-a-service: the `mkor serve` daemon and its clients.
//!
//! A long-running daemon accepts sweep jobs over a versioned line-JSON
//! TCP protocol and runs them through the existing crash-isolated
//! subprocess dispatcher, so a job's merged artifacts are byte-identical
//! to a direct `mkor sweep --jobs 1 --deterministic` run:
//!
//! ```text
//! mkor serve --addr 127.0.0.1:7070 --dir serve-data &
//! mkor submit --addr 127.0.0.1:7070 --specs "kfac:f={5,10};lamb" \
//!     --task images --steps 50 --wait --out sweep.csv
//! mkor jobs --addr 127.0.0.1:7070
//! mkor observe j1 --addr 127.0.0.1:7070
//! ```
//!
//! The layers, bottom-up:
//!
//! * [`protocol`] — the wire format: one JSON object per line, `"v":1`
//!   everywhere, every malformed/oversized/skewed input mapped to a typed
//!   error (the daemon never dies or desyncs on untrusted bytes);
//! * [`queue`] — bounded FIFO of [`queue::JobRecord`]s behind a
//!   crash-safe JSONL journal; a restarted daemon replays it and
//!   re-queues interrupted jobs;
//! * [`session`] — one thread per connection: ordered request/response
//!   plus inline subscription streams fed by the daemon's trace sink;
//! * [`daemon`] — accept loop, runner threads, trace pump, clean
//!   SIGTERM/SIGINT shutdown ([`signal`]);
//! * [`client`] / [`commands`] — the typed client and the
//!   `serve|submit|jobs|observe|artifacts` CLI front-ends.

pub mod client;
pub mod commands;
pub mod daemon;
pub mod protocol;
pub mod queue;
pub mod session;
pub mod signal;

pub use client::Client;
pub use daemon::{ServeOptions, Subscribers};
pub use protocol::{
    parse_request, ErrorCode, JobSpec, JobView, ProtoError, Request, Response, MAX_LINE_BYTES,
    PROTOCOL_VERSION,
};
pub use queue::{JobQueue, JobRecord, JobState};
