//! The daemon's job queue: a bounded FIFO of sweep jobs with a
//! crash-safe JSONL journal.
//!
//! Job lifecycle is a one-way state machine:
//!
//! ```text
//!              claim_next            finish(Ok)
//!   queued ───────────────▶ running ───────────▶ done
//!     │                        │    finish(Err)
//!     │ cancel                 └───────────────▶ failed
//!     └──────▶ cancelled
//! ```
//!
//! Every transition appends one line to `journal.jsonl` and flushes before
//! the transition is visible to anyone, so a daemon killed at any instant
//! can be restarted on the same directory and [`JobQueue::open`] replays
//! the journal back into memory. Jobs that were `running` when the daemon
//! died are re-queued (recorded with an explicit `requeued` line) — the
//! job's own worker-level progress is recovered separately by
//! `run_sweep_mp`'s scratch-file scan, so a re-run resumes rather than
//! repeats. A torn final line (the daemon died mid-write) is dropped with
//! a warning *and truncated off the file*, so post-recovery appends start
//! on a clean line boundary instead of concatenating onto the fragment;
//! garbage anywhere else in the journal is a hard error, never a silent
//! skip.

use crate::obs;
use crate::serve::protocol::{ErrorCode, JobSpec, JobView, ProtoError};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Journal line schema version (independent of the wire protocol's).
pub const JOURNAL_FORMAT_VERSION: u64 = 1;

/// The journal file inside the daemon directory.
pub const JOURNAL_FILE: &str = "journal.jsonl";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<JobState> {
        Some(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            _ => return None,
        })
    }

    /// Terminal states never transition again (and end subscriptions).
    pub fn terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// One job: submission order (`seq`), wire id (`j<seq>`), the full spec,
/// and where it is in the state machine.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub seq: u64,
    pub id: String,
    pub spec: JobSpec,
    pub state: JobState,
    /// Failure message for `failed` jobs.
    pub detail: Option<String>,
}

impl JobRecord {
    pub fn view(&self) -> JobView {
        JobView {
            id: self.id.clone(),
            state: self.state.as_str().to_string(),
            specs: self.spec.specs.clone(),
            task: self.spec.task.clone(),
            steps: self.spec.steps,
            detail: self.detail.clone(),
        }
    }
}

struct Inner {
    jobs: BTreeMap<u64, JobRecord>,
    next_seq: u64,
    journal: File,
    /// Cleared by [`JobQueue::shutdown`]: submits are refused and
    /// [`JobQueue::claim_next`] immediately returns `None` — queued jobs
    /// are NOT drained; they stay journaled for the next start.
    accepting: bool,
}

impl Inner {
    fn append(&mut self, line: &Json) -> Result<()> {
        // One line per transition, flushed before the new state is
        // observable — a crash may lose at most the line being written,
        // which replay tolerates as a torn tail.
        writeln!(self.journal, "{line}").context("appending to job journal")?;
        self.journal.flush().context("flushing job journal")
    }

    fn append_state(&mut self, seq: u64, kind: &str) -> Result<()> {
        let (id, state, detail) = {
            let job = &self.jobs[&seq];
            (job.id.clone(), job.state.as_str(), job.detail.clone())
        };
        let mut o = Json::obj();
        o.set("v", Json::Num(JOURNAL_FORMAT_VERSION as f64))
            .set("kind", Json::Str(kind.into()))
            .set("id", Json::Str(id))
            .set("state", Json::Str(state.into()));
        if let Some(d) = detail {
            o.set("detail", Json::Str(d));
        }
        self.append(&o)
    }
}

/// The bounded, journaled FIFO shared by sessions (producers) and runner
/// threads (consumers).
pub struct JobQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
    path: PathBuf,
}

impl JobQueue {
    /// Open (or create) the queue journaled at `dir/journal.jsonl`,
    /// replaying any prior state. `capacity` bounds *queued* jobs only —
    /// running and terminal jobs don't count against it.
    pub fn open(dir: &Path, capacity: usize) -> Result<JobQueue> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating daemon dir {}", dir.display()))?;
        let path = dir.join(JOURNAL_FILE);
        let mut jobs = BTreeMap::new();
        let mut truncate_to = None;
        let mut add_terminator = false;
        if path.is_file() {
            let (good, terminated) = replay(&path, &mut jobs)?;
            let len = std::fs::metadata(&path)
                .with_context(|| format!("stat of job journal {}", path.display()))?
                .len();
            if good < len {
                truncate_to = Some(good);
            } else {
                add_terminator = !terminated && len > 0;
            }
        }
        let next_seq = jobs.keys().next_back().map_or(1, |&s| s + 1);
        let mut journal = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening job journal {}", path.display()))?;
        if let Some(good) = truncate_to {
            // Cut the torn tail off the file: the next append must start
            // on a fresh line, or it would concatenate onto the fragment —
            // poisoning the journal for the restart after this one, where
            // the merged garbage would sit mid-file and be a hard error.
            journal
                .set_len(good)
                .with_context(|| format!("truncating torn journal tail {}", path.display()))?;
        } else if add_terminator {
            // A crash that lost only the final '\n' of a valid line: keep
            // the entry, restore the framing.
            journal
                .write_all(b"\n")
                .with_context(|| format!("re-terminating job journal {}", path.display()))?;
        }
        let mut inner = Inner { jobs, next_seq, journal, accepting: true };
        // Re-queue interrupted jobs, recording the transition so a second
        // replay sees the same state this process now holds.
        let interrupted: Vec<u64> = inner
            .jobs
            .iter()
            .filter(|(_, j)| j.state == JobState::Running)
            .map(|(&s, _)| s)
            .collect();
        for seq in interrupted {
            let job = inner.jobs.get_mut(&seq).unwrap();
            obs::log::note(&format!("serve: re-queueing interrupted job {}", job.id));
            job.state = JobState::Queued;
            inner.append_state(seq, "requeued")?;
        }
        Ok(JobQueue { inner: Mutex::new(inner), cv: Condvar::new(), capacity, path })
    }

    pub fn journal_path(&self) -> &Path {
        &self.path
    }

    /// Enqueue a validated spec; returns the new job's id. Refuses with
    /// `queue_full` when `capacity` jobs are already queued and with
    /// `bad_request` after shutdown began.
    pub fn submit(&self, spec: JobSpec) -> Result<JobRecord, ProtoError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.accepting {
            return Err(ProtoError::bad_request("daemon is shutting down; not accepting jobs"));
        }
        let queued = inner.jobs.values().filter(|j| j.state == JobState::Queued).count();
        if queued >= self.capacity {
            return Err(ProtoError::new(
                ErrorCode::QueueFull,
                format!(
                    "queue holds {queued}/{} queued jobs; retry after one starts or cancel one",
                    self.capacity
                ),
            ));
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        let job = JobRecord {
            seq,
            id: format!("j{seq}"),
            spec,
            state: JobState::Queued,
            detail: None,
        };
        let mut line = Json::obj();
        line.set("v", Json::Num(JOURNAL_FORMAT_VERSION as f64))
            .set("kind", Json::Str("submit".into()))
            .set("seq", Json::Num(seq as f64))
            .set("id", Json::Str(job.id.clone()))
            .set("spec", job.spec.to_json());
        inner.jobs.insert(seq, job.clone());
        if let Err(e) = inner.append(&line) {
            // A job the journal can't record must not exist: a crash would
            // silently forget it.
            inner.jobs.remove(&seq);
            return Err(ProtoError::bad_request(format!("journal write failed: {e:#}")));
        }
        self.cv.notify_all();
        Ok(job)
    }

    /// Block up to `timeout` for the oldest queued job, marking it running.
    /// Returns `None` on timeout or as soon as the queue is shut down —
    /// even with jobs still queued, so shutdown waits only for the
    /// in-flight job and queued work stays journaled for the next start.
    /// Callers loop, re-checking their stop condition between claims.
    pub fn claim_next(&self, timeout: Duration) -> Option<JobRecord> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.accepting {
                return None;
            }
            let next = inner
                .jobs
                .iter()
                .find(|(_, j)| j.state == JobState::Queued)
                .map(|(&s, _)| s);
            if let Some(seq) = next {
                let job = inner.jobs.get_mut(&seq).unwrap();
                job.state = JobState::Running;
                let claimed = job.clone();
                if let Err(e) = inner.append_state(seq, "state") {
                    obs::log::warn(&format!("serve: journal write failed: {e:#}"));
                }
                return Some(claimed);
            }
            let (guard, wait) = self.cv.wait_timeout(inner, timeout).unwrap();
            inner = guard;
            if wait.timed_out() {
                return None;
            }
        }
    }

    /// Record a running job's outcome; returns the terminal record.
    pub fn finish(&self, id: &str, outcome: Result<(), String>) -> Result<JobRecord> {
        let mut inner = self.inner.lock().unwrap();
        let seq = seq_of(id, &inner.jobs).ok_or_else(|| anyhow!("finish: no job `{id}`"))?;
        let job = inner.jobs.get_mut(&seq).unwrap();
        if job.state != JobState::Running {
            bail!("finish: job `{id}` is {}, not running", job.state.as_str());
        }
        match outcome {
            Ok(()) => job.state = JobState::Done,
            Err(msg) => {
                job.state = JobState::Failed;
                job.detail = Some(msg);
            }
        }
        let done = job.clone();
        inner.append_state(seq, "state")?;
        self.cv.notify_all();
        Ok(done)
    }

    /// Cancel a *queued* job. Running jobs are single-owner (a subprocess
    /// fan-out mid-flight) and terminal jobs are history; both refuse with
    /// `not_cancellable` naming the actual state.
    pub fn cancel(&self, id: &str) -> Result<JobRecord, ProtoError> {
        let mut inner = self.inner.lock().unwrap();
        let seq = seq_of(id, &inner.jobs).ok_or_else(|| ProtoError::unknown_job(id))?;
        let job = inner.jobs.get_mut(&seq).unwrap();
        if job.state != JobState::Queued {
            return Err(ProtoError::new(
                ErrorCode::NotCancellable,
                format!("job `{id}` is {}; only queued jobs can be cancelled", job.state.as_str()),
            ));
        }
        job.state = JobState::Cancelled;
        let cancelled = job.clone();
        if let Err(e) = inner.append_state(seq, "state") {
            obs::log::warn(&format!("serve: journal write failed: {e:#}"));
        }
        self.cv.notify_all();
        Ok(cancelled)
    }

    pub fn get(&self, id: &str) -> Option<JobRecord> {
        let inner = self.inner.lock().unwrap();
        seq_of(id, &inner.jobs).map(|s| inner.jobs[&s].clone())
    }

    /// All jobs in submission order.
    pub fn list(&self) -> Vec<JobRecord> {
        self.inner.lock().unwrap().jobs.values().cloned().collect()
    }

    /// Ids of all currently running jobs, in submission order. The trace
    /// pump uses this to attribute events to subscriptions — attribution
    /// is only unambiguous when exactly one job is running (`--runners 1`).
    pub fn running_jobs(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .jobs
            .values()
            .filter(|j| j.state == JobState::Running)
            .map(|j| j.id.clone())
            .collect()
    }

    /// Stop accepting submits *and claims*, and wake all claim waiters:
    /// runners see `claim_next() == None` and exit after at most their
    /// current in-flight job. Queued jobs are left untouched — the journal
    /// re-queues them on the next start.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().accepting = false;
        self.cv.notify_all();
    }
}

fn seq_of(id: &str, jobs: &BTreeMap<u64, JobRecord>) -> Option<u64> {
    id.strip_prefix('j')
        .and_then(|n| n.parse::<u64>().ok())
        .filter(|seq| jobs.contains_key(seq))
}

/// Rebuild queue state from the journal. The only tolerated defect is a
/// torn *final* line (killed mid-write); anything else malformed is a
/// hard error naming the line. Returns `(good, terminated)`: the byte
/// length of the replayed prefix — shorter than the file exactly when a
/// torn tail was dropped — and whether that prefix ends on a `\n`
/// boundary, so [`JobQueue::open`] can restore clean framing before any
/// post-recovery append.
fn replay(path: &Path, jobs: &mut BTreeMap<u64, JobRecord>) -> Result<(u64, bool)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading job journal {}", path.display()))?;
    let segments: Vec<&str> = text.split_inclusive('\n').collect();
    let mut good = 0u64;
    let mut terminated = true;
    for (i, &seg) in segments.iter().enumerate() {
        let raw = seg.strip_suffix('\n').unwrap_or(seg);
        if raw.trim().is_empty() {
            good += seg.len() as u64;
            terminated = seg.ends_with('\n');
            continue;
        }
        let entry = match Json::parse(raw).map_err(|e| anyhow!("{e}")).and_then(|v| {
            apply_entry(&v, jobs)?;
            Ok(())
        }) {
            Ok(()) => {
                good += seg.len() as u64;
                terminated = seg.ends_with('\n');
                continue;
            }
            Err(e) => e,
        };
        if i + 1 == segments.len() {
            obs::log::warn(&format!(
                "serve: dropping torn final journal line (daemon died mid-write): {entry:#}"
            ));
            // `good` stops at the previous segment, which (being non-final)
            // necessarily ended with '\n'.
            return Ok((good, true));
        }
        bail!("corrupt job journal {} line {}: {entry:#}", path.display(), i + 1);
    }
    Ok((good, terminated))
}

fn apply_entry(v: &Json, jobs: &mut BTreeMap<u64, JobRecord>) -> Result<()> {
    let ver = v.require_usize("v")? as u64;
    if ver != JOURNAL_FORMAT_VERSION {
        bail!("journal format v{ver} unsupported (this build reads v{JOURNAL_FORMAT_VERSION})");
    }
    match v.require_str("kind")? {
        "submit" => {
            let seq = v.require_usize("seq")? as u64;
            let id = v.require_str("id")?.to_string();
            let spec = v.get("spec").ok_or_else(|| anyhow!("submit entry missing `spec`"))?;
            let spec = JobSpec::from_json(spec).map_err(|e| anyhow!("{e}"))?;
            if jobs.insert(seq, JobRecord { seq, id, spec, state: JobState::Queued, detail: None })
                .is_some()
            {
                bail!("duplicate submit for seq {seq}");
            }
            Ok(())
        }
        "state" | "requeued" => {
            let id = v.require_str("id")?;
            let state = JobState::parse(v.require_str("state")?)
                .ok_or_else(|| anyhow!("unknown job state in journal"))?;
            let seq =
                seq_of(id, jobs).ok_or_else(|| anyhow!("state entry for unknown job `{id}`"))?;
            let job = jobs.get_mut(&seq).unwrap();
            job.state = state;
            job.detail = v.get("detail").and_then(Json::as_str).map(str::to_string);
            Ok(())
        }
        other => bail!("unknown journal entry kind `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn scratch(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "mkor-queue-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spec() -> JobSpec {
        JobSpec::new("lamb", "glue")
    }

    #[test]
    fn lifecycle_survives_reopen_at_every_stage() {
        let dir = scratch("lifecycle");
        let q = JobQueue::open(&dir, 8).unwrap();
        let a = q.submit(spec()).unwrap();
        let b = q.submit(spec()).unwrap();
        assert_eq!((a.id.as_str(), b.id.as_str()), ("j1", "j2"));
        let claimed = q.claim_next(Duration::from_millis(10)).unwrap();
        assert_eq!(claimed.id, "j1");
        q.finish("j1", Err("boom".into())).unwrap();
        drop(q);

        // Reopen: j1 failed with its detail, j2 still queued, ids continue.
        let q = JobQueue::open(&dir, 8).unwrap();
        let jobs = q.list();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].state, JobState::Failed);
        assert_eq!(jobs[0].detail.as_deref(), Some("boom"));
        assert_eq!(jobs[1].state, JobState::Queued);
        let c = q.submit(spec()).unwrap();
        assert_eq!(c.id, "j3");

        // A job left running is re-queued on the next open, once.
        assert_eq!(q.claim_next(Duration::from_millis(10)).unwrap().id, "j2");
        drop(q);
        let q = JobQueue::open(&dir, 8).unwrap();
        assert_eq!(q.get("j2").unwrap().state, JobState::Queued);
        assert_eq!(q.claim_next(Duration::from_millis(10)).unwrap().id, "j2");
        assert_eq!(q.running_jobs(), vec!["j2".to_string()]);
    }

    #[test]
    fn capacity_counts_only_queued_jobs() {
        let dir = scratch("capacity");
        let q = JobQueue::open(&dir, 1).unwrap();
        q.submit(spec()).unwrap();
        assert_eq!(q.submit(spec()).unwrap_err().code, ErrorCode::QueueFull);
        // Claiming frees the slot: running jobs don't count.
        q.claim_next(Duration::from_millis(10)).unwrap();
        let b = q.submit(spec()).unwrap();
        // Cancel frees it again.
        q.cancel(&b.id).unwrap();
        q.submit(spec()).unwrap();
    }

    #[test]
    fn cancel_is_queued_only_and_typed() {
        let dir = scratch("cancel");
        let q = JobQueue::open(&dir, 8).unwrap();
        let a = q.submit(spec()).unwrap();
        assert_eq!(q.cancel("j99").unwrap_err().code, ErrorCode::UnknownJob);
        q.claim_next(Duration::from_millis(10)).unwrap();
        let e = q.cancel(&a.id).unwrap_err();
        assert_eq!(e.code, ErrorCode::NotCancellable);
        assert!(e.message.contains("running"), "{}", e.message);
        q.finish(&a.id, Ok(())).unwrap();
        assert_eq!(q.cancel(&a.id).unwrap_err().code, ErrorCode::NotCancellable);
        assert_eq!(q.get(&a.id).unwrap().state, JobState::Done);
    }

    #[test]
    fn torn_tail_is_dropped_but_mid_file_garbage_is_fatal() {
        let dir = scratch("torn");
        {
            let q = JobQueue::open(&dir, 8).unwrap();
            q.submit(spec()).unwrap();
        }
        let journal = dir.join(JOURNAL_FILE);
        let mut text = std::fs::read_to_string(&journal).unwrap();
        // Simulate dying mid-append: a half-written line with no close.
        text.push_str("{\"v\":1,\"kind\":\"state\",\"id\":\"j1\",\"sta");
        std::fs::write(&journal, &text).unwrap();
        let q = JobQueue::open(&dir, 8).unwrap();
        assert_eq!(q.get("j1").unwrap().state, JobState::Queued);
        // Recovery must truncate the torn fragment so post-recovery
        // appends start on a fresh line — otherwise the NEXT restart sees
        // merged garbage mid-file and refuses to start.
        let q2_id = q.submit(spec()).unwrap().id;
        drop(q);
        let q = JobQueue::open(&dir, 8).unwrap();
        assert_eq!(q.get("j1").unwrap().state, JobState::Queued);
        assert_eq!(q.get(&q2_id).unwrap().state, JobState::Queued);
        for line in std::fs::read_to_string(&journal).unwrap().lines() {
            Json::parse(line)
                .unwrap_or_else(|e| panic!("corrupt post-recovery line `{line}`: {e}"));
        }
        drop(q);

        let broken = format!("not json\n{}", std::fs::read_to_string(&journal).unwrap());
        std::fs::write(&journal, broken).unwrap();
        let err = JobQueue::open(&dir, 8).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn missing_final_newline_keeps_the_entry_and_restores_framing() {
        let dir = scratch("terminator");
        {
            let q = JobQueue::open(&dir, 8).unwrap();
            q.submit(spec()).unwrap();
            q.claim_next(Duration::from_millis(10)).unwrap();
            q.finish("j1", Ok(())).unwrap();
        }
        let journal = dir.join(JOURNAL_FILE);
        let text = std::fs::read_to_string(&journal).unwrap();
        // A crash that lost exactly the trailing '\n' of a valid line.
        std::fs::write(&journal, text.trim_end_matches('\n')).unwrap();
        let q = JobQueue::open(&dir, 8).unwrap();
        assert_eq!(q.get("j1").unwrap().state, JobState::Done);
        q.submit(spec()).unwrap();
        drop(q);
        let q = JobQueue::open(&dir, 8).unwrap();
        assert_eq!(q.get("j1").unwrap().state, JobState::Done);
        assert_eq!(q.get("j2").unwrap().state, JobState::Queued);
    }

    #[test]
    fn shutdown_unblocks_claimers_and_refuses_submits() {
        let dir = scratch("shutdown");
        let q = std::sync::Arc::new(JobQueue::open(&dir, 8).unwrap());
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.claim_next(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(50));
        q.shutdown();
        assert!(waiter.join().unwrap().is_none());
        assert!(q.submit(spec()).unwrap_err().message.contains("shutting down"));
    }

    #[test]
    fn shutdown_leaves_queued_jobs_for_the_next_start() {
        let dir = scratch("shutdown-queue");
        let q = JobQueue::open(&dir, 8).unwrap();
        q.submit(spec()).unwrap();
        q.submit(spec()).unwrap();
        assert_eq!(q.claim_next(Duration::from_millis(10)).unwrap().id, "j1");
        q.shutdown();
        // Shutdown must not drain the queue: j2 stays queued, unclaimed.
        assert!(q.claim_next(Duration::from_millis(10)).is_none());
        q.finish("j1", Ok(())).unwrap();
        drop(q);
        let q = JobQueue::open(&dir, 8).unwrap();
        assert_eq!(q.get("j1").unwrap().state, JobState::Done);
        assert_eq!(q.get("j2").unwrap().state, JobState::Queued);
        assert_eq!(q.claim_next(Duration::from_millis(10)).unwrap().id, "j2");
    }
}
