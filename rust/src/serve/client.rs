//! Client side of the serve protocol: one TCP connection, typed helpers
//! over the line framing. Used by the `mkor submit|jobs|observe` CLI and
//! by the integration tests (which also speak raw bytes through
//! [`Client::raw_roundtrip`] to probe the daemon's error handling).

use crate::serve::protocol::{JobSpec, JobView, Request, PROTOCOL_VERSION};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to mkor serve at {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().context("cloning client socket")?);
        Ok(Client { reader, writer: stream })
    }

    /// Connect with retries — for clients racing a daemon that is still
    /// binding its listener.
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Client> {
        let t0 = Instant::now();
        loop {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if t0.elapsed() >= timeout => return Err(e),
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// Send one raw line (no trailing `\n` needed) and read one response
    /// line. The raw form exists so tests can send deliberately broken
    /// bytes; normal callers use the typed helpers.
    pub fn raw_roundtrip(&mut self, line: &[u8]) -> Result<Json> {
        self.writer.write_all(line).context("sending request")?;
        self.writer.write_all(b"\n").context("sending request")?;
        self.read_json_line()?.ok_or_else(|| anyhow!("daemon closed the connection"))
    }

    /// Read one line and parse it as JSON; `None` on a clean EOF.
    pub fn read_json_line(&mut self) -> Result<Option<Json>> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading response")?;
        if n == 0 {
            return Ok(None);
        }
        let trimmed = line.trim_end();
        Ok(Some(Json::parse(trimmed).map_err(|e| anyhow!("bad response line `{trimmed}`: {e}"))?))
    }

    /// Typed request → verified-`ok` response object. Error responses
    /// surface as `code: message` anyhow errors.
    pub fn roundtrip(&mut self, req: &Request) -> Result<Json> {
        let resp = self.raw_roundtrip(req.to_line().as_bytes())?;
        expect_ok(resp)
    }

    pub fn ping(&mut self) -> Result<String> {
        Ok(self.roundtrip(&Request::Ping)?.require_str("server")?.to_string())
    }

    pub fn submit(&mut self, spec: &JobSpec) -> Result<String> {
        let resp = self.roundtrip(&Request::Submit { spec: spec.clone() })?;
        Ok(resp.require_str("job")?.to_string())
    }

    pub fn jobs(&mut self) -> Result<Vec<JobView>> {
        let resp = self.roundtrip(&Request::Jobs)?;
        let arr = resp.get("jobs").and_then(Json::as_arr).unwrap_or(&[]);
        arr.iter().map(JobView::from_json).collect()
    }

    pub fn status(&mut self, job: &str) -> Result<JobView> {
        let resp = self.roundtrip(&Request::Status { job: job.into() })?;
        JobView::from_json(resp.get("job").ok_or_else(|| anyhow!("status response lacks `job`"))?)
    }

    pub fn cancel(&mut self, job: &str) -> Result<()> {
        self.roundtrip(&Request::Cancel { job: job.into() }).map(|_| ())
    }

    /// Fetch a done job's merged artifacts as `(csv, json)` — the exact
    /// bytes the daemon wrote, suitable for byte-for-byte comparison with
    /// a direct `mkor sweep --jobs 1 --deterministic` run.
    pub fn result(&mut self, job: &str) -> Result<(String, String)> {
        let resp = self.roundtrip(&Request::Result { job: job.into() })?;
        Ok((resp.require_str("csv")?.to_string(), resp.require_str("json")?.to_string()))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.roundtrip(&Request::Shutdown).map(|_| ())
    }

    /// Poll `status` until the job reaches a terminal state.
    pub fn wait(&mut self, job: &str, timeout: Duration) -> Result<JobView> {
        let t0 = Instant::now();
        loop {
            let view = self.status(job)?;
            if matches!(view.state.as_str(), "done" | "failed" | "cancelled") {
                return Ok(view);
            }
            if t0.elapsed() >= timeout {
                bail!("timed out after {:?} waiting for {job} (state: {})", timeout, view.state);
            }
            std::thread::sleep(Duration::from_millis(150));
        }
    }

    /// Start a subscription stream. Returns once the `subscribed` ack is
    /// verified; subsequent [`Client::read_json_line`] calls yield stream
    /// lines until a terminal `state` line.
    pub fn subscribe(&mut self, job: &str) -> Result<()> {
        self.roundtrip(&Request::Subscribe { job: job.into() }).map(|_| ())
    }
}

/// Check the envelope of a response object: version match and `ok:true`,
/// or a decoded typed error.
pub fn expect_ok(resp: Json) -> Result<Json> {
    let v = resp.require_usize("v")? as u64;
    if v != PROTOCOL_VERSION {
        bail!("daemon speaks protocol v{v}, this client v{PROTOCOL_VERSION}");
    }
    match resp.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(resp),
        Some(false) => {
            let err = resp.get("error").ok_or_else(|| anyhow!("error response lacks `error`"))?;
            bail!(
                "{}: {}",
                err.require_str("code").unwrap_or("unknown"),
                err.require_str("message").unwrap_or("(no message)")
            )
        }
        None => bail!("response lacks `ok`: {resp}"),
    }
}
