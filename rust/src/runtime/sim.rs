//! The pure-Rust artifact backend: reference executables for the
//! `train_step` / `mkor_step` / `eval_step` contracts.
//!
//! The original artifact path compiled Python-lowered HLO through PJRT —
//! a native toolchain this build cannot assume (see
//! [`crate::runtime::pjrt`], feature-gated off by default). This module
//! implements the same three executables directly against a small
//! masked-LM proxy model, so `mkor artifacts` can generate a complete,
//! dependency-free fixture set and the artifact-driven trainer
//! ([`crate::runtime::XlaTrainer`]) runs end to end on any machine.
//!
//! The proxy model (all parameters 2-D, shapes published in `meta.json`):
//!
//! ```text
//! h   = E[token] + P[position]                  embed [vocab,d] + pos [seq,d]
//! ×L: h = h + relu(h·W1)·W2                     W1 [d,d_ff], W2 [d_ff,d]
//! hn  = rmsnorm(h)                              (parameter-free, scale-stable)
//! logits = hn·W_head                            head [d,vocab]
//! loss = masked mean cross-entropy
//! ```
//!
//! `mkor_step` is *literally* Algorithm 1: factor inverses advance via
//! [`Mkor::sm_update`] (Eq. 5/6) and deltas are `rescale(R⁻¹ ∇ L⁻¹)`,
//! the exact dense evaluation `rust/tests/xla_cross_check.rs` compares
//! against — the cross-check validates the argument order, shape
//! plumbing and rescale normalization of the executable contract.
//!
//! The embed/pos tables are params 0 and 1 and are never preconditioned;
//! `factor_dims` lists every following 2-D matrix in order, matching the
//! `precond_idx` alignment rule the cross-check asserts.

use crate::linalg::{ops, Matrix};
use crate::optim::Mkor;
use crate::runtime::artifact::PresetMeta;
use crate::runtime::tensor::Literal;
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// `meta.json` marker selecting this backend (absent = legacy PJRT).
pub const SIM_BACKEND: &str = "sim";

/// RMS-norm epsilon (inside the sqrt, so the norm is exact-differentiable).
const RMS_EPS: f32 = 1e-6;

/// The preset catalog `mkor artifacts` can generate.
pub const PRESETS: [&str; 2] = ["tiny", "small"];

/// Build the [`PresetMeta`] of a named sim preset.
pub fn preset_meta(preset: &str) -> Result<PresetMeta> {
    let (vocab, d_model, n_layers, n_heads, d_ff, seq_len, batch) = match preset {
        "tiny" => (64, 32, 2, 2, 64, 16, 8),
        "small" => (256, 64, 4, 4, 128, 32, 16),
        other => bail!(
            "unknown artifact preset `{other}` (available: {})",
            PRESETS.join(", ")
        ),
    };
    let mut param_shapes = vec![vec![vocab, d_model], vec![seq_len, d_model]];
    for _ in 0..n_layers {
        param_shapes.push(vec![d_model, d_ff]);
        param_shapes.push(vec![d_ff, d_model]);
    }
    param_shapes.push(vec![d_model, vocab]);
    let factor_dims: Vec<(usize, usize)> =
        param_shapes[2..].iter().map(|s| (s[0], s[1])).collect();
    let params = param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    Ok(PresetMeta {
        preset: preset.to_string(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        seq_len,
        batch,
        params,
        factor_dims,
        param_shapes,
    })
}

/// Serialize a preset's `meta.json` (sorted keys — stable bytes).
pub fn preset_meta_json(meta: &PresetMeta) -> Json {
    let mut j = Json::obj();
    j.set("backend", Json::Str(SIM_BACKEND.to_string()))
        .set("preset", Json::Str(meta.preset.clone()))
        .set("vocab", Json::Num(meta.vocab as f64))
        .set("d_model", Json::Num(meta.d_model as f64))
        .set("n_layers", Json::Num(meta.n_layers as f64))
        .set("n_heads", Json::Num(meta.n_heads as f64))
        .set("d_ff", Json::Num(meta.d_ff as f64))
        .set("seq_len", Json::Num(meta.seq_len as f64))
        .set("batch", Json::Num(meta.batch as f64))
        .set("params", Json::Num(meta.params as f64))
        .set(
            "factor_dims",
            Json::Arr(
                meta.factor_dims
                    .iter()
                    .map(|&(a, b)| Json::from_usizes(&[a, b]))
                    .collect(),
            ),
        )
        .set(
            "param_shapes",
            Json::Arr(meta.param_shapes.iter().map(|s| Json::from_usizes(s)).collect()),
        );
    j
}

/// Write `dir/<preset>/meta.json` for a sim preset; returns the preset
/// directory. This is the whole fixture set: the sim backend needs no
/// lowered HLO files.
pub fn write_preset(dir: &Path, preset: &str) -> Result<PathBuf> {
    let meta = preset_meta(preset)?;
    let pdir = dir.join(preset);
    std::fs::create_dir_all(&pdir)
        .map_err(|e| anyhow!("creating {}: {e}", pdir.display()))?;
    let path = pdir.join("meta.json");
    preset_meta_json(&meta)
        .to_file(&path)
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(pdir)
}

/// The sim model: [`PresetMeta`] plus the derived preconditioning index.
pub struct SimModel {
    pub meta: PresetMeta,
    /// For each factor pair j, the index of the param it preconditions.
    precond_idx: Vec<usize>,
}

impl SimModel {
    /// Validate the meta against the layout this backend implements.
    pub fn new(meta: PresetMeta) -> Result<SimModel> {
        let np = meta.param_shapes.len();
        ensure!(
            np == 3 + 2 * meta.n_layers,
            "sim backend expects embed + pos + {}×(W1,W2) + head = {} params, meta lists {np}",
            meta.n_layers,
            3 + 2 * meta.n_layers
        );
        let d = meta.d_model;
        let expect: Vec<Vec<usize>> = {
            let mut v = vec![vec![meta.vocab, d], vec![meta.seq_len, d]];
            for _ in 0..meta.n_layers {
                v.push(vec![d, meta.d_ff]);
                v.push(vec![meta.d_ff, d]);
            }
            v.push(vec![d, meta.vocab]);
            v
        };
        ensure!(
            meta.param_shapes == expect,
            "sim backend param layout mismatch: meta has {:?}, expected {:?} — regenerate \
             with `mkor artifacts`",
            meta.param_shapes,
            expect
        );
        let want_factors: Vec<(usize, usize)> =
            expect[2..].iter().map(|s| (s[0], s[1])).collect();
        ensure!(
            meta.factor_dims == want_factors,
            "sim backend factor_dims mismatch: meta has {:?}, expected {:?}",
            meta.factor_dims,
            want_factors
        );
        let precond_idx: Vec<usize> = (2..np).collect();
        Ok(SimModel { meta, precond_idx })
    }

    fn np(&self) -> usize {
        self.meta.param_shapes.len()
    }

    fn nm(&self) -> usize {
        self.meta.factor_dims.len()
    }

    // ---- argument parsing ----------------------------------------------

    fn want_f32(&self, args: &[Literal], k: usize, dims: &[i64], what: &str) -> Result<Vec<f32>> {
        let lit = args
            .get(k)
            .ok_or_else(|| anyhow!("missing arg {k} (`{what}`)"))?;
        ensure!(
            lit.dims() == dims,
            "arg {k} (`{what}`): expected f32{dims:?}, got {lit}"
        );
        lit.to_vec::<f32>()
            .map_err(|e| anyhow!("arg {k} (`{what}`): {e}"))
    }

    fn want_i32(&self, args: &[Literal], k: usize, dims: &[i64], what: &str) -> Result<Vec<i32>> {
        let lit = args
            .get(k)
            .ok_or_else(|| anyhow!("missing arg {k} (`{what}`)"))?;
        ensure!(
            lit.dims() == dims,
            "arg {k} (`{what}`): expected i32{dims:?}, got {lit}"
        );
        lit.to_vec::<i32>()
            .map_err(|e| anyhow!("arg {k} (`{what}`): {e}"))
    }

    fn parse_params(&self, args: &[Literal]) -> Result<Vec<Matrix>> {
        self.meta
            .param_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
                let data = self.want_f32(args, i, &dims, &format!("param {i}"))?;
                Ok(Matrix::from_vec(s[0], s[1], data))
            })
            .collect()
    }

    /// Parse the trailing (tokens, targets, mask) batch triple starting at
    /// argument `at`; the leading (batch) dim is taken from the literal —
    /// shards are smaller than `meta.batch`.
    fn parse_batch(
        &self,
        args: &[Literal],
        at: usize,
    ) -> Result<(usize, Vec<i32>, Vec<i32>, Vec<f32>)> {
        let s = self.meta.seq_len;
        let tok_lit = args
            .get(at)
            .ok_or_else(|| anyhow!("missing arg {at} (`tokens`)"))?;
        let dims = tok_lit.dims().to_vec();
        ensure!(
            dims.len() == 2 && dims[1] == s as i64 && dims[0] >= 1,
            "arg {at} (`tokens`): expected i32[b,{s}], got {tok_lit}"
        );
        let b = dims[0] as usize;
        let toks = self.want_i32(args, at, &dims, "tokens")?;
        let tgts = self.want_i32(args, at + 1, &dims, "targets")?;
        let mask = self.want_f32(args, at + 2, &dims, "mask")?;
        let vocab = self.meta.vocab as i32;
        for (r, &t) in toks.iter().enumerate() {
            ensure!(
                (0..vocab).contains(&t),
                "tokens[{r}] = {t} out of range for vocab {vocab}"
            );
        }
        for (r, (&g, &m)) in tgts.iter().zip(&mask).enumerate() {
            ensure!(m.is_finite() && m >= 0.0, "mask[{r}] = {m} is not a weight");
            if m > 0.0 {
                ensure!(
                    (0..vocab).contains(&g),
                    "targets[{r}] = {g} out of range for vocab {vocab}"
                );
            }
        }
        Ok((b, toks, tgts, mask))
    }

    // ---- forward / backward --------------------------------------------

    /// `train_step`: `(params…, tokens, targets, mask)` →
    /// `(loss, grads…, a_vecs…, g_vecs…)`.
    pub fn train_step(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let np = self.np();
        ensure!(
            args.len() == np + 3,
            "train_step takes {} args ({np} params + tokens/targets/mask), got {}",
            np + 3,
            args.len()
        );
        let params = self.parse_params(args)?;
        let (b, toks, tgts, mask) = self.parse_batch(args, np)?;
        let fwd = self.forward(&params, b, &toks);
        let (loss, dlogits) = self.loss_and_dlogits(&fwd.logits, &tgts, &mask);

        let d = self.meta.d_model;
        let nl = self.meta.n_layers;
        let head = &params[np - 1];

        // Backward through the head and the parameter-free RMS norm.
        let g_head = ops::matmul_tn(&fwd.hn, &dlogits);
        let dhn = ops::matmul_nt(&dlogits, head);
        let mut dh = rmsnorm_backward(&fwd.hn, &fwd.rms, &dhn);

        // Per-factor rank-1 statistics (batch means), factor order.
        let nm = self.nm();
        let mut a_vecs: Vec<Vec<f32>> = vec![Vec::new(); nm];
        let mut g_vecs: Vec<Vec<f32>> = vec![Vec::new(); nm];
        a_vecs[nm - 1] = mean_rows(&fwd.hn);
        g_vecs[nm - 1] = mean_rows(&dlogits);

        // Backward through the residual MLP stack.
        let mut grads: Vec<Matrix> = Vec::with_capacity(np);
        let mut layer_grads: Vec<(Matrix, Matrix)> = Vec::with_capacity(nl);
        for l in (0..nl).rev() {
            let w1 = &params[2 + 2 * l];
            let w2 = &params[2 + 2 * l + 1];
            let lf = &fwd.layers[l];
            let dv = dh.clone(); // residual branch output grad
            let g_w2 = ops::matmul_tn(&lf.act, &dv);
            let da = ops::matmul_nt(&dv, w2);
            let mut du = da;
            relu_backward_inplace(&mut du, &lf.pre);
            let g_w1 = ops::matmul_tn(&lf.input, &du);
            // Rank-1 stats for this layer's two factor pairs.
            a_vecs[2 * l] = mean_rows(&lf.input);
            g_vecs[2 * l] = mean_rows(&du);
            a_vecs[2 * l + 1] = mean_rows(&lf.act);
            g_vecs[2 * l + 1] = mean_rows(&dv);
            // dh flows through both the skip and the MLP branch.
            let dskip = ops::matmul_nt(&du, w1);
            add_inplace(&mut dh, &dskip);
            layer_grads.push((g_w1, g_w2));
        }
        layer_grads.reverse();

        // Embedding/position gradients: scatter dh rows.
        let s = self.meta.seq_len;
        let mut g_embed = Matrix::zeros(self.meta.vocab, d);
        let mut g_pos = Matrix::zeros(s, d);
        for i in 0..b {
            for t in 0..s {
                let r = i * s + t;
                let tok = toks[r] as usize;
                let row = &dh.data()[r * d..(r + 1) * d];
                let e = &mut g_embed.data_mut()[tok * d..(tok + 1) * d];
                for (ev, &rv) in e.iter_mut().zip(row) {
                    *ev += rv;
                }
                let p = &mut g_pos.data_mut()[t * d..(t + 1) * d];
                for (pv, &rv) in p.iter_mut().zip(row) {
                    *pv += rv;
                }
            }
        }
        grads.push(g_embed);
        grads.push(g_pos);
        for (g1, g2) in layer_grads {
            grads.push(g1);
            grads.push(g2);
        }
        grads.push(g_head);

        // Package: (loss, grads…, a_vecs…, g_vecs…).
        let mut out = Vec::with_capacity(1 + np + 2 * nm);
        out.push(Literal::scalar_f32(loss));
        for (g, shape) in grads.iter().zip(&self.meta.param_shapes) {
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            out.push(Literal::f32(g.data(), &dims)?);
        }
        for (a, &(din, _)) in a_vecs.iter().zip(&self.meta.factor_dims) {
            out.push(Literal::f32(a, &[din as i64])?);
        }
        for (g, &(_, dout)) in g_vecs.iter().zip(&self.meta.factor_dims) {
            out.push(Literal::f32(g, &[dout as i64])?);
        }
        Ok(out)
    }

    /// `eval_step`: `(params…, tokens, targets, mask)` → `(loss,)`.
    pub fn eval_step(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let np = self.np();
        ensure!(
            args.len() == np + 3,
            "eval_step takes {} args, got {}",
            np + 3,
            args.len()
        );
        let params = self.parse_params(args)?;
        let (b, toks, tgts, mask) = self.parse_batch(args, np)?;
        let fwd = self.forward(&params, b, &toks);
        let (loss, _) = self.loss_and_dlogits(&fwd.logits, &tgts, &mask);
        Ok(vec![Literal::scalar_f32(loss)])
    }

    /// `mkor_step`: `(grads…, linvs…, rinvs…, a…, g…, gamma, flag)` →
    /// `(deltas…, new_linvs…, new_rinvs…)`. With `flag > 0.5` the factor
    /// inverses advance by [`Mkor::sm_update`] first; either way the
    /// preconditioned deltas are `rescale(R⁻¹ ∇ L⁻¹)` and the embed/pos
    /// grads pass through untouched.
    pub fn mkor_step(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let (np, nm) = (self.np(), self.nm());
        let want = np + 4 * nm + 2;
        ensure!(
            args.len() == want,
            "mkor_step takes {want} args ({np} grads + {nm}×(linv,rinv,a,g) + gamma + flag), \
             got {}",
            args.len()
        );
        let mut grads = Vec::with_capacity(np);
        for (i, shape) in self.meta.param_shapes.iter().enumerate() {
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            let data = self.want_f32(args, i, &dims, &format!("grad {i}"))?;
            grads.push(Matrix::from_vec(shape[0], shape[1], data));
        }
        let mut linvs = Vec::with_capacity(nm);
        let mut rinvs = Vec::with_capacity(nm);
        for (j, &(_, dout)) in self.meta.factor_dims.iter().enumerate() {
            let dims = [dout as i64, dout as i64];
            let data = self.want_f32(args, np + j, &dims, &format!("linv {j}"))?;
            linvs.push(Matrix::from_vec(dout, dout, data));
        }
        for (j, &(din, _)) in self.meta.factor_dims.iter().enumerate() {
            let dims = [din as i64, din as i64];
            let data = self.want_f32(args, np + nm + j, &dims, &format!("rinv {j}"))?;
            rinvs.push(Matrix::from_vec(din, din, data));
        }
        let mut a_vecs = Vec::with_capacity(nm);
        let mut g_vecs = Vec::with_capacity(nm);
        for (j, &(din, _)) in self.meta.factor_dims.iter().enumerate() {
            a_vecs.push(self.want_f32(args, np + 2 * nm + j, &[din as i64], &format!("a {j}"))?);
        }
        for (j, &(_, dout)) in self.meta.factor_dims.iter().enumerate() {
            g_vecs.push(self.want_f32(args, np + 3 * nm + j, &[dout as i64], &format!("g {j}"))?);
        }
        let gamma = self.want_f32(args, np + 4 * nm, &[], "gamma")?[0];
        let flag = self.want_f32(args, np + 4 * nm + 1, &[], "update flag")?[0];

        // Factor update (Eq. 5/6) when the flag is raised.
        if flag > 0.5 {
            for j in 0..nm {
                let (din, dout) = self.meta.factor_dims[j];
                let mut scratch = vec![0.0f32; dout];
                Mkor::sm_update(&mut linvs[j], &g_vecs[j], gamma, &mut scratch);
                let mut scratch = vec![0.0f32; din];
                Mkor::sm_update(&mut rinvs[j], &a_vecs[j], gamma, &mut scratch);
            }
        }

        // Preconditioning + rescale; non-preconditioned grads pass through.
        let mut deltas: Vec<Matrix> = grads.clone();
        for (j, &i) in self.precond_idx.iter().enumerate() {
            let raw = ops::matmul(&ops::matmul(&rinvs[j], &grads[i]), &linvs[j]);
            let gn = grads[i].fro_norm();
            let dn = raw.fro_norm();
            let mut scaled = raw;
            scaled.scale((gn / dn.max(1e-30)) as f32);
            deltas[i] = scaled;
        }

        let mut out = Vec::with_capacity(np + 2 * nm);
        for (d, shape) in deltas.iter().zip(&self.meta.param_shapes) {
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            out.push(Literal::f32(d.data(), &dims)?);
        }
        for (l, &(_, dout)) in linvs.iter().zip(&self.meta.factor_dims) {
            out.push(Literal::f32(l.data(), &[dout as i64, dout as i64])?);
        }
        for (r, &(din, _)) in rinvs.iter().zip(&self.meta.factor_dims) {
            out.push(Literal::f32(r.data(), &[din as i64, din as i64])?);
        }
        Ok(out)
    }

    fn forward(&self, params: &[Matrix], b: usize, toks: &[i32]) -> Forward {
        let d = self.meta.d_model;
        let s = self.meta.seq_len;
        let n = b * s;
        let embed = &params[0];
        let pos = &params[1];
        let mut h = Matrix::zeros(n, d);
        for i in 0..b {
            for t in 0..s {
                let r = i * s + t;
                let tok = toks[r] as usize;
                let e = &embed.data()[tok * d..(tok + 1) * d];
                let p = &pos.data()[t * d..(t + 1) * d];
                let row = &mut h.data_mut()[r * d..(r + 1) * d];
                for (hv, (&ev, &pv)) in row.iter_mut().zip(e.iter().zip(p)) {
                    *hv = ev + pv;
                }
            }
        }
        let mut layers = Vec::with_capacity(self.meta.n_layers);
        for l in 0..self.meta.n_layers {
            let w1 = &params[2 + 2 * l];
            let w2 = &params[2 + 2 * l + 1];
            let input = h.clone();
            let pre = ops::matmul(&input, w1);
            let mut act = pre.clone();
            for v in act.data_mut() {
                *v = v.max(0.0);
            }
            let out = ops::matmul(&act, w2);
            add_inplace(&mut h, &out);
            layers.push(LayerFwd { input, pre, act });
        }
        let (hn, rms) = rmsnorm_rows(&h);
        let logits = ops::matmul(&hn, &params[params.len() - 1]);
        Forward { layers, hn, rms, logits }
    }

    /// Masked mean cross-entropy over the logits, plus its gradient.
    fn loss_and_dlogits(
        &self,
        logits: &Matrix,
        tgts: &[i32],
        mask: &[f32],
    ) -> (f32, Matrix) {
        let (n, v) = (logits.rows(), logits.cols());
        let wsum: f64 = mask.iter().map(|&m| m as f64).sum();
        let denom = wsum.max(1e-12);
        let mut dlogits = Matrix::zeros(n, v);
        let mut loss = 0.0f64;
        for r in 0..n {
            let row = &logits.data()[r * v..(r + 1) * v];
            let m = mask[r];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut z = 0.0f64;
            for &x in row {
                z += ((x - mx) as f64).exp();
            }
            let log_z = mx as f64 + z.ln();
            if m > 0.0 {
                let t = tgts[r] as usize;
                loss += (m as f64) * (log_z - row[t] as f64);
            }
            let drow = &mut dlogits.data_mut()[r * v..(r + 1) * v];
            if m > 0.0 {
                let t = tgts[r] as usize;
                let w = (m as f64 / denom) as f32;
                for (c, dv) in drow.iter_mut().enumerate() {
                    let p = (((row[c] - mx) as f64).exp() / z) as f32;
                    *dv = w * (p - f32::from(c == t));
                }
            }
        }
        ((loss / denom) as f32, dlogits)
    }
}

struct LayerFwd {
    input: Matrix,
    pre: Matrix,
    act: Matrix,
}

struct Forward {
    layers: Vec<LayerFwd>,
    hn: Matrix,
    rms: Vec<f32>,
    logits: Matrix,
}

fn add_inplace(dst: &mut Matrix, src: &Matrix) {
    debug_assert_eq!((dst.rows(), dst.cols()), (src.rows(), src.cols()));
    for (d, &s) in dst.data_mut().iter_mut().zip(src.data()) {
        *d += s;
    }
}

fn relu_backward_inplace(grad: &mut Matrix, pre: &Matrix) {
    for (g, &p) in grad.data_mut().iter_mut().zip(pre.data()) {
        if p <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Row-wise RMS normalization: `y_r = x_r / sqrt(mean(x_r²) + ε)`.
fn rmsnorm_rows(x: &Matrix) -> (Matrix, Vec<f32>) {
    let (n, d) = (x.rows(), x.cols());
    let mut y = Matrix::zeros(n, d);
    let mut rms = vec![0.0f32; n];
    for r in 0..n {
        let row = &x.data()[r * d..(r + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let rv = (ms + RMS_EPS as f64).sqrt() as f32;
        rms[r] = rv;
        let yr = &mut y.data_mut()[r * d..(r + 1) * d];
        for (yv, &xv) in yr.iter_mut().zip(row) {
            *yv = xv / rv;
        }
    }
    (y, rms)
}

/// Exact backward of [`rmsnorm_rows`], per row:
/// `dx_j = (dy_j − y_j · Σ_k dy_k y_k / d) / r`.
fn rmsnorm_backward(y: &Matrix, rms: &[f32], dy: &Matrix) -> Matrix {
    let (n, d) = (y.rows(), y.cols());
    let mut dx = Matrix::zeros(n, d);
    for r in 0..n {
        let yr = &y.data()[r * d..(r + 1) * d];
        let dyr = &dy.data()[r * d..(r + 1) * d];
        let s: f64 = yr.iter().zip(dyr).map(|(&a, &b)| (a as f64) * (b as f64)).sum();
        let s = (s / d as f64) as f32;
        let rv = rms[r];
        let dxr = &mut dx.data_mut()[r * d..(r + 1) * d];
        for ((dv, &yv), &dyv) in dxr.iter_mut().zip(yr).zip(dyr) {
            *dv = (dyv - yv * s) / rv;
        }
    }
    dx
}

/// Mean over the rows of an `n×d` matrix → length-`d` vector.
fn mean_rows(m: &Matrix) -> Vec<f32> {
    let (n, d) = (m.rows(), m.cols());
    let mut out = vec![0.0f64; d];
    for r in 0..n {
        for (o, &v) in out.iter_mut().zip(&m.data()[r * d..(r + 1) * d]) {
            *o += v as f64;
        }
    }
    out.iter().map(|&v| (v / n.max(1) as f64) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::xla_trainer::init_params;
    use crate::util::Rng;

    fn mini_model() -> SimModel {
        let mut meta = preset_meta("tiny").unwrap();
        meta.preset = "mini".into();
        meta.vocab = 7;
        meta.d_model = 4;
        meta.n_layers = 1;
        meta.n_heads = 1;
        meta.d_ff = 5;
        meta.seq_len = 3;
        meta.batch = 2;
        meta.param_shapes = vec![
            vec![7, 4],
            vec![3, 4],
            vec![4, 5],
            vec![5, 4],
            vec![4, 7],
        ];
        meta.factor_dims = vec![(4, 5), (5, 4), (4, 7)];
        meta.params = meta.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        SimModel::new(meta).unwrap()
    }

    fn lit_args(model: &SimModel, params: &[Vec<f32>], b: usize, seed: u64) -> Vec<Literal> {
        let meta = &model.meta;
        let mut rng = Rng::new(seed);
        let s = meta.seq_len;
        let mut toks = Vec::new();
        let mut tgts = Vec::new();
        let mut mask = Vec::new();
        for _ in 0..b * s {
            toks.push((rng.next_u64() % meta.vocab as u64) as i32);
            tgts.push((rng.next_u64() % meta.vocab as u64) as i32);
            mask.push(if rng.next_u64() % 3 == 0 { 0.0 } else { 1.0 });
        }
        mask[0] = 1.0; // at least one supervised position, whatever the seed

        let mut args: Vec<Literal> = params
            .iter()
            .zip(&meta.param_shapes)
            .map(|(p, sh)| {
                let dims: Vec<i64> = sh.iter().map(|&d| d as i64).collect();
                Literal::f32(p, &dims).unwrap()
            })
            .collect();
        let dims = [b as i64, s as i64];
        args.push(Literal::i32(&toks, &dims).unwrap());
        args.push(Literal::i32(&tgts, &dims).unwrap());
        args.push(Literal::f32(&mask, &dims).unwrap());
        args
    }

    #[test]
    fn presets_generate_consistent_meta() {
        for name in PRESETS {
            let meta = preset_meta(name).unwrap();
            let model = SimModel::new(meta.clone()).unwrap();
            assert_eq!(model.nm(), meta.param_shapes.len() - 2);
            // The cross-check's alignment rule must hold: factor j maps to
            // param j+2, and embed/pos (params 0/1) are never factored.
            assert_eq!(model.precond_idx, (2..meta.param_shapes.len()).collect::<Vec<_>>());
            let j = preset_meta_json(&meta);
            let back = PresetMeta::from_json(&j).unwrap();
            assert_eq!(back.factor_dims, meta.factor_dims);
            assert_eq!(back.param_shapes, meta.param_shapes);
        }
        assert!(preset_meta("bogus").is_err());
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        let model = mini_model();
        let mut rng = Rng::new(11);
        let mut params = init_params(&model.meta, &mut rng);
        // Non-degenerate magnitudes so finite differences are well-scaled.
        for p in &mut params {
            for v in p.iter_mut() {
                *v *= 10.0;
            }
        }
        let args = lit_args(&model, &params, 2, 3);
        let out = model.train_step(&args).unwrap();
        let np = model.meta.param_shapes.len();
        assert_eq!(out.len(), 1 + np + 2 * model.meta.factor_dims.len());
        let loss = out[0].to_vec::<f32>().unwrap()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

        let eval_loss = |params: &[Vec<f32>]| -> f32 {
            let args = lit_args(&model, params, 2, 3);
            model.eval_step(&args).unwrap()[0].to_vec::<f32>().unwrap()[0]
        };
        assert!((eval_loss(&params) - loss).abs() < 1e-6, "eval/train forward agree");

        let h = 1e-2f32;
        for pi in 0..np {
            let grad = out[1 + pi].to_vec::<f32>().unwrap();
            let n = grad.len();
            for &k in &[0usize, n / 2, n - 1] {
                let mut up = params.to_vec();
                up[pi][k] += h;
                let mut dn = params.to_vec();
                dn[pi][k] -= h;
                let fd = (eval_loss(&up) - eval_loss(&dn)) / (2.0 * h);
                let g = grad[k];
                assert!(
                    (fd - g).abs() < 5e-3 + 0.02 * g.abs(),
                    "param {pi}[{k}]: analytic {g} vs finite-diff {fd}"
                );
            }
        }
    }

    #[test]
    fn mkor_step_with_identity_factors_passes_grads_through() {
        let model = mini_model();
        let meta = &model.meta;
        let (np, nm) = (meta.param_shapes.len(), meta.factor_dims.len());
        let mut rng = Rng::new(5);
        let mut args = Vec::new();
        let mut grads = Vec::new();
        for sh in &meta.param_shapes {
            let n: usize = sh.iter().product();
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian(&mut v, 1.0);
            let dims: Vec<i64> = sh.iter().map(|&d| d as i64).collect();
            args.push(Literal::f32(&v, &dims).unwrap());
            grads.push(v);
        }
        for &(_, dout) in &meta.factor_dims {
            let m = Matrix::identity(dout);
            args.push(Literal::f32(m.data(), &[dout as i64, dout as i64]).unwrap());
        }
        for &(din, _) in &meta.factor_dims {
            let m = Matrix::identity(din);
            args.push(Literal::f32(m.data(), &[din as i64, din as i64]).unwrap());
        }
        for &(din, _) in &meta.factor_dims {
            args.push(Literal::f32(&vec![0.5f32; din], &[din as i64]).unwrap());
        }
        for &(_, dout) in &meta.factor_dims {
            args.push(Literal::f32(&vec![0.5f32; dout], &[dout as i64]).unwrap());
        }
        args.push(Literal::scalar_f32(0.9));
        args.push(Literal::scalar_f32(0.0)); // flag off: factors frozen
        let out = model.mkor_step(&args).unwrap();
        assert_eq!(out.len(), np + 2 * nm);
        // Identity factors + rescale ⇒ deltas equal the grads (scale 1).
        for i in 0..np {
            let d = out[i].to_vec::<f32>().unwrap();
            for (a, b) in d.iter().zip(&grads[i]) {
                assert!((a - b).abs() < 1e-5, "param {i}: {a} vs {b}");
            }
        }
        // flag = 0: identity in, identity out.
        for (j, &(_, dout)) in meta.factor_dims.iter().enumerate() {
            let got = out[np + j].to_vec::<f32>().unwrap();
            assert_eq!(got, Matrix::identity(dout).data().to_vec(), "linv {j}");
        }
    }

    #[test]
    fn executables_reject_malformed_arguments() {
        let model = mini_model();
        let e = model.train_step(&[]).unwrap_err().to_string();
        assert!(e.contains("train_step takes"), "{e}");
        let mut rng = Rng::new(2);
        let params = init_params(&model.meta, &mut rng);
        let mut args = lit_args(&model, &params, 2, 3);
        // Token out of vocab range.
        let s = model.meta.seq_len as i64;
        args[5] = Literal::i32(&vec![99; 2 * s as usize], &[2, s]).unwrap();
        let e = model.train_step(&args).unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        // Wrong element type where f32 is expected.
        let mut args = lit_args(&model, &params, 2, 3);
        let last = args.len() - 1;
        args[last] = Literal::i32(&vec![1; 2 * s as usize], &[2, s]).unwrap();
        let e = model.train_step(&args).unwrap_err().to_string();
        assert!(e.contains("mask"), "{e}");
    }

    #[test]
    fn write_preset_round_trips_through_meta_json() {
        let dir = std::env::temp_dir().join(format!("mkor-sim-preset-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let pdir = write_preset(&dir, "tiny").unwrap();
        let j = Json::from_file(&pdir.join("meta.json")).unwrap();
        assert_eq!(j.get("backend").and_then(Json::as_str), Some(SIM_BACKEND));
        let meta = PresetMeta::from_json(&j).unwrap();
        SimModel::new(meta).unwrap();
        assert!(write_preset(&dir, "nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
