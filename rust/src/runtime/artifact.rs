//! Artifact loading: preset metadata (`meta.json`) plus the three
//! executables (`train_step`/`mkor_step`/`eval_step`) behind a uniform
//! [`Executable::run`] interface.
//!
//! Two backends implement the contract:
//!
//! * **sim** (default, always available) — `meta.json` carries
//!   `"backend": "sim"` and the executables are the pure-Rust reference
//!   programs in [`crate::runtime::sim`]. Generate the fixture set with
//!   `mkor artifacts`.
//! * **pjrt** (feature `pjrt`, off by default) — the original path:
//!   Python-lowered `*.hlo.txt` compiled through a PJRT CPU client. See
//!   [`crate::runtime::pjrt`] for what enabling it requires.

use crate::runtime::tensor::Literal;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Which of the three contract programs an [`Executable`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ProgramKind {
    TrainStep,
    MkorStep,
    EvalStep,
}

enum Backend {
    Sim(Arc<crate::runtime::sim::SimModel>),
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::pjrt::PjrtExecutable),
}

/// One compiled computation.
pub struct Executable {
    pub name: String,
    kind: ProgramKind,
    backend: Backend,
}

impl Executable {
    /// Execute on literals; returns the flattened tuple outputs.
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let out = match &self.backend {
            Backend::Sim(model) => match self.kind {
                ProgramKind::TrainStep => model.train_step(args),
                ProgramKind::MkorStep => model.mkor_step(args),
                ProgramKind::EvalStep => model.eval_step(args),
            },
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(exe) => exe.run(args),
        };
        out.with_context(|| format!("executing artifact `{}`", self.name))
    }
}

/// Metadata for one model preset (`artifacts/<preset>/meta.json`),
/// written by `mkor artifacts` (sim) or mirrored from the Python lowering
/// configs (pjrt).
#[derive(Clone, Debug)]
pub struct PresetMeta {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub params: usize,
    /// `(d_in, d_out)` of each preconditioned weight matrix (`x @ W`
    /// convention), in the order the `mkor_step` artifact consumes their
    /// factor inverses: `R⁻¹` is d_in×d_in, `L⁻¹` is d_out×d_out.
    pub factor_dims: Vec<(usize, usize)>,
    /// Parameter tensor shapes, in artifact argument order.
    pub param_shapes: Vec<Vec<usize>>,
}

impl PresetMeta {
    pub fn from_json(j: &Json) -> Result<Self> {
        let factor_dims = j
            .get("factor_dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing factor_dims"))?
            .iter()
            .map(|p| {
                let a = p.as_arr().ok_or_else(|| anyhow!("bad factor_dims entry"))?;
                Ok((
                    a[0].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                    a[1].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let param_shapes = j
            .get("param_shapes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing param_shapes"))?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| anyhow!("bad param shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PresetMeta {
            preset: j.require_str("preset")?.to_string(),
            vocab: j.require_usize("vocab")?,
            d_model: j.require_usize("d_model")?,
            n_layers: j.require_usize("n_layers")?,
            n_heads: j.require_usize("n_heads")?,
            d_ff: j.require_usize("d_ff")?,
            seq_len: j.require_usize("seq_len")?,
            batch: j.require_usize("batch")?,
            params: j.require_usize("params")?,
            factor_dims,
            param_shapes,
        })
    }
}

/// All artifacts of one preset: metadata + the three executables.
pub struct ArtifactBundle {
    pub meta: PresetMeta,
    pub dir: PathBuf,
    platform: String,
    /// `train_step`: (params…, tokens, targets, mask) → (loss, grads…, a_vecs…, g_vecs…)
    pub train_step: Executable,
    /// `mkor_step`: (grads…, linvs…, rinvs…, a…, g…, gamma, flag) →
    /// (deltas…, new_linvs…, new_rinvs…)
    pub mkor_step: Executable,
    /// `eval_step`: (params…, tokens, targets, mask) → (loss,)
    pub eval_step: Executable,
}

impl ArtifactBundle {
    /// Load `artifacts/<preset>/` (generate with `mkor artifacts` first).
    /// `meta.json`'s `backend` field selects the implementation; absent
    /// means the legacy PJRT layout.
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<Self> {
        let dir = artifacts_dir.join(preset);
        let meta_path = dir.join("meta.json");
        let meta_json = Json::from_file(&meta_path)?;
        let meta = PresetMeta::from_json(&meta_json)
            .with_context(|| format!("parsing {}", meta_path.display()))?;
        let backend = meta_json
            .get("backend")
            .and_then(Json::as_str)
            .unwrap_or("pjrt")
            .to_string();
        match backend.as_str() {
            crate::runtime::sim::SIM_BACKEND => {
                let model = Arc::new(
                    crate::runtime::sim::SimModel::new(meta.clone())
                        .with_context(|| format!("validating {}", meta_path.display()))?,
                );
                let exe = |name: &str, kind: ProgramKind| Executable {
                    name: name.to_string(),
                    kind,
                    backend: Backend::Sim(Arc::clone(&model)),
                };
                Ok(ArtifactBundle {
                    train_step: exe("train_step", ProgramKind::TrainStep),
                    mkor_step: exe("mkor_step", ProgramKind::MkorStep),
                    eval_step: exe("eval_step", ProgramKind::EvalStep),
                    meta,
                    dir,
                    platform: "sim-cpu".to_string(),
                })
            }
            "pjrt" => Self::load_pjrt(meta, dir),
            other => Err(anyhow!(
                "{}: unknown artifact backend `{other}` (this build knows `sim`{}) — \
                 regenerate with `mkor artifacts`",
                meta_path.display(),
                if cfg!(feature = "pjrt") { " and `pjrt`" } else { "" }
            )),
        }
    }

    #[cfg(feature = "pjrt")]
    fn load_pjrt(meta: PresetMeta, dir: PathBuf) -> Result<Self> {
        let loaded = crate::runtime::pjrt::load_bundle(&dir)?;
        let exe = |name: &str, kind: ProgramKind, e: crate::runtime::pjrt::PjrtExecutable| {
            Executable { name: name.to_string(), kind, backend: Backend::Pjrt(e) }
        };
        Ok(ArtifactBundle {
            train_step: exe("train_step", ProgramKind::TrainStep, loaded.train_step),
            mkor_step: exe("mkor_step", ProgramKind::MkorStep, loaded.mkor_step),
            eval_step: exe("eval_step", ProgramKind::EvalStep, loaded.eval_step),
            meta,
            dir,
            platform: loaded.platform,
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn load_pjrt(_meta: PresetMeta, dir: PathBuf) -> Result<Self> {
        Err(anyhow!(
            "{}: this bundle targets the PJRT backend (lowered HLO), but this build has no \
             `pjrt` feature — run `mkor artifacts` to generate the pure-Rust sim bundle, or \
             rebuild with `--features pjrt` in a PJRT-equipped environment \
             (see rust/src/runtime/pjrt.rs)",
            dir.display()
        ))
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    Ok(Literal::f32(data, dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    Ok(Literal::i32(data, dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> Result<Literal> {
    Ok(Literal::scalar_f32(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_meta_parses() {
        let j = Json::parse(
            r#"{"preset":"tiny","vocab":1024,"d_model":128,"n_layers":2,
                "n_heads":4,"d_ff":512,"seq_len":64,"batch":8,"params":1000,
                "factor_dims":[[128,128],[128,512]],
                "param_shapes":[[128,128],[128,512]]}"#,
        )
        .unwrap();
        let m = PresetMeta::from_json(&j).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.factor_dims, vec![(128, 128), (128, 512)]);
        assert_eq!(m.param_shapes, vec![vec![128, 128], vec![128, 512]]);
    }

    #[test]
    fn preset_meta_rejects_missing_fields() {
        let j = Json::parse(r#"{"preset":"x"}"#).unwrap();
        assert!(PresetMeta::from_json(&j).is_err());
    }

    #[test]
    fn bundle_loads_generated_sim_preset_and_rejects_pjrt_without_feature() {
        let dir = std::env::temp_dir().join(format!("mkor-artifact-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        crate::runtime::sim::write_preset(&dir, "tiny").unwrap();
        let bundle = ArtifactBundle::load(&dir, "tiny").unwrap();
        assert_eq!(bundle.platform(), "sim-cpu");
        assert_eq!(bundle.meta.preset, "tiny");

        // A meta without the backend marker means legacy PJRT — without
        // the feature that must be an actionable error, not a skip.
        if cfg!(not(feature = "pjrt")) {
            let pdir = dir.join("legacy");
            std::fs::create_dir_all(&pdir).unwrap();
            let mut j = crate::runtime::sim::preset_meta_json(&bundle.meta);
            j.set("backend", Json::Str("pjrt".to_string()));
            j.to_file(&pdir.join("meta.json")).unwrap();
            let e = ArtifactBundle::load(&dir, "legacy").unwrap_err().to_string();
            assert!(e.contains("pjrt"), "{e}");
            assert!(e.contains("mkor artifacts"), "{e}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
