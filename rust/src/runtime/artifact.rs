//! Artifact loading: HLO text → compiled PJRT executable, plus the preset
//! metadata (`meta.json`) that tells Rust the shapes/argument order the
//! Python side lowered with.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One compiled computation.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on literals; returns the flattened tuple outputs.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing artifact `{}`", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of `{}`", self.name))?;
        // aot.py lowers with return_tuple=True, so outputs are one tuple.
        Ok(out.to_tuple()?)
    }
}

/// Metadata for one model preset, mirrored from `python/compile/configs.py`
/// by `aot.py` into `artifacts/<preset>/meta.json`.
#[derive(Clone, Debug)]
pub struct PresetMeta {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub params: usize,
    /// `(d_in, d_out)` of each preconditioned weight matrix (JAX `x @ W`
    /// convention), in the order the `mkor_step` artifact consumes their
    /// factor inverses: `R⁻¹` is d_in×d_in, `L⁻¹` is d_out×d_out.
    pub factor_dims: Vec<(usize, usize)>,
    /// Parameter tensor shapes, in artifact argument order.
    pub param_shapes: Vec<Vec<usize>>,
}

impl PresetMeta {
    pub fn from_json(j: &Json) -> Result<Self> {
        let factor_dims = j
            .get("factor_dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing factor_dims"))?
            .iter()
            .map(|p| {
                let a = p.as_arr().ok_or_else(|| anyhow!("bad factor_dims entry"))?;
                Ok((
                    a[0].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                    a[1].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let param_shapes = j
            .get("param_shapes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing param_shapes"))?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| anyhow!("bad param shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PresetMeta {
            preset: j.require_str("preset")?.to_string(),
            vocab: j.require_usize("vocab")?,
            d_model: j.require_usize("d_model")?,
            n_layers: j.require_usize("n_layers")?,
            n_heads: j.require_usize("n_heads")?,
            d_ff: j.require_usize("d_ff")?,
            seq_len: j.require_usize("seq_len")?,
            batch: j.require_usize("batch")?,
            params: j.require_usize("params")?,
            factor_dims,
            param_shapes,
        })
    }
}

/// All artifacts of one preset: metadata + the compiled computations.
pub struct ArtifactBundle {
    pub meta: PresetMeta,
    pub dir: PathBuf,
    client: xla::PjRtClient,
    /// `train_step`: (params…, tokens, targets, mask) → (loss, grads…, a_vecs…, g_vecs…)
    pub train_step: Executable,
    /// `mkor_step`: (params…, grads…, linvs…, rinvs…, a…, g…, scalars) →
    /// (new_params…, new_linvs…, new_rinvs…)
    pub mkor_step: Executable,
    /// `eval_step`: (params…, tokens, targets, mask) → (loss,)
    pub eval_step: Executable,
}

impl ArtifactBundle {
    /// Load and compile `artifacts/<preset>/` (run `make artifacts` first).
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<Self> {
        let dir = artifacts_dir.join(preset);
        let meta_path = dir.join("meta.json");
        let meta = PresetMeta::from_json(&Json::from_file(&meta_path)?)
            .with_context(|| format!("parsing {}", meta_path.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |name: &str| -> Result<Executable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
            Ok(Executable { name: name.to_string(), exe })
        };
        Ok(ArtifactBundle {
            train_step: load("train_step")?,
            mkor_step: load("mkor_step")?,
            eval_step: load("eval_step")?,
            meta,
            dir,
            client,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[x]).reshape(&[])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_meta_parses() {
        let j = Json::parse(
            r#"{"preset":"tiny","vocab":1024,"d_model":128,"n_layers":2,
                "n_heads":4,"d_ff":512,"seq_len":64,"batch":8,"params":1000,
                "factor_dims":[[128,128],[128,512]],
                "param_shapes":[[128,128],[128,512]]}"#,
        )
        .unwrap();
        let m = PresetMeta::from_json(&j).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.factor_dims, vec![(128, 128), (128, 512)]);
        assert_eq!(m.param_shapes, vec![vec![128, 128], vec![128, 512]]);
    }

    #[test]
    fn preset_meta_rejects_missing_fields() {
        let j = Json::parse(r#"{"preset":"x"}"#).unwrap();
        assert!(PresetMeta::from_json(&j).is_err());
    }
}
