//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the Rust training path.
//!
//! Python runs once at build time (`make artifacts`); afterwards the `mkor`
//! binary is self-contained. The interchange format is **HLO text** (not a
//! serialized `HloModuleProto`) — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see `/opt/xla-example/README.md`).

pub mod artifact;
pub mod xla_trainer;

pub use artifact::{ArtifactBundle, Executable, PresetMeta};
pub use xla_trainer::XlaTrainer;
