//! The native PJRT artifact backend (feature `pjrt`, **off by default**).
//!
//! This is the original loading path: Python-lowered `*.hlo.txt` compiled
//! through a PJRT CPU client. It depends on the out-of-tree `xla` crate
//! (a native XLA/PJRT binding), which is intentionally **not** declared in
//! `Cargo.toml` — this repository builds offline, and an undeclared native
//! toolchain must fail at feature-selection time with a clear message, not
//! at link time deep in a build.
//!
//! To enable in a PJRT-equipped environment:
//!
//! 1. add the binding to `Cargo.toml` (e.g. `xla = "0.1"` or a vendored
//!    path dependency) under `[dependencies]`, and
//! 2. build with `cargo build --features pjrt`.
//!
//! Everything else — [`crate::runtime::ArtifactBundle`], the trainer, the
//! tests — is backend-agnostic over [`Literal`]; this module only converts
//! at the boundary.

use crate::runtime::tensor::Literal;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One PJRT-compiled computation.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
}

/// The three compiled programs plus the client's platform name.
pub struct LoadedBundle {
    pub platform: String,
    pub train_step: PjrtExecutable,
    pub mkor_step: PjrtExecutable,
    pub eval_step: PjrtExecutable,
}

fn to_xla(lit: &Literal) -> Result<xla::Literal> {
    let dims = lit.dims().to_vec();
    match lit {
        Literal::F32 { data, .. } => Ok(xla::Literal::vec1(data).reshape(&dims)?),
        Literal::I32 { data, .. } => Ok(xla::Literal::vec1(data).reshape(&dims)?),
    }
}

fn from_xla(lit: &xla::Literal) -> Result<Literal> {
    let shape = lit.shape()?;
    let dims: Vec<i64> = match &shape {
        xla::Shape::Array(a) => a.dims().to_vec(),
        _ => anyhow::bail!("non-array literal in artifact output"),
    };
    match lit.to_vec::<f32>() {
        Ok(v) => Ok(Literal::f32(&v, &dims)?),
        Err(_) => Ok(Literal::i32(&lit.to_vec::<i32>()?, &dims)?),
    }
}

impl PjrtExecutable {
    /// Execute on literals; returns the flattened tuple outputs
    /// (the lowering uses `return_tuple=True`).
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let xargs = args.iter().map(to_xla).collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&xargs)
            .context("executing PJRT artifact")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching PJRT artifact output")?;
        out.to_tuple()?.iter().map(from_xla).collect()
    }
}

/// Compile `dir/{train_step,mkor_step,eval_step}.hlo.txt` on the PJRT
/// CPU client.
pub fn load_bundle(dir: &Path) -> Result<LoadedBundle> {
    let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
    let load = |name: &str| -> Result<PjrtExecutable> {
        let path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(PjrtExecutable { exe })
    };
    Ok(LoadedBundle {
        platform: client.platform_name(),
        train_step: load("train_step")?,
        mkor_step: load("mkor_step")?,
        eval_step: load("eval_step")?,
    })
}
