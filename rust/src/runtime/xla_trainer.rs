//! End-to-end transformer training through the AOT artifacts.
//!
//! The full three-layer loop (Python never runs here):
//!
//! 1. **L3 (this struct)** owns the parameter/factor/momentum state, the
//!    data-parallel topology and the schedule. Per step it shards the token
//!    batch over workers and executes the `train_step` artifact per shard.
//! 2. Gradients (and, on factor steps, the 2d rank-1 vectors — bf16 on the
//!    wire) are combined with the real ring all-reduce.
//! 3. The leader executes the `mkor_step` artifact — the L2 graph whose
//!    factor updates and preconditioning are the L1 Pallas kernels — and L3
//!    applies the momentum SGD weight update (Algorithm 1 line 14) and
//!    broadcasts.
//!
//! MKOR-H's switch and the stabilizer threshold run in Rust where the loss
//! stream lives.

use crate::collective::ring::{allreduce_mean, allreduce_mean_bf16};
use crate::coordinator::metrics::{RunRecord, StepRecord};
use crate::data::text::TokenBatch;
use crate::linalg::half::HalfKind;
use crate::optim::hybrid::SwitchConfig;
use crate::optim::{MkorConfig, OptimizerSpec};
use crate::runtime::artifact::{literal_f32, literal_i32, literal_scalar, ArtifactBundle};
use crate::runtime::tensor::Literal;
use crate::util::stats::Ema;
use anyhow::{Context, Result};

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct XlaTrainerConfig {
    pub workers: usize,
    pub lr: f32,
    pub momentum: f32,
    pub gamma: f32,
    /// Factor-update period f.
    pub inv_freq: usize,
    /// bf16 wire format for the rank-1 vector sync.
    pub half_sync: bool,
    /// Enable the MKOR-H switch (None = plain MKOR).
    pub hybrid_switch_ratio: Option<f64>,
    /// EMA smoothing of the loss-decrease rate the switch rule watches
    /// (only meaningful with `hybrid_switch_ratio`).
    pub hybrid_switch_beta: f64,
    /// Stabilizer threshold ε on ‖J⁻¹‖∞ (checked in Rust between steps).
    pub stabilizer_epsilon: f64,
    pub stabilizer_zeta: f32,
}

impl Default for XlaTrainerConfig {
    fn default() -> Self {
        XlaTrainerConfig {
            workers: 2,
            lr: 0.05,
            momentum: 0.9,
            gamma: 0.99,
            inv_freq: 10,
            half_sync: true,
            hybrid_switch_ratio: None,
            hybrid_switch_beta: SwitchConfig::default().beta,
            stabilizer_epsilon: 100.0,
            stabilizer_zeta: 0.5,
        }
    }
}

impl XlaTrainerConfig {
    /// The [`OptimizerSpec`] this configuration corresponds to — written
    /// into the run record so XLA runs carry the same canonical spec string
    /// as the Rust-native trainer's runs. (The artifact path executes its
    /// optimizer state inline rather than through the registry.)
    pub fn optimizer_spec(&self) -> OptimizerSpec {
        let mut mkor = MkorConfig::default();
        mkor.gamma = self.gamma;
        mkor.inv_freq = self.inv_freq;
        mkor.momentum = self.momentum;
        mkor.half_sync = if self.half_sync { Some(HalfKind::Bf16) } else { None };
        mkor.stabilizer.epsilon = self.stabilizer_epsilon;
        mkor.stabilizer.zeta = self.stabilizer_zeta;
        match self.hybrid_switch_ratio {
            Some(ratio) => {
                let mut switch = SwitchConfig::default();
                switch.switch_ratio = ratio;
                switch.beta = self.hybrid_switch_beta;
                OptimizerSpec::MkorH { mkor, switch }
            }
            None => OptimizerSpec::Mkor(mkor),
        }
    }
}

/// The XLA-backed trainer.
pub struct XlaTrainer {
    pub bundle: ArtifactBundle,
    pub cfg: XlaTrainerConfig,
    /// Flat parameter buffers, artifact argument order.
    params: Vec<Vec<f32>>,
    /// Momentum buffers matching `params`.
    momentum: Vec<Vec<f32>>,
    /// Factor inverses per preconditioned matrix (flattened square).
    linvs: Vec<Vec<f32>>,
    rinvs: Vec<Vec<f32>>,
    pub record: RunRecord,
    t: usize,
    switched: bool,
    rate_ema: Ema,
    peak_rate: f64,
    last_loss: Option<f64>,
}

impl XlaTrainer {
    /// Initialize from a loaded bundle. `init_params` must match
    /// `meta.param_shapes` (produced by the `init_params` dump of aot.py or
    /// randomly initialized by the caller).
    pub fn new(bundle: ArtifactBundle, init_params: Vec<Vec<f32>>, cfg: XlaTrainerConfig) -> Self {
        assert_eq!(init_params.len(), bundle.meta.param_shapes.len());
        for (p, s) in init_params.iter().zip(&bundle.meta.param_shapes) {
            assert_eq!(p.len(), s.iter().product::<usize>(), "param shape mismatch");
        }
        let momentum = init_params.iter().map(|p| vec![0.0; p.len()]).collect();
        let linvs = bundle
            .meta
            .factor_dims
            .iter()
            .map(|&(_, dout)| identity_flat(dout))
            .collect();
        let rinvs = bundle
            .meta
            .factor_dims
            .iter()
            .map(|&(din, _)| identity_flat(din))
            .collect();
        let switch_beta = cfg.hybrid_switch_beta;
        let spec = cfg.optimizer_spec();
        let record = RunRecord {
            name: format!("xla-{}", bundle.meta.preset),
            optimizer: spec.name().into(),
            spec: spec.canonical(),
            ..Default::default()
        };
        XlaTrainer {
            bundle,
            cfg,
            params: init_params,
            momentum,
            linvs,
            rinvs,
            record,
            t: 0,
            switched: false,
            rate_ema: Ema::new(switch_beta),
            peak_rate: 0.0,
            last_loss: None,
        }
    }

    pub fn steps_done(&self) -> usize {
        self.t
    }

    pub fn switched(&self) -> bool {
        self.switched
    }

    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    fn is_factor_step(&self) -> bool {
        !self.switched && self.t % self.cfg.inv_freq == 0
    }

    /// Shard `batch` rows (sequences) across workers.
    fn shard(&self, batch: &TokenBatch) -> Vec<TokenBatch> {
        let w = self.cfg.workers;
        let b = batch.tokens.len();
        let base = b / w;
        let rem = b % w;
        let mut out = Vec::with_capacity(w);
        let mut at = 0;
        for r in 0..w {
            let len = base + usize::from(r < rem);
            out.push(TokenBatch {
                tokens: batch.tokens[at..at + len].to_vec(),
                targets: batch.targets[at..at + len].to_vec(),
            });
            at += len;
        }
        out
    }

    fn param_literals(&self) -> Result<Vec<Literal>> {
        self.params
            .iter()
            .zip(&self.bundle.meta.param_shapes)
            .map(|(p, s)| {
                let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
                literal_f32(p, &dims)
            })
            .collect()
    }

    fn batch_literals(&self, shard: &TokenBatch) -> Result<Vec<Literal>> {
        let b = shard.tokens.len();
        let s = self.bundle.meta.seq_len;
        let (toks, tgts, mask) = shard.to_flat();
        Ok(vec![
            literal_i32(&toks, &[b as i64, s as i64])?,
            literal_i32(&tgts, &[b as i64, s as i64])?,
            literal_f32(&mask, &[b as i64, s as i64])?,
        ])
    }

    /// One synchronous training step over a global token batch.
    pub fn step(&mut self, batch: &TokenBatch) -> Result<f64> {
        let t0 = std::time::Instant::now();
        let meta_np = self.params.len();
        let n_mats = self.bundle.meta.factor_dims.len();
        let factor_step = self.is_factor_step();

        // ---- per-worker train_step execution ----------------------------
        let shards = self.shard(batch);
        let params_lit = self.param_literals()?;
        let mut losses = Vec::with_capacity(shards.len());
        let mut grads: Vec<Vec<Vec<f32>>> = Vec::new(); // [worker][param]
        let mut a_vecs: Vec<Vec<Vec<f32>>> = Vec::new(); // [worker][matrix]
        let mut g_vecs: Vec<Vec<Vec<f32>>> = Vec::new();
        for shard in &shards {
            if shard.tokens.is_empty() {
                continue;
            }
            let mut args = params_lit.clone();
            args.extend(self.batch_literals(shard)?);
            let out = self.bundle.train_step.run(&args)?;
            anyhow::ensure!(
                out.len() == 1 + meta_np + 2 * n_mats,
                "train_step returned {} outputs, expected {}",
                out.len(),
                1 + meta_np + 2 * n_mats
            );
            losses.push(out[0].to_vec::<f32>()?[0] as f64);
            grads.push(
                out[1..1 + meta_np]
                    .iter()
                    .map(|l| l.to_vec::<f32>())
                    .collect::<std::result::Result<_, _>>()?,
            );
            a_vecs.push(
                out[1 + meta_np..1 + meta_np + n_mats]
                    .iter()
                    .map(|l| l.to_vec::<f32>())
                    .collect::<std::result::Result<_, _>>()?,
            );
            g_vecs.push(
                out[1 + meta_np + n_mats..]
                    .iter()
                    .map(|l| l.to_vec::<f32>())
                    .collect::<std::result::Result<_, _>>()?,
            );
        }
        let loss = losses.iter().sum::<f64>() / losses.len().max(1) as f64;

        // ---- all-reduce gradients (fp32) and rank-1 vectors (bf16) ------
        let mut grad_bytes = 0usize;
        let mut sync_bytes = 0usize;
        let workers = grads.len();
        let mut mean_grads: Vec<Vec<f32>> = Vec::with_capacity(meta_np);
        for p in 0..meta_np {
            let mut bufs: Vec<Vec<f32>> = (0..workers).map(|w| grads[w][p].clone()).collect();
            let stats = allreduce_mean(&mut bufs);
            grad_bytes += stats.bytes_per_worker;
            mean_grads.push(bufs.into_iter().next().unwrap());
        }
        let (mut mean_a, mut mean_g) = (Vec::new(), Vec::new());
        if factor_step {
            for m in 0..n_mats {
                let mut bufs: Vec<Vec<f32>> = (0..workers).map(|w| a_vecs[w][m].clone()).collect();
                let stats = if self.cfg.half_sync {
                    allreduce_mean_bf16(&mut bufs)
                } else {
                    allreduce_mean(&mut bufs)
                };
                sync_bytes += stats.bytes_per_worker;
                mean_a.push(bufs.into_iter().next().unwrap());
                let mut bufs: Vec<Vec<f32>> = (0..workers).map(|w| g_vecs[w][m].clone()).collect();
                let stats = if self.cfg.half_sync {
                    allreduce_mean_bf16(&mut bufs)
                } else {
                    allreduce_mean(&mut bufs)
                };
                sync_bytes += stats.bytes_per_worker;
                mean_g.push(bufs.into_iter().next().unwrap());
            }
        } else {
            // mkor_step still needs placeholder vectors; zeros are ignored
            // when update_flag = 0.
            for &(din, dout) in &self.bundle.meta.factor_dims {
                mean_a.push(vec![0.0; din]);
                mean_g.push(vec![0.0; dout]);
            }
        }

        // ---- leader: stabilizer (Rust) + mkor_step artifact --------------
        let deltas: Vec<Vec<f32>> = if self.switched {
            mean_grads.clone()
        } else {
            self.stabilize_factors();
            let mut args: Vec<Literal> = Vec::new();
            for (g, s) in mean_grads.iter().zip(&self.bundle.meta.param_shapes) {
                let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
                args.push(literal_f32(g, &dims)?);
            }
            for (l, &(_, dout)) in self.linvs.iter().zip(&self.bundle.meta.factor_dims) {
                args.push(literal_f32(l, &[dout as i64, dout as i64])?);
            }
            for (r, &(din, _)) in self.rinvs.iter().zip(&self.bundle.meta.factor_dims) {
                args.push(literal_f32(r, &[din as i64, din as i64])?);
            }
            for (a, &(din, _)) in mean_a.iter().zip(&self.bundle.meta.factor_dims) {
                args.push(literal_f32(a, &[din as i64])?);
            }
            for (g, &(_, dout)) in mean_g.iter().zip(&self.bundle.meta.factor_dims) {
                args.push(literal_f32(g, &[dout as i64])?);
            }
            args.push(literal_scalar(self.cfg.gamma)?);
            args.push(literal_scalar(if factor_step { 1.0 } else { 0.0 })?);
            let out = self.bundle.mkor_step.run(&args).context("mkor_step")?;
            anyhow::ensure!(out.len() == meta_np + 2 * n_mats, "mkor_step output arity");
            let deltas: Vec<Vec<f32>> = out[..meta_np]
                .iter()
                .map(|l| l.to_vec::<f32>())
                .collect::<std::result::Result<_, _>>()?;
            for (dst, l) in self.linvs.iter_mut().zip(&out[meta_np..meta_np + n_mats]) {
                *dst = l.to_vec::<f32>()?;
            }
            for (dst, l) in self.rinvs.iter_mut().zip(&out[meta_np + n_mats..]) {
                *dst = l.to_vec::<f32>()?;
            }
            deltas
        };

        // ---- line 14: momentum SGD + (logical) broadcast -----------------
        for ((p, m), d) in self.params.iter_mut().zip(&mut self.momentum).zip(&deltas) {
            for ((pv, mv), &dv) in p.iter_mut().zip(m.iter_mut()).zip(d) {
                *mv = self.cfg.momentum * *mv + dv;
                *pv -= self.cfg.lr * *mv;
            }
        }

        // ---- MKOR-H switching rule ---------------------------------------
        if let Some(ratio) = self.cfg.hybrid_switch_ratio {
            if let Some(prev) = self.last_loss {
                let rate = self.rate_ema.update((prev - loss).max(0.0));
                if self.rate_ema.steps() >= 20 {
                    self.peak_rate = self.peak_rate.max(rate);
                    if !self.switched && self.peak_rate > 0.0 && rate < ratio * self.peak_rate {
                        self.switched = true;
                        self.record.switched_at = Some(self.t);
                    }
                }
            }
            self.last_loss = Some(loss);
        }

        self.record.steps.push(StepRecord {
            step: self.t,
            loss,
            eval_metric: None,
            lr: self.cfg.lr,
            wall_secs: t0.elapsed().as_secs_f64(),
            grad_comm_bytes: grad_bytes,
            sync_comm_bytes: sync_bytes,
            inverse_updated: factor_step && !self.switched,
            second_order_secs: 0.0,
        });
        self.t += 1;
        Ok(loss)
    }

    /// Norm-based stabilizer on the flat factor inverses (lines 5–6).
    fn stabilize_factors(&mut self) {
        let eps = self.cfg.stabilizer_epsilon;
        let zeta = self.cfg.stabilizer_zeta;
        for (buf, &(_, dout)) in self.linvs.iter_mut().zip(&self.bundle.meta.factor_dims) {
            stabilize_flat(buf, dout, eps, zeta);
        }
        let dims: Vec<usize> = self.bundle.meta.factor_dims.iter().map(|&(din, _)| din).collect();
        for (buf, &din) in self.rinvs.iter_mut().zip(&dims) {
            stabilize_flat(buf, din, eps, zeta);
        }
    }

    /// Held-out evaluation loss via the `eval_step` artifact.
    pub fn evaluate(&mut self, batch: &TokenBatch) -> Result<f64> {
        let mut args = self.param_literals()?;
        args.extend(self.batch_literals(batch)?);
        let out = self.bundle.eval_step.run(&args)?;
        let loss = out[0].to_vec::<f32>()?[0] as f64;
        if let Some(rec) = self.record.steps.last_mut() {
            rec.eval_metric = Some(-loss);
        }
        Ok(loss)
    }
}

/// Seeded parameter initialization matching the family model.py uses:
/// ≥2-D tensors get N(0, σ²) with σ = min(0.02, 1/√fan_in); 1-D tensors
/// (layernorm scales/biases) start at zero — model.py applies scales as
/// `(1 + s)` so zero is the identity transform.
pub fn init_params(meta: &crate::runtime::PresetMeta, rng: &mut crate::util::Rng) -> Vec<Vec<f32>> {
    meta.param_shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            let mut v = vec![0.0f32; n];
            if s.len() >= 2 {
                let fan_in = s[0];
                let sigma = 0.02f32.min((1.0 / fan_in as f32).sqrt());
                rng.fill_gaussian(&mut v, sigma);
            }
            v
        })
        .collect()
}

fn identity_flat(n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    v
}

fn stabilize_flat(buf: &mut [f32], n: usize, eps: f64, zeta: f32) {
    // ‖·‖∞ (max abs row sum) + finiteness.
    let mut norm = 0.0f64;
    let mut finite = true;
    for i in 0..n {
        let mut s = 0.0f64;
        for j in 0..n {
            let v = buf[i * n + j];
            finite &= v.is_finite();
            s += v.abs() as f64;
        }
        norm = norm.max(s);
    }
    if !finite {
        buf.fill(0.0);
        for i in 0..n {
            buf[i * n + i] = 1.0;
        }
        return;
    }
    if norm > eps {
        for v in buf.iter_mut() {
            *v *= zeta;
        }
        for i in 0..n {
            buf[i * n + i] += 1.0 - zeta;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_flat_is_identity() {
        let v = identity_flat(3);
        assert_eq!(v, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn stabilize_flat_blends_and_resets() {
        let mut big = vec![200.0f32, 0.0, 0.0, 200.0];
        stabilize_flat(&mut big, 2, 100.0, 0.5);
        assert_eq!(big, vec![100.5, 0.0, 0.0, 100.5]);
        let mut nan = vec![1.0f32, f32::NAN, 0.0, 1.0];
        stabilize_flat(&mut nan, 2, 100.0, 0.5);
        assert_eq!(nan, identity_flat(2));
        let mut small = vec![1.0f32, 0.0, 0.0, 1.0];
        stabilize_flat(&mut small, 2, 100.0, 0.5);
        assert_eq!(small, identity_flat(2));
    }
}
