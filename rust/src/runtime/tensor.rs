//! The backend-neutral tensor value the runtime layer traffics in.
//!
//! Historically the artifact path used the PJRT crate's `Literal`
//! directly, which welded the whole runtime module to an out-of-tree
//! native dependency. [`Literal`] is the in-crate replacement: a flat
//! host buffer plus dims, dense row-major, exactly the shapes the
//! `train_step`/`mkor_step`/`eval_step` artifact contracts exchange
//! (f32 tensors, i32 token grids, scalars). The sim backend
//! ([`crate::runtime::sim`]) consumes it natively; the optional PJRT
//! backend converts at its boundary.

use std::fmt;

/// What can be wrong with building or reading a literal.
#[derive(Debug, thiserror::Error)]
pub enum TensorError {
    #[error("literal shape/data mismatch: dims {dims:?} hold {want} elements, got {got}")]
    ShapeMismatch { dims: Vec<i64>, want: usize, got: usize },
    #[error("literal holds {found} elements, expected {expected}")]
    WrongElementType { found: &'static str, expected: &'static str },
    #[error("negative dimension {0} in literal shape")]
    NegativeDim(i64),
}

/// A dense row-major host tensor: the value type artifact executables
/// accept and return.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
}

fn checked_len(dims: &[i64]) -> Result<usize, TensorError> {
    let mut n = 1usize;
    for &d in dims {
        if d < 0 {
            return Err(TensorError::NegativeDim(d));
        }
        n = n.saturating_mul(d as usize);
    }
    Ok(n)
}

impl Literal {
    /// Build an f32 literal of the given shape from a flat slice.
    pub fn f32(data: &[f32], dims: &[i64]) -> Result<Literal, TensorError> {
        let want = checked_len(dims)?;
        if want != data.len() {
            return Err(TensorError::ShapeMismatch {
                dims: dims.to_vec(),
                want,
                got: data.len(),
            });
        }
        Ok(Literal::F32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Build an i32 literal of the given shape from a flat slice.
    pub fn i32(data: &[i32], dims: &[i64]) -> Result<Literal, TensorError> {
        let want = checked_len(dims)?;
        if want != data.len() {
            return Err(TensorError::ShapeMismatch {
                dims: dims.to_vec(),
                want,
                got: data.len(),
            });
        }
        Ok(Literal::I32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Rank-0 f32 scalar.
    pub fn scalar_f32(x: f32) -> Literal {
        Literal::F32 { data: vec![x], dims: Vec::new() }
    }

    pub fn dims(&self) -> &[i64] {
        match self {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } => dims,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn type_name(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "i32",
        }
    }

    /// Borrow the f32 buffer, or `None` for an i32 literal.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Literal::F32 { data, .. } => Some(data),
            Literal::I32 { .. } => None,
        }
    }

    /// Borrow the i32 buffer, or `None` for an f32 literal.
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Literal::I32 { data, .. } => Some(data),
            Literal::F32 { .. } => None,
        }
    }

    /// Copy the buffer out as `Vec<T>` — the accessor the trainer uses
    /// (`out[k].to_vec::<f32>()?`), mirroring the PJRT literal API it
    /// replaced. Asking an i32 literal for f32 (or vice versa) is a typed
    /// error, never a silent cast.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, TensorError> {
        T::extract(self)
    }
}

impl fmt::Display for Literal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{:?} ({} elems)", self.type_name(), self.dims(), self.len())
    }
}

/// Element types a [`Literal`] can yield via [`Literal::to_vec`].
pub trait Element: Sized + Copy {
    fn extract(lit: &Literal) -> Result<Vec<Self>, TensorError>;
}

impl Element for f32 {
    fn extract(lit: &Literal) -> Result<Vec<f32>, TensorError> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            Literal::I32 { .. } => Err(TensorError::WrongElementType {
                found: "i32",
                expected: "f32",
            }),
        }
    }
}

impl Element for i32 {
    fn extract(lit: &Literal) -> Result<Vec<i32>, TensorError> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            Literal::F32 { .. } => Err(TensorError::WrongElementType {
                found: "f32",
                expected: "i32",
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_and_checks_shapes() {
        let l = Literal::f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err(), "no silent casts");
        assert!(Literal::f32(&[1.0], &[2, 2]).is_err());
        assert!(Literal::i32(&[1], &[-1]).is_err());
        let s = Literal::scalar_f32(0.5);
        assert!(s.dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn i32_literal_holds_token_grids() {
        let l = Literal::i32(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(l.as_i32().unwrap().len(), 6);
        assert!(l.as_f32().is_none());
        let c = l.clone();
        assert_eq!(c, l);
    }
}
