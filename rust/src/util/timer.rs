//! Wall-clock timing helpers and a named phase accumulator used for the
//! per-step time-breakdown experiments (Figure 3) and the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Clone, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Timer {
    fn default() -> Self {
        Timer::start()
    }
}

/// Accumulates wall time per named phase ("factor", "precondition",
/// "weight_update", "allreduce", ...). Phases are what Figure 3 plots.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a named phase.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    /// Record an externally-measured duration.
    pub fn add(&mut self, phase: &str, d: Duration) {
        *self.totals.entry(phase.to_string()).or_default() += d;
        *self.counts.entry(phase.to_string()).or_default() += 1;
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn total_secs(&self, phase: &str) -> f64 {
        self.total(phase).as_secs_f64()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or(0)
    }

    /// Mean seconds per occurrence of a phase (0 if never seen).
    pub fn mean_secs(&self, phase: &str) -> f64 {
        let c = self.count(phase);
        if c == 0 {
            0.0
        } else {
            self.total_secs(phase) / c as f64
        }
    }

    /// All phases, sorted by name.
    pub fn phases(&self) -> Vec<&str> {
        self.totals.keys().map(String::as_str).collect()
    }

    /// Merge another accumulator into this one (used to sum workers).
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    pub fn clear(&mut self) {
        self.totals.clear();
        self.counts.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_accumulation() {
        let mut p = PhaseTimer::new();
        p.add("a", Duration::from_millis(10));
        p.add("a", Duration::from_millis(20));
        p.add("b", Duration::from_millis(5));
        assert_eq!(p.count("a"), 2);
        assert!((p.total_secs("a") - 0.030).abs() < 1e-9);
        assert!((p.mean_secs("a") - 0.015).abs() < 1e-9);
        assert_eq!(p.phases(), vec!["a", "b"]);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseTimer::new();
        let v = p.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(p.count("work"), 1);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        let mut b = PhaseTimer::new();
        a.add("x", Duration::from_millis(1));
        b.add("x", Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.count("x"), 2);
        assert!((a.total_secs("x") - 0.003).abs() < 1e-9);
    }
}
