//! Small statistics toolkit: exponential moving averages (used by the
//! knee-point LR scheduler and the MKOR-H switcher), quantiles, histograms
//! (Figure 5 error distributions) and summary stats for the bench harness.

/// Exponential moving average with bias correction (Adam-style).
#[derive(Clone, Debug)]
pub struct Ema {
    beta: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta));
        Ema { beta, value: 0.0, steps: 0 }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.steps += 1;
        self.get()
    }

    /// Bias-corrected current value (0 before any update).
    pub fn get(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        let corr = 1.0 - self.beta.powi(self.steps as i32);
        self.value / corr
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Raw accumulator state `(value, steps)` — the uncorrected EMA value,
    /// for checkpointing (`beta` is configuration, not state).
    pub fn state(&self) -> (f64, u64) {
        (self.value, self.steps)
    }

    /// Restore state captured by [`Ema::state`].
    pub fn set_state(&mut self, value: f64, steps: u64) {
        self.value = value;
        self.steps = steps;
    }
}

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

/// Compute summary statistics (sorts a copy).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        max: s[n - 1],
        median: quantile_sorted(&s, 0.5),
        p95: quantile_sorted(&s, 0.95),
    }
}

/// Linear-interpolated quantile of a pre-sorted slice, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice.
pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&s, 0.5)
}

/// First index at which the trailing-`window` mean of `series` drops to
/// `target` or below — the steps-to-target smoothing shared by the
/// convergence harness and the sweep-based benches (one definition, so
/// their reported step counts stay comparable).
pub fn first_at_or_below(series: &[f64], target: f64, window: usize) -> Option<usize> {
    let window = window.max(1);
    for i in 0..series.len() {
        let lo = i.saturating_sub(window - 1);
        let mean = series[lo..=i].iter().sum::<f64>() / (i - lo + 1) as f64;
        if mean <= target {
            return Some(i);
        }
    }
    None
}

/// Fixed-range histogram (Figure 5 / Figure 10 error distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0, total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64) as usize;
            let last = self.counts.len() - 1;
            self.counts[b.min(last)] += 1;
        }
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Bin centers (for CSV/plot output).
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }

    /// Normalized densities summing to 1 over in-range mass.
    pub fn densities(&self) -> Vec<f64> {
        let in_range: u64 = self.counts.iter().sum();
        if in_range == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts.iter().map(|&c| c as f64 / in_range as f64).collect()
    }

    /// Render a terminal sparkline-ish bar chart (for bench output).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        let centers = self.centers();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width + max as usize - 1) / max as usize);
            out.push_str(&format!("{:>10.4} | {:<w$} {}\n", centers[i], bar, c, w = width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_bias_correction() {
        let mut e = Ema::new(0.9);
        // First update of a bias-corrected EMA returns the sample itself.
        assert!((e.update(5.0) - 5.0).abs() < 1e-12);
        // Constant stream stays at the constant.
        for _ in 0..100 {
            e.update(5.0);
        }
        assert!((e.get() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ema_tracks_shift() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(1.0);
        }
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn summary_of_known_sample() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn first_at_or_below_smooths_over_the_window() {
        let s = [5.0, 1.0, 1.0];
        // Window mean at index 1 is 3.0.
        assert_eq!(first_at_or_below(&s, 3.0, 2), Some(1));
        assert_eq!(first_at_or_below(&s, 0.5, 2), None);
        assert_eq!(first_at_or_below(&s, 5.0, 2), Some(0));
        assert_eq!(first_at_or_below(&[], 1.0, 5), None);
        // window 0 is clamped to 1 (no smoothing).
        assert_eq!(first_at_or_below(&s, 1.0, 0), Some(1));
    }

    #[test]
    fn quantiles_interpolate() {
        let s = [0.0, 10.0];
        assert!((quantile_sorted(&s, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(-0.1);
        h.add(0.05);
        h.add(0.95);
        h.add(1.5);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.total, 4);
        let d = h.densities();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
