//! Shared utilities: PRNG, JSON, statistics, timing, lightweight logging.
//!
//! These exist because the offline crate set has no `rand`, `serde`,
//! `criterion` or `tracing`; see DESIGN.md §6.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
