//! Deterministic PRNG: SplitMix64 for seeding, xoshiro256** for the stream,
//! Box–Muller for Gaussians.
//!
//! The offline crate set has only `rand_core` (traits, no generator), so we
//! implement the generators directly. All experiments in this repository are
//! seeded through this module, which makes every table and figure
//! reproducible bit-for-bit on the same target.

/// SplitMix64 step — used to expand a single `u64` seed into the xoshiro
/// state, as recommended by the xoshiro authors.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker / per-layer RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The full generator state: the four xoshiro words plus the cached
    /// Box–Muller spare. Together with [`Rng::set_state`] this makes the
    /// stream checkpointable — restoring and drawing continues bit-for-bit
    /// where the saved generator left off.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Restore state captured by [`Rng::state`].
    pub fn set_state(&mut self, s: [u64; 4], gauss_spare: Option<f64>) {
        self.s = s;
        self.gauss_spare = gauss_spare;
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (n must be > 0). Uses rejection sampling
    /// to avoid modulo bias.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] so ln is finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32() * sigma;
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo as f64, hi as f64) as f32;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

/// Zipf(s) sampler over `1..=n` via precomputed CDF — used by the synthetic
/// token corpus (natural-language token frequencies are approximately
/// Zipfian, which is what makes the MLM proxy task representative).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for ranks `0..n` with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in `0..n` (0 = most frequent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(123);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var={m2}");
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let mut r = Rng::new(11);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn split_streams_are_independent_enough() {
        let mut root = Rng::new(77);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
